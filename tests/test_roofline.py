"""HLO analyzer: trip-aware FLOPs vs XLA cost_analysis ground truth."""

import json
import os
import subprocess
import sys

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    # pin the platform: fake host devices need CPU anyway, and leaving it
    # unset makes jax probe the TPU plugin, which stalls for minutes on
    # the (absent) GCP metadata server in sandboxed environments
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_analyzer_matches_cost_analysis_on_unrolled():
    code = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze

from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(2, 4)

def _cost(compiled):
    ca = compiled.cost_analysis()  # dict on jax >= 0.5, [dict] on 0.4.x
    return ca[0] if isinstance(ca, list) else (ca or {})

def body(x, w):
    return jnp.tanh(x @ w), None

def fn_scan(x, ws):
    y, _ = jax.lax.scan(body, x, ws)
    return y.sum()

def fn_unroll(x, ws):
    for i in range(ws.shape[0]):
        x, _ = body(x, ws[i])
    return x.sum()

L, d = 12, 256
x = jax.ShapeDtypeStruct((32, d), jnp.float32,
                         sharding=NamedSharding(mesh, P("data", None)))
ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32,
                          sharding=NamedSharding(mesh, P(None, None, "model")))
cs = jax.jit(fn_scan).lower(x, ws).compile()
cu = jax.jit(fn_unroll).lower(x, ws).compile()
a_scan = analyze(cs.as_text())
a_unroll = analyze(cu.as_text())
print(json.dumps({
    "scan_flops": a_scan.dot_flops,
    "unroll_flops": a_unroll.dot_flops,
    "xla_unroll_flops": float(_cost(cu).get("flops", -1)),
    "trips": a_scan.trip_counts,
    "expected": float(L * 16 * d * (d // 4) * 2),
}))
"""
    res = _run(code)
    # analyzer on scan == analyzer on unroll == XLA on unroll == closed form
    np.testing.assert_allclose(res["scan_flops"], res["expected"], rtol=0.02)
    np.testing.assert_allclose(res["unroll_flops"], res["expected"], rtol=0.02)
    np.testing.assert_allclose(res["xla_unroll_flops"], res["expected"], rtol=0.02)
    assert res["trips"] == [12]


def test_collectives_detected_and_trip_multiplied():
    code = r"""
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze

from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(2, 4)

def fn(x, ws):
    def body(h, w):
        return jnp.tanh(h @ w), None
    y, _ = jax.lax.scan(body, x, ws)
    return y.sum()

x = jax.ShapeDtypeStruct((32, 256), jnp.float32,
                         sharding=NamedSharding(mesh, P("data", None)))
ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32,
                          sharding=NamedSharding(mesh, P(None, None, "model")))
cost = analyze(jax.jit(fn).lower(x, ws).compile().as_text())
print(json.dumps({"coll": cost.collective_breakdown,
                  "total": cost.collective_bytes}))
"""
    res = _run(code)
    assert res["total"] > 0
    assert any(k in res["coll"] for k in ("all-gather", "all-reduce"))


def test_roofline_terms_math():
    from repro.launch.hlo_analysis import HLOCost, roofline_from_cost

    cost = HLOCost(dot_flops=197e12, fusion_boundary_bytes=819e9,
                   collective_bytes=50e9)
    t = roofline_from_cost(cost, model_flops_per_dev=98.5e12)
    np.testing.assert_allclose(t.compute_s, 1.0)
    np.testing.assert_allclose(t.memory_s, 1.0)
    np.testing.assert_allclose(t.collective_s, 1.0)
    assert abs(t.useful_flop_ratio - 0.5) < 1e-9
