"""Tree-training substrate: learns, is deterministic, respects constraints."""

import numpy as np
import pytest

from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, RFParams, train_gbdt, train_rf
from repro.data.tabular import accuracy_metric, make_dataset


@pytest.fixture(scope="module")
def churn():
    ds = make_dataset("churn")
    q = FeatureQuantizer.fit(ds.x_train, n_bins=256)
    return ds, q, q.transform(ds.x_train), q.transform(ds.x_test)


def test_gbdt_beats_majority_binary(churn):
    ds, q, xb_tr, xb_te = churn
    ens = train_gbdt(xb_tr, ds.y_train, task="binary", n_bins=256,
                     params=GBDTParams(n_rounds=30, max_leaves=64))
    acc = accuracy_metric("binary", ds.y_test, ens.predict(xb_te))
    base = max(np.mean(ds.y_test), 1 - np.mean(ds.y_test))
    assert acc > base + 0.03, (acc, base)


def test_gbdt_multiclass_and_leaf_constraints():
    ds = make_dataset("eye")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    xb_tr, xb_te = q.transform(ds.x_train), q.transform(ds.x_test)
    ens = train_gbdt(xb_tr, ds.y_train, task="multiclass", n_bins=256,
                     n_classes=ds.n_classes,
                     params=GBDTParams(n_rounds=10, max_leaves=32, max_depth=6))
    acc = accuracy_metric("multiclass", ds.y_test, ens.predict(xb_te))
    assert acc > 1.0 / ds.n_classes + 0.1
    assert ens.max_leaves <= 32
    assert all(t.max_depth <= 6 for t in ens.trees)
    assert ens.n_trees == 10 * ds.n_classes


def test_gbdt_regression_r2():
    ds = make_dataset("rossmann")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    xb_tr, xb_te = q.transform(ds.x_train), q.transform(ds.x_test)
    ens = train_gbdt(xb_tr, ds.y_train, task="regression", n_bins=256,
                     params=GBDTParams(n_rounds=30, max_leaves=64, learning_rate=0.2))
    r2 = accuracy_metric("regression", ds.y_test, ens.predict(xb_te))
    assert r2 > 0.25, r2


def test_rf_classification(churn):
    ds, q, xb_tr, xb_te = churn
    rf = train_rf(xb_tr, ds.y_train, task="binary", n_bins=256,
                  params=RFParams(n_trees=20, max_leaves=64, colsample=0.7))
    acc = accuracy_metric("binary", ds.y_test, rf.predict(xb_te))
    base = max(np.mean(ds.y_test), 1 - np.mean(ds.y_test))
    assert acc > base, (acc, base)


def test_training_deterministic(churn):
    ds, q, xb_tr, _ = churn
    p = GBDTParams(n_rounds=3, max_leaves=16, subsample=0.8, seed=7)
    a = train_gbdt(xb_tr, ds.y_train, task="binary", n_bins=256, params=p)
    b = train_gbdt(xb_tr, ds.y_train, task="binary", n_bins=256, params=p)
    for ta, tb in zip(a.trees, b.trees):
        np.testing.assert_array_equal(ta.feature, tb.feature)
        np.testing.assert_array_equal(ta.threshold, tb.threshold)
        np.testing.assert_array_equal(ta.value, tb.value)


def test_quantizer_bin_float_consistency():
    """bin(x) < t  <=>  x < edges[t-1] — the trainer/CAM convention."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(2000, 4)).astype(np.float32)
    q = FeatureQuantizer.fit(x, n_bins=64)
    xb = q.transform(x)
    for f in range(4):
        for t in (1, 5, 30):
            if t - 1 >= len(q.edges[f]):
                continue
            thr = q.threshold_value(f, t)
            np.testing.assert_array_equal(xb[:, f] < t, x[:, f] < thr)
