"""Serving layer: bucket selection, un-pad/reorder correctness against the
direct engine path, registry hot-swap, and the padded engine entry."""

import logging

import numpy as np
import pytest

from repro.core.compile import compile_ensemble
from repro.core.engine import XTimeEngine
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import make_dataset
from repro.kernels import ops as kops
from repro.serve import BucketSpec, MicroBatcher, ServeLoop, TableRegistry


@pytest.fixture(scope="module")
def served_binary():
    ds = make_dataset("churn")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    ens = train_gbdt(
        q.transform(ds.x_train), ds.y_train, task="binary", n_bins=256,
        params=GBDTParams(n_rounds=8, max_leaves=32),
    )
    return ens, q.transform(ds.x_test).astype(np.int32)


@pytest.fixture(scope="module")
def served_multiclass():
    ds = make_dataset("eye")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    ens = train_gbdt(
        q.transform(ds.x_train), ds.y_train, task="multiclass", n_bins=256,
        n_classes=ds.n_classes,
        params=GBDTParams(n_rounds=6, max_leaves=32),
    )
    return ens, q.transform(ds.x_test).astype(np.int32)


# -- bucket selection ---------------------------------------------------------


def test_bucket_sizes_pow2_then_blk_multiples():
    spec = BucketSpec(b_blk=128, max_batch=512, multiple=1)
    assert spec.sizes() == [1, 2, 4, 8, 16, 32, 64, 128, 256, 384, 512]


def test_bucket_sizes_respect_batch_multiple():
    # pallas-style engines admit only b_blk multiples
    spec = BucketSpec(b_blk=128, max_batch=384, multiple=128)
    assert spec.sizes() == [128, 256, 384]
    assert spec.select(1) == 128


def test_bucket_select_exact_boundary():
    spec = BucketSpec(b_blk=128, max_batch=512, multiple=1)
    assert spec.select(64) == 64  # exact bucket stays put
    assert spec.select(65) == 128  # one over rolls to the next
    assert spec.select(128) == 128
    assert spec.select(129) == 256
    assert spec.select(512) == 512


def test_bucket_multiple_larger_than_b_blk():
    # 16x16 production mesh with the 'batch' NoC config: 256 batch shards
    spec = BucketSpec(b_blk=128, max_batch=1024, multiple=256)
    assert spec.sizes() == [256, 512, 768, 1024]
    assert spec.select(1) == 256
    assert spec.select(257) == 512
    assert spec.select(2000) == 2048  # over-max fallback keeps the lcm step
    with pytest.raises(ValueError):
        BucketSpec(b_blk=128, max_batch=128, multiple=256)  # max < lcm


def test_bucket_select_over_max_fallback(caplog):
    spec = BucketSpec(b_blk=128, max_batch=256, multiple=1)
    with caplog.at_level(logging.WARNING, logger="repro.serve.batching"):
        assert spec.select(300) == 384  # next b_blk multiple, uncached
    assert any("uncached bucket" in r.message for r in caplog.records)
    with pytest.raises(ValueError):
        spec.select(0)


def test_pad_to_bucket_contract():
    q = np.arange(6, dtype=np.int32).reshape(2, 3)
    out = np.asarray(kops.pad_to_bucket(q, 4, 8))
    assert out.shape == (4, 8)
    np.testing.assert_array_equal(out[:2, :3], q)
    assert (out[2:] == 0).all() and (out[:, 3:] == 0).all()
    with pytest.raises(ValueError):
        kops.pad_to_bucket(q, 1, 8)
    with pytest.raises(ValueError):
        kops.pad_to_bucket(q, 4, 2)


# -- micro-batch == direct engine --------------------------------------------


def test_microbatch_results_equal_direct_predict(served_binary):
    ens, xb = served_binary
    eng = XTimeEngine(compile_ensemble(ens))
    mb = MicroBatcher.for_engine(eng, max_batch=256)
    sizes = [1, 3, 1, 7, 2, 1, 17, 1]
    chunks, ids, row = [], [], 0
    for s in sizes:
        chunk = xb[row : row + s]
        ids.append(mb.submit(chunk))
        chunks.append(chunk)
        row += s
    results = mb.flush()
    assert mb.pending_requests == 0
    for rid, chunk in zip(ids, chunks):
        np.testing.assert_array_equal(
            results[rid], np.asarray(eng.predict(chunk))
        )


def test_microbatch_margin_kind_matches_raw_margin(served_multiclass):
    ens, xb = served_multiclass
    eng = XTimeEngine(compile_ensemble(ens))
    mb = MicroBatcher.for_engine(eng, max_batch=256, kind="margin")
    a = mb.submit(xb[:5])
    b = mb.submit(xb[5:12])
    out = mb.flush()
    # bucket shape changes XLA's accumulation order -> float-level jitter
    np.testing.assert_allclose(
        out[a], np.asarray(eng.raw_margin(xb[:5])), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_allclose(
        out[b], np.asarray(eng.raw_margin(xb[5:12])), rtol=1e-5, atol=1e-6
    )


def test_padded_fn_equals_predict_per_bucket(served_binary):
    ens, xb = served_binary
    eng = XTimeEngine(compile_ensemble(ens))
    direct = np.asarray(eng.predict(xb[:37]))
    for bucket in (64, 128):
        qp = kops.pad_to_bucket(xb[:37], bucket, eng.arrays.f_pad)
        out = np.asarray(eng.predict_padded(qp))
        assert out.shape[0] == bucket
        np.testing.assert_array_equal(out[:37], direct)


def test_padded_fn_rejects_bad_shapes(served_binary):
    ens, xb = served_binary
    eng = XTimeEngine(compile_ensemble(ens))
    with pytest.raises(ValueError):
        eng.predict_padded(xb[:4])  # unpadded feature width
    with pytest.raises(ValueError):
        eng.padded_fn("nope")


# -- serve loop ---------------------------------------------------------------


def test_serve_loop_single_row_traffic(served_binary):
    ens, xb = served_binary
    reg = TableRegistry()
    reg.register("m", ens)
    loop = ServeLoop(reg, window_s=100.0, flush_rows=32)
    handles = [loop.submit("m", xb[i]) for i in range(50)]
    loop.drain()
    got = np.concatenate([loop.result(h) for h in handles])
    np.testing.assert_array_equal(got, np.asarray(reg.engine("m").predict(xb[:50])))
    s = loop.stats("m")
    assert s.n_requests == 50 and s.n_rows == 50
    assert s.n_flushes == 2  # 32-row bucket + 18-row drain
    assert s.p99_ms >= s.p50_ms >= 0.0
    assert s.requests_per_s > 0


def test_serve_loop_window_expiry_flushes():
    t = [0.0]
    ds = make_dataset("churn")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    ens = train_gbdt(
        q.transform(ds.x_train), ds.y_train, task="binary", n_bins=256,
        params=GBDTParams(n_rounds=4, max_leaves=16),
    )
    reg = TableRegistry()
    reg.register("m", ens)
    loop = ServeLoop(reg, window_s=1.0, flush_rows=1000, clock=lambda: t[0])
    xb = q.transform(ds.x_test).astype(np.int32)
    h = loop.submit("m", xb[0])
    assert loop.poll() == 0  # window not expired, nothing flushed
    t[0] = 2.0
    assert loop.poll() == 1  # expiry forces the flush
    assert loop.result(h).shape == (1,)


def test_registry_hot_swap(served_binary, served_multiclass):
    ens_a, xb = served_binary
    reg = TableRegistry()
    assert reg.version("m") == 0
    reg.register("m", ens_a)
    assert reg.version("m") == 1 and "m" in reg and reg.names() == ["m"]

    # swap in a retrained model (different table) under live traffic
    ds = make_dataset("churn")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    ens_b = train_gbdt(
        q.transform(ds.x_train), ds.y_train, task="binary", n_bins=256,
        params=GBDTParams(n_rounds=3, max_leaves=16),
    )
    loop = ServeLoop(reg, window_s=100.0, flush_rows=64)
    h_old = loop.submit("m", xb[:8])
    reg.swap("m", ens_b)
    assert reg.version("m") == 2
    h_new = loop.submit("m", xb[:8])  # old pending flushed through old engine
    loop.drain()
    np.testing.assert_array_equal(
        loop.result(h_old), np.asarray(XTimeEngine(compile_ensemble(ens_a)).predict(xb[:8]))
    )
    np.testing.assert_array_equal(
        loop.result(h_new), np.asarray(XTimeEngine(compile_ensemble(ens_b)).predict(xb[:8]))
    )

    with pytest.raises(KeyError):
        reg.swap("ghost", ens_b)
    reg.unregister("m")
    assert "m" not in reg and reg.version("m") == 0
    with pytest.raises(KeyError):
        reg.get("m")


def test_submit_copies_caller_buffer(served_binary):
    ens, xb = served_binary
    eng = XTimeEngine(compile_ensemble(ens))
    mb = MicroBatcher.for_engine(eng, max_batch=256)
    buf = xb[0].copy()
    rid = mb.submit(buf)
    expected = np.asarray(eng.predict(xb[:1]))
    buf[:] = 0  # caller reuses its buffer before the flush
    np.testing.assert_array_equal(mb.flush()[rid], expected)


def test_swap_retains_serving_configuration(served_binary):
    ens, _ = served_binary
    reg = TableRegistry()
    a = reg.register("m", ens, batching=True)
    assert a.batching and a.noc.config == "batch"
    b = reg.swap("m", ens)  # no batching arg: must inherit, not reset
    assert b.batching and b.noc.config == "batch"
    assert b.version == 2
    c = reg.register("m", ens, batching=False)  # explicit override wins
    assert not c.batching and c.noc.config != "batch"


def test_serve_report_includes_chip_model(served_binary):
    ens, xb = served_binary
    reg = TableRegistry()
    reg.register("m", ens)
    loop = ServeLoop(reg, window_s=100.0, flush_rows=16)
    for i in range(20):
        loop.submit("m", xb[i])
    loop.drain()
    rep = loop.report("m")
    assert rep["measured"]["requests"] == 20
    assert rep["xtime_chip_model"]["throughput_msps"] > 0
    assert rep["xtime_chip_model"]["latency_ns"] > 0
