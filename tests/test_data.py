"""Data pipelines: determinism, resume semantics, host slicing."""

import numpy as np

from repro.data.tabular import PAPER_DATASETS, make_dataset
from repro.data.tokens import EmbeddingPipeline, TokenPipeline


def test_token_pipeline_pure_function_of_step():
    p1 = TokenPipeline(512, 4, 64, seed=1)
    p2 = TokenPipeline(512, 4, 64, seed=1)
    for step in (0, 3, 17):
        a, b = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])
    assert not np.array_equal(p1.batch(0)["tokens"], p1.batch(1)["tokens"])


def test_token_pipeline_host_slicing_consistent():
    p = TokenPipeline(512, 8, 32, seed=2)
    full = p.batch(5)["tokens"]
    parts = [p.host_batch(5, host_id=h, n_hosts=4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_labels_are_shifted_tokens():
    p = TokenPipeline(512, 2, 16, seed=0)
    b = p.batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_tokens_learnable_structure():
    """Markov structure: conditional entropy << unigram entropy."""
    p = TokenPipeline(256, 16, 256, seed=0)
    toks = np.concatenate([p.batch(s)["tokens"].ravel() for s in range(4)])
    pairs = {}
    for a, b in zip(toks[:-1], toks[1:]):
        pairs.setdefault(int(a), []).append(int(b))
    # average number of distinct successors is far below vocab size
    branching = np.mean([len(set(v)) for v in pairs.values() if len(v) >= 8])
    assert branching < 64


def test_embedding_pipeline_shapes():
    p = EmbeddingPipeline(d_model=32, global_batch=2, seq_len=64,
                          vocab_size=100, seed=0)
    b = p.batch(0, kind="vlm")
    assert b["embeds"].shape == (2, 64, 32) and b["labels"].shape == (2, 64)
    a = p.batch(0, kind="audio")
    assert a["frames"].shape == (2, 64, 32)
    assert a["tokens"].shape == a["labels"].shape


def test_tabular_datasets_match_paper_spec():
    for name, (task, n, n_feat, n_classes) in PAPER_DATASETS.items():
        ds = make_dataset(name)
        total = len(ds.y_train) + len(ds.y_valid) + len(ds.y_test)
        assert total == n
        assert ds.n_features == n_feat
        assert ds.task == task
        if task != "regression":
            assert set(np.unique(ds.y_train)) <= set(range(n_classes))
