"""Compiled-artifact API: build -> save -> load -> engine round trips,
registry cold start from disk artifacts, schema versioning, and the
DeployConfig deprecation shims."""

import json
import warnings

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro.api as api
from repro.api import SCHEMA_VERSION, CompiledModel, build
from repro.core.compile import ChipSpec, CorePlacement, compile_ensemble
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, RFParams, train_gbdt, train_rf
from repro.data.tabular import make_dataset
from repro.launch.mesh import make_host_mesh
from repro.serve import ServedModel, ServeLoop, TableRegistry

CELL_MODES = ("direct", "msb_lsb", "two_cycle")


@pytest.fixture(scope="module")
def trained():
    """One model per task shape: binary gbdt, multiclass gbdt, rf votes."""
    out = {}
    for key, (name, task, kind) in {
        "binary": ("churn", "binary", "gbdt"),
        "multiclass": ("eye", "multiclass", "gbdt"),
        "rf": ("churn", "binary", "rf"),
    }.items():
        ds = make_dataset(name)
        q = FeatureQuantizer.fit(ds.x_train, 256)
        xb_tr, xb_te = q.transform(ds.x_train), q.transform(ds.x_test)
        if kind == "gbdt":
            ens = train_gbdt(xb_tr, ds.y_train, task=task, n_bins=256,
                             n_classes=ds.n_classes,
                             params=GBDTParams(n_rounds=4, max_leaves=32))
        else:
            ens = train_rf(xb_tr, ds.y_train, task=task, n_bins=256,
                           n_classes=ds.n_classes,
                           params=RFParams(n_trees=8, max_leaves=32))
        out[key] = (ens, xb_te[:96].astype(np.int32))
    return out


# -- build ---------------------------------------------------------------------


def test_build_bundles_whole_pipeline(trained):
    ens, _ = trained["binary"]
    cm = build(ens)
    assert cm.table.n_rows == ens.total_leaves
    assert cm.placement.n_cores_used >= 1
    assert cm.noc.config in ("accumulate", "forward", "batch")
    assert cm.perf.latency_ns > 0
    assert cm.deploy == DeployConfig()
    assert cm.chip is cm.placement.spec


def test_build_accepts_camtable_and_rejects_junk(trained):
    ens, _ = trained["binary"]
    table = compile_ensemble(ens)
    cm = build(table, deploy=DeployConfig(batching=True))
    assert cm.table is table
    assert cm.noc.config == "batch"  # §III-D input batching requested
    with pytest.raises(TypeError):
        build(np.zeros(3))


def test_build_batching_alters_noc_only():
    ds = make_dataset("churn")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    ens = train_gbdt(q.transform(ds.x_train), ds.y_train, task="binary",
                     n_bins=256, params=GBDTParams(n_rounds=3, max_leaves=16))
    a = build(ens)
    b = a.with_deploy(a.deploy.replace(batching=True))
    assert b.table is a.table and b.placement is a.placement
    assert b.noc.config == "batch" and a.noc.config != "batch"
    # unchanged batching: pure config swap, plans reused
    c = a.with_deploy(a.deploy.replace(mode="msb_lsb"))
    assert c.noc is a.noc and c.perf is a.perf
    assert a.with_deploy(a.deploy) is a


# -- save / load / engine ------------------------------------------------------


@pytest.mark.parametrize("mode", CELL_MODES)
@pytest.mark.parametrize("key", ["binary", "multiclass", "rf"])
def test_roundtrip_bit_equivalent(trained, key, mode, tmp_path):
    """build -> save -> load -> engine reproduces Ensemble.raw_margin for
    every cell mode and task shape; the reloaded engine is bit-identical
    to the pre-save engine."""
    ens, xb = trained[key]
    cm = build(ens, deploy=DeployConfig(mode=mode))
    loaded = CompiledModel.load(cm.save(tmp_path / f"{key}-{mode}"))

    direct = np.asarray(cm.engine().raw_margin(xb))
    reloaded = np.asarray(loaded.engine().raw_margin(xb))
    np.testing.assert_array_equal(reloaded, direct)  # bit-equivalent
    np.testing.assert_allclose(
        reloaded, ens.raw_margin(xb), rtol=1e-4, atol=1e-5
    )
    if ens.task != "regression":
        np.testing.assert_array_equal(
            np.asarray(loaded.engine().predict(xb)), ens.predict(xb)
        )


def test_roundtrip_bit_equivalent_on_mesh(trained, tmp_path):
    """The artifact binds to a sharded mesh engine after reload — the NoC
    accumulate collective over 'model' keeps margins equal."""
    ens, xb = trained["multiclass"]
    mesh = make_host_mesh()
    cm = build(ens)
    loaded = CompiledModel.load(cm.save(tmp_path / "mesh"))
    host = np.asarray(cm.engine().raw_margin(xb))
    sharded = np.asarray(loaded.engine(mesh=mesh).raw_margin(xb))
    np.testing.assert_allclose(sharded, host, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        sharded, ens.raw_margin(xb), rtol=1e-4, atol=1e-5
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_roundtrip_property_random_queries(trained, tmp_path_factory, seed):
    """Property: reload equivalence holds for ARBITRARY bin vectors."""
    ens, _ = trained["binary"]
    cm = build(ens)
    base = tmp_path_factory.mktemp("prop") / "m"
    loaded = CompiledModel.load(cm.save(base))
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 256, size=(17, ens.n_features)).astype(np.int32)
    np.testing.assert_array_equal(
        np.asarray(loaded.engine().raw_margin(q)),
        np.asarray(cm.engine().raw_margin(q)),
    )


def test_save_load_path_forms(trained, tmp_path):
    ens, _ = trained["binary"]
    cm = build(ens)
    sidecar = cm.save(tmp_path / "m.npz")  # suffix is normalized away
    assert sidecar == tmp_path / "m.json"
    for p in (tmp_path / "m", tmp_path / "m.npz", tmp_path / "m.json"):
        assert CompiledModel.load(p).table.n_rows == cm.table.n_rows


def test_load_preserves_plans_and_config(trained, tmp_path):
    ens, _ = trained["multiclass"]
    chip = ChipSpec(n_cores=512, n_stacked=4)
    cm = build(ens, deploy=DeployConfig(mode="msb_lsb", b_blk=64), chip=chip)
    loaded = CompiledModel.load(cm.save(tmp_path / "m"))
    assert loaded.deploy == cm.deploy
    assert loaded.chip == chip
    assert loaded.placement.core_trees == cm.placement.core_trees
    assert loaded.noc == cm.noc
    assert loaded.perf == cm.perf


def test_schema_version_mismatch_rejected(trained, tmp_path):
    ens, _ = trained["binary"]
    sidecar = build(ens).save(tmp_path / "m")
    doc = json.loads(sidecar.read_text())
    doc["schema_version"] = SCHEMA_VERSION + 1
    sidecar.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="schema_version"):
        CompiledModel.load(tmp_path / "m")
    doc["format"] = "something-else"
    sidecar.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="format"):
        CompiledModel.load(tmp_path / "m")


def test_engine_binding_is_cached(trained):
    ens, _ = trained["binary"]
    cm = build(ens)
    assert cm.engine() is cm.engine()
    assert cm.engine(mode="two_cycle") is not cm.engine()
    assert cm.engine().mode == "direct"
    assert cm.engine(mode="two_cycle").mode == "two_cycle"
    # batching is a build-time knob (replans the NoC) — not an engine bind
    with pytest.raises(ValueError, match="batching"):
        cm.engine(batching=True)


def test_auto_noc_resolution(trained):
    ens, _ = trained["binary"]
    cm = build(ens, deploy=DeployConfig(batching=True))
    assert cm.noc.engine_noc_config == "batch"
    # no mesh to replicate over -> degrade to the universal collective
    assert cm.resolved_deploy(mesh=None).noc_config == "accumulate"
    assert cm.resolved_deploy(mesh=make_host_mesh()).noc_config == "batch"


# -- registry cold start -------------------------------------------------------


def test_registry_cold_start_from_artifact(trained, tmp_path, monkeypatch):
    """register(name, CompiledModel) must serve with ZERO recompilation —
    the compiler entry points are poisoned to prove it."""
    ens, xb = trained["binary"]
    expected = np.asarray(build(ens).engine().predict(xb))
    artifact = CompiledModel.load(build(ens).save(tmp_path / "cold"))

    def _poisoned(*a, **kw):  # pragma: no cover - failure path
        raise AssertionError("cold start must not recompile")

    monkeypatch.setattr(api, "compile_ensemble", _poisoned)
    monkeypatch.setattr(api, "pack_cores", _poisoned)
    monkeypatch.setattr(api, "plan_noc", _poisoned)

    reg = TableRegistry()
    entry = reg.register("cold", artifact)
    assert entry.artifact is artifact and entry.version == 1
    assert isinstance(entry.placement, CorePlacement)
    loop = ServeLoop(reg, window_s=100.0, flush_rows=16)
    handles = [loop.submit("cold", xb[i]) for i in range(20)]
    loop.drain()
    got = np.concatenate([loop.result(h) for h in handles])
    np.testing.assert_array_equal(got, expected[:20])
    rep = loop.report("cold")
    assert rep["deploy"]["backend"] == "jnp"


def test_registry_artifact_hot_swap_keeps_settings(trained, tmp_path):
    ens, xb = trained["binary"]
    reg = TableRegistry()
    a = reg.register("m", build(ens), batching=True)
    assert a.batching and a.noc.config == "batch"
    b = reg.swap("m", CompiledModel.load(a.artifact.save(tmp_path / "v2")))
    assert b.version == 2 and b.batching and b.noc.config == "batch"


def test_explicit_deploy_beats_carried_over_overrides(trained):
    """A swap with deploy=DeployConfig(...) is a full config reset — stale
    loose kwargs from the previous registration must not outrank it."""
    ens, _ = trained["binary"]
    reg = TableRegistry()
    with pytest.warns(DeprecationWarning):
        reg.register("m", ens, mode="msb_lsb")
    entry = reg.register("m", ens, deploy=DeployConfig(mode="direct"))
    assert entry.deploy.mode == "direct"
    assert entry.engine.mode == "direct"
    # the reset config is what carries over on subsequent swaps
    entry = reg.register("m", ens)
    assert entry.deploy.mode == "direct" and entry.engine_overrides == {}


def test_registry_unregister_unknown_is_helpful(trained):
    reg = TableRegistry()
    with pytest.raises(KeyError, match="unknown model 'nope'; registered"):
        reg.unregister("nope")


def test_register_tolerates_manual_entry_without_overrides(trained):
    """Hot-swap over a hand-rolled ServedModel with engine_overrides=None
    must not crash on the carry-over merge."""
    ens, _ = trained["binary"]
    reg = TableRegistry()
    cm = build(ens)
    reg._models["m"] = ServedModel(
        name="m", version=3, artifact=cm, engine=cm.engine(),
        engine_overrides=None,
    )
    entry = reg.register("m", ens)
    assert entry.version == 4 and entry.engine_overrides == {}


# -- deprecation shims ---------------------------------------------------------


def test_legacy_engine_kwargs_warn_but_work(trained):
    ens, xb = trained["binary"]
    table = compile_ensemble(ens)
    with pytest.warns(DeprecationWarning):
        eng = XTimeEngine(table, backend="jnp", mode="direct", b_blk=64)
    assert eng.config == DeployConfig(backend="jnp", mode="direct", b_blk=64)
    np.testing.assert_allclose(
        np.asarray(eng.raw_margin(xb)), ens.raw_margin(xb),
        rtol=1e-4, atol=1e-5,
    )
    with pytest.raises(TypeError):
        XTimeEngine(table, config=DeployConfig(), backend="jnp")


def test_config_engine_form_does_not_warn(trained):
    ens, _ = trained["binary"]
    table = compile_ensemble(ens)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        XTimeEngine(table)
        XTimeEngine.from_config(table, DeployConfig(mode="msb_lsb"))


def test_legacy_registry_kwargs_warn_but_work(trained):
    ens, xb = trained["binary"]
    with pytest.warns(DeprecationWarning):
        reg = TableRegistry(b_blk=64, mode="direct")
    assert reg.deploy.b_blk == 64
    with pytest.warns(DeprecationWarning):
        entry = reg.register("m", ens, mode="two_cycle")
    assert entry.deploy.mode == "two_cycle" and entry.deploy.b_blk == 64
    np.testing.assert_allclose(
        np.asarray(entry.engine.raw_margin(xb)), ens.raw_margin(xb),
        rtol=1e-4, atol=1e-5,
    )


# -- float-in predict API ------------------------------------------------------


@pytest.fixture(scope="module")
def gridded():
    """An artifact with an attached grid + the float queries it bins."""
    ds = make_dataset("churn")
    q = FeatureQuantizer.fit(ds.x_train, 64)
    ens = train_gbdt(q.transform(ds.x_train), ds.y_train, task="binary",
                     n_bins=64, params=GBDTParams(n_rounds=4, max_leaves=16))
    return build(ens, quantizer=q), ds.x_test[:96].astype(np.float64), q


def test_predict_one_call_equals_two_step(gridded):
    """model.predict(x) == the old bin -> engine().predict two-step,
    bit for bit (same engine binding via batch_hint)."""
    cm, x, q = gridded
    xb = q.transform(x)
    eng = cm.engine(batch_hint=x.shape[0])
    np.testing.assert_array_equal(cm.predict(x), np.asarray(eng.predict(xb)))
    np.testing.assert_array_equal(
        cm.raw_margin(x), np.asarray(eng.raw_margin(xb))
    )
    # pre-binned integer queries skip the grid
    np.testing.assert_array_equal(cm.predict(xb), np.asarray(eng.predict(xb)))


def test_predict_without_grid_is_a_clear_error(trained):
    ens, xb = trained["binary"]
    cm = build(ens)  # no quantizer attached
    with pytest.raises(ValueError, match="no feature grid"):
        cm.predict(xb.astype(np.float64))
    with pytest.raises(ValueError, match="no feature grid"):
        cm.raw_margin(xb.astype(np.float64))
    # binned input still serves without a grid
    assert cm.predict(xb).shape == (xb.shape[0],)


def test_bin_shim_warns_but_still_bins(gridded):
    cm, x, q = gridded
    with pytest.warns(DeprecationWarning, match="CompiledModel.bin"):
        xb = cm.bin(x)
    np.testing.assert_array_equal(xb, q.transform(x))


def test_deploy_config_validation():
    with pytest.raises(ValueError):
        DeployConfig(backend="cuda")
    with pytest.raises(ValueError):
        DeployConfig(mode="nope")
    with pytest.raises(ValueError):
        DeployConfig(noc_config="forward")
    cfg = DeployConfig.from_dict(
        {"backend": "pallas", "mode": "msb_lsb", "some_future_field": 1}
    )
    assert cfg == DeployConfig(backend="pallas", mode="msb_lsb")
    assert DeployConfig.from_dict(cfg.to_dict()) == cfg


def test_lazy_package_exports():
    import repro
    import repro.core as core

    assert core.XTimeEngine is XTimeEngine
    assert core.CompiledModel is CompiledModel
    assert core.build is build and repro.build is build
    assert repro.CompiledModel is CompiledModel
    assert repro.DeployConfig is DeployConfig
    assert "XTimeEngine" in dir(core) and "CompiledModel" in dir(repro)
    with pytest.raises(AttributeError):
        core.does_not_exist
