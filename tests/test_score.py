"""Streaming offline scoring (repro.score, DESIGN.md §14).

The load-bearing contract is bit-equivalence: for any chunking — sizes
that don't divide the row count, 1-row tails, double-buffering on or
off, single device or the 8-fake-device mesh under the ``batch`` NoC
program — the concatenated streamed outputs must be BIT-IDENTICAL to a
one-shot engine call over the whole file.  Plus the golden loop: the
committed ``xgb_deep`` fixture goes ingest -> build -> save -> score
(from the committed ``.npy``) -> verify against the frozen record.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro
from repro.api import CompiledModel, build
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import random_deep_ensemble
from repro.launch.mesh import make_host_mesh
from repro.score import (
    NpySource,
    PredictionWriter,
    ScoreResult,
    open_columnar,
    score_file,
)

FIXTURES = Path(__file__).resolve().parent / "fixtures"
SCRIPTS = Path(__file__).resolve().parent.parent / "scripts"


@pytest.fixture(scope="module")
def binary_cm():
    """Small gridless binary model + pre-binned int queries + oracle."""
    ens = random_deep_ensemble(n_trees=12, depth=4, n_features=9,
                               n_bins=32, seed=3)
    cm = build(ens)
    rng = np.random.default_rng(0)
    q = rng.integers(0, 32, size=(301, 9)).astype(np.int32)
    eng = cm.engine()
    return cm, q, np.asarray(eng.raw_margin(q)), np.asarray(eng.predict(q))


@pytest.fixture(scope="module")
def multiclass_cm():
    """Multi-channel margins: the (B, n_outputs) writer/streaming path."""
    ens = random_deep_ensemble(n_trees=9, depth=3, n_features=6, n_bins=16,
                               task="multiclass", n_classes=3, seed=11)
    cm = build(ens)
    rng = np.random.default_rng(1)
    q = rng.integers(0, 16, size=(157, 6)).astype(np.int32)
    eng = cm.engine()
    return cm, q, np.asarray(eng.raw_margin(q)), np.asarray(eng.predict(q))


# -- bit-equivalence: streamed == one-shot -------------------------------------


@settings(max_examples=12, deadline=None)
@given(chunk_rows=st.integers(min_value=1, max_value=400))
def test_streamed_bit_equal_one_shot_any_chunking(binary_cm, chunk_rows):
    """THE acceptance property: for arbitrary chunk sizes — dividing the
    301 rows or not — streamed outputs are bit-identical to one-shot."""
    cm, q, ref_m, ref_p = binary_cm
    r = score_file(cm, q, kind="margin", chunk_rows=chunk_rows)
    np.testing.assert_array_equal(r.values, ref_m)
    assert r.values.dtype == ref_m.dtype
    r = score_file(cm, q, kind="predict", chunk_rows=chunk_rows)
    np.testing.assert_array_equal(r.values, ref_p)


def test_double_buffer_off_same_bits(binary_cm):
    cm, q, ref_m, _ = binary_cm
    on = score_file(cm, q, kind="margin", chunk_rows=33, double_buffer=True)
    off = score_file(cm, q, kind="margin", chunk_rows=33, double_buffer=False)
    np.testing.assert_array_equal(on.values, ref_m)
    np.testing.assert_array_equal(off.values, on.values)
    assert on.double_buffered and not off.double_buffered


def test_multichannel_margins_stream_bit_equal(multiclass_cm):
    cm, q, ref_m, ref_p = multiclass_cm
    assert ref_m.shape[1] == 3  # genuinely multi-channel
    for chunk in (13, 64, 157):
        r = score_file(cm, q, kind="margin", chunk_rows=chunk)
        np.testing.assert_array_equal(r.values, ref_m)
    r = score_file(cm, q, kind="predict", chunk_rows=50)
    np.testing.assert_array_equal(r.values, ref_p)
    assert r.values.dtype == np.int32


def test_empty_and_one_row_tails(binary_cm, multiclass_cm):
    cm, q, ref_m, ref_p = binary_cm
    r0 = score_file(cm, q[:0], kind="margin")
    assert r0.values.shape == (0, ref_m.shape[1])
    assert r0.n_chunks == 0 and r0.rows_per_s == 0.0
    mc, mq, mref, _ = multiclass_cm
    r0 = score_file(mc, mq[:0], kind="margin")
    assert r0.values.shape == (0, 3)
    r1 = score_file(cm, q[:1], kind="predict", chunk_rows=64)
    np.testing.assert_array_equal(r1.values, ref_p[:1])
    # a chunk size exactly one short of the row count: a 1-row tail chunk
    r = score_file(cm, q, kind="margin", chunk_rows=q.shape[0] - 1)
    np.testing.assert_array_equal(r.values, ref_m)
    assert r.n_chunks == 2


def test_mesh_batch_noc_bit_equal(binary_cm):
    """Chunks fan out across the 8-fake-device mesh under the 'batch'
    NoC program (replicated tables, no collective) — same bits."""
    cm, q, ref_m, _ = binary_cm
    mesh = make_host_mesh(8, 1)
    r = score_file(cm, q, kind="margin", chunk_rows=40, mesh=mesh)
    np.testing.assert_array_equal(r.values, ref_m)
    assert r.engine["devices"] == 8
    assert r.engine["noc_config"] == "batch"
    # the bucket must satisfy the mesh's batch-divisibility contract
    assert r.bucket % 8 == 0


def test_float_input_binned_chunkwise_bit_equal():
    """Float rows bin chunk-by-chunk with the artifact's own grid —
    identical to binning the whole file up front."""
    rng = np.random.default_rng(7)
    ens = random_deep_ensemble(n_trees=8, depth=4, n_features=5,
                               n_bins=32, seed=5)
    xf = rng.normal(size=(203, 5))
    fq = FeatureQuantizer.fit(xf, n_bins=32)
    cm = build(ens, quantizer=fq)
    ref = np.asarray(cm.engine().raw_margin(fq.transform(xf)))
    r = score_file(cm, xf, kind="margin", chunk_rows=48)
    assert r.binned
    np.testing.assert_array_equal(r.values, ref)


# -- file round trips ----------------------------------------------------------


def test_npy_in_npy_out_round_trip(binary_cm, tmp_path):
    cm, q, ref_m, _ = binary_cm
    np.save(tmp_path / "rows.npy", q)
    r = score_file(cm, tmp_path / "rows.npy", kind="margin",
                   chunk_rows=50, out=tmp_path / "preds")
    assert r.path == tmp_path / "preds.npy"  # suffix appended
    np.testing.assert_array_equal(np.load(r.path), ref_m)
    np.testing.assert_array_equal(r.values, ref_m)


def test_artifact_path_accepted(binary_cm, tmp_path):
    cm, q, ref_m, _ = binary_cm
    cm.save(tmp_path / "art")
    r = score_file(tmp_path / "art", q, kind="margin", chunk_rows=100)
    np.testing.assert_array_equal(r.values, ref_m)


def test_parquet_source_streams(binary_cm, tmp_path):
    pa = pytest.importorskip("pyarrow")
    import pyarrow.parquet as pq

    cm, q, ref_m, _ = binary_cm
    tbl = pa.table({f"f{i}": q[:, i] for i in range(q.shape[1])})
    pq.write_table(tbl, tmp_path / "rows.parquet", row_group_size=64)
    r = score_file(cm, tmp_path / "rows.parquet", kind="margin",
                   chunk_rows=37)
    np.testing.assert_array_equal(r.values, ref_m)
    # explicit column selection, same order
    r2 = score_file(cm, tmp_path / "rows.parquet", kind="margin",
                    columns=[f"f{i}" for i in range(q.shape[1])])
    np.testing.assert_array_equal(r2.values, ref_m)


# -- error surface -------------------------------------------------------------


def test_float_without_grid_is_a_clear_error(binary_cm):
    cm, q, _, _ = binary_cm  # built gridless
    with pytest.raises(ValueError, match="feature grid"):
        score_file(cm, q.astype(np.float64))


def test_feature_width_mismatch(binary_cm):
    cm, q, _, _ = binary_cm
    with pytest.raises(ValueError, match="feature columns"):
        score_file(cm, q[:, :4])


def test_bad_kind_and_chunk_rows(binary_cm):
    cm, q, _, _ = binary_cm
    with pytest.raises(ValueError, match="kind"):
        score_file(cm, q, kind="margins")
    with pytest.raises(ValueError, match="chunk_rows"):
        score_file(cm, q, chunk_rows=0)


def test_open_columnar_rejects_unknown_suffix(tmp_path):
    p = tmp_path / "rows.csv"
    p.write_text("1,2\n")
    with pytest.raises(ValueError, match="unsupported columnar input"):
        open_columnar(p)
    with pytest.raises(FileNotFoundError):
        open_columnar(tmp_path / "nope.npy")
    with pytest.raises(ValueError, match="2-D"):
        open_columnar(np.zeros(5))


def test_writer_enforces_sequential_order():
    w = PredictionWriter(10)
    w.write(0, np.zeros((4, 2), np.float32))
    with pytest.raises(ValueError, match="out-of-order"):
        w.write(8, np.zeros((2, 2), np.float32))
    w.write(4, np.zeros((6, 2), np.float32))
    out = w.finalize()
    assert out.shape == (10, 2)
    with pytest.raises(ValueError, match="overruns"):
        PredictionWriter(2).write(0, np.zeros((3,), np.float32))


def test_npy_source_is_memory_mapped(tmp_path):
    q = np.arange(20, dtype=np.int32).reshape(10, 2)
    np.save(tmp_path / "r.npy", q)
    src = open_columnar(tmp_path / "r.npy")
    assert isinstance(src, NpySource)
    assert isinstance(src.array, np.memmap)
    chunks = list(src.iter_chunks(4))
    assert [s for s, _ in chunks] == [0, 4, 8]
    # chunks are real copies: safe to donate after the source closes
    assert not any(isinstance(c, np.memmap) for _, c in chunks)
    np.testing.assert_array_equal(np.concatenate([c for _, c in chunks]), q)
    src.close()


# -- the golden loop on the committed fixture ----------------------------------


def test_xgb_deep_golden_save_score_verify(tmp_path):
    """ingest -> build -> save -> score the committed .npy on the 8-fake
    device mesh -> bit-identical to the frozen record."""
    exp = json.loads(
        (FIXTURES / "ingest" / "xgb_deep.expected.json").read_text()
    )
    cm = build(str(FIXTURES / "ingest" / "xgb_deep.json"))
    cm.save(tmp_path / "art")
    loaded = CompiledModel.load(tmp_path / "art")

    mesh = make_host_mesh(8, 1)
    r = score_file(loaded, FIXTURES / "score" / "xgb_deep_x.npy",
                   kind="margin", chunk_rows=10, mesh=mesh)
    want = np.asarray(exp["raw_margin"], dtype=np.float32)
    np.testing.assert_allclose(r.values, want, rtol=1e-5, atol=1e-6)
    # regression fixture: predictions ARE margins (engine tolerance)
    rp = score_file(loaded, FIXTURES / "score" / "xgb_deep_x.npy",
                    kind="predict", chunk_rows=10, mesh=mesh)
    np.testing.assert_allclose(rp.values, np.asarray(exp["predict"]),
                               rtol=1e-5, atol=1e-6)


def test_score_fixture_matches_expected_record():
    """The committed .npy must stay the expected.json queries, byte for
    byte (make_fixtures.py regenerates it)."""
    exp = json.loads(
        (FIXTURES / "ingest" / "xgb_deep.expected.json").read_text()
    )
    x = np.load(FIXTURES / "score" / "xgb_deep_x.npy")
    np.testing.assert_array_equal(x, np.asarray(exp["x"], dtype=np.float64))


def test_score_cli_expected_round_trip(tmp_path):
    """The CI score-golden job's exact path: ingest CLI -> score CLI
    --expected, in a subprocess (exercises the shared _cli plumbing)."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)  # single device is fine and faster here
    ingest = subprocess.run(
        [sys.executable, str(SCRIPTS / "ingest.py"),
         str(FIXTURES / "ingest" / "xgb_deep.json"),
         "--out", str(tmp_path / "art")],
        capture_output=True, text=True, env=env,
    )
    assert ingest.returncode == 0, ingest.stderr
    score = subprocess.run(
        [sys.executable, str(SCRIPTS / "score.py"), str(tmp_path / "art"),
         str(FIXTURES / "score" / "xgb_deep_x.npy"),
         "--expected", str(FIXTURES / "ingest" / "xgb_deep.expected.json"),
         "--chunk-rows", "10"],
        capture_output=True, text=True, env=env,
    )
    assert score.returncode == 0, score.stdout + score.stderr
    assert "[verify]  OK" in score.stdout


# -- public surface ------------------------------------------------------------


def test_repro_all_resolves():
    """Every documented name in repro.__all__ must import — the README
    module map contract."""
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name
    # and the score package's own surface
    import repro.score as sc

    for name in sc.__all__:
        assert getattr(sc, name) is not None, name
    assert "score_file" in repro.__all__
    assert "CompiledModel" in repro.__all__


def test_score_result_reports_throughput(binary_cm):
    cm, q, _, _ = binary_cm
    r = score_file(cm, q, kind="predict", chunk_rows=100)
    assert isinstance(r, ScoreResult)
    assert r.n_rows == q.shape[0] and r.n_chunks == 4
    assert r.elapsed_s > 0 and r.rows_per_s > 0
    assert r.engine["kernel"].startswith("v")
    assert set(r.engine) >= {"backend", "table_dtype", "kernel",
                             "noc_config", "devices"}
