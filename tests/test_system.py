"""End-to-end behaviour of the paper's system: dataset -> train -> 8-bit
quantize -> CAM compile -> placement -> NoC plan -> engine -> prediction,
reproducing the paper's workflow (Fig. 7d) and its accuracy claims
qualitatively (Fig. 9a): 8-bit matches float, 4-bit degrades."""

import numpy as np
import pytest

from repro.core.compile import compile_ensemble, pack_cores
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.core.noc import plan_noc
from repro.core.perfmodel import gpu_perf_model, xtime_perf
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import accuracy_metric, make_dataset


@pytest.fixture(scope="module")
def pipeline():
    ds = make_dataset("churn")
    out = {}
    for bits, rounds, leaves in (("8bit", 40, 64), ("4bit", 40, 128)):
        n_bins = 256 if bits == "8bit" else 16
        q = FeatureQuantizer.fit(ds.x_train, n_bins)
        xb_tr, xb_te = q.transform(ds.x_train), q.transform(ds.x_test)
        ens = train_gbdt(xb_tr, ds.y_train, task="binary", n_bins=n_bins,
                         params=GBDTParams(n_rounds=rounds, max_leaves=leaves))
        out[bits] = (ens, xb_te, ds)
    # float-ish baseline: 4096 bins
    q = FeatureQuantizer.fit(ds.x_train, 4096)
    ens = train_gbdt(q.transform(ds.x_train), ds.y_train, task="binary",
                     n_bins=4096, params=GBDTParams(n_rounds=40, max_leaves=64))
    out["float"] = (ens, q.transform(ds.x_test), ds)
    return out


def test_end_to_end_accuracy_through_engine(pipeline):
    ens, xb_te, ds = pipeline["8bit"]
    table = compile_ensemble(ens)
    eng = XTimeEngine.from_config(table, DeployConfig(backend="jnp"))
    acc = accuracy_metric("binary", ds.y_test, np.asarray(eng.predict(xb_te)))
    base = max(np.mean(ds.y_test), 1 - np.mean(ds.y_test))
    assert acc > base + 0.03


def test_8bit_close_to_float(pipeline):
    """Fig. 9(a): 8-bit matches the unconstrained baseline on binary
    classification (the paper's 4-bit losses concentrate on regression /
    many-class tasks — tested below on rossmann)."""
    accs = {}
    for key in ("float", "8bit"):
        ens, xb_te, ds = pipeline[key]
        accs[key] = accuracy_metric("binary", ds.y_test, ens.predict(xb_te))
    assert accs["8bit"] >= accs["float"] - 0.02


def test_4bit_degrades_regression():
    """Fig. 9(a): 4-bit thresholds lose accuracy on regression (paper:
    -20% on Rossmann)."""
    ds = make_dataset("rossmann")
    r2 = {}
    for bits, n_bins in (("8bit", 256), ("4bit", 16)):
        q = FeatureQuantizer.fit(ds.x_train, n_bins)
        ens = train_gbdt(q.transform(ds.x_train), ds.y_train, task="regression",
                         n_bins=n_bins,
                         params=GBDTParams(n_rounds=40, max_leaves=64,
                                           learning_rate=0.2))
        r2[bits] = accuracy_metric("regression", ds.y_test,
                                   ens.predict(q.transform(ds.x_test)))
    assert r2["4bit"] < r2["8bit"] - 0.01, r2


def test_full_stack_objects_consistent(pipeline):
    ens, xb_te, ds = pipeline["8bit"]
    table = compile_ensemble(ens)
    plc = pack_cores(table)
    noc = plan_noc(table, plc)
    rep = xtime_perf(table, plc, noc)
    gpu = gpu_perf_model(n_trees=ens.n_trees, depth=8)
    # qualitative paper claims on a real trained model:
    assert rep.latency_ns < 1e3 < gpu.latency_ns  # ns vs us-ms
    assert rep.throughput_msps > gpu.throughput_msps
    assert rep.power_w < 25.0  # single chip under GPU idle power
