"""benchmarks/run.py structured records: a real driver run writes a
BENCH_*.json that the CI validator accepts, and failures exit nonzero."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if ROOT not in sys.path:  # `benchmarks` is a namespace package at the root
    sys.path.insert(0, ROOT)


def _run_driver(args: list[str], tmp_path) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "run.py"), *args],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600,
    )


@pytest.fixture(scope="module")
def bench_file(tmp_path_factory):
    """One fast analytic-module run shared by the schema tests."""
    out_dir = tmp_path_factory.mktemp("bench")
    proc = _run_driver(
        ["--only", "fig8_area_power", "--out", str(out_dir)], out_dir
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    files = sorted(out_dir.glob("BENCH_*.json"))
    assert len(files) == 1, list(out_dir.iterdir())
    return files[0]


def test_bench_record_schema(bench_file):
    from benchmarks.run import RECORD_FIELDS, validate_payload

    payload = json.loads(bench_file.read_text())
    validate_payload(payload)  # the check CI runs on the artifact
    assert payload["records"], "driver wrote an empty record set"
    for rec in payload["records"]:
        assert set(RECORD_FIELDS) <= set(rec)
        assert rec["module"] == "fig8_area_power"
        assert rec["git_rev"] == payload["git_rev"]


def test_bench_record_check_mode(bench_file, tmp_path):
    ok = _run_driver(["--check", str(bench_file)], tmp_path)
    assert ok.returncode == 0, ok.stderr[-2000:]
    assert "valid xtime-bench" in ok.stdout

    broken = tmp_path / "BENCH_broken.json"
    payload = json.loads(bench_file.read_text())
    del payload["records"][0]["us_per_call"]
    broken.write_text(json.dumps(payload))
    bad = _run_driver(["--check", str(broken)], tmp_path)
    assert bad.returncode != 0


def test_validator_rejects_malformed_payloads():
    from benchmarks.run import validate_payload

    good = {
        "format": "xtime-bench", "schema_version": 1, "git_rev": "abc",
        "fast": True, "env": {}, "records": [], "failures": [],
    }
    validate_payload(good)
    for mutate in (
        lambda d: d.update(format="other"),
        lambda d: d.update(schema_version=99),
        lambda d: d.pop("git_rev"),
        lambda d: d.update(records=[{"name": "x"}]),
        lambda d: d.update(failures=[{"module": "m"}]),
    ):
        bad = json.loads(json.dumps(good))
        mutate(bad)
        with pytest.raises(ValueError):
            validate_payload(bad)
