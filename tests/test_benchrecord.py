"""benchmarks/run.py structured records: a real driver run writes a
BENCH_*.json that the CI validator accepts, and failures exit nonzero."""

import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if ROOT not in sys.path:  # `benchmarks` is a namespace package at the root
    sys.path.insert(0, ROOT)


def _run_driver(args: list[str], tmp_path) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "run.py"), *args],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600,
    )


@pytest.fixture(scope="module")
def bench_file(tmp_path_factory):
    """One fast analytic-module run shared by the schema tests."""
    out_dir = tmp_path_factory.mktemp("bench")
    proc = _run_driver(
        ["--only", "fig8_area_power", "--out", str(out_dir)], out_dir
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    files = sorted(out_dir.glob("BENCH_*.json"))
    assert len(files) == 1, list(out_dir.iterdir())
    return files[0]


def test_bench_record_schema(bench_file):
    from benchmarks.run import RECORD_FIELDS, validate_payload

    payload = json.loads(bench_file.read_text())
    validate_payload(payload)  # the check CI runs on the artifact
    assert payload["records"], "driver wrote an empty record set"
    for rec in payload["records"]:
        assert set(RECORD_FIELDS) <= set(rec)
        assert rec["module"] == "fig8_area_power"
        assert rec["git_rev"] == payload["git_rev"]


def test_bench_record_check_mode(bench_file, tmp_path):
    ok = _run_driver(["--check", str(bench_file)], tmp_path)
    assert ok.returncode == 0, ok.stderr[-2000:]
    assert "valid xtime-bench" in ok.stdout

    broken = tmp_path / "BENCH_broken.json"
    payload = json.loads(bench_file.read_text())
    del payload["records"][0]["us_per_call"]
    broken.write_text(json.dumps(payload))
    bad = _run_driver(["--check", str(broken)], tmp_path)
    assert bad.returncode != 0


def test_check_mode_globs_directories(bench_file, tmp_path):
    """--check on a directory validates every BENCH_*.json inside."""
    import shutil

    shutil.copy(bench_file, tmp_path / "BENCH_one.json")
    shutil.copy(bench_file, tmp_path / "BENCH_two.json")
    ok = _run_driver(["--check", str(tmp_path)], tmp_path)
    assert ok.returncode == 0, ok.stderr[-2000:]
    assert ok.stdout.count("valid xtime-bench") == 2

    empty = tmp_path / "empty"
    empty.mkdir()
    bad = _run_driver(["--check", str(empty)], tmp_path)
    assert bad.returncode != 0


# -- baseline regression gate --------------------------------------------------


def _payload(entries: dict[tuple[str, str], float]) -> dict:
    return {
        "format": "xtime-bench", "schema_version": 1, "git_rev": "base123",
        "fast": True, "env": {}, "failures": [],
        "records": [
            {"module": m, "name": n, "us_per_call": us, "derived": "",
             "config": None, "git_rev": "base123"}
            for (m, n), us in entries.items()
        ],
    }


def test_gate_passes_within_tolerance_and_reports_changes():
    from benchmarks.run import compare_to_baseline

    baseline = _payload({("m", "a"): 100.0, ("m", "gone"): 5.0})
    current = _payload({("m", "a"): 120.0, ("m", "new"): 9.0})["records"]
    regressions, lines = compare_to_baseline(current, baseline, 25.0)
    assert regressions == []
    text = "\n".join(lines)
    assert "new" in text and "missing" in text


def test_gate_fails_on_synthetic_regression_beyond_tolerance(
        bench_file, tmp_path):
    """The acceptance-criteria demo: a >tolerance slowdown must fail CI."""
    from benchmarks.run import compare_to_baseline

    baseline = _payload({("m", "a"): 100.0, ("m", "b"): 10.0})
    current = _payload({("m", "a"): 160.0, ("m", "b"): 10.0})["records"]
    regressions, _ = compare_to_baseline(current, baseline, 50.0)
    assert [r["name"] for r in regressions] == ["a"]
    assert regressions[0]["ratio"] == pytest.approx(1.6)

    # end to end through the CLI, exactly as the bench-smoke job runs it:
    # a current record 4x slower than its baseline on one entry
    cur_path = tmp_path / "cur" / "BENCH_cur.json"
    cur_path.parent.mkdir()
    cur_path.write_text(json.dumps(
        _payload({("m", "a"): 400.0, ("m", "b"): 10.0})))
    base_path = tmp_path / "BENCH_baseline.json"
    base_path.write_text(json.dumps(
        _payload({("m", "a"): 100.0, ("m", "b"): 10.0})))
    proc = _run_driver(
        ["--check", str(cur_path.parent), "--baseline", str(base_path),
         "--tolerance", "50"], tmp_path,
    )
    assert proc.returncode == 3, proc.stderr[-2000:]
    assert "PERF REGRESSION" in proc.stderr
    # a second, fast record in the same dir must NOT mask the regression
    # (each file is gated on its own)
    (cur_path.parent / "BENCH_zzz.json").write_text(json.dumps(
        _payload({("m", "a"): 100.0, ("m", "b"): 10.0})))
    proc = _run_driver(
        ["--check", str(cur_path.parent), "--baseline", str(base_path),
         "--tolerance", "50"], tmp_path,
    )
    assert proc.returncode == 3, proc.stderr[-2000:]
    # and with generous tolerance the same comparison passes
    proc = _run_driver(
        ["--check", str(cur_path.parent), "--baseline", str(base_path),
         "--tolerance", "500"], tmp_path,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "baseline gate: OK" in proc.stderr


def test_per_entry_tolerance_overrides_global():
    """A baseline record's tolerance_pct replaces the global --tolerance
    for that entry only — tight kernel rows gate harder, noisy rows
    looser, in one baseline file."""
    from benchmarks.run import compare_to_baseline

    baseline = _payload({("m", "tight"): 100.0, ("m", "loose"): 100.0,
                         ("m", "plain"): 100.0})
    for rec in baseline["records"]:
        if rec["name"] == "tight":
            rec["tolerance_pct"] = 10
        elif rec["name"] == "loose":
            rec["tolerance_pct"] = 1000
    current = _payload({("m", "tight"): 150.0, ("m", "loose"): 500.0,
                        ("m", "plain"): 150.0})["records"]
    # global 300%: 'plain' at 1.5x passes, 'loose' at 5x passes via its
    # wide override, 'tight' at 1.5x FAILS via its 10% override
    regressions, lines = compare_to_baseline(current, baseline, 300.0)
    assert [r["name"] for r in regressions] == ["tight"]
    assert regressions[0]["tolerance_pct"] == 10
    assert any("tol +10%" in ln for ln in lines)


def test_validator_checks_tolerance_pct():
    from benchmarks.run import validate_payload

    payload = _payload({("m", "a"): 1.0})
    payload["records"][0]["tolerance_pct"] = 150
    validate_payload(payload)  # optional, additive
    for bad in ("wide", 0, -5):
        payload["records"][0]["tolerance_pct"] = bad
        with pytest.raises(ValueError):
            validate_payload(payload)


def test_committed_baseline_is_valid_and_covers_smoke_modules():
    from benchmarks.run import check_file

    payload = check_file(
        os.path.join(ROOT, "benchmarks", "baselines", "BENCH_baseline.json")
    )
    assert not payload["failures"]
    modules = {r["module"] for r in payload["records"]}
    assert {"fig11_scaling", "serve_bench", "ingest_bench",
            "kernel_bench"} <= modules
    # the kernel microbench rows carry their hand-annotated per-entry
    # tolerances (benchmarks/README.md) — losing them on a baseline
    # refresh should fail here, not silently widen the gate to 300%
    kernel_rows = [r for r in payload["records"]
                   if r["module"] == "kernel_bench"]
    assert kernel_rows
    assert all(r.get("tolerance_pct") for r in kernel_rows)
    # packed-path speedup is recorded in the committed record
    assert any("speedup_vs_int32" in r["derived"] for r in kernel_rows)


def test_committed_records_are_valid():
    from benchmarks.run import check_path

    checked = check_path(os.path.join(ROOT, "benchmarks", "records"))
    assert checked, "no committed BENCH records"
    for _, payload in checked:
        assert not payload["failures"]


def test_aggregate_bench_trajectory(bench_file, tmp_path, capsys):
    from benchmarks.aggregate import bench_table, load_bench_records

    import shutil
    shutil.copy(bench_file, tmp_path / "BENCH_run.json")
    payloads = load_bench_records(
        [os.path.join(ROOT, "benchmarks", "baselines"), str(tmp_path)]
    )
    assert len(payloads) == 2
    table = bench_table(payloads)
    assert table.startswith("| module/name |")
    assert "fig8_area_power" in table
    assert bench_table([]).startswith("(no BENCH_")


def test_validator_rejects_malformed_payloads():
    from benchmarks.run import validate_payload

    good = {
        "format": "xtime-bench", "schema_version": 1, "git_rev": "abc",
        "fast": True, "env": {}, "records": [], "failures": [],
    }
    validate_payload(good)
    for mutate in (
        lambda d: d.update(format="other"),
        lambda d: d.update(schema_version=99),
        lambda d: d.pop("git_rev"),
        lambda d: d.update(records=[{"name": "x"}]),
        lambda d: d.update(failures=[{"module": "m"}]),
    ):
        bad = json.loads(json.dumps(good))
        mutate(bad)
        with pytest.raises(ValueError):
            validate_payload(bad)
