"""Kernel v2 (DESIGN.md §10): compact-dtype packing, feature-grid tiling,
wildcard row ordering, interpret resolution and autotune persistence.

The non-negotiable contract: the packed uint8/uint16 paths are BIT-EQUAL
to the v1 int32 oracle across every cell mode, including bin values at
the dtype boundaries (0, 255/65535) and wildcard sentinel rows — on a
single device here and under shard_map in tests/test_scaleout-style
subprocess harnesses below.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from oracles import assert_packed_reencoding_bit_equal, random_tables

from repro.core.compile import (
    compile_ensemble,
    order_rows_by_wildcards,
    select_table_dtype,
)
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine, resolve_table_dtype
from repro.core.trees import GBDTParams, train_gbdt
from repro.kernels import ops as kops

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# -- dtype selection -----------------------------------------------------------


def test_select_table_dtype_thresholds():
    assert select_table_dtype(2) == "uint8"
    assert select_table_dtype(256) == "uint8"
    assert select_table_dtype(257) == "uint16"
    assert select_table_dtype(1 << 16) == "uint16"
    assert select_table_dtype((1 << 16) + 1) == "int32"


def test_compile_records_table_dtype():
    rng = np.random.default_rng(0)
    xb = rng.integers(0, 16, size=(64, 4))
    y = (xb.sum(1) > 30).astype(np.int64)
    ens = train_gbdt(xb, y, task="binary", n_bins=16,
                     params=GBDTParams(n_rounds=2, max_leaves=4))
    assert compile_ensemble(ens).table_dtype == "uint8"
    assert compile_ensemble(ens, table_dtype="int32").table_dtype == "int32"
    with pytest.raises(ValueError):
        compile_ensemble(ens, table_dtype="float32")


def test_faithful_modes_pin_int32():
    rng = np.random.default_rng(0)
    xb = rng.integers(0, 16, size=(64, 4))
    y = (xb.sum(1) > 30).astype(np.int64)
    ens = train_gbdt(xb, y, task="binary", n_bins=16,
                     params=GBDTParams(n_rounds=2, max_leaves=4))
    table = compile_ensemble(ens)
    assert table.table_dtype == "uint8"
    for mode in ("msb_lsb", "two_cycle"):
        cfg = DeployConfig(mode=mode)
        assert resolve_table_dtype(table, cfg) == "int32"
        with pytest.raises(ValueError):
            DeployConfig(mode=mode, table_dtype="uint8")


# -- packed-kernel bit-equivalence vs the v1 int32 oracle ----------------------
# (the generators and the differential assertion live in tests/oracles.py,
# shared with test_kernel_compact.py and test_kernel_v3.py)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_uint8_packed_bit_equals_int32_oracle(seed):
    """Property: uint8 inclusive packing is a re-encoding of the int32
    exclusive tables — identical bits out, jnp and Pallas, boundary bins
    0/255 and wildcard rows included."""
    for backend in ("jnp", "pallas"):
        assert_packed_reencoding_bit_equal(seed, 256, "uint8", "direct", backend)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_uint16_packed_bit_equals_int32_oracle(seed):
    """Same property on a 16-bit grid (boundary bin 65535)."""
    assert_packed_reencoding_bit_equal(seed, 1 << 16, "uint16", "direct", "jnp")


def test_uint16_pallas_spot():
    assert_packed_reencoding_bit_equal(7, 1 << 16, "uint16", "direct", "pallas")


def test_packed_overflow_rejected():
    rng = np.random.default_rng(0)
    low, high = random_tables(rng, 8, 4, 4096)
    leaf = np.zeros((8, 1), dtype=np.float32)
    with pytest.raises(ValueError):
        kops.pack_tables(low, high, leaf, n_bins=4096, dtype="uint8")


@pytest.mark.parametrize("mode", ["direct", "inclusive", "msb_lsb", "two_cycle"])
def test_engine_all_modes_bit_equal_across_dtypes(mode):
    """Engine-level: every cell mode × admissible table dtype produces the
    exact same margins (the kernel-v2 equivalence contract)."""
    rng = np.random.default_rng(3)
    xb = rng.integers(0, 256, size=(200, 9))
    y = (xb[:, 0].astype(np.int64) * 3 + xb[:, 4] > 500).astype(np.int64)
    ens = train_gbdt(xb, y, task="binary", n_bins=256,
                     params=GBDTParams(n_rounds=4, max_leaves=16))
    table = compile_ensemble(ens)
    ref = None
    dtypes = ("int32",) if mode in ("msb_lsb", "two_cycle") else (
        "int32", "uint8", "uint16",
    )
    for backend in ("jnp", "pallas"):
        backend_ref = None  # packing is bit-exact within one backend
        for td in dtypes:
            eng = XTimeEngine.from_config(
                table,
                DeployConfig(backend=backend, mode=mode, table_dtype=td,
                             b_blk=64, r_blk=64),
            )
            m = np.asarray(eng.raw_margin(xb))
            if backend_ref is None:
                backend_ref = m
            np.testing.assert_array_equal(m, backend_ref)
            if ref is None:
                ref = m
            # across backends the tiled accumulation may reassociate the
            # float32 sums — semantics identical, bits within 1 ULP
            np.testing.assert_allclose(m, ref, rtol=1e-6, atol=1e-7)


# -- wildcard tile mask + row ordering ----------------------------------------


def test_tile_mask_marks_wildcard_tiles():
    n_bins = 256
    low = np.zeros((64, 256), dtype=np.int32)
    high = np.full((64, 256), n_bins, dtype=np.int32)
    low[:32, 0] = 3  # first row block constrains feature tile 0 only
    high[:32, 0] = 7
    lo_p, hi_p, lm, incl = kops.pack_tables(
        low, high, np.zeros((64, 8), np.float32),
        r_blk=32, n_bins=n_bins, dtype="uint8",
    )
    mask = kops.wildcard_tile_mask(
        lo_p, hi_p, r_blk=32, f_blk=128, n_bins=n_bins, inclusive=incl,
    )
    np.testing.assert_array_equal(mask, [[1, 0], [0, 0]])


def test_row_ordering_increases_skippable_tiles_and_preserves_bits():
    """Interleaved rows that constrain alternating feature tiles: unordered
    they poison every (row, feature) tile; ordered, half the tiles become
    skippable — with identical predictions."""
    rng = np.random.default_rng(5)
    xb = rng.integers(0, 256, size=(300, 300))
    y = (xb[:, 0] > 127).astype(np.int64)
    ens = train_gbdt(xb, y, task="binary", n_bins=256,
                     params=GBDTParams(n_rounds=4, max_leaves=8))
    unordered = compile_ensemble(ens, order_rows=False)
    ordered = order_rows_by_wildcards(unordered)
    assert (
        ordered.tile_skip_fraction(64, 128)
        >= unordered.tile_skip_fraction(64, 128)
    )
    m0 = np.asarray(
        XTimeEngine.from_config(unordered, DeployConfig()).raw_margin(xb[:64])
    )
    m1 = np.asarray(
        XTimeEngine.from_config(ordered, DeployConfig()).raw_margin(xb[:64])
    )
    np.testing.assert_array_equal(m0, m1)


def test_engine_mask_actually_skips_and_stays_correct():
    """A pallas engine on a table whose constraints live entirely in the
    first feature tile must skip the second tile's compares — and still
    agree with the jnp oracle to the last bit."""
    from repro.core.compile import CAMTable

    rng = np.random.default_rng(6)
    R, F, n_bins = 64, 200, 256
    low = np.zeros((R, F), dtype=np.int32)
    high = np.full((R, F), n_bins, dtype=np.int32)
    low[:, :16] = rng.integers(0, 128, size=(R, 16))
    high[:, :16] = low[:, :16] + rng.integers(1, 128, size=(R, 16))
    table = CAMTable(
        low=low, high=high,
        leaf=rng.normal(size=R).astype(np.float32),
        tree_id=np.arange(R, dtype=np.int32),
        class_id=(np.arange(R) % 2).astype(np.int32),
        n_trees=R, n_features=F, n_bins=n_bins, n_outputs=2,
        task="multiclass", kind="gbdt", base_score=0.0, n_classes=2,
        table_dtype="uint8",
    )
    eng = XTimeEngine.from_config(
        table, DeployConfig(backend="pallas", b_blk=32, r_blk=32),
    )
    mask = np.asarray(eng.arrays.tile_mask)
    assert mask.shape == (2, 2)
    np.testing.assert_array_equal(mask[:, 1], 0)  # tile 1: all wildcards
    xq = rng.integers(0, n_bins, size=(96, F))
    ref = np.asarray(
        XTimeEngine.from_config(
            table, DeployConfig(backend="jnp", table_dtype="int32",
                                b_blk=32, r_blk=32)
        ).raw_margin(xq)
    )
    np.testing.assert_allclose(
        np.asarray(eng.raw_margin(xq)), ref, rtol=1e-6, atol=1e-7
    )


def test_out_of_range_queries_rejected_not_wrapped():
    """The v1 int32 compare was accidentally lenient with out-of-grid bins
    (value >= high never matches); a packed engine must REJECT them — a
    uint8 cast would wrap 300 to 44 and match rows it must not."""
    rng = np.random.default_rng(8)
    xb = rng.integers(0, 256, size=(128, 5))
    y = (xb[:, 0] > 127).astype(np.int64)
    ens = train_gbdt(xb, y, task="binary", n_bins=256,
                     params=GBDTParams(n_rounds=2, max_leaves=8))
    eng = XTimeEngine.from_config(compile_ensemble(ens), DeployConfig())
    assert eng.table_dtype == "uint8"
    bad = xb.copy()
    bad[0, 0] = 300
    with pytest.raises(ValueError, match="do not fit table dtype"):
        eng.raw_margin(bad)
    with pytest.raises(ValueError, match="do not fit table dtype"):
        kops.pad_to_bucket(bad, 128, eng.arrays.f_pad, dtype="uint8")
    eng.raw_margin(xb)  # in-range bins unaffected


def test_defect_injected_table_falls_back_to_int32():
    """Defect flips can push bounds outside the packed encoding (low to
    n_bins, high below low); the perturbed table must drop to the int32
    layout and still bind an engine (the serving hot-swap defect study)."""
    from repro.core.defects import inject_table_defects

    rng = np.random.default_rng(9)
    xb = rng.integers(0, 256, size=(200, 6))
    y = (xb[:, 1] > 127).astype(np.int64)
    ens = train_gbdt(xb, y, task="binary", n_bins=256,
                     params=GBDTParams(n_rounds=3, max_leaves=8))
    table = compile_ensemble(ens)
    assert table.table_dtype == "uint8"
    bad = inject_table_defects(table, 0.1, np.random.default_rng(0))
    assert bad.table_dtype == "int32"
    eng = XTimeEngine.from_config(bad, DeployConfig())  # must not raise
    assert eng.table_dtype == "int32"
    eng.raw_margin(xb[:32])
    # an explicit packed override on an out-of-range table fails loudly
    if int(bad.low.max()) > 255 or int(bad.high.min()) < 1:
        with pytest.raises(ValueError):
            XTimeEngine.from_config(bad, DeployConfig(table_dtype="uint8"))


# -- interpret resolution ------------------------------------------------------


def test_interpret_auto_resolves_per_platform():
    assert DeployConfig().interpret == "auto"
    with pytest.raises(ValueError):
        DeployConfig(interpret="yes")
    rng = np.random.default_rng(0)
    xb = rng.integers(0, 16, size=(64, 4))
    y = (xb.sum(1) > 30).astype(np.int64)
    ens = train_gbdt(xb, y, task="binary", n_bins=16,
                     params=GBDTParams(n_rounds=2, max_leaves=4))
    table = compile_ensemble(ens)
    eng = XTimeEngine.from_config(table, DeployConfig())
    # the suite pins JAX_PLATFORMS=cpu, so 'auto' must resolve to the
    # interpreter (False only ever happens on real TPU)
    assert eng.interpret is True
    assert XTimeEngine.from_config(
        table, DeployConfig(interpret=False)
    ).interpret is False


# -- shard_map packed equivalence (8 fake devices, subprocess) -----------------

_SHARD_CODE = """
import json
import numpy as np
from repro.api import build
from repro.core.deploy import DeployConfig
from repro.core.trees import GBDTParams, train_gbdt
from repro.launch.mesh import make_host_mesh

rng = np.random.default_rng(0)
xb = rng.integers(0, 256, size=(256, 12))
y = (xb[:, 0].astype(np.int64) + xb[:, 5] > 250).astype(np.int64)
ens = train_gbdt(xb, y, task="binary", n_bins=256,
                 params=GBDTParams(n_rounds=5, max_leaves=16))
cm = build(ens)
assert cm.table.table_dtype == "uint8"
ref = np.asarray(
    cm.engine(**{"table_dtype": "int32", "mode": "direct"}).raw_margin(xb)
)
mesh = make_host_mesh()
out = {}
for mode in ("direct", "inclusive", "msb_lsb", "two_cycle"):
    for td in ("auto", "int32"):
        eng = cm.engine(mesh=mesh, mode=mode, table_dtype=td)
        m = np.asarray(eng.raw_margin(xb))
        out[f"{mode}/{td}"] = {
            "spmd": eng.spmd,
            "dtype": eng.table_dtype,
            "bit_equal": bool(np.array_equal(m, ref)),
            "max_err": float(np.abs(m - ref).max()),
        }
print(json.dumps(out))
"""


def test_packed_paths_bit_equal_under_shard_map():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", _SHARD_CODE], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert results["direct/auto"]["dtype"] == "uint8"
    assert results["msb_lsb/auto"]["dtype"] == "int32"
    for key, res in results.items():
        assert res["spmd"] == "shard_map", (key, res)
        # psum reduction reordering allows <= 1 ULP vs single device; the
        # packed re-encoding itself must not add ANY error on top
        assert res["bit_equal"] or res["max_err"] < 1e-5, (key, res)
    # packed and int32 agree bitwise WITH EACH OTHER under shard_map
    for mode in ("direct", "inclusive"):
        a, b = results[f"{mode}/auto"], results[f"{mode}/int32"]
        assert a["max_err"] == b["max_err"], (mode, a, b)
