"""Serving loop: generation runs, greedy decode is deterministic, and the
decode path agrees with teacher-forced prefill on the generated tokens."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.llama32_3b import smoke
from repro.launch.serve import generate
from repro.models.registry import build_model


def _setup():
    cfg = smoke().replace(dtype="float32", remat=False)
    bundle = build_model(cfg, flash_blk=16)
    params = bundle.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    return cfg, bundle, params, prompts


def test_greedy_generation_deterministic():
    cfg, bundle, params, prompts = _setup()
    a = generate(bundle, params, prompts, max_new=8, temperature=0.0)
    b = generate(bundle, params, prompts, max_new=8, temperature=0.0)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 8)
    assert (a >= 0).all() and (a < cfg.vocab_size).all()


def test_greedy_matches_teacher_forced_prefill():
    """Replaying prompt+generated through prefill must reproduce the same
    greedy choices (KV-cache decode == full forward)."""
    cfg, bundle, params, prompts = _setup()
    gen = generate(bundle, params, prompts, max_new=4, temperature=0.0)
    full = jnp.concatenate([prompts, jnp.asarray(gen[:, :-1])], axis=1)
    logits, _ = jax.jit(bundle.prefill)(params, {"tokens": full})
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(logits, -1)), gen[:, -1]
    )


def test_temperature_sampling_runs():
    cfg, bundle, params, prompts = _setup()
    out = generate(bundle, params, prompts, max_new=4, temperature=1.0, seed=1)
    assert out.shape == (2, 4)
