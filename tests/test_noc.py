"""NoC router programs (§III-D) and their collective mapping."""

import numpy as np

from repro.core.compile import ChipSpec, compile_ensemble, pack_cores
from repro.core.noc import plan_noc
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import make_dataset


def _table(name, task, n_classes, rounds=4, leaves=32):
    ds = make_dataset(name)
    q = FeatureQuantizer.fit(ds.x_train, 256)
    xb = q.transform(ds.x_train)
    ens = train_gbdt(xb, ds.y_train, task=task, n_bins=256, n_classes=n_classes,
                     params=GBDTParams(n_rounds=rounds, max_leaves=leaves))
    return compile_ensemble(ens)


def test_regression_plan_is_full_accumulate():
    table = _table("rossmann", "regression", 1)
    plc = pack_cores(table)
    plan = plan_noc(table, plc, batching=False)
    assert plan.config == "accumulate"
    assert all(b == 1 for b in plan.router_bits)
    assert plan.flits_per_sample_per_level[-1] == 1.0
    assert plan.cp_ops_per_sample == 1
    assert plan.engine_noc_config == "accumulate"


def test_multiclass_plan_forwards_class_streams():
    table = _table("eye", "multiclass", 3)
    plc = pack_cores(table)
    plan = plan_noc(table, plc, batching=False)
    assert plan.config == "forward"
    # the root link carries one flit per class per sample -> the paper's
    # 1/N_classes samples-per-clock bound
    assert plan.flits_per_sample_per_level[-1] == float(table.n_outputs)
    assert plan.router_bits[-1] == 0
    assert plan.cp_ops_per_sample == table.n_outputs + 1


def test_batch_plan_replicates_below_boundary():
    table = _table("churn", "binary", 2)
    plc = pack_cores(table)
    assert plc.replication > 1  # small model, chip mostly free
    plan = plan_noc(table, plc, batching=True)
    assert plan.config == "batch"
    assert 1 in plan.router_bits and 0 in plan.router_bits
    assert plan.replication == plc.replication
    assert plan.engine_noc_config == "batch"


def test_htree_depth():
    table = _table("churn", "binary", 2)
    plc = pack_cores(table)
    plan = plan_noc(table, plc)
    assert plan.n_levels == int(round(np.log(4096) / np.log(4)))  # 6
    assert len(plan.router_bits) == plan.n_levels
