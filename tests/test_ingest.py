"""Ingestion frontend: golden-fixture bit-exactness across all cell
modes, malformed-dump error paths, threshold-grid mapping, and the
native -> XGBoost-JSON -> native round trip."""

import json
from pathlib import Path

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.api import CompiledModel, build
from repro.core.compile import compile_ensemble, validate_ensemble
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.ingest import (
    IngestError,
    detect_format,
    import_lightgbm_text,
    import_sklearn_dict,
    import_xgboost_json,
    load_model,
    lower_to_ensemble,
    to_xgboost_json,
)
from repro.serve import TableRegistry

FIXTURES = Path(__file__).parent / "fixtures" / "ingest"
DUMPS = sorted(
    p for p in FIXTURES.iterdir()
    if p.suffix in (".json", ".txt") and ".expected" not in p.name
    and p.name != "make_fixtures.py"
)
CELL_MODES = ("direct", "inclusive", "msb_lsb", "two_cycle")


def _expected(dump: Path) -> dict:
    exp = json.loads(
        (dump.with_name(dump.name.rsplit(".", 1)[0] + ".expected.json"))
        .read_text()
    )
    exp["x"] = np.asarray(exp["x"], dtype=np.float64)
    exp["raw_margin"] = np.asarray(exp["raw_margin"], dtype=np.float32)
    exp["predict"] = np.asarray(exp["predict"])
    return exp


def test_fixture_set_is_complete():
    """All three formats are represented in the golden set."""
    sources = {load_model(p).source for p in DUMPS}
    assert sources == {"xgboost-json", "lightgbm-text", "sklearn-dict"}
    assert len(DUMPS) >= 6


# -- golden fixtures: bit-exact through the whole stack ------------------------


@pytest.mark.parametrize("dump", DUMPS, ids=lambda p: p.name)
def test_golden_lowering_bit_exact(dump):
    """Float reference == binned lowering == recorded golden, bitwise."""
    exp = _expected(dump)
    imported = load_model(dump)
    ens, quant, report = lower_to_ensemble(imported)
    assert report.exact and report.remapped_splits == 0
    xb = quant.transform(exp["x"])
    margin = ens.raw_margin(xb)
    np.testing.assert_array_equal(margin, exp["raw_margin"])
    np.testing.assert_array_equal(margin, imported.raw_margin(exp["x"]))
    pred = ens.predict(xb)
    np.testing.assert_array_equal(
        np.asarray(pred, dtype=exp["predict"].dtype), exp["predict"]
    )


@pytest.mark.parametrize("mode", CELL_MODES)
@pytest.mark.parametrize("dump", DUMPS, ids=lambda p: p.name)
def test_golden_engine_all_cell_modes(dump, mode):
    """Engine predictions bit-identical to the record in every aCAM cell
    mode; margins within the engine's ~1 ULP accumulation contract."""
    exp = _expected(dump)
    cm = build(str(dump))
    xb = cm.quantizer.transform(exp["x"])
    eng = cm.engine(mode=mode)
    got_pred = np.asarray(eng.predict(xb))
    if cm.table.task == "regression":
        # regression "predictions" ARE the margins: engine tolerance
        np.testing.assert_allclose(got_pred, exp["predict"],
                                   rtol=1e-5, atol=1e-6)
    else:
        np.testing.assert_array_equal(
            np.asarray(got_pred, dtype=exp["predict"].dtype), exp["predict"]
        )
    np.testing.assert_allclose(
        np.asarray(eng.raw_margin(xb)), exp["raw_margin"],
        rtol=1e-5, atol=1e-6,
    )


@pytest.mark.parametrize("dump", DUMPS, ids=lambda p: p.name)
def test_golden_compressed_build_matches_record(dump):
    """``build(dump, compress='auto')`` serves the same answers as the
    frozen float record: the pass runs grid-aware (the artifact's own
    quantizer), so compression must be invisible on every golden fixture,
    not just the deep one it exists for."""
    exp = _expected(dump)
    cm = build(str(dump), compress="auto")
    assert cm.compression is not None
    assert cm.deploy.compress == "full"
    xb = cm.quantizer.transform(exp["x"])
    got_pred = np.asarray(cm.engine().predict(xb))
    if cm.table.task == "regression":
        np.testing.assert_allclose(got_pred, exp["predict"],
                                   rtol=1e-5, atol=1e-6)
    else:
        np.testing.assert_array_equal(
            np.asarray(got_pred, dtype=exp["predict"].dtype), exp["predict"]
        )
    np.testing.assert_allclose(
        np.asarray(cm.engine().raw_margin(xb)), exp["raw_margin"],
        rtol=1e-5, atol=1e-6,
    )


def test_deep_fixture_compresses_bit_exactly():
    """The deep duplicate-split fixture is the compression showcase:
    rows drop, and its k/16 leaves keep the engine margins bit-equal to
    the float record (exact float32 sums — no allclose escape hatch)."""
    dump = FIXTURES / "xgb_deep.json"
    exp = _expected(dump)
    cm = build(str(dump), compress="auto")
    rep = cm.compression
    assert rep["rows_saved"] > 0 and rep["rows_after"] < rep["rows_before"]
    # only 2 of 5 features ever split: collapse must fire as well
    assert rep["collapsed_columns"] >= 3
    xb = cm.quantizer.transform(exp["x"])
    np.testing.assert_array_equal(
        np.asarray(cm.engine().raw_margin(xb)), exp["raw_margin"]
    )
    # and against the uncompressed build of the same dump, bitwise
    cm0 = build(str(dump))
    np.testing.assert_array_equal(
        np.asarray(cm.engine().raw_margin(xb)),
        np.asarray(cm0.engine(table_dtype="int32").raw_margin(xb)),
    )


@pytest.mark.parametrize("dump", DUMPS[::3], ids=lambda p: p.name)
def test_golden_save_load_serve_cold_start(dump, tmp_path):
    """dump -> build -> save -> load -> TableRegistry, no recompilation."""
    exp = _expected(dump)
    cm = build(str(dump))
    cm.save(tmp_path / "art")
    loaded = CompiledModel.load(tmp_path / "art")
    assert loaded.ingest == cm.ingest
    assert loaded.ingest["exact"] is True
    assert [e.tolist() for e in loaded.quantizer.edges] == \
        [e.tolist() for e in cm.quantizer.edges]
    reg = TableRegistry()
    entry = reg.register("m", loaded)
    xb = loaded.quantizer.transform(exp["x"])
    got = np.asarray(entry.engine.predict(xb))
    np.testing.assert_array_equal(
        np.asarray(got, dtype=exp["predict"].dtype), exp["predict"]
    )


def test_sidecar_carries_grid_occupancy(tmp_path):
    cm = build(str(DUMPS[0]))
    cm.save(tmp_path / "a")
    sidecar = json.loads((tmp_path / "a.json").read_text())
    rep = sidecar["ingest"]
    assert rep["n_bins"] == 256
    assert len(rep["grid"]) == rep["n_features"]
    assert all(g["capacity"] == 255 for g in rep["grid"])
    assert sidecar["quantizer"]["n_bins"] == 256


# -- importer semantics --------------------------------------------------------


def test_xgboost_dart_weights_scale_leaves():
    doc = json.loads((FIXTURES / "xgb_dart_reg.json").read_text())
    weighted = import_xgboost_json(doc)
    doc["learner"]["gradient_booster"]["weight_drop"] = [1.0] * 4
    unweighted = import_xgboost_json(doc)
    x = _expected(FIXTURES / "xgb_dart_reg.json")["x"]
    assert weighted.source_kind == "dart"
    assert not np.array_equal(weighted.raw_margin(x), unweighted.raw_margin(x))


def test_xgboost_logistic_base_score_is_logit():
    doc = json.loads((FIXTURES / "xgb_binary.json").read_text())
    imported = import_xgboost_json(doc)
    assert imported.base_score[0] == pytest.approx(np.log(0.25 / 0.75))


def test_lightgbm_categorical_expansion_matches_membership():
    """The fixture's bitset {0,1,3,6,7} must route exactly."""
    imported = import_lightgbm_text(str(FIXTURES / "lgbm_binary.txt"))
    ens, quant, report = lower_to_ensemble(imported)
    member, nonmember = 0.45, -0.52  # tree 1 leaf values
    for cat, is_member in [(0, True), (1, True), (2, False), (3, True),
                           (4, False), (5, False), (6, True), (7, True),
                           (12, False)]:
        x = np.array([[10.0, 10.0, float(cat)]])  # tree 0 -> fixed leaf
        contrib = imported.raw_margin(x)[0, 0] - (-0.27)
        assert contrib == pytest.approx(member if is_member else nonmember), cat


def test_sklearn_rf_margins_are_mean_proba():
    doc = json.loads((FIXTURES / "sk_rf_cls.json").read_text())
    imported = import_sklearn_dict(doc)
    x = _expected(FIXTURES / "sk_rf_cls.json")["x"]
    m = imported.raw_margin(x)
    np.testing.assert_allclose(m.sum(axis=1), 1.0, rtol=1e-5)  # mean proba


def test_per_class_base_scores_become_bias_rows():
    doc = json.loads((FIXTURES / "sk_gbdt_reg.json").read_text())
    doc["task"] = "multiclass"
    doc["n_classes"] = 2
    doc["init"] = [0.75, -1.5]
    doc["trees"] = [dict(t, **{"class": i % 2})
                    for i, t in enumerate(doc["trees"][:4])]
    ens, quant, report = lower_to_ensemble(import_sklearn_dict(doc))
    assert report.bias_rows == 2
    table = compile_ensemble(ens)
    # bias rows are all-wildcard: they match every query
    assert table.n_rows == ens.total_leaves
    xb = quant.transform(np.zeros((1, 5)))
    m = ens.raw_margin(xb)
    imported = import_sklearn_dict(doc)
    np.testing.assert_array_equal(m, imported.raw_margin(np.zeros((1, 5))))


# -- threshold-grid mapping ----------------------------------------------------


def test_from_thresholds_exact_occupancy():
    q, merged = FeatureQuantizer.from_thresholds(
        [np.array([0.5, 1.5, 2.5]), np.array([])], n_bins=256
    )
    assert merged == [0, 0]
    assert q.effective_bins(0) == 4
    assert q.bin_of_threshold(0, 1.5) == (2, True)
    # binned split semantics: bin < 2  <=>  x < 1.5
    xb = q.transform(np.array([[1.4999, 0.0], [1.5, 0.0]]))
    assert xb[0, 0] < 2 <= xb[1, 0]


def test_from_thresholds_overflow_merge_and_raise():
    dense = [np.arange(40, dtype=np.float64)]
    with pytest.raises(ValueError, match="exceed"):
        FeatureQuantizer.from_thresholds(dense, n_bins=16, on_overflow="raise")
    q, merged = FeatureQuantizer.from_thresholds(dense, n_bins=16)
    assert merged == [40 - 15]
    assert q.edges[0].shape[0] == 15
    dropped = sorted(set(np.arange(40.0)) - set(q.edges[0]))[0]
    t, exact = q.bin_of_threshold(0, float(dropped))
    assert not exact and 1 <= t <= 15


def test_overflow_lowering_reports_inexact():
    rng = np.random.default_rng(0)
    xb = rng.integers(0, 64, size=(400, 3)).astype(np.uint8)
    y = rng.normal(size=400)
    ens = train_gbdt(xb, y, task="regression", n_bins=64,
                     params=GBDTParams(n_rounds=6, max_leaves=32))
    imported = import_xgboost_json(to_xgboost_json(ens))
    with pytest.raises(IngestError, match="exceed"):
        lower_to_ensemble(imported, n_bins=8, on_overflow="raise")
    low, q, report = lower_to_ensemble(imported, n_bins=8)
    assert not report.exact and report.remapped_splits > 0
    assert report.merged_thresholds == sum(g["merged"] for g in report.grid)
    # still structurally valid and servable
    validate_ensemble(low)
    assert compile_ensemble(low).n_rows == low.total_leaves


# -- malformed dumps -----------------------------------------------------------


def test_malformed_xgboost_paths():
    with pytest.raises(IngestError, match="learner"):
        import_xgboost_json({"not": "a model"})
    with pytest.raises(IngestError, match="valid JSON"):
        import_xgboost_json("{broken")
    doc = json.loads((FIXTURES / "xgb_binary.json").read_text())
    doc["learner"]["objective"]["name"] = "rank:pairwise"
    with pytest.raises(IngestError, match="rank:pairwise"):
        import_xgboost_json(doc)
    doc = json.loads((FIXTURES / "xgb_binary.json").read_text())
    trees = doc["learner"]["gradient_booster"]["model"]["trees"]
    trees[0]["split_type"] = [1] * len(trees[0]["split_type"])
    with pytest.raises(IngestError, match="categorical"):
        import_xgboost_json(doc)
    doc = json.loads((FIXTURES / "xgb_binary.json").read_text())
    doc["learner"]["gradient_booster"]["model"]["trees"][0]["left_children"] = [999]
    with pytest.raises(IngestError):
        import_xgboost_json(doc)


def test_malformed_lightgbm_paths():
    good = (FIXTURES / "lgbm_binary.txt").read_text()
    with pytest.raises(IngestError, match="magic"):
        import_lightgbm_text("not a model\n")
    with pytest.raises(IngestError, match="truncated"):
        import_lightgbm_text(good.split("end of trees")[0])
    with pytest.raises(IngestError, match="objective"):
        import_lightgbm_text(good.replace("objective=binary sigmoid:1",
                                          "objective=lambdarank"))
    with pytest.raises(IngestError, match="length"):
        import_lightgbm_text(good.replace("split_feature=0 1",
                                          "split_feature=0"))


def test_malformed_sklearn_paths():
    good = json.loads((FIXTURES / "sk_rf_cls.json").read_text())
    with pytest.raises(IngestError, match="format"):
        import_sklearn_dict({"format": "pickle"})
    bad = dict(good, kind="extra-trees")
    with pytest.raises(IngestError, match="kind"):
        import_sklearn_dict(bad)
    bad = json.loads(json.dumps(good))
    bad["trees"][0].pop("children_left")
    with pytest.raises(IngestError, match="children_left"):
        import_sklearn_dict(bad)
    bad = json.loads(json.dumps(good))
    bad["trees"][0]["value"] = [[1.0]] * len(bad["trees"][0]["feature"])
    with pytest.raises(IngestError, match="class counts"):
        import_sklearn_dict(bad)


def test_detect_format_and_load_model(tmp_path):
    assert detect_format(FIXTURES / "xgb_binary.json") == "xgboost-json"
    assert detect_format(FIXTURES / "lgbm_binary.txt") == "lightgbm-text"
    assert detect_format(FIXTURES / "sk_rf_cls.json") == "sklearn-dict"
    # content decides, not the extension: a JSON booster saved as .txt
    mislabeled = tmp_path / "model.txt"
    mislabeled.write_text((FIXTURES / "xgb_binary.json").read_text())
    assert detect_format(mislabeled) == "xgboost-json"
    assert load_model(mislabeled).source == "xgboost-json"
    stray = tmp_path / "model.json"
    stray.write_text('{"weights": [1, 2]}')
    with pytest.raises(IngestError, match="neither"):
        load_model(stray)
    with pytest.raises(IngestError, match="not found"):
        load_model(tmp_path / "nope.json")
    with pytest.raises(IngestError, match="unknown format"):
        load_model(stray, format="onnx")


def test_build_rejects_junk_still():
    with pytest.raises(TypeError, match="build"):
        build(np.zeros(3))


# -- round trip: native GBDT -> XGBoost JSON -> re-ingest ----------------------


@settings(max_examples=8, deadline=None)
@given(
    n_rounds=st.integers(min_value=1, max_value=4),
    max_leaves=st.integers(min_value=2, max_value=16),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_roundtrip_native_to_xgboost_json(n_rounds, max_leaves, seed):
    """train native -> export to the XGBoost schema -> re-ingest ->
    bit-equal margins and predictions on binned inputs."""
    rng = np.random.default_rng(seed)
    n, F, B = 200, 4, 32
    x = rng.normal(size=(n, F))
    y = (x[:, 0] + 0.5 * x[:, 1] > 0).astype(np.int64)
    q = FeatureQuantizer.fit(x, B)
    xb = q.transform(x)
    ens = train_gbdt(xb, y, task="binary", n_bins=B,
                     params=GBDTParams(n_rounds=n_rounds,
                                       max_leaves=max_leaves, seed=seed))
    imported = import_xgboost_json(to_xgboost_json(ens, q))
    low, q2, report = lower_to_ensemble(imported, n_bins=B)
    assert report.exact
    np.testing.assert_array_equal(
        low.raw_margin(q2.transform(x)), ens.raw_margin(xb)
    )
    np.testing.assert_array_equal(
        low.predict(q2.transform(x)), ens.predict(xb)
    )
