"""CAM compiler invariants.

The load-bearing property: for any tree and any query, EXACTLY ONE CAM row
of that tree matches (the leaves partition bin space).  This is what makes
``match @ leaf_matrix`` equal to leaf lookup.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.compile import ChipSpec, compile_ensemble, pack_cores, padded_table
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import make_dataset


def _match_matrix(table, q):
    lo = table.low[None, :, :]
    hi = table.high[None, :, :]
    qe = q[:, None, :]
    return ((lo <= qe) & (qe < hi)).all(axis=-1)  # (B, R)


@pytest.fixture(scope="module")
def small_ensemble():
    ds = make_dataset("eye")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    xb = q.transform(ds.x_train)
    ens = train_gbdt(xb, ds.y_train, task="multiclass", n_bins=256,
                     n_classes=ds.n_classes,
                     params=GBDTParams(n_rounds=4, max_leaves=32))
    return ens, xb


def test_row_count_equals_total_leaves(small_ensemble):
    ens, _ = small_ensemble
    table = compile_ensemble(ens)
    assert table.n_rows == ens.total_leaves


def test_exactly_one_match_per_tree(small_ensemble):
    ens, xb = small_ensemble
    table = compile_ensemble(ens)
    q = xb[:200].astype(np.int32)
    match = _match_matrix(table, q)
    for i in range(ens.n_trees):
        rows = table.tree_id == i
        counts = match[:, rows].sum(axis=1)
        np.testing.assert_array_equal(counts, 1)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_exactly_one_match_random_queries(small_ensemble, seed):
    """Property: holds for ARBITRARY bin vectors, not just dataset rows."""
    ens, _ = small_ensemble
    table = compile_ensemble(ens)
    rng = np.random.default_rng(seed)
    q = rng.integers(0, 256, size=(16, table.n_features)).astype(np.int32)
    match = _match_matrix(table, q)
    for i in range(min(8, ens.n_trees)):
        counts = match[:, table.tree_id == i].sum(axis=1)
        np.testing.assert_array_equal(counts, 1)


def test_dont_care_fraction_positive(small_ensemble):
    ens, _ = small_ensemble
    table = compile_ensemble(ens)
    # shallow trees over 26 features touch few features per path
    assert table.dont_care_fraction() > 0.5


def test_pack_cores_capacity(small_ensemble):
    ens, _ = small_ensemble
    table = compile_ensemble(ens)
    plc = pack_cores(table)
    spec = plc.spec
    leaves = np.bincount(table.tree_id, minlength=table.n_trees)
    for trees, used in zip(plc.core_trees, plc.core_rows_used):
        assert sum(int(leaves[t]) for t in trees) == used <= spec.n_words
    placed = sorted(t for core in plc.core_trees for t in core)
    assert placed == list(range(table.n_trees))
    assert plc.replication >= 1
    assert plc.n_feature_segments == int(np.ceil(table.n_features / spec.array_cols))


def test_pack_cores_rejects_oversized_tree(small_ensemble):
    ens, _ = small_ensemble
    table = compile_ensemble(ens)
    with pytest.raises(ValueError):
        pack_cores(table, ChipSpec(array_rows=4, n_stacked=2))


def test_padded_rows_never_match(small_ensemble):
    ens, _ = small_ensemble
    table = compile_ensemble(ens)
    low, high, leaf_m, r_pad = padded_table(table, row_multiple=256)
    assert r_pad % 256 == 0
    rng = np.random.default_rng(0)
    q = rng.integers(0, 256, size=(8, table.n_features)).astype(np.int32)
    pad_match = (
        (low[None, table.n_rows:] <= q[:, None]) & (q[:, None] < high[None, table.n_rows:])
    ).all(-1)
    assert not pad_match.any()
    np.testing.assert_array_equal(leaf_m[table.n_rows:], 0.0)
