"""Soft-boundary cell mode (DESIGN.md §15): per-cell sigmoid match
scores with temperature tau, aggregated in log space, behind the
first-class ``CellMode`` registry.

The correctness contract has two halves:

  * tau=0 is the EXACT hard limit — margins and predictions are
    BIT-EQUAL to mode='direct' on both backends (the half-integer bound
    offsets guarantee no integer bin ever lands on a boundary, and the
    margin path multiplies the same plain leaf matrix in the same float
    order);
  * finite tau passes the shared differential-oracle gate
    (tests/oracles.py): pallas vs the jnp soft reference within 1 ULP.

Plus the uncertainty channel (score-weighted leaf spread via the
moments pass), the probability surface (``CompiledModel.predict_proba``),
and the registry-driven error surfaces.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from oracles import assert_bit_equal_to_oracle, env_interpret, random_cam_table

import jax.numpy as jnp

from repro.api import CompiledModel, build
from repro.core.deploy import (
    FAITHFUL_MODES,
    MODES,
    PACKABLE_MODES,
    DeployConfig,
)
from repro.core.engine import XTimeEngine
from repro.core.precision import (
    CELL_MODES,
    encode_soft_bounds,
    get_cell_mode,
    mode_names,
    soft_cell_logscore,
)
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt

SRC = str(Path(__file__).resolve().parent.parent / "src")


# -- registry (the CellMode API) ----------------------------------------------


def test_registry_names_and_derived_tuples():
    assert set(mode_names()) == {
        "direct", "inclusive", "msb_lsb", "two_cycle", "soft",
    }
    assert MODES == mode_names()
    assert set(FAITHFUL_MODES) == {"msb_lsb", "two_cycle"}
    assert set(PACKABLE_MODES) == {"direct", "inclusive"}
    soft = get_cell_mode("soft")
    assert soft.soft and not soft.packable and not soft.faithful
    assert soft.table_dtype_policy == "float32"
    for name in FAITHFUL_MODES:
        assert CELL_MODES[name].table_dtype_policy == "int32"


def test_unknown_mode_error_lists_registry():
    with pytest.raises(ValueError, match="soft"):
        get_cell_mode("fuzzy")
    with pytest.raises(ValueError, match="two_cycle"):
        DeployConfig(mode="fuzzy")


def test_deploy_validation():
    with pytest.raises(ValueError, match="float32"):
        DeployConfig(mode="soft", table_dtype="uint8")
    with pytest.raises(ValueError, match="soft"):
        DeployConfig(mode="direct", table_dtype="float32")
    with pytest.raises(ValueError, match="tau"):
        DeployConfig(mode="soft", tau=-0.1)
    with pytest.raises(ValueError, match="tau"):
        DeployConfig(mode="soft", tau=float("inf"))
    # tau=0 (the exact hard limit) is a valid temperature
    DeployConfig(mode="soft", tau=0.0)


# -- tau=0 bit-equality and the finite-tau oracle gate ------------------------


def _queries(rng, table, b=64):
    q = rng.integers(0, table.n_bins, size=(b, table.n_features))
    q = q.astype(np.int32)
    q[:4] = 0
    q[4:8] = table.n_bins - 1  # dtype-boundary bins
    return q


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_tau_zero_bit_equal_to_direct(backend):
    rng = np.random.default_rng(0)
    table = random_cam_table(rng, r=96, f=12, n_bins=256, n_outputs=3)
    q = _queries(rng, table)
    kw = dict(backend=backend, interpret=env_interpret())
    hard = XTimeEngine.from_config(table, DeployConfig(mode="direct", **kw))
    soft = XTimeEngine.from_config(
        table, DeployConfig(mode="soft", tau=0.0, **kw)
    )
    np.testing.assert_array_equal(
        np.asarray(soft.raw_margin(q)), np.asarray(hard.raw_margin(q))
    )
    np.testing.assert_array_equal(
        np.asarray(soft.predict(q)), np.asarray(hard.predict(q))
    )


@pytest.mark.parametrize("mode", MODES)
def test_oracle_harness_all_modes(mode):
    """The CI cell-modes job's workload: every registered mode through
    the shared differential-oracle gate on the pallas backend."""
    rng = np.random.default_rng(1)
    table = random_cam_table(rng, r=96, f=12, n_bins=256, n_outputs=2)
    q = _queries(rng, table)
    cfg = DeployConfig(
        backend="pallas", mode=mode, interpret=env_interpret(),
        tau=0.25 if mode == "soft" else 0.0,
    )
    assert_bit_equal_to_oracle(table, q, cfg)


def test_soft_scores_finite_and_bounded():
    """No NaN/positive log-score anywhere: wildcards are exactly 0,
    never-match cells exactly -inf, everything else strictly between."""
    rng = np.random.default_rng(2)
    table = random_cam_table(rng, r=64, f=10, n_bins=256)
    lo, hi = encode_soft_bounds(table.low, table.high, table.n_bins)
    q = rng.integers(0, 256, size=(16, 10)).astype(np.float32)
    for tau in (0.0, 0.1, 1.0):
        logs = np.asarray(
            soft_cell_logscore(
                jnp.asarray(q)[:, None, :], jnp.asarray(lo)[None],
                jnp.asarray(hi)[None], tau,
            )
        )
        assert not np.isnan(logs).any()
        assert (logs <= 0.0).all()
    # wildcard cells score exactly 1 (log 0) at every temperature — the
    # invariant that keeps tile skipping and column clustering valid
    wild = (table.low <= 0) & (table.high >= table.n_bins)
    assert wild.any()
    logs = np.asarray(
        soft_cell_logscore(
            jnp.asarray(q)[:1, None, :], jnp.asarray(lo)[None],
            jnp.asarray(hi)[None], 0.5,
        )
    )[0]
    assert (logs[wild] == 0.0).all()


@settings(max_examples=25, deadline=None)
@given(
    low=st.integers(min_value=0, max_value=250),
    width=st.integers(min_value=1, max_value=255),
    q=st.integers(min_value=0, max_value=255),
)
def test_soft_score_monotone_in_tau(low, width, q):
    """Shrinking tau moves every cell score monotonically toward the
    hard 0/1 indicator (for tau <= 0.5 bin units — the supported
    smoothing regime), so tau is a true sharpness dial."""
    high = min(low + width, 256)
    lo, hi = encode_soft_bounds(
        np.array([[low]]), np.array([[high]]), 256
    )
    hard = 1.0 if low <= q < high else 0.0
    dists = []
    for tau in (0.05, 0.1, 0.2, 0.35, 0.5):
        s = float(
            np.exp(
                np.asarray(
                    soft_cell_logscore(
                        jnp.asarray([[float(q)]]), jnp.asarray(lo),
                        jnp.asarray(hi), tau,
                    )
                )
            )[0, 0]
        )
        dists.append(abs(s - hard))
    assert all(b >= a - 1e-6 for a, b in zip(dists, dists[1:])), dists


# -- uncertainty channel -------------------------------------------------------


def _trained_model(task="binary", tau=0.25, n=400, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 6)).astype(np.float32)
    if task == "binary":
        y = (x[:, 0] + 0.5 * x[:, 1] + 0.3 * rng.normal(size=n) > 0)
        y = y.astype(np.int32)
        n_classes = 1
    else:
        y = ((x[:, 0] > 0).astype(np.int32) + (x[:, 1] > 0.5)).astype(np.int32)
        n_classes = 3
        task = "multiclass"
    quant = FeatureQuantizer.fit(x, 256)
    ens = train_gbdt(
        quant.transform(x), y, task=task, n_bins=256, n_classes=n_classes,
        params=GBDTParams(n_rounds=8, max_depth=3),
    )
    cm = build(ens, quantizer=quant, deploy=DeployConfig(mode="soft", tau=tau))
    return cm, x, y


def test_uncertainty_shape_and_tau_zero_semantics():
    cm, x, _ = _trained_model()
    eng = cm.engine()
    q = cm.quantizer.transform(x)
    u = np.asarray(eng.uncertainty(q))
    assert u.shape == (x.shape[0], cm.table.n_outputs)
    assert np.isfinite(u).all() and (u >= 0).all()
    # tau=0: every weight is 0/1, the mass per channel is the tree count
    # routed there, and the spread is the honest across-tree disagreement
    eng0 = cm.engine(tau=0.0)
    m = np.asarray(eng0.raw_moments(q))
    C = cm.table.n_outputs
    mass = m[:, 2 * C :]
    assert np.allclose(mass.sum(axis=1), cm.table.n_trees)


def test_hard_engines_raise_clear_errors():
    cm, x, _ = _trained_model()
    hard = cm.engine(mode="direct")
    with pytest.raises(ValueError, match="soft"):
        hard.uncertainty(cm.quantizer.transform(x))
    with pytest.raises(ValueError, match="cell_mode='soft'"):
        cm.predict_proba(x, mode="direct")
    with pytest.raises(ValueError, match="cell_mode='soft'"):
        cm.predict(x, return_uncertainty=True, mode="direct")


def test_predict_proba_and_calibration_sanity():
    cm, x, y = _trained_model()
    p = cm.predict_proba(x)
    assert p.shape == (x.shape[0], 2)
    np.testing.assert_allclose(p.sum(axis=1), 1.0, rtol=1e-5)
    assert (p >= 0).all() and (p <= 1).all()
    # calibration-bin sanity: confident predictions must be MORE accurate
    # than unconfident ones (coarse two-bin check — monotone reliability)
    conf = p.max(axis=1)
    pred = p.argmax(axis=1)
    order = np.argsort(conf)
    half = len(order) // 2
    acc_lo = float((pred[order[:half]] == y[order[:half]]).mean())
    acc_hi = float((pred[order[half:]] == y[order[half:]]).mean())
    assert acc_hi >= acc_lo - 1e-9, (acc_lo, acc_hi)


def test_predict_uncertainty_and_proba_roundtrip(tmp_path):
    cm, x, _ = _trained_model(task="multiclass")
    p = cm.predict_proba(x)
    pred, unc = cm.predict(x, return_uncertainty=True)
    assert p.shape == (x.shape[0], 3) and unc.shape == (x.shape[0],)
    cm.save(tmp_path / "soft")
    loaded = CompiledModel.load(tmp_path / "soft")
    assert loaded.deploy.mode == "soft"
    assert loaded.deploy.tau == cm.deploy.tau  # sidecar records mode + tau
    np.testing.assert_array_equal(loaded.predict_proba(x), p)
    pred2, unc2 = loaded.predict(x, return_uncertainty=True)
    np.testing.assert_array_equal(pred2, pred)
    np.testing.assert_array_equal(unc2, unc)


# -- autotune integration ------------------------------------------------------


def test_autotune_respects_soft_pinning():
    from repro.core.tune import autotune_kernel, kernel_version

    assert kernel_version("float32") == "soft"
    assert kernel_version("int32") == "v1"
    assert kernel_version("uint8") == "v2"

    rng = np.random.default_rng(3)
    table = random_cam_table(rng, r=64, f=8, n_bins=256)
    plan = autotune_kernel(
        table, deploy=DeployConfig(mode="soft", tau=0.1), batch=32,
        b_blks=(32,), r_blks=(32, 64), warmup=0, iters=1,
    )
    assert plan.mode == "soft"
    assert plan.table_dtype == "float32"
    assert plan.kernel == "soft"
    assert all(t["mode"] == "soft" for t in plan.trials)


# -- scale-out -----------------------------------------------------------------


_SHARD_CODE = """
import json
import numpy as np
import jax
from jax.sharding import Mesh
from oracles import random_cam_table
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine

rng = np.random.default_rng(7)
table = random_cam_table(rng, r=128, f=10, n_bins=256, n_outputs=2)
q = rng.integers(0, 256, size=(64, 10)).astype(np.int32)

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("model", "data"))
cfg = DeployConfig(mode="soft", tau=0.5, spmd="shard_map")
m_mesh = np.asarray(
    XTimeEngine.from_config(table, cfg, mesh=mesh).raw_margin(q)
)
m_one = np.asarray(
    XTimeEngine.from_config(table, DeployConfig(mode="soft", tau=0.5))
    .raw_margin(q)
)
cfg0 = DeployConfig(mode="soft", tau=0.0, spmd="shard_map")
m0 = np.asarray(XTimeEngine.from_config(table, cfg0, mesh=mesh).raw_margin(q))
mh = np.asarray(
    XTimeEngine.from_config(
        table, DeployConfig(mode="direct", spmd="shard_map"), mesh=mesh
    ).raw_margin(q)
)
u_mesh = np.asarray(XTimeEngine.from_config(table, cfg, mesh=mesh).uncertainty(q))
u_one = np.asarray(
    XTimeEngine.from_config(table, DeployConfig(mode="soft", tau=0.5))
    .uncertainty(q)
)
print(json.dumps({
    "finite_tau_max_err": float(np.abs(m_mesh - m_one).max()),
    "tau0_bit_equal_direct": bool(np.array_equal(m0, mh)),
    "uncertainty_max_err": float(np.abs(u_mesh - u_one).max()),
}))
"""


def test_soft_mode_under_shard_map():
    """Soft margins + the moments pass ride the same NoC collectives:
    on 8 fake devices the row-sharded psum must reproduce the
    single-device result, and tau=0 stays bit-equal to 'direct'."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + str(Path(__file__).parent)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", _SHARD_CODE], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["tau0_bit_equal_direct"], res
    assert res["finite_tau_max_err"] <= 1e-5, res
    assert res["uncertainty_max_err"] <= 1e-5, res
