"""Chip performance model vs the paper's reported numbers (§III-C, §V)."""

import numpy as np
import pytest

from repro.core.compile import ChipSpec, compile_ensemble, pack_cores
from repro.core.noc import plan_noc
from repro.core.perfmodel import (
    GPUSpec,
    PowerAreaSpec,
    booster_perf,
    core_throughput_msps,
    gpu_perf_model,
    xtime_perf,
)
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import make_dataset


def test_eq4_core_throughput_250msps():
    tau = core_throughput_msps(1, ChipSpec())
    assert abs(tau - 250.0) < 1.0  # Eq. 4


def test_eq5_core_throughput_200msps():
    tau = core_throughput_msps(5, ChipSpec())
    assert abs(tau - 200.0) < 1.0  # Eq. 5 with N_trees,core = 5


def test_peak_power_19w():
    p = PowerAreaSpec().chip_power_w(ChipSpec())
    assert abs(p - 19.0) < 0.5  # Fig. 8 total


@pytest.fixture(scope="module")
def churn_model():
    ds = make_dataset("churn")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    xb = q.transform(ds.x_train)
    ens = train_gbdt(xb, ds.y_train, task="binary", n_bins=256,
                     params=GBDTParams(n_rounds=50, max_leaves=256, max_depth=8))
    table = compile_ensemble(ens)
    plc = pack_cores(table)
    return table, plc


def test_latency_near_100ns(churn_model):
    table, plc = churn_model
    rep = xtime_perf(table, plc, plan_noc(table, plc))
    assert 50 < rep.latency_ns < 200  # "frequently ~100 ns" (§V-B)


def test_headline_ratios_vs_gpu():
    """The paper's Churn headline: 9740x latency, 119x throughput vs V100
    (404-tree CatBoost, 256 leaves).  The X-TIME side uses the paper's own
    placement math: 404 cores at 1 tree/core -> replication 10 -> 2.5 GS/s."""
    gpu = gpu_perf_model(n_trees=404, depth=8)
    xtime_tput = 250.0 * (4096 // 404)  # MS/s
    lat_ratio = gpu.latency_ns / 100.0
    tput_ratio = xtime_tput / gpu.throughput_msps
    assert 0.7 < lat_ratio / 9740.0 < 1.3
    assert 0.7 < tput_ratio / 119.0 < 1.3


def test_gpu_model_latency_in_measured_range():
    # §IV-C: measured 10 us .. ~ms across Table II models
    small = gpu_perf_model(n_trees=159, depth=2)
    large = gpu_perf_model(n_trees=2352, depth=8)
    assert 1e4 < small.latency_ns < 1e6
    assert 1e5 < large.latency_ns < 1e7


def test_booster_is_slower_than_xtime_in_throughput(churn_model):
    """§V-B: Booster core is O(D) per sample -> ~8x lower throughput for
    depth-8 trees; latency gap is moderate."""
    table, plc = churn_model
    noc = plan_noc(table, plc)
    xt = xtime_perf(table, plc, noc)
    bo = booster_perf(table, plc, noc, depth=8)
    assert xt.throughput_msps / bo.throughput_msps > 4
    assert bo.latency_ns > xt.latency_ns


def test_throughput_flat_in_trees_for_xtime(churn_model):
    """Fig. 11(a): X-TIME throughput is constant in N_trees (until the
    chip fills and replication drops)."""
    table, plc = churn_model
    noc = plan_noc(table, plc, batching=False)
    rep = xtime_perf(table, plc, noc)
    tau_unbatched = rep.throughput_msps
    assert abs(tau_unbatched - 250.0) < 10  # one tree per core pipeline


def test_gpu_throughput_linear_decay_in_trees_and_depth():
    t1 = gpu_perf_model(n_trees=100, depth=8).throughput_msps
    t2 = gpu_perf_model(n_trees=200, depth=8).throughput_msps
    t3 = gpu_perf_model(n_trees=100, depth=4).throughput_msps
    assert 1.7 < t1 / t2 < 2.3
    assert 1.7 < t3 / t1 < 2.3


def test_energy_sub_nanojoule_for_batched_small_model():
    """'down to 0.3 nJ/decision' (§V-A) — telco-like models (few tiny
    trees, massive replication)."""
    ds = make_dataset("telco")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    xb = q.transform(ds.x_train)
    ens = train_gbdt(xb, ds.y_train, task="binary", n_bins=256,
                     params=GBDTParams(n_rounds=159, max_leaves=4, max_depth=2))
    table = compile_ensemble(ens)
    plc = pack_cores(table)
    rep = xtime_perf(table, plc, plan_noc(table, plc))
    assert rep.energy_nj_per_dec < 2.0
    assert rep.throughput_msps > 5_000
