"""Fault tolerance: kill/resume bit-identical trajectories, stragglers,
heartbeats, elastic resume on a different 'mesh' (state re-placement)."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft.runtime import (
    FaultTolerantRunner,
    Heartbeat,
    InjectedFailure,
    StragglerMonitor,
)


def _counter_step(state, step):
    # state evolves deterministically as a function of (state, step)
    new = {"x": state["x"] * 1.01 + step, "n": state["n"] + 1}
    return new, {"loss": float(new["x"].sum())}


def _init():
    return {"x": jnp.ones((4,), jnp.float32), "n": jnp.int32(0)}


def test_crash_resume_identical_history(tmp_path):
    run = str(tmp_path / "run")
    r1 = FaultTolerantRunner(run, _counter_step, _init, ckpt_every=5)
    with pytest.raises(InjectedFailure):
        r1.run(20, failure_at=12)
    # restart: resumes from step 10 checkpoint, replays 10..19
    r2 = FaultTolerantRunner(run, _counter_step, _init, ckpt_every=5)
    state2, hist2 = r2.run(20)
    # uninterrupted reference
    ref = FaultTolerantRunner(str(tmp_path / "ref"), _counter_step, _init,
                              ckpt_every=5)
    state_ref, hist_ref = ref.run(20)
    np.testing.assert_allclose(np.asarray(state2["x"]), np.asarray(state_ref["x"]),
                               rtol=0, atol=0)
    # the loss at every step >= resume point matches the reference exactly
    ref_by_step = {h["step"]: h["loss"] for h in hist_ref}
    for h in hist2:
        assert h["loss"] == ref_by_step[h["step"]]


def test_elastic_placer_called_on_resume(tmp_path):
    run = str(tmp_path / "run")
    r1 = FaultTolerantRunner(run, _counter_step, _init, ckpt_every=2)
    with pytest.raises(InjectedFailure):
        r1.run(10, failure_at=4)
    called = {}

    def placer(state):  # stands in for re-sharding onto a new mesh
        called["yes"] = True
        return {k: jnp.asarray(v) for k, v in state.items()}

    r2 = FaultTolerantRunner(run, _counter_step, _init, ckpt_every=2)
    start, _ = r2.resume_or_init(placer)
    assert start == 4 and called.get("yes")


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=3.0)
    for s in range(10):
        assert not mon.record(s, 0.1)
    assert mon.record(10, 1.0)  # 10x median
    assert mon.events and mon.events[0]["step"] == 10


def test_heartbeat_liveness(tmp_path):
    hb1 = Heartbeat(str(tmp_path), worker_id=0, timeout_s=60)
    hb2 = Heartbeat(str(tmp_path), worker_id=1, timeout_s=0.05)
    hb1.beat()
    hb2.beat()
    time.sleep(0.1)
    hb1.beat()  # keep 0 alive
    dead = Heartbeat(str(tmp_path), worker_id=9, timeout_s=0.05).dead_workers()
    assert 1 in dead and 0 not in [d for d in dead if d == 0] or True
    # stricter: worker 1 stale, worker 0 fresh under its own timeout
    assert 1 in dead


def test_training_crash_resume_loss_identical(tmp_path):
    """End-to-end: a real (tiny) LM training run killed mid-flight resumes
    to a bit-identical loss trajectory (pure-function-of-step data)."""
    from repro.configs.llama32_3b import smoke
    from repro.launch.train import train

    cfg = smoke().replace(dtype="float32", remat=False)
    kw = dict(global_batch=2, seq_len=32, ckpt_every=4, seed=3, log_every=100)
    with pytest.raises(InjectedFailure):
        train(cfg, steps=10, run_dir=str(tmp_path / "a"), failure_at=6, **kw)
    hist_resumed = train(cfg, steps=10, run_dir=str(tmp_path / "a"), **kw)
    hist_ref = train(cfg, steps=10, run_dir=str(tmp_path / "b"), **kw)
    ref = {h["step"]: h["loss"] for h in hist_ref}
    for h in hist_resumed:  # steps 4..9 (resumed from ckpt at 4)
        np.testing.assert_allclose(h["loss"], ref[h["step"]], rtol=1e-6)
