"""Generator for the checked-in golden ingestion fixtures.

Run once (``PYTHONPATH=src python tests/fixtures/ingest/make_fixtures.py``)
to (re)emit every dump + its ``*.expected.json`` reference.  The outputs
are FROZEN in git — tests and the CI ``ingest-golden`` job read the
files, never this generator — so regenerating after a semantics change
is a reviewable diff, not a silent re-record.

Each fixture is a small hand-shaped model (deterministic rng) written in
the target library's serialization format by hand — the source libraries
are not installed in this repo, which is the point: the parsers must
understand the *format*, not the library.  The expected ``raw_margin`` /
``predict`` are recorded from the lowered ``Ensemble`` (pure numpy,
float64 accumulation — deterministic on every host); engine margins are
asserted close to and predictions bit-equal against the same record.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.ingest import load_model, lower_to_ensemble

HERE = Path(__file__).resolve().parent
N_QUERIES = 32


def _rand_tree(rng: np.random.Generator, n_features: int, n_nodes: int,
               value_scale: float = 1.0) -> dict:
    """Random well-formed tree in xgboost array layout (strict-< splits).

    Nodes are allocated breadth-first: node j splits while the frontier
    still has room, guaranteeing parents precede children.
    """
    assert n_nodes % 2 == 1, "binary trees have odd node counts"
    feature = np.full(n_nodes, -1, dtype=np.int64)
    threshold = np.zeros(n_nodes)
    left = np.full(n_nodes, -1, dtype=np.int64)
    right = np.full(n_nodes, -1, dtype=np.int64)
    value = np.zeros(n_nodes)
    next_free = 1
    for j in range(n_nodes):
        if next_free + 1 < n_nodes + 1 and next_free + 2 <= n_nodes:
            feature[j] = rng.integers(0, n_features)
            # quarter-grid thresholds: varied but exactly representable
            threshold[j] = float(rng.integers(-8, 9)) / 4.0
            left[j] = next_free
            right[j] = next_free + 1
            next_free += 2
        else:
            value[j] = round(float(rng.normal()) * value_scale, 3)
    return {
        "feature": feature, "threshold": threshold,
        "left": left, "right": right, "value": value,
    }


def _deep_dup_tree(rng: np.random.Generator, n_features: int, depth: int) -> dict:
    """Complete depth-``depth`` tree that re-splits a 2-feature subset with
    quarter-grid thresholds — duplicate (often contradictory) splits on
    every path — and k/16 leaf values, whose float32 sums are exact in any
    accumulation order.  Trained boosters never emit these shapes; the
    compression pass (repro.core.compress) exists for them, and the other
    ``n_features - 2`` columns stay unsplit so column collapse fires too.
    """
    n_nodes = 2 ** (depth + 1) - 1
    n_internal = 2**depth - 1
    feature = np.full(n_nodes, -1, dtype=np.int64)
    threshold = np.zeros(n_nodes)
    left = np.full(n_nodes, -1, dtype=np.int64)
    right = np.full(n_nodes, -1, dtype=np.int64)
    value = np.zeros(n_nodes)
    for j in range(n_internal):
        feature[j] = int(rng.choice([0, 2]))
        threshold[j] = float(rng.integers(-8, 9)) / 4.0
        left[j] = 2 * j + 1
        right[j] = 2 * j + 2
    for j in range(n_internal, n_nodes):
        value[j] = float(rng.integers(-16, 17)) / 16.0
    return {
        "feature": feature, "threshold": threshold,
        "left": left, "right": right, "value": value,
    }


def _xgb_tree_json(t: dict, tree_id: int, n_features: int) -> dict:
    is_leaf = t["feature"] < 0
    n = len(t["feature"])
    return {
        "base_weights": [0.0] * n,
        "categories": [], "categories_nodes": [],
        "categories_segments": [], "categories_sizes": [],
        "default_left": [0] * n,
        "id": tree_id,
        "left_children": t["left"].tolist(),
        "loss_changes": [0.0] * n,
        "parents": [2147483647] * n,
        "right_children": t["right"].tolist(),
        "split_conditions": np.where(is_leaf, t["value"], t["threshold"]).tolist(),
        "split_indices": np.maximum(t["feature"], 0).tolist(),
        "split_type": [0] * n,
        "sum_hessian": [1.0] * n,
        "tree_param": {"num_deleted": "0", "num_feature": str(n_features),
                       "num_nodes": str(n), "size_leaf_vector": "1"},
    }


def _xgb_doc(trees: list[dict], *, objective: str, n_features: int,
             base_score: float, num_class: int = 0,
             tree_info: list[int] | None = None,
             dart_weights: list[float] | None = None) -> dict:
    trees_json = [_xgb_tree_json(t, i, n_features) for i, t in enumerate(trees)]
    model = {
        "gbtree_model_param": {"num_parallel_tree": "1",
                               "num_trees": str(len(trees))},
        "tree_info": tree_info or [0] * len(trees),
        "trees": trees_json,
    }
    if dart_weights is None:
        booster = {"model": model, "name": "gbtree"}
    else:
        booster = {"gbtree": {"model": model, "name": "gbtree"},
                   "name": "dart", "weight_drop": dart_weights}
    return {
        "learner": {
            "attributes": {}, "feature_names": [], "feature_types": [],
            "gradient_booster": booster,
            "learner_model_param": {
                "base_score": repr(base_score), "boost_from_average": "1",
                "num_class": str(num_class), "num_feature": str(n_features),
                "num_target": "1",
            },
            "objective": {"name": objective},
        },
        "version": [2, 0, 0],
    }


def _lgbm_tree_text(idx: int, *, num_leaves: int, split_feature, threshold,
                    decision_type, left_child, right_child, leaf_value,
                    num_cat: int = 0, cat_boundaries=None, cat_threshold=None
                    ) -> str:
    def row(name, vals):
        return f"{name}=" + " ".join(str(v) for v in vals)
    n_int = num_leaves - 1
    lines = [
        f"Tree={idx}", f"num_leaves={num_leaves}", f"num_cat={num_cat}",
        row("split_feature", split_feature),
        row("split_gain", [1.0] * n_int),
        row("threshold", threshold),
        row("decision_type", decision_type),
        row("left_child", left_child),
        row("right_child", right_child),
        row("leaf_value", leaf_value),
        row("leaf_weight", [1.0] * num_leaves),
        row("leaf_count", [1] * num_leaves),
        row("internal_value", [0.0] * n_int),
        row("internal_weight", [0.0] * n_int),
        row("internal_count", [0] * n_int),
    ]
    if num_cat:
        lines.append(row("cat_boundaries", cat_boundaries))
        lines.append(row("cat_threshold", cat_threshold))
    lines += ["is_linear=0", "shrinkage=0.1"]
    return "\n".join(lines)


def _lgbm_doc(trees_text: list[str], *, objective: str, n_features: int,
              num_class: int = 1, per_iter: int = 1) -> str:
    header = "\n".join([
        "tree", "version=v4", f"num_class={num_class}",
        f"num_tree_per_iteration={per_iter}", "label_index=0",
        f"max_feature_idx={n_features - 1}", f"objective={objective}",
        "feature_names=" + " ".join(f"f{i}" for i in range(n_features)),
        "feature_infos=" + " ".join("none" for _ in range(n_features)),
    ])
    return (header + "\n\n" + "\n\n".join(trees_text)
            + "\n\nend of trees\n\nparameters:\n[boosting: gbdt]\n"
              "\nend of parameters\n")


def _sk_tree(t: dict, value) -> dict:
    # back to sklearn conventions: leaf marker -2, <= thresholds.  The
    # generator's strict-< quarter-grid thresholds shift down one float
    # so that `x <= nextafter-normalized threshold` reproduces `x < t`.
    is_leaf = t["feature"] < 0
    le_threshold = np.where(is_leaf, -2.0, np.nextafter(t["threshold"], -np.inf))
    return {
        "feature": np.where(is_leaf, -2, t["feature"]).tolist(),
        "threshold": le_threshold.tolist(),
        "children_left": t["left"].tolist(),
        "children_right": t["right"].tolist(),
        "value": value,
    }


def _record(path: Path, rng: np.random.Generator) -> None:
    """Lower the dump and freeze queries + reference outputs beside it."""
    imported = load_model(path)
    ens, quant, report = lower_to_ensemble(imported)
    x = np.round(rng.uniform(-3, 3, size=(N_QUERIES, imported.n_features)), 2)
    xb = quant.transform(x)
    margin = ens.raw_margin(xb)
    pred = ens.predict(xb)
    assert np.array_equal(margin, imported.raw_margin(x)), path.name
    assert report.exact, path.name
    if ens.task == "binary" and margin.shape[1] == 1:
        # the engine margin contract is ~1 ULP: keep the sign test far
        # from the decision boundary so predictions stay bit-stable
        assert np.abs(margin).min() > 1e-4, f"{path.name}: margin at boundary"
    out = path.with_name(path.name.rsplit(".", 1)[0] + ".expected.json")
    out.write_text(json.dumps({
        "dump": path.name,
        "x": x.tolist(),
        "raw_margin": [[float(v) for v in row] for row in margin],
        "predict": [float(v) if ens.task == "regression" else int(v)
                    for v in pred],
    }, indent=1))
    print(f"  {path.name}: {imported.n_trees} trees -> "
          f"{ens.total_leaves} rows, {report.occupancy_summary()}")


def main() -> None:
    rng = np.random.default_rng(20260730)
    F = 5

    # 1. XGBoost gbtree, binary:logistic with a nontrivial base_score
    trees = [_rand_tree(rng, F, 9) for _ in range(3)]
    (HERE / "xgb_binary.json").write_text(json.dumps(
        _xgb_doc(trees, objective="binary:logistic", n_features=F,
                 base_score=0.25), indent=1))

    # 2. XGBoost gbtree, multi:softprob, 2 rounds x 3 classes
    trees = [_rand_tree(rng, F, 7) for _ in range(6)]
    (HERE / "xgb_multi.json").write_text(json.dumps(
        _xgb_doc(trees, objective="multi:softprob", n_features=F,
                 base_score=0.5, num_class=3,
                 tree_info=[0, 1, 2, 0, 1, 2]), indent=1))

    # 3. XGBoost DART regression: weight_drop folded into leaves
    trees = [_rand_tree(rng, F, 9) for _ in range(4)]
    (HERE / "xgb_dart_reg.json").write_text(json.dumps(
        _xgb_doc(trees, objective="reg:squarederror", n_features=F,
                 base_score=1.5, dart_weights=[1.0, 0.75, 0.5, 0.25]),
        indent=1))

    # 4. LightGBM binary with one categorical split (bitset {0,1,3,6,7})
    t0 = _lgbm_tree_text(
        0, num_leaves=3, split_feature=[0, 1],
        threshold=[0.5, -1.25], decision_type=[2, 2],
        left_child=[1, -1], right_child=[-2, -3],
        leaf_value=[0.12, -0.27, 0.31])
    t1 = _lgbm_tree_text(
        1, num_leaves=2, split_feature=[2],
        threshold=[0], decision_type=[1],
        left_child=[-1], right_child=[-2],
        leaf_value=[0.45, -0.52],
        num_cat=1, cat_boundaries=[0, 1], cat_threshold=[0b11001011])
    (HERE / "lgbm_binary.txt").write_text(
        _lgbm_doc([t0, t1], objective="binary sigmoid:1", n_features=3))

    # 5. LightGBM multiclass: 2 rounds x 3 classes, interleaved
    trees_text = []
    for i in range(6):
        t = _rand_tree(rng, 4, 5)
        internal = t["feature"] >= 0
        # map array layout to lgbm child encoding: leaves get ~leaf_idx
        leaf_pos = {j: k for k, j in enumerate(np.flatnonzero(~internal))}
        def child(c):
            return int(c) if t["feature"][c] >= 0 else ~leaf_pos[int(c)]
        int_nodes = np.flatnonzero(internal)
        remap = {j: k for k, j in enumerate(int_nodes)}
        trees_text.append(_lgbm_tree_text(
            i, num_leaves=int((~internal).sum()),
            split_feature=[int(t["feature"][j]) for j in int_nodes],
            threshold=[t["threshold"][j] for j in int_nodes],
            decision_type=[2] * len(int_nodes),
            left_child=[(remap[int(t["left"][j])]
                         if t["feature"][t["left"][j]] >= 0
                         else child(t["left"][j])) for j in int_nodes],
            right_child=[(remap[int(t["right"][j])]
                          if t["feature"][t["right"][j]] >= 0
                          else child(t["right"][j])) for j in int_nodes],
            leaf_value=[t["value"][j] for j in np.flatnonzero(~internal)]))
    (HERE / "lgbm_multi.txt").write_text(
        _lgbm_doc(trees_text, objective="multiclass num_class:3",
                  n_features=4, num_class=3, per_iter=3))

    # 6. sklearn RandomForestClassifier dict (class-count leaf rows)
    sk_trees = []
    for _ in range(4):
        t = _rand_tree(rng, F, 7)
        counts = np.zeros((7, 3))
        for j in np.flatnonzero(t["feature"] < 0):
            counts[j] = rng.integers(0, 9, size=3) + [1, 0, 0]
        sk_trees.append(_sk_tree(t, counts.tolist()))
    (HERE / "sk_rf_cls.json").write_text(json.dumps({
        "format": "sklearn-forest", "kind": "rf", "task": "multiclass",
        "n_features": F, "n_classes": 3, "trees": sk_trees}, indent=1))

    # 7. sklearn GradientBoostingRegressor dict (init + learning_rate)
    sk_trees = [_sk_tree(t, t["value"].tolist())
                for t in (_rand_tree(rng, F, 9) for _ in range(5))]
    (HERE / "sk_gbdt_reg.json").write_text(json.dumps({
        "format": "sklearn-forest", "kind": "gbdt", "task": "regression",
        "n_features": F, "n_classes": 1, "learning_rate": 0.1,
        "init": 2.125, "trees": sk_trees}, indent=1))

    # 8. deep duplicate-split XGBoost regression: the compression fixture.
    #    Own rng stream (and recorded last): the original fixtures' draws
    #    — and thus their frozen files — stay byte-identical
    rng_deep = np.random.default_rng(20260808)
    trees = [_deep_dup_tree(rng_deep, F, depth=7) for _ in range(5)]
    (HERE / "xgb_deep.json").write_text(json.dumps(
        _xgb_doc(trees, objective="reg:squarederror", n_features=F,
                 base_score=0.5), indent=1))

    print("fixtures:")
    for name in ("xgb_binary.json", "xgb_multi.json", "xgb_dart_reg.json",
                 "lgbm_binary.txt", "lgbm_multi.txt", "sk_rf_cls.json",
                 "sk_gbdt_reg.json", "xgb_deep.json"):
        _record(HERE / name, rng)


if __name__ == "__main__":
    main()
