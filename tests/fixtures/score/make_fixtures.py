"""Regenerate the committed score fixtures.

    python tests/fixtures/score/make_fixtures.py

``xgb_deep_x.npy`` is the golden query block from
``tests/fixtures/ingest/xgb_deep.expected.json`` re-serialized as the
columnar ``.npy`` input ``scripts/score.py`` streams — CI's
``score-golden`` job scores it against that same record, closing the
ingest -> save -> score -> verify loop on one fixture.  Deriving the
file (rather than hand-writing it) keeps the two copies of the queries
provably in sync.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

HERE = Path(__file__).resolve().parent


def main() -> None:
    exp = json.loads(
        (HERE.parent / "ingest" / "xgb_deep.expected.json").read_text()
    )
    x = np.asarray(exp["x"], dtype=np.float64)
    out = HERE / "xgb_deep_x.npy"
    np.save(out, x)
    print(f"{out.name}: {x.shape} {x.dtype}, {out.stat().st_size} bytes")


if __name__ == "__main__":
    main()
