"""Engine correctness: CAM engine == traversal baseline == Ensemble, for
every (kind, task) combination, plus defect injection behaviour."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import TraversalBaseline
from repro.core.compile import compile_ensemble
from repro.core.defects import inject_query_defects, inject_table_defects
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, RFParams, train_gbdt, train_rf
from repro.data.tabular import make_dataset

CASES = [
    ("churn", "binary", "gbdt"),
    ("eye", "multiclass", "gbdt"),
    ("rossmann", "regression", "gbdt"),
    ("eye", "multiclass", "rf"),
    ("churn", "binary", "rf"),
    ("rossmann", "regression", "rf"),
]


@pytest.fixture(scope="module")
def trained():
    out = {}
    for name, task, kind in CASES:
        ds = make_dataset(name)
        q = FeatureQuantizer.fit(ds.x_train, 256)
        xb_tr, xb_te = q.transform(ds.x_train), q.transform(ds.x_test)
        if kind == "gbdt":
            ens = train_gbdt(xb_tr, ds.y_train, task=task, n_bins=256,
                             n_classes=ds.n_classes,
                             params=GBDTParams(n_rounds=5, max_leaves=32))
        else:
            ens = train_rf(xb_tr, ds.y_train, task=task, n_bins=256,
                           n_classes=ds.n_classes,
                           params=RFParams(n_trees=10, max_leaves=32))
        out[(name, task, kind)] = (ens, xb_te[:128])
    return out


@pytest.mark.parametrize("case", CASES, ids=[f"{k}-{t}-{n}" for n, t, k in CASES])
def test_engine_matches_ensemble(trained, case):
    ens, xb = trained[case]
    table = compile_ensemble(ens)
    eng = XTimeEngine.from_config(table, DeployConfig(backend="jnp"))
    np.testing.assert_allclose(
        np.asarray(eng.raw_margin(xb)), ens.raw_margin(xb), rtol=1e-4, atol=1e-5
    )
    if ens.task != "regression":
        np.testing.assert_array_equal(np.asarray(eng.predict(xb)), ens.predict(xb))


@pytest.mark.parametrize("case", CASES[:3], ids=[f"{k}-{t}-{n}" for n, t, k in CASES[:3]])
def test_traversal_matches_ensemble(trained, case):
    ens, xb = trained[case]
    tb = TraversalBaseline(ens)
    np.testing.assert_allclose(
        np.asarray(tb.raw_margin(xb)), ens.raw_margin(xb), rtol=1e-4, atol=1e-5
    )


def test_pallas_engine_matches_jnp(trained):
    ens, xb = trained[("eye", "multiclass", "gbdt")]
    table = compile_ensemble(ens)
    ej = XTimeEngine.from_config(table, DeployConfig(backend="jnp"))
    for mode in ("direct", "msb_lsb", "two_cycle"):
        ep = XTimeEngine.from_config(
            table, DeployConfig(backend="pallas", mode=mode, interpret=True)
        )
        np.testing.assert_allclose(
            np.asarray(ep.raw_margin(xb)), np.asarray(ej.raw_margin(xb)),
            rtol=1e-5, atol=1e-6,
        )


def test_defects_zero_fraction_is_identity(trained):
    ens, xb = trained[("eye", "multiclass", "gbdt")]
    table = compile_ensemble(ens)
    t2 = inject_table_defects(table, 0.0, np.random.default_rng(0))
    np.testing.assert_array_equal(t2.low, table.low)
    np.testing.assert_array_equal(t2.high, table.high)
    q2 = inject_query_defects(xb.astype(np.int32), 0.0, 256, np.random.default_rng(0))
    np.testing.assert_array_equal(q2, xb.astype(np.int32))


def test_defects_degrade_gracefully(trained):
    """Small defect rates keep most predictions; large rates break more
    (Fig. 9b qualitative shape)."""
    ens, xb = trained[("eye", "multiclass", "gbdt")]
    table = compile_ensemble(ens)
    base = np.asarray(XTimeEngine(table).predict(xb))
    agree = {}
    for frac in (0.005, 0.2):
        t2 = inject_table_defects(table, frac, np.random.default_rng(1))
        pred = np.asarray(XTimeEngine(t2).predict(xb))
        agree[frac] = float((pred == base).mean())
    assert agree[0.005] > 0.9
    assert agree[0.005] >= agree[0.2]
