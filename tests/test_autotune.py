"""Kernel autotuner: sweep -> TunePlan -> artifact sidecar -> cold start.

The persistence contract (DESIGN.md §10): a tuned artifact saved to disk
binds its engine with the tuned (b_blk, r_blk, table_dtype, mode) on any
later host — ``TableRegistry`` cold starts included — with no re-search.
"""

import numpy as np
import pytest

from repro.api import CompiledModel, build
from repro.core.deploy import DeployConfig
from repro.core.trees import GBDTParams, train_gbdt
from repro.core.tune import TunePlan, autotune_kernel
from repro.serve.registry import TableRegistry


@pytest.fixture(scope="module")
def artifact():
    rng = np.random.default_rng(0)
    xb = rng.integers(0, 256, size=(256, 8))
    y = (xb[:, 0].astype(np.int64) + xb[:, 3] > 250).astype(np.int64)
    ens = train_gbdt(xb, y, task="binary", n_bins=256,
                     params=GBDTParams(n_rounds=4, max_leaves=16))
    return build(ens), xb


def _quick_plan(cm):
    return autotune_kernel(
        cm, batch=64, b_blks=(32, 64), r_blks=(64, 128), warmup=1, iters=1,
    )


def test_autotune_sweeps_and_picks_winner(artifact):
    cm, _ = artifact
    plan = _quick_plan(cm)
    assert plan.b_blk in (32, 64) and plan.r_blk in (64, 128)
    assert plan.table_dtype in ("uint8", "uint16", "int32")  # resolved, not 'auto'
    assert plan.us_per_call > 0
    # full sweep recorded: every (b, r, dtype/kernel-mode) candidate timed
    assert len(plan.trials) >= 8
    assert {t["us_per_call"] >= 0 for t in plan.trials} == {True}
    assert plan.env["platform"] == "cpu"
    winner_us = min(t["us_per_call"] for t in plan.trials)
    assert plan.us_per_call == winner_us


def test_plan_round_trips_and_applies(artifact):
    cm, _ = artifact
    plan = _quick_plan(cm)
    assert TunePlan.from_dict(plan.to_dict()) == plan
    cfg = plan.apply(DeployConfig())
    assert (cfg.b_blk, cfg.r_blk, cfg.table_dtype, cfg.mode) == (
        plan.b_blk, plan.r_blk, plan.table_dtype, plan.mode,
    )


def test_faithful_mode_sweep_stays_int32(artifact):
    cm, _ = artifact
    plan = autotune_kernel(
        cm, deploy=DeployConfig(mode="msb_lsb"), batch=32,
        b_blks=(32,), r_blks=(64,), iters=1,
    )
    assert plan.mode == "msb_lsb"
    assert plan.table_dtype == "int32"


def test_tuned_artifact_save_load_round_trip(artifact, tmp_path):
    cm, xb = artifact
    plan = _quick_plan(cm)
    tuned = cm.with_tuning(plan)
    assert tuned.tuning == plan.to_dict()
    assert tuned.deploy.b_blk == plan.b_blk
    assert tuned.summary()["tuned"] is True

    tuned.save(tmp_path / "m")
    loaded = CompiledModel.load(tmp_path / "m")
    # the autotune plan survives the round trip, knobs already folded in
    assert loaded.tuning == plan.to_dict()
    assert loaded.tune_plan() == plan
    assert loaded.deploy.b_blk == plan.b_blk
    assert loaded.deploy.r_blk == plan.r_blk
    assert loaded.deploy.table_dtype == plan.table_dtype
    # and the tuned engine computes the same bits as the untuned one
    m0 = np.asarray(cm.engine().raw_margin(xb))
    m1 = np.asarray(loaded.engine().raw_margin(xb))
    np.testing.assert_array_equal(m0, m1)


def test_registry_cold_start_uses_tuned_plan(artifact, tmp_path):
    cm, xb = artifact
    plan = _quick_plan(cm)
    cm.with_tuning(plan).save(tmp_path / "m")

    reg = TableRegistry()
    entry = reg.register("churn", CompiledModel.load(tmp_path / "m"))
    assert entry.tuning == plan.to_dict()
    assert entry.engine.b_blk == plan.b_blk
    assert entry.engine.r_blk == plan.r_blk
    assert entry.engine.table_dtype == plan.table_dtype
    np.testing.assert_array_equal(
        np.asarray(entry.engine.raw_margin(xb)),
        np.asarray(cm.engine().raw_margin(xb)),
    )


def test_untuned_artifact_has_no_plan(artifact, tmp_path):
    cm, _ = artifact
    assert cm.tuning is None and cm.tune_plan() is None
    cm.save(tmp_path / "m")
    assert CompiledModel.load(tmp_path / "m").tuning is None


def test_v1_artifact_still_loads(artifact, tmp_path):
    """Pre-kernel-v2 artifacts (schema_version 1: int32 exclusive-high
    arrays, no table_dtype) must keep loading unchanged."""
    import json

    import numpy as np

    cm, xb = artifact
    cm.save(tmp_path / "m")
    sidecar = json.loads((tmp_path / "m.json").read_text())
    assert sidecar["schema_version"] == 2
    # rewrite as a faithful v1 artifact
    sidecar["schema_version"] = 1
    del sidecar["table"]["table_dtype"]
    (tmp_path / "m.json").write_text(json.dumps(sidecar))
    with np.load(tmp_path / "m.npz") as npz:
        arrays = dict(npz)
    arrays["low"] = cm.table.low.astype(np.int32)
    arrays["high"] = cm.table.high.astype(np.int32)
    np.savez_compressed(tmp_path / "m.npz", **arrays)

    old = CompiledModel.load(tmp_path / "m")
    assert old.table.table_dtype == "int32"  # pre-v2 layout, as saved
    np.testing.assert_array_equal(old.table.low, cm.table.low)
    np.testing.assert_array_equal(old.table.high, cm.table.high)
    np.testing.assert_array_equal(
        np.asarray(old.engine().raw_margin(xb)),
        np.asarray(cm.engine().raw_margin(xb)),
    )
