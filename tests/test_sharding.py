"""Sharding rules + multi-device execution (subprocess with 8 fake host
devices so the single-device unit-test environment stays untouched)."""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    # pin the platform: fake host devices need CPU anyway, and leaving it
    # unset makes jax probe the TPU plugin, which stalls for minutes on
    # the (absent) GCP metadata server in sandboxed environments
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_param_pspecs_divisibility_all_archs():
    """Every full-size arch: specs fit shapes on the production mesh."""
    from repro.config import get_config
    from repro.configs import ASSIGNED_ARCHS
    from repro.models.registry import build_model
    from repro.sharding.partition import MeshAxes, param_pspecs

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), dtype=object)

    axes = MeshAxes(FakeMesh())
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        bundle = build_model(cfg)
        shapes = bundle.params_shape()
        specs = param_pspecs(shapes, cfg, axes)
        for (path, sds), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(shapes)[0],
            jax.tree_util.tree_flatten_with_path(specs)[0],
        ):
            for dim, ax in zip(sds.shape, tuple(spec)):
                if ax is None:
                    continue
                size = axes.axis_size(ax)
                assert dim % size == 0, (arch, jax.tree_util.keystr(path),
                                         sds.shape, spec)


def test_sharded_train_step_matches_single_device():
    """Loss on an 8-device (data x model) mesh == single-device loss."""
    code = r"""
import json, numpy as np
import jax, jax.numpy as jnp
from repro.configs.llama32_3b import smoke
from repro.launch.mesh import make_host_mesh
from repro.launch.train import make_train_step, place_batch, place_params
from repro.models.registry import build_model
from repro.optim.adamw import AdamW, AdamWConfig
from repro.sharding.partition import activation_sharder

cfg = smoke().replace(dtype="float32", remat=False)
mesh = make_host_mesh(4, 2)
bundle = build_model(cfg, flash_blk=16)
params = bundle.init_params(jax.random.key(0))
rng = np.random.default_rng(0)
toks = rng.integers(0, cfg.vocab_size, (8, 32))
batch = {"tokens": jnp.asarray(toks, jnp.int32),
         "labels": jnp.asarray(np.roll(toks, -1, 1), jnp.int32)}
loss_single, _ = jax.jit(bundle.loss_fn)(params, batch)

bundle2 = build_model(cfg, flash_blk=16)
bundle2.model.shard_x = activation_sharder(mesh)
p2 = place_params(mesh, cfg, params)
b2 = place_batch(mesh, batch)
loss_sharded, _ = jax.jit(bundle2.loss_fn)(p2, b2)

opt = AdamW(AdamWConfig())
step = make_train_step(bundle2, opt, mesh)
opt_state = opt.init(p2)
p3, opt_state, _, metrics = step(p2, opt_state, {"none": jnp.zeros(())}, b2)
print(json.dumps({
    "loss_single": float(loss_single),
    "loss_sharded": float(loss_sharded),
    "step_loss": float(metrics["loss"]),
    "n_dev": len(jax.devices()),
}))
"""
    res = _run_subprocess(code)
    assert res["n_dev"] == 8
    np.testing.assert_allclose(res["loss_sharded"], res["loss_single"], rtol=2e-4)
    np.testing.assert_allclose(res["step_loss"], res["loss_single"], rtol=2e-4)


def test_sharded_xtime_engine_matches_single_device():
    """CAM rows over `model`, batch over `data`: psum == local sum."""
    code = r"""
import json, numpy as np
import jax
from repro.core.compile import compile_ensemble
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import train_gbdt, GBDTParams
from repro.data.tabular import make_dataset
from repro.launch.mesh import make_host_mesh

ds = make_dataset("eye")
q = FeatureQuantizer.fit(ds.x_train, 256)
xb = q.transform(ds.x_train)[:64]
ens = train_gbdt(q.transform(ds.x_train), ds.y_train, task="multiclass",
                 n_bins=256, n_classes=ds.n_classes,
                 params=GBDTParams(n_rounds=4, max_leaves=32))
table = compile_ensemble(ens)
mesh = make_host_mesh(2, 4)
e1 = XTimeEngine.from_config(table, DeployConfig(backend="jnp"))
e2 = XTimeEngine.from_config(table, DeployConfig(backend="jnp"), mesh=mesh)
m1 = np.asarray(e1.raw_margin(xb))
m2 = np.asarray(e2.raw_margin(xb))
print(json.dumps({"maxerr": float(np.abs(m1-m2).max()),
                  "n_dev": len(jax.devices())}))
"""
    res = _run_subprocess(code)
    assert res["n_dev"] == 8
    assert res["maxerr"] < 1e-4


def test_batch_replicated_noc_config_matches():
    """Input-batching config (Fig. 7c): table replicated, batch over all
    axes — same numbers as the accumulate config."""
    code = r"""
import json, numpy as np
from repro.core.compile import compile_ensemble
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import train_gbdt, GBDTParams
from repro.data.tabular import make_dataset
from repro.launch.mesh import make_host_mesh

ds = make_dataset("churn")
q = FeatureQuantizer.fit(ds.x_train, 256)
xb = q.transform(ds.x_train)[:64]
ens = train_gbdt(q.transform(ds.x_train), ds.y_train, task="binary",
                 n_bins=256, params=GBDTParams(n_rounds=3, max_leaves=16))
table = compile_ensemble(ens)
mesh = make_host_mesh(2, 4)
e1 = XTimeEngine.from_config(table, DeployConfig(backend="jnp"))
e2 = XTimeEngine.from_config(
    table, DeployConfig(backend="jnp", noc_config="batch"), mesh=mesh)
m1 = np.asarray(e1.raw_margin(xb))
m2 = np.asarray(e2.raw_margin(xb))
print(json.dumps({"maxerr": float(np.abs(m1-m2).max())}))
"""
    res = _run_subprocess(code)
    assert res["maxerr"] < 1e-4
