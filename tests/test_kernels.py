"""Pallas cam_match kernel: shape/dtype/mode sweep vs the ref.py oracle
(interpret=True executes the kernel body on CPU)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels.cam_match import cam_match_pallas
from repro.kernels.ref import cam_match_bits_ref, cam_match_ref


def _random_problem(rng, b, r, f, c, n_bins=256):
    low = rng.integers(0, n_bins, size=(r, f)).astype(np.int32)
    width = rng.integers(0, n_bins, size=(r, f))
    high = np.minimum(low + width, n_bins).astype(np.int32)
    # sprinkle don't-cares
    dc = rng.random((r, f)) < 0.3
    low[dc], high[dc] = 0, n_bins
    leaf = rng.normal(size=(r, c)).astype(np.float32)
    q = rng.integers(0, n_bins, size=(b, f)).astype(np.int32)
    return q, low, high, leaf


@pytest.mark.parametrize("b,r,f,c", [
    (8, 64, 10, 1),
    (64, 512, 130, 8),
    (128, 256, 26, 3),
    (1, 300, 54, 7),
])
@pytest.mark.parametrize("mode", ["direct", "msb_lsb"])
def test_kernel_vs_oracle_shapes(b, r, f, c, mode):
    rng = np.random.default_rng(b * 1000 + r + f + c)
    q, low, high, leaf = _random_problem(rng, b, r, f, c)
    lo_p, hi_p, leaf_p = kops.pad_tables(low, high, leaf, r_blk=256, n_bins=256)
    q_p = kops.pad_queries(jnp.asarray(q), lo_p.shape[1])
    out = kops.cam_match(
        q_p, jnp.asarray(lo_p), jnp.asarray(hi_p), jnp.asarray(leaf_p),
        out_b=b, out_c=c, mode=mode, interpret=True,
    )
    ref = cam_match_ref(jnp.asarray(q), jnp.asarray(low), jnp.asarray(high),
                        jnp.asarray(leaf), mode="direct")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("qdtype", [np.int32, np.uint8])
def test_kernel_query_dtypes(qdtype):
    rng = np.random.default_rng(5)
    q, low, high, leaf = _random_problem(rng, 16, 128, 20, 2)
    lo_p, hi_p, leaf_p = kops.pad_tables(low, high, leaf, n_bins=256)
    q_p = kops.pad_queries(jnp.asarray(q.astype(qdtype)), lo_p.shape[1])
    out = kops.cam_match(q_p, jnp.asarray(lo_p), jnp.asarray(hi_p),
                         jnp.asarray(leaf_p), out_b=16, out_c=2, interpret=True)
    ref = cam_match_ref(jnp.asarray(q), jnp.asarray(low), jnp.asarray(high),
                        jnp.asarray(leaf))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_kernel_16bit_bins_direct_mode():
    """n_bins = 4096 ('unconstrained' grid) — direct mode handles wider
    integer thresholds."""
    rng = np.random.default_rng(6)
    q, low, high, leaf = _random_problem(rng, 8, 128, 12, 1, n_bins=4096)
    lo_p, hi_p, leaf_p = kops.pad_tables(low, high, leaf, n_bins=4096)
    q_p = kops.pad_queries(jnp.asarray(q), lo_p.shape[1])
    out = kops.cam_match(q_p, jnp.asarray(lo_p), jnp.asarray(hi_p),
                         jnp.asarray(leaf_p), out_b=8, out_c=1, interpret=True)
    ref = cam_match_ref(jnp.asarray(q), jnp.asarray(low), jnp.asarray(high),
                        jnp.asarray(leaf))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_block_shape_invariance():
    rng = np.random.default_rng(7)
    q, low, high, leaf = _random_problem(rng, 32, 512, 30, 4)
    outs = []
    for r_blk in (128, 256, 512):
        lo_p, hi_p, leaf_p = kops.pad_tables(low, high, leaf, r_blk=r_blk, n_bins=256)
        q_p = kops.pad_queries(jnp.asarray(q), lo_p.shape[1])
        outs.append(np.asarray(kops.cam_match(
            q_p, jnp.asarray(lo_p), jnp.asarray(hi_p), jnp.asarray(leaf_p),
            out_b=32, out_c=4, r_blk=r_blk, interpret=True,
        )))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-6)


def test_match_bits_oracle_modes_agree():
    rng = np.random.default_rng(8)
    q, low, high, _ = _random_problem(rng, 16, 64, 9, 1)
    args = (jnp.asarray(q), jnp.asarray(low), jnp.asarray(high))
    d = cam_match_bits_ref(*args, mode="direct")
    m = cam_match_bits_ref(*args, mode="msb_lsb")
    c = cam_match_bits_ref(*args, mode="two_cycle")
    assert bool(jnp.all(d == m)) and bool(jnp.all(d == c))
