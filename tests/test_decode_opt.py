"""shard_map flash-decode merge == single-device decode attention."""

import json
import os
import subprocess
import sys

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_flash_decode_shardmap_matches_reference():
    code = r"""
import json, numpy as np
import jax, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.models.attention import decode_attention
from repro.models.decode_opt import flash_decode_shardmap

mesh = make_host_mesh(2, 4)
rng = np.random.default_rng(0)
b, s, h, kv, d = 2, 64, 8, 1, 16  # MQA: kv=1 cannot shard heads
q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
pos = jnp.int32(37)  # part of the cache is invalid/masked

ref = decode_attention(q, k, v, pos)
out = jax.jit(lambda *a: flash_decode_shardmap(mesh, *a))(q, k, v, pos)
print(json.dumps({"maxerr": float(jnp.abs(ref - out).max()),
                  "scale": float(jnp.abs(ref).max())}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    # pin the platform: fake host devices need CPU anyway, and leaving it
    # unset makes jax probe the TPU plugin, which stalls for minutes on
    # the (absent) GCP metadata server in sandboxed environments
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["maxerr"] < 1e-5 * max(1.0, res["scale"]), res
