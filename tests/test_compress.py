"""RETENTION-style compression differential harness (DESIGN.md §11).

The non-negotiable contract: ``compress_table`` output is BIT-EQUAL to
the uncompressed int32 oracle for every query the engine can be handed —
at every level, in every cell mode x table dtype the engine admits, under
jit and under shard_map.  The test population is
``random_deep_ensemble``: deep complete trees with duplicate-split paths
(structurally empty boxes) and k/16-quantized leaves, whose float32 sums
are exact in any accumulation order — so equality assertions stay
``assert_array_equal`` even when compression changes row counts, padding
and shard boundaries.

Adversarial corners get their own tests: all-wildcard tables (column
collapse), single-row tables, empty-interval rows (which break uint8
packing until pruned), duplicate leaves (which must NOT merge), and
grid-unreachable rows (prunable only under the artifact's quantizer).
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest
from _hypothesis_compat import deep_ensemble_params, given, settings

from repro.api import SCHEMA_VERSION, CompiledModel, build
from repro.core.compile import CAMTable, compile_ensemble
from repro.core.compress import (
    COMPRESS_LEVELS,
    CompressionReport,
    compress_table,
    resolve_level,
)
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import random_deep_ensemble
from repro.kernels import ops as kops
from repro.serve.batching import MicroBatcher

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# the uncompressed oracle must run int32: duplicate-split ensembles emit
# empty [low, high) boxes whose inclusive-high encoding (high - 1 = -1)
# does not fit a packed dtype — one of the things compression fixes
ORACLE = DeployConfig(table_dtype="int32")


def _margins(table, config=ORACLE, q=None):
    return np.asarray(XTimeEngine.from_config(table, config).raw_margin(q))


def _queries(rng, n, n_features, n_bins):
    q = rng.integers(0, n_bins, size=(n, n_features)).astype(np.int32)
    q[: min(4, n)] = 0  # grid-boundary rows
    q[min(4, n) : min(8, n)] = n_bins - 1
    return q


# -- property: every level bit-equals the uncompressed oracle ------------------


@settings(max_examples=8, deadline=None)
@given(params=deep_ensemble_params(max_trees=8, max_depth=6))
def test_levels_bit_equal_oracle(params):
    kw = dict(params)
    kw["p_dup"] = kw.pop("p_dup_pct") / 100
    kw.pop("n_classes")
    ens = random_deep_ensemble(n_bins=256, **kw)
    table = compile_ensemble(ens)
    rng = np.random.default_rng(kw["seed"] + 1)
    q = _queries(rng, 48, table.n_features, 256)
    ref = _margins(table, q=q)
    rows = {}
    for level in ("prune", "merge", "full"):
        ct, rep = compress_table(table, level=level)
        rows[level] = ct.n_rows
        assert rep.rows_after == ct.n_rows
        assert rep.cols_after == ct.n_cols
        np.testing.assert_array_equal(_margins(ct, q=q), ref)
    # level monotonicity: each level only ever removes more rows
    assert rows["full"] <= rows["merge"] <= rows["prune"] <= table.n_rows


def test_multiclass_levels_bit_equal_oracle():
    ens = random_deep_ensemble(
        n_trees=9, depth=5, n_features=8, n_bins=256,
        task="multiclass", n_classes=3, p_dup=0.5, seed=11,
    )
    table = compile_ensemble(ens)
    q = _queries(np.random.default_rng(0), 32, 8, 256)
    ref = _margins(table, q=q)
    assert ref.shape[1] == 3
    for level in ("prune", "merge", "full"):
        ct, _ = compress_table(table, level=level)
        np.testing.assert_array_equal(_margins(ct, q=q), ref)


# -- every admissible cell mode x table dtype on the compressed table ----------


@pytest.mark.parametrize(
    "mode,dtype",
    [
        ("direct", "uint8"),
        ("direct", "uint16"),
        ("direct", "int32"),
        ("inclusive", "uint8"),
        ("inclusive", "uint16"),
        ("inclusive", "int32"),
        # faithful hardware modes pin int32 via 'auto' (kernel-v2 rule)
        ("msb_lsb", "auto"),
        ("two_cycle", "auto"),
    ],
)
def test_compressed_bit_equal_across_modes_and_dtypes(mode, dtype):
    ens = random_deep_ensemble(
        n_trees=10, depth=6, n_features=12, n_bins=256, p_dup=0.55, seed=5,
    )
    table = compile_ensemble(ens)
    ct, rep = compress_table(table, level="full")
    assert rep.rows_saved > 0
    q = _queries(np.random.default_rng(2), 64, 12, 256)
    ref = _margins(table, q=q)
    cfg = DeployConfig(mode=mode, table_dtype=dtype)
    eng = XTimeEngine.from_config(ct, cfg)
    if dtype == "auto":
        assert eng.table_dtype == "int32"
    # k/16 leaves: exact float32 sums, so even across row-count and
    # padding changes the margins agree to the last bit
    np.testing.assert_array_equal(np.asarray(eng.raw_margin(q)), ref)


# -- shard_map: compressed vs uncompressed on the 8-device mesh ----------------

_SHARD_CODE = """
import json
import numpy as np
from repro.api import build
from repro.core.trees import random_deep_ensemble
from repro.launch.mesh import make_host_mesh

ens = random_deep_ensemble(
    n_trees=12, depth=6, n_features=16, n_bins=256, p_dup=0.5, seed=7,
)
rng = np.random.default_rng(1)
q = rng.integers(0, 256, size=(128, 16)).astype(np.int32)
cm0 = build(ens)                    # compress='off'
cm1 = build(ens, compress="auto")
ref = np.asarray(cm0.engine(table_dtype="int32").raw_margin(q))
mesh = make_host_mesh()
out = {"rows": [cm0.table.n_rows, cm1.table.n_rows]}
for noc in ("accumulate", "hybrid"):
    eng = cm1.engine(mesh=mesh, noc_config=noc)
    m = np.asarray(eng.raw_margin(q))
    out[noc] = {
        "spmd": eng.spmd,
        "bit_equal": bool(np.array_equal(m, ref)),
        "max_err": float(np.abs(m - ref).max()),
    }
print(json.dumps(out))
"""


def test_compressed_bit_equal_under_shard_map():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", _SHARD_CODE], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert results["rows"][1] < results["rows"][0]
    for noc in ("accumulate", "hybrid"):
        res = results[noc]
        assert res["spmd"] == "shard_map", (noc, res)
        # k/16 leaves keep psum reductions exact: bit-equal, not allclose
        assert res["bit_equal"], (noc, res)


# -- adversarial corners -------------------------------------------------------


def _manual_table(low, high, leaf, *, tree_id=None, n_bins=256, dtype="int32"):
    low, high = np.asarray(low), np.asarray(high)
    r, f = low.shape
    return CAMTable(
        low=np.asarray(low, np.int32), high=np.asarray(high, np.int32),
        leaf=np.asarray(leaf, np.float32),
        tree_id=np.asarray(
            tree_id if tree_id is not None else np.zeros(r), np.int32
        ),
        class_id=np.zeros(r, dtype=np.int32),
        n_trees=int(np.max(tree_id) + 1) if tree_id is not None else 1,
        n_features=f, n_bins=n_bins, n_outputs=1,
        task="regression", kind="gbdt", base_score=0.0, n_classes=1,
        table_dtype=dtype,
    )


def test_all_wildcard_table_collapses_columns_keeps_rows():
    r, f, B = 4, 6, 256
    low = np.zeros((r, f)); high = np.full((r, f), B)
    t = _manual_table(low, high, [0.25, 0.5, 0.75, 1.0],
                      tree_id=np.arange(4))
    ct, rep = compress_table(t, level="full")
    # distinct trees: nothing merges; columns collapse to the 1-col floor
    assert ct.n_rows == 4 and ct.n_cols == 1
    assert rep.collapsed_columns == f - 1
    assert ct.feature_ids is not None and ct.feature_ids.shape == (1,)
    q = _queries(np.random.default_rng(0), 16, f, B)
    np.testing.assert_array_equal(_margins(ct, q=q), _margins(t, q=q))


def test_single_row_table_unchanged():
    t = _manual_table([[3, 0]], [[9, 256]], [1.5])
    for level in ("prune", "merge", "full"):
        ct, rep = compress_table(t, level=level)
        assert ct.n_rows == 1 and rep.rows_saved == 0
        q = _queries(np.random.default_rng(0), 8, 2, 256)
        np.testing.assert_array_equal(_margins(ct, q=q), _margins(t, q=q))


def test_empty_interval_rows_pruned_and_packability_restored():
    low = np.array([[5, 0], [7, 7], [0, 0]])   # row 1: empty; row 2 high=0
    high = np.array([[9, 256], [7, 256], [256, 0]])
    t = _manual_table(low, high, [0.5, 99.0, 99.0])
    # uncompressed, the empty rows break the packed uint8 encoding
    with pytest.raises(ValueError):
        kops.pack_tables(
            t.low, t.high, t.leaf[:, None], n_bins=256, dtype="uint8"
        )
    ct, rep = compress_table(t, level="prune")
    assert rep.pruned_empty == 2 and ct.n_rows == 1
    kops.pack_tables(
        ct.low, ct.high, ct.leaf[:, None], n_bins=256, dtype="uint8"
    )
    q = _queries(np.random.default_rng(0), 16, 2, 256)
    np.testing.assert_array_equal(_margins(ct, q=q), _margins(t, q=q))


def test_fully_pruned_table_keeps_wildcard_sentinel():
    t = _manual_table([[5, 5]], [[5, 256]], [42.0])  # single empty row
    ct, rep = compress_table(t, level="full")
    assert rep.sentinel_rows == 1 and ct.n_rows == 1
    assert float(ct.leaf[0]) == 0.0
    q = _queries(np.random.default_rng(0), 8, 2, 256)
    np.testing.assert_array_equal(_margins(ct, q=q), _margins(t, q=q))


def test_duplicate_identical_boxes_never_merge():
    # same tree, same box, same leaf: each copy contributes its value
    low = np.array([[4, 8], [4, 8]]); high = np.array([[10, 16], [10, 16]])
    t = _manual_table(low, high, [0.5, 0.5])
    ct, rep = compress_table(t, level="full")
    assert ct.n_rows == 2 and rep.merged_rows == 0
    q = np.array([[5, 9]], dtype=np.int32)
    np.testing.assert_array_equal(_margins(ct, q=q), [[1.0]])


def test_adjacent_same_leaf_rows_merge_but_different_leaves_do_not():
    # rows 0/1: adjacent in feature 0, identical leaf bits -> fuse;
    # rows 2/3: adjacent but different leaves -> must survive
    low = np.array([[0, 8], [6, 8], [0, 2], [6, 2]])
    high = np.array([[6, 16], [12, 16], [6, 8], [12, 8]])
    t = _manual_table(low, high, [0.5, 0.5, 0.25, 0.75])
    ct, rep = compress_table(t, level="merge")
    assert rep.merged_rows == 1 and ct.n_rows == 3
    q = _queries(np.random.default_rng(3), 64, 2, 256)
    np.testing.assert_array_equal(_margins(ct, q=q), _margins(t, q=q))


def test_cross_tree_adjacent_rows_never_merge():
    low = np.array([[0, 0], [6, 0]]); high = np.array([[6, 256], [12, 256]])
    t = _manual_table(low, high, [0.5, 0.5], tree_id=np.array([0, 1]))
    ct, _ = compress_table(t, level="merge")
    assert ct.n_rows == 2  # one query can match both: multiset would change


def test_grid_unreachable_pruning_exact_for_realizable_queries():
    # quantizer fit on 4 distinct values per feature: tiny effective grid
    rng = np.random.default_rng(4)
    x = rng.choice([0.1, 0.7, 1.3, 2.9], size=(64, 6))
    grid = FeatureQuantizer.fit(x, n_bins=256)
    ens = random_deep_ensemble(
        n_trees=6, depth=5, n_features=6, n_bins=256, p_dup=0.4, seed=13,
    )
    table = compile_ensemble(ens)
    ct, rep = compress_table(table, grid, level="full")
    # thresholds live all over [1, 256) but only ~4 bins are realizable:
    # the grid-aware stages must fire
    assert rep.pruned_unreachable > 0
    assert rep.widened_cells > 0
    q = grid.transform(x)  # every grid-realizable query shape
    np.testing.assert_array_equal(_margins(ct, q=q), _margins(table, q=q))


def test_grid_feature_count_mismatch_rejected():
    grid = FeatureQuantizer.fit(np.zeros((8, 3)), n_bins=256)
    t = _manual_table([[0, 0]], [[256, 256]], [1.0])
    with pytest.raises(ValueError, match="quantizer"):
        compress_table(t, grid, level="prune")


def test_compress_idempotent():
    ens = random_deep_ensemble(
        n_trees=8, depth=6, n_features=10, n_bins=256, p_dup=0.6, seed=9,
    )
    ct, rep = compress_table(compile_ensemble(ens), level="full")
    ct2, rep2 = compress_table(ct, level="full")
    assert rep2.rows_saved == 0
    assert rep2.collapsed_columns == 0
    assert ct2.n_rows == ct.n_rows and ct2.n_cols == ct.n_cols
    q = _queries(np.random.default_rng(0), 32, 10, 256)
    np.testing.assert_array_equal(_margins(ct2, q=q), _margins(ct, q=q))


# -- report + level plumbing ---------------------------------------------------


def test_report_arithmetic_and_roundtrip():
    rep = CompressionReport(
        level="full", rows_before=100, rows_after=40,
        cols_before=8, cols_after=6, pruned_empty=50, merged_rows=10,
        collapsed_columns=2,
    )
    assert rep.rows_saved == 60
    assert rep.row_savings_fraction == 0.6
    d = rep.to_dict()
    assert d["rows_saved"] == 60 and d["row_savings_fraction"] == 0.6
    # derived keys in the dict are ignored on the way back in
    assert CompressionReport.from_dict(d) == rep
    empty = CompressionReport(
        level="off", rows_before=0, rows_after=0, cols_before=1, cols_after=1,
    )
    assert empty.row_savings_fraction == 0.0


def test_resolve_level():
    assert resolve_level("auto") == "full"
    for lv in ("off", "prune", "merge", "full"):
        assert resolve_level(lv) == lv
    with pytest.raises(ValueError, match="compress level"):
        resolve_level("max")
    with pytest.raises(ValueError, match="compress"):
        DeployConfig(compress="bogus")
    assert DeployConfig().compress == "off"
    assert set(COMPRESS_LEVELS) == {"off", "prune", "merge", "full", "auto"}


def test_level_off_is_identity():
    ens = random_deep_ensemble(n_trees=4, depth=4, n_features=6, seed=2)
    table = compile_ensemble(ens)
    ct, rep = compress_table(table, level="off")
    assert ct is table and rep.rows_saved == 0


# -- build() wiring, artifact roundtrip, serving -------------------------------


def test_build_compress_wiring_and_summary():
    ens = random_deep_ensemble(
        n_trees=8, depth=6, n_features=10, n_bins=256, p_dup=0.5, seed=3,
    )
    cm = build(ens, compress="auto")
    assert cm.deploy.compress == "full"  # resolved, not the alias
    assert cm.compression is not None
    assert cm.compression["rows_saved"] > 0
    s = cm.summary()
    assert s["compress"] == "full" and s["rows_saved"] > 0
    assert s["columns"] == cm.table.n_cols
    # compression is baked into the table: an engine-time override of the
    # build-time knob must be rejected, like batching
    with pytest.raises(ValueError, match="compress"):
        cm.engine(compress="off")
    # uncompressed build records nothing
    cm0 = build(ens)
    assert cm0.compression is None and cm0.deploy.compress == "off"


def test_artifact_roundtrip_preserves_compression(tmp_path):
    # many features, few used -> column collapse -> feature_ids -> v3
    ens = random_deep_ensemble(n_trees=5, depth=4, n_features=24, seed=7)
    cm = build(ens, compress="auto")
    assert cm.table.feature_ids is not None
    path = str(tmp_path / "m")
    cm.save(path)
    sidecar = json.loads((tmp_path / "m.json").read_text())
    assert sidecar["schema_version"] == SCHEMA_VERSION
    cm2 = CompiledModel.load(path)
    np.testing.assert_array_equal(cm2.table.feature_ids, cm.table.feature_ids)
    assert cm2.compression == cm.compression
    q = _queries(np.random.default_rng(0), 32, 24, 256)
    np.testing.assert_array_equal(
        np.asarray(cm2.engine().raw_margin(q)),
        np.asarray(cm.engine().raw_margin(q)),
    )


def test_uncollapsed_compressed_artifact_stays_schema_v2(tmp_path):
    # prune-only: no feature_ids, so v2 readers still load the artifact
    ens = random_deep_ensemble(n_trees=6, depth=5, n_features=8,
                               p_dup=0.6, seed=4)
    cm = build(ens, compress="prune")
    assert cm.table.feature_ids is None and cm.compression is not None
    path = str(tmp_path / "m")
    cm.save(path)
    assert json.loads((tmp_path / "m.json").read_text())["schema_version"] == 2


def test_microbatcher_serves_compressed_engine_full_width_queries():
    ens = random_deep_ensemble(n_trees=5, depth=4, n_features=24, seed=7)
    cm = build(ens, compress="auto")
    eng = cm.engine()
    assert eng.feature_ids is not None  # collapsed: engine selects columns
    q = _queries(np.random.default_rng(1), 10, 24, 256)
    mb = MicroBatcher.for_engine(eng, kind="margin")
    ids = [mb.submit(q[i : i + 2]) for i in range(0, 10, 2)]
    out = mb.flush()
    direct = np.asarray(eng.raw_margin(q))
    got = np.concatenate([out[i] for i in ids], axis=0)
    np.testing.assert_array_equal(got, direct)
