"""Shared differential-oracle harness for the CAM kernels (kernel v3).

Every kernel version — v1 int32 exclusive-high, v2 packed inclusive-high,
and the v3 variants (fused epilogue, column clustering, dispatch-selected
configurations) — is gated by the same two references:

  * the SAME-BACKEND v1 int32 engine with the fused epilogue disabled.
    A packed / permuted / fused engine is a re-encoding of the identical
    computation at the same tile sizes, so the float32 reduction order
    matches and the margins must be BIT-EQUAL;
  * the plain jnp reference (``cam_match_ref`` via a jnp engine).  A
    different backend may reassociate the tiled float32 sums, so
    agreement is within 1 ULP (``rtol=1e-6, atol=1e-7``).

``XTIME_TEST_INTERPRET`` selects how the Pallas kernel runs under test:
``auto`` (default) resolves per platform exactly like production, ``1``
pins ``interpret=True``.  CI runs the harness under both settings.

This module lives on the tests path (imported bare, like
``_hypothesis_compat``); it holds shared fixtures/assertions only — no
test functions.
"""

from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro.core.compile import CAMTable
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.kernels import ops as kops
from repro.kernels.ref import cam_match_ref


def env_interpret() -> bool | str:
    """Interpret setting for engine-level tests: 'auto' or True.

    Driven by ``XTIME_TEST_INTERPRET`` so CI can force the interpreter
    explicitly ('1') and separately exercise the production 'auto'
    resolution path.
    """
    return True if os.environ.get("XTIME_TEST_INTERPRET", "auto") == "1" else "auto"


def env_interpret_kernel() -> bool | None:
    """Interpret setting for direct ``cam_match`` calls: True or None
    (None defers to the kernel's per-platform resolution)."""
    return True if os.environ.get("XTIME_TEST_INTERPRET", "auto") == "1" else None


# -- table generators ----------------------------------------------------------


def random_tables(rng, r, f, n_bins, *, edge_bias=0.3, wildcard=0.3):
    """Exclusive-high int32 tables with wildcard rows and dtype-boundary
    bin values (0 and n_bins-1 appear both as thresholds and queries)."""
    low = rng.integers(0, n_bins, size=(r, f)).astype(np.int32)
    high = np.minimum(low + rng.integers(1, n_bins, size=(r, f)), n_bins)
    high = high.astype(np.int32)
    # force dtype-boundary cells: [0, 1) at the bottom, [n_bins-1, n_bins)
    # at the top of the grid
    edge = rng.random((r, f)) < edge_bias
    lo_edge = rng.random((r, f)) < 0.5
    low[edge & lo_edge], high[edge & lo_edge] = 0, 1
    low[edge & ~lo_edge], high[edge & ~lo_edge] = n_bins - 1, n_bins
    dc = rng.random((r, f)) < wildcard
    low[dc], high[dc] = 0, n_bins
    # whole-row wildcard sentinels (ingest bias rows)
    low[: max(1, r // 16)] = 0
    high[: max(1, r // 16)] = n_bins
    return low, high


def compact_problem(rng, b, r, f, c):
    """Pre-packed inclusive uint8 tables + queries (kernel-native form)."""
    low = rng.integers(0, 256, size=(r, f)).astype(np.uint8)
    width = rng.integers(0, 256, size=(r, f))
    high = np.minimum(low.astype(np.int64) + width, 255).astype(np.uint8)
    dc = rng.random((r, f)) < 0.3  # always-match cells
    low[dc], high[dc] = 0, 255
    # never-match padding rows: low=1 > high=0
    low[-3:], high[-3:] = 1, 0
    leaf = rng.normal(size=(r, c)).astype(np.float32)
    leaf[-3:] = 0.0
    q = rng.integers(0, 256, size=(b, f)).astype(np.uint8)
    return q, low, high, leaf


def random_cam_table(rng, *, r=64, f=20, n_bins=256, n_outputs=2) -> CAMTable:
    """A standalone CAMTable over :func:`random_tables` bounds, for
    engine-level oracle checks without training an ensemble."""
    low, high = random_tables(rng, r, f, n_bins)
    return CAMTable(
        low=low, high=high,
        leaf=rng.normal(size=r).astype(np.float32),
        tree_id=np.arange(r, dtype=np.int32),
        class_id=(np.arange(r) % n_outputs).astype(np.int32),
        n_trees=r, n_features=f, n_bins=n_bins, n_outputs=n_outputs,
        task="multiclass" if n_outputs > 1 else "regression",
        kind="gbdt", base_score=0.25, n_classes=n_outputs,
        table_dtype="uint8" if n_bins <= 256 else "uint16",
    )


# -- kernel-level differential runs --------------------------------------------


def run_encoding(q, low, high, leaf, *, n_bins, dtype, mode, backend, b, c):
    """One cam_match evaluation in the given table encoding/backend."""
    lo_p, hi_p, lm, incl = kops.pack_tables(
        low, high, leaf, r_blk=32, n_bins=n_bins, dtype=dtype,
    )
    assert incl == (np.dtype(dtype).kind == "u")
    mask = kops.wildcard_tile_mask(
        lo_p, hi_p, r_blk=32, f_blk=128, n_bins=n_bins, inclusive=incl,
    )
    kernel_mode = "inclusive" if incl else mode
    qp = kops.pad_queries(jnp.asarray(q), lo_p.shape[1], b_blk=32, dtype=dtype)
    if backend == "pallas":
        out = kops.cam_match(
            qp, jnp.asarray(lo_p), jnp.asarray(hi_p), jnp.asarray(lm),
            jnp.asarray(mask), out_b=b, out_c=c, b_blk=32, r_blk=32,
            mode=kernel_mode, interpret=env_interpret_kernel(),
        )
    else:
        out = cam_match_ref(
            qp, jnp.asarray(lo_p), jnp.asarray(hi_p), jnp.asarray(lm),
            mode=kernel_mode,
        )[:b, :c]
    return np.asarray(out)


def assert_packed_reencoding_bit_equal(seed, n_bins, dtype, mode, backend):
    """Packed tables are a RE-ENCODING of the v1 int32 layout: identical
    bits out when only the encoding differs (same shapes, same backend,
    hence the same float reduction order)."""
    rng = np.random.default_rng(seed)
    b, r, f, c = 32, 96, 11, 3
    low, high = random_tables(rng, r, f, n_bins)
    leaf = rng.normal(size=(r, c)).astype(np.float32)
    q = rng.integers(0, n_bins, size=(b, f)).astype(np.int32)
    # boundary queries
    q[:4] = 0
    q[4:8] = n_bins - 1

    kw = dict(n_bins=n_bins, mode=mode, backend=backend, b=b, c=c)
    oracle = run_encoding(q, low, high, leaf, dtype="int32", **kw)
    packed = run_encoding(q, low, high, leaf, dtype=dtype, **kw)
    np.testing.assert_array_equal(packed, oracle)
    # and the match SEMANTICS (not just the float sums) agree with the
    # plain unpadded reference within float32 reassociation
    ref = np.asarray(
        cam_match_ref(jnp.asarray(q), jnp.asarray(low), jnp.asarray(high),
                      jnp.asarray(leaf), mode="direct")
    )
    np.testing.assert_allclose(packed, ref, rtol=1e-5, atol=1e-6)


# -- the engine-level oracle gate ---------------------------------------------


def assert_bit_equal_to_oracle(
    table: CAMTable,
    queries: np.ndarray,
    deploy: DeployConfig,
) -> np.ndarray:
    """The shared differential-oracle gate every kernel version must pass.

    Binds ``deploy`` on ``table`` and asserts its margins are

      1. BIT-EQUAL to the same-backend engine on the mode's CANONICAL
         table layout with the fused epilogue off (the registry's pinned
         ``table_dtype_policy``, int32 for the hard modes — same tile
         sizes → identical float32 reduction order), and
      2. within 1 ULP of the jnp reference engine (mode='soft' compares
         against the jnp soft engine at the SAME tau; every hard mode
         against the jnp 'direct' int32 engine).

    Returns the candidate margins for further assertions.
    """
    from repro.core.precision import get_cell_mode

    candidate = XTimeEngine.from_config(table, deploy)
    m = np.asarray(candidate.raw_margin(queries))

    policy = get_cell_mode(deploy.mode).table_dtype_policy
    v1 = XTimeEngine.from_config(
        table,
        deploy.replace(table_dtype=policy or "int32", fuse_epilogue=False),
    )
    np.testing.assert_array_equal(m, np.asarray(v1.raw_margin(queries)))

    if get_cell_mode(deploy.mode).soft:
        ref_cfg = DeployConfig(
            backend="jnp", mode="soft", tau=deploy.tau,
            table_dtype="float32",
            b_blk=deploy.b_blk, r_blk=deploy.r_blk, f_blk=deploy.f_blk,
        )
    else:
        ref_cfg = DeployConfig(
            backend="jnp", mode="direct", table_dtype="int32",
            b_blk=deploy.b_blk, r_blk=deploy.r_blk, f_blk=deploy.f_blk,
        )
    ref = XTimeEngine.from_config(table, ref_cfg)
    np.testing.assert_allclose(
        m, np.asarray(ref.raw_margin(queries)), rtol=1e-6, atol=1e-7,
    )
    return m
