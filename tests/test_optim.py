"""Optimizer: AdamW math vs a numpy reference; schedules; compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamW, AdamWConfig, lr_schedule
from repro.optim.compress import compress_tree, decompress_tree, quantize_int8


def _np_adamw_step(p, g, m, v, step, cfg, decay_mask):
    lr = float(lr_schedule(cfg, jnp.int32(step)))
    gn = np.sqrt(np.sum(g * g))
    scale = min(1.0, cfg.clip_norm / max(gn, 1e-12))
    g = g * scale
    m2 = cfg.b1 * m + (1 - cfg.b1) * g
    v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
    mhat = m2 / (1 - cfg.b1 ** step)
    vhat = v2 / (1 - cfg.b2 ** step)
    p2 = p - lr * (mhat / (np.sqrt(vhat) + cfg.eps) + cfg.weight_decay * decay_mask * p)
    return p2, m2, v2


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=2, decay_steps=100,
                      weight_decay=0.05, clip_norm=10.0)
    opt = AdamW(cfg)
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
    state = opt.init(params)
    np_p = {k: np.asarray(v) for k, v in params.items()}
    np_m = {k: np.zeros_like(v) for k, v in np_p.items()}
    np_v = {k: np.zeros_like(v) for k, v in np_p.items()}
    for step in range(1, 4):
        grads = {k: jnp.asarray(rng.normal(size=v.shape), jnp.float32)
                 for k, v in params.items()}
        # reference (shared global clip over both tensors)
        g_all = np.concatenate([np.asarray(grads[k]).ravel() for k in ("w", "b")])
        gn = np.sqrt(np.sum(g_all ** 2))
        scale = min(1.0, cfg.clip_norm / max(gn, 1e-12))
        lr = float(lr_schedule(cfg, jnp.int32(step)))
        for k in ("w", "b"):
            g = np.asarray(grads[k]) * scale
            np_m[k] = cfg.b1 * np_m[k] + (1 - cfg.b1) * g
            np_v[k] = cfg.b2 * np_v[k] + (1 - cfg.b2) * g * g
            mhat = np_m[k] / (1 - cfg.b1 ** step)
            vhat = np_v[k] / (1 - cfg.b2 ** step)
            dm = 1.0 if np_p[k].ndim >= 2 else 0.0
            np_p[k] = np_p[k] - lr * (mhat / (np.sqrt(vhat) + cfg.eps)
                                      + cfg.weight_decay * dm * np_p[k])
        params, state, metrics = opt.update(grads, state, params)
        for k in ("w", "b"):
            np.testing.assert_allclose(np.asarray(params[k]), np_p[k],
                                       rtol=2e-5, atol=2e-6)
    assert int(state["step"]) == 3


def test_lr_schedule_shape():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100, 200)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert 0.1 < lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6
    assert abs(lrs[5] - 0.1) < 1e-6  # floor after decay


def test_quantize_int8_roundtrip_accuracy():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(x) - np.asarray(q, np.float32) * float(s))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_is_unbiased_over_steps():
    """Sum of dequantized grads + final residual == sum of true grads."""
    rng = np.random.default_rng(2)
    grads_seq = [
        {"w": jnp.asarray(rng.normal(size=(32, 8)) * 10 ** rng.uniform(-3, 0),
                          jnp.float32)}
        for _ in range(8)
    ]
    residual = None
    total_sent = np.zeros((32, 8), np.float32)
    for g in grads_seq:
        (q, s), residual = compress_tree(g, residual)
        sent = decompress_tree(q, s, g)
        total_sent += np.asarray(sent["w"])
    total_true = sum(np.asarray(g["w"]) for g in grads_seq)
    np.testing.assert_allclose(
        total_sent + np.asarray(residual["w"]), total_true, rtol=1e-4, atol=1e-4
    )
    # and the carried residual stays bounded by one quantization step
    assert np.abs(np.asarray(residual["w"])).max() < 1.0
