"""Compact uint8 / inclusive-bound kernel mode (§Perf X1 table format)."""

import jax.numpy as jnp
import numpy as np
from oracles import compact_problem

from repro.kernels.cam_match import cam_match_pallas
from repro.kernels.ref import cam_match_ref


def test_inclusive_uint8_kernel_matches_oracle():
    rng = np.random.default_rng(11)
    b, r, f, c = 128, 512, 128, 8
    q, low, high, leaf = compact_problem(rng, b, r, f, c)
    out = cam_match_pallas(
        jnp.asarray(q), jnp.asarray(low), jnp.asarray(high), jnp.asarray(leaf),
        b_blk=128, r_blk=256, mode="inclusive", interpret=True,
    )
    ref = cam_match_ref(
        jnp.asarray(q), jnp.asarray(low), jnp.asarray(high), jnp.asarray(leaf),
        mode="inclusive",
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_inclusive_equals_exclusive_semantics():
    """inclusive(low, high-1) == direct(low, high) for high >= 1."""
    rng = np.random.default_rng(12)
    b, r, f, c = 32, 128, 16, 2
    low = rng.integers(0, 200, size=(r, f)).astype(np.int32)
    high = low + rng.integers(1, 56, size=(r, f))  # exclusive, >= low+1
    leaf = rng.normal(size=(r, c)).astype(np.float32)
    q = rng.integers(0, 256, size=(b, f)).astype(np.int32)
    a = cam_match_ref(jnp.asarray(q), jnp.asarray(low), jnp.asarray(high),
                      jnp.asarray(leaf), mode="direct")
    b_ = cam_match_ref(jnp.asarray(q), jnp.asarray(low), jnp.asarray(high - 1),
                       jnp.asarray(leaf), mode="inclusive")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-6)
