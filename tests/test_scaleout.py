"""Scale-out equivalence: the shard_map and GSPMD engine paths against the
single-device engine, exhaustively over (spmd × noc_config × cell mode)
— the DESIGN.md §8 bit-equivalence guarantee.

The multi-device sweep reuses the 8-fake-host-device subprocess harness
of tests/test_sharding.py: ONE subprocess builds the model and loops the
whole configuration grid (amortizing training/compile), printing per-
config max errors as JSON.  The guarantee it asserts:

  * shard_map and GSPMD produce BIT-IDENTICAL margins to each other
    (same per-shard partial sums, same reduction tree), and
  * both match the single-device engine within one float32 ULP of
    reduction reordering, with predictions exactly equal.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.deploy import DeployConfig

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    # pin the platform: fake host devices need CPU anyway, and leaving it
    # unset makes jax probe the TPU plugin, which stalls for minutes on
    # the (absent) GCP metadata server in sandboxed environments
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# -- config-level behaviour (no mesh needed) -----------------------------------


def test_deploy_config_spmd_validation():
    assert DeployConfig().spmd == "auto"
    DeployConfig(spmd="gspmd")
    DeployConfig(spmd="shard_map")
    with pytest.raises(ValueError):
        DeployConfig(spmd="magic")
    with pytest.raises(ValueError):
        DeployConfig(noc_config="sideways")


def test_deploy_config_hybrid_and_spmd_round_trip():
    cfg = DeployConfig(noc_config="hybrid", spmd="shard_map")
    assert DeployConfig.from_dict(cfg.to_dict()) == cfg
    # pre-spmd sidecars (schema v1 artifacts saved before the field
    # existed) must still load, defaulting to 'auto'
    legacy = {k: v for k, v in cfg.to_dict().items() if k != "spmd"}
    assert DeployConfig.from_dict(legacy).spmd == "auto"


def test_engine_resolves_spmd_without_mesh():
    from repro.core.compile import compile_ensemble
    from repro.core.engine import XTimeEngine
    from repro.core.trees import GBDTParams, train_gbdt

    rng = np.random.default_rng(0)
    xb = rng.integers(0, 16, size=(64, 4))
    y = (xb.sum(1) > 30).astype(np.int64)
    ens = train_gbdt(xb, y, task="binary", n_bins=16,
                     params=GBDTParams(n_rounds=2, max_leaves=4))
    table = compile_ensemble(ens)
    # no mesh: both 'auto' and an explicit 'shard_map' degrade to plain jit
    assert XTimeEngine(table, config=DeployConfig()).spmd == "gspmd"
    eng = XTimeEngine(table, config=DeployConfig(spmd="shard_map"))
    assert eng.spmd == "gspmd"
    np.testing.assert_allclose(
        np.asarray(eng.raw_margin(xb)), ens.raw_margin(xb),
        rtol=1e-4, atol=1e-5,
    )


def test_resolved_deploy_spmd_from_mesh():
    from repro.api import build
    from repro.core.trees import GBDTParams, train_gbdt
    from repro.launch.mesh import make_host_mesh

    rng = np.random.default_rng(0)
    xb = rng.integers(0, 16, size=(64, 4))
    y = (xb.sum(1) > 30).astype(np.int64)
    cm = build(train_gbdt(xb, y, task="binary", n_bins=16,
                          params=GBDTParams(n_rounds=2, max_leaves=4)))
    assert cm.resolved_deploy(mesh=None).spmd == "gspmd"
    mesh = make_host_mesh()
    assert cm.resolved_deploy(mesh=mesh).spmd == "shard_map"
    assert cm.resolved_deploy(mesh=mesh, spmd="gspmd").spmd == "gspmd"
    # the resolved engine actually binds in the resolved mode
    assert cm.engine(mesh=mesh).spmd == "shard_map"


# -- the 8-device property sweep -----------------------------------------------

_SWEEP = r"""
import json, numpy as np
import jax
from repro.core.compile import compile_ensemble
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import train_gbdt, GBDTParams
from repro.data.tabular import make_dataset
from repro.launch.mesh import make_host_mesh

ds = make_dataset("eye")
q = FeatureQuantizer.fit(ds.x_train, 256)
xb = q.transform(ds.x_train)[:64]
ens = train_gbdt(q.transform(ds.x_train), ds.y_train, task="multiclass",
                 n_bins=256, n_classes=ds.n_classes,
                 params=GBDTParams(n_rounds=3, max_leaves=16))
table = compile_ensemble(ens)
mesh = make_host_mesh(2, 4)

results = {"n_dev": len(jax.devices()), "cases": []}
MODES = ("direct", "inclusive", "msb_lsb", "two_cycle")
NOCS = ("accumulate", "batch", "hybrid")

for mode in MODES:
    # single-device reference engine for this cell mode
    e0 = XTimeEngine(table, config=DeployConfig(mode=mode))
    m0 = np.asarray(e0.raw_margin(xb))
    p0 = np.asarray(e0.predict(xb))
    for noc in NOCS:
        margins = {}
        for spmd in ("gspmd", "shard_map"):
            if noc == "hybrid" and spmd == "gspmd":
                continue  # hybrid is shard_map-only by construction
            cfg = DeployConfig(mode=mode, noc_config=noc, spmd=spmd)
            e = XTimeEngine(table, config=cfg, mesh=mesh)
            m = np.asarray(e.raw_margin(xb))
            p = np.asarray(e.predict(xb))
            margins[spmd] = m
            results["cases"].append({
                "mode": mode, "noc": noc, "spmd": spmd,
                "maxerr_vs_single": float(np.abs(m - m0).max()),
                "pred_equal": bool((p == p0).all()),
            })
        if len(margins) == 2:
            results["cases"][-1]["bitwise_vs_gspmd"] = bool(
                (margins["gspmd"] == margins["shard_map"]).all()
            )

# pallas backend spot-check under shard_map (interpret mode; small tiles)
for noc in NOCS:
    cfg = DeployConfig(backend="pallas", b_blk=8, r_blk=64,
                       noc_config=noc, spmd="shard_map")
    e = XTimeEngine(table, config=cfg, mesh=mesh)
    m = np.asarray(e.raw_margin(xb))
    e0 = XTimeEngine(table, config=DeployConfig())
    results["cases"].append({
        "mode": "direct", "noc": noc, "spmd": "shard_map", "backend": "pallas",
        "maxerr_vs_single": float(np.abs(m - np.asarray(e0.raw_margin(xb))).max()),
        "pred_equal": bool(
            (np.asarray(e.predict(xb)) == np.asarray(e0.predict(xb))).all()
        ),
    })
print(json.dumps(results))
"""


def test_spmd_paths_match_single_device_all_modes():
    res = _run_subprocess(_SWEEP)
    assert res["n_dev"] == 8
    # jnp grid: 4 modes x (accumulate, batch: 2 spmds; hybrid: 1) = 20,
    # plus 3 pallas spot-checks
    assert len(res["cases"]) == 23
    for case in res["cases"]:
        # <= 1 float32 ULP of reduction reordering at these magnitudes
        assert case["maxerr_vs_single"] < 1e-5, case
        assert case["pred_equal"], case
        if "bitwise_vs_gspmd" in case:
            assert case["bitwise_vs_gspmd"], case


_SERVE_SWEEP = r"""
import json, numpy as np
import jax
from repro.api import build
from repro.core.deploy import DeployConfig
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import train_gbdt, GBDTParams
from repro.data.tabular import make_dataset
from repro.launch.mesh import make_host_mesh
from repro.serve import ServeLoop, TableRegistry

ds = make_dataset("churn")
q = FeatureQuantizer.fit(ds.x_train, 256)
xb = q.transform(ds.x_train)[:32].astype(np.int32)
ens = train_gbdt(q.transform(ds.x_train), ds.y_train, task="binary",
                 n_bins=256, params=GBDTParams(n_rounds=3, max_leaves=16))
cm = build(ens)
mesh = make_host_mesh(2, 4)
reg = TableRegistry(mesh=mesh)
entry = reg.register("m", cm)
loop = ServeLoop(reg, window_s=10.0, flush_rows=64)
handles = [loop.submit("m", row) for row in xb]
loop.drain()
served = np.concatenate([loop.result(h) for h in handles])
expected = np.asarray(cm.engine().predict(xb))
print(json.dumps({
    "spmd": entry.engine.spmd,
    "n_dev": len(jax.devices()),
    "serve_equal": bool((served == expected).all()),
    "batch_multiple": entry.engine.batch_multiple,
}))
"""


def test_registry_serves_shard_map_for_free():
    """A mesh registry binds the shard_map path with no caller changes,
    and the micro-batched serving outputs still match single-device."""
    res = _run_subprocess(_SERVE_SWEEP)
    assert res["n_dev"] == 8
    assert res["spmd"] == "shard_map"
    assert res["serve_equal"]
    # jnp backend on a (2, 4) mesh: buckets must split across 2 data shards
    assert res["batch_multiple"] == 2
