"""shard_map all-to-all MoE == pjit moe_forward (no-drop regime), with
gradients, on an 8-device host mesh."""

import json
import os
import subprocess
import sys

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    # pin the platform: fake host devices need CPU anyway, and leaving it
    # unset makes jax probe the TPU plugin, which stalls for minutes on
    # the (absent) GCP metadata server in sandboxed environments
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_shardmap_moe_matches_pjit_moe():
    code = r"""
import json, numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.models.moe import init_moe_params, moe_forward
from repro.models.moe_shardmap import make_shardmap_moe

mesh = make_host_mesh(2, 4)
d, f, e, k = 32, 64, 8, 2
p = init_moe_params(jax.random.key(0), d, f, e, 1, jnp.float32)
x = jax.random.normal(jax.random.key(1), (4, 16, d), jnp.float32)

ref, aux_ref = jax.jit(
    lambda pp, xx: moe_forward(pp, xx, top_k=k, capacity_factor=16.0)
)(p, x)

sm_moe = make_shardmap_moe(mesh)
xs = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
ps = jax.device_put(p, jax.tree.map(
    lambda a: NamedSharding(mesh, P("model", None, None) if a.ndim == 3
              else P(*([None] * a.ndim))), p))
out, aux = jax.jit(
    lambda pp, xx: sm_moe(pp, xx, top_k=k, capacity_factor=16.0)
)(ps, xs)

# gradients flow through the shard_map (router + experts + shared)
def loss(pp, xx):
    y, a = sm_moe(pp, xx, top_k=k, capacity_factor=16.0)
    return jnp.sum(y * y) + 0.01 * a
g = jax.jit(jax.grad(loss))(ps, xs)
gnorm = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))

print(json.dumps({
    "out_err": float(jnp.abs(out - ref).max()),
    "aux_err": float(jnp.abs(aux - aux_ref)),
    "scale": float(jnp.abs(ref).max()),
    "grad_norm_finite": bool(np.isfinite(gnorm) and gnorm > 0),
}))
"""
    res = _run(code)
    assert res["out_err"] < 1e-4 * max(1.0, res["scale"]), res
    assert res["aux_err"] < 1e-5, res
    assert res["grad_norm_finite"], res
