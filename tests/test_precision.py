"""Eq. 1-3 / Table I precision-doubling scheme: bit-exact equivalence."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import precision


def test_exhaustive_over_queries_random_thresholds():
    """All 256 query values x 4096 random (T_L, T_H) pairs."""
    rng = np.random.default_rng(0)
    q = jnp.arange(256)[:, None]
    tl = jnp.asarray(rng.integers(0, 256, size=4096))[None, :]
    th = jnp.asarray(rng.integers(0, 257, size=4096))[None, :]
    d = precision.match_direct(q, tl, th)
    assert bool(jnp.all(d == precision.match_msb_lsb(q, tl, th)))
    assert bool(jnp.all(d == precision.match_two_cycle(q, tl, th)))


def test_exhaustive_small_grid():
    """Fully exhaustive q x T_L x T_H over a coarse grid crossing every
    MSB/LSB boundary combination."""
    vals = np.array([0, 1, 15, 16, 17, 31, 32, 127, 128, 129, 240, 255, 256])
    q = jnp.arange(256).reshape(-1, 1, 1)
    tl = jnp.asarray(vals[vals < 256]).reshape(1, -1, 1)
    th = jnp.asarray(vals).reshape(1, 1, -1)
    d = precision.match_direct(q, tl, th)
    m = precision.match_msb_lsb(q, tl, th)
    c = precision.match_two_cycle(q, tl, th)
    assert bool(jnp.all(d == m)) and bool(jnp.all(d == c))


@settings(max_examples=100, deadline=None)
@given(
    q=st.integers(0, 255),
    tl=st.integers(0, 255),
    th=st.integers(0, 256),
)
def test_property_single_cell(q, tl, th):
    d = bool(precision.match_direct(jnp.int32(q), jnp.int32(tl), jnp.int32(th)))
    assert d == (tl <= q < th)
    assert d == bool(precision.match_msb_lsb(jnp.int32(q), jnp.int32(tl), jnp.int32(th)))
    assert d == bool(precision.match_two_cycle(jnp.int32(q), jnp.int32(tl), jnp.int32(th)))


def test_dont_care_cell_always_matches():
    q = jnp.arange(256)
    assert bool(jnp.all(precision.match_msb_lsb(q, jnp.int32(0), jnp.int32(256))))


def test_macro_cell_count():
    # the paper's point: 2 cells for 8-bit, not 2^(N-M) = 16 (§III-B)
    assert precision.macro_cell_count(130, n_bits=8) == 260
    assert precision.macro_cell_count(130, n_bits=4) == 130
    with pytest.raises(ValueError):
        precision.macro_cell_count(10, n_bits=12)


def test_split_roundtrip():
    v = jnp.arange(256)
    hi, lo = precision.split_msb_lsb(v)
    assert bool(jnp.all(hi * 16 + lo == v))
    assert int(hi.max()) == 15 and int(lo.max()) == 15
