"""Async serving tier: bit-equality vs the synchronous ServeLoop oracle,
heartbeat-timeout failover, crash failover, straggler exclusion, hot-swap
under live traffic, overload shedding, elastic restore, adaptive flush
windows, deterministic traffic replay, and thread-safety of the shared
MicroBatcher/TableRegistry (DESIGN.md §12)."""

import threading
import time

import numpy as np
import pytest

from repro.api import build
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import make_dataset
from repro.ft.runtime import StragglerMonitor
from repro.serve import (
    AdaptiveWindow,
    ClusterClosed,
    ClusterServer,
    MicroBatcher,
    ServeLoop,
    ShedError,
    TableRegistry,
    make_trace,
    replay_trace,
)

pytestmark = pytest.mark.filterwarnings(
    "ignore:pallas TPU support unavailable"
)


@pytest.fixture(scope="module")
def served():
    """(artifact_v1, artifact_v2, xb_test) — v1/v2 differ somewhere."""
    ds = make_dataset("churn")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    xb_tr = q.transform(ds.x_train)
    ens_a = train_gbdt(
        xb_tr, ds.y_train, task="binary", n_bins=256,
        params=GBDTParams(n_rounds=4, max_leaves=16),
    )
    ens_b = train_gbdt(
        xb_tr, ds.y_train, task="binary", n_bins=256,
        params=GBDTParams(n_rounds=2, max_leaves=8),
    )
    xb = q.transform(ds.x_test).astype(np.int32)[:256]
    return build(ens_a), build(ens_b), xb


def _server(**kw):
    defaults = dict(
        n_replicas=2, flush_rows=16, max_batch=128, heartbeat_timeout_s=0.6,
        monitor_interval_s=0.02,
    )
    defaults.update(kw)
    return ClusterServer(**defaults)


def _oracle_results(artifact, trace, xb, *, flush_rows=16):
    """Replay the identical trace through the synchronous ServeLoop."""
    reg = TableRegistry()
    reg.register("m", artifact)
    loop = ServeLoop(reg, window_s=100.0, flush_rows=flush_rows, max_batch=128)
    res = replay_trace(loop.submit, trace, {"m": xb}, speed=0)
    loop.drain()
    return [loop.result(h) for h in res.handles]


# -- adaptive window ----------------------------------------------------------


def test_adaptive_window_tracks_arrival_rate():
    w = AdaptiveWindow(min_s=1e-3, max_s=0.1, target_rows=10, alpha=0.5)
    assert w.window_s == 0.1  # no observations yet: maximum coalescing wait
    t = 0.0
    for _ in range(20):  # 1 row per ms -> window ~ 10 rows * 1ms = 10ms
        w.observe(t)
        t += 1e-3
    assert 5e-3 < w.window_s < 2e-2
    for _ in range(20):  # traffic goes quiet: window grows to the cap
        w.observe(t)
        t += 10.0
    assert w.window_s == 0.1
    for _ in range(30):  # flood: window floors at min_s
        w.observe(t)
        t += 1e-6
    assert w.window_s == 1e-3


def test_adaptive_window_multirow_counts_rows():
    w = AdaptiveWindow(min_s=1e-4, max_s=1.0, target_rows=100, alpha=1.0)
    w.observe(0.0, n_rows=1)
    w.observe(1e-2, n_rows=10)  # 10 rows in 10ms -> 1ms/row -> 100ms window
    assert w.window_s == pytest.approx(0.1)


# -- straggler monitor (EWMA mode) -------------------------------------------


def test_straggler_ewma_flags_and_freezes_baseline():
    mon = StragglerMonitor(threshold=3.0, ewma_alpha=0.5, min_samples=4)
    for s in range(6):
        assert not mon.record(s, 0.01)
    assert mon.baseline == pytest.approx(0.01)
    # flagged samples must NOT be folded into the baseline — a replica
    # that turns slow keeps getting flagged instead of normalizing
    for s in range(6, 10):
        assert mon.record(s, 1.0)
    assert mon.baseline == pytest.approx(0.01)
    assert len(mon.events) == 4 and "baseline" in mon.events[0]


def test_straggler_median_mode_unchanged():
    mon = StragglerMonitor(threshold=3.0)
    for s in range(10):
        assert not mon.record(s, 0.1)
    assert mon.record(10, 1.0)
    assert mon.events[0]["median"] == pytest.approx(0.1)


# -- traffic generation -------------------------------------------------------


def test_trace_deterministic_and_heavy_tailed():
    a = make_trace(["x", "y"], 500, seed=11, mean_interval_s=1e-3)
    b = make_trace(["x", "y"], 500, seed=11, mean_interval_s=1e-3)
    assert a == b  # same seed, same bits
    c = make_trace(["x", "y"], 500, seed=12, mean_interval_s=1e-3)
    assert a != c
    gaps = np.diff([0.0] + [r.t for r in a.requests])
    assert gaps.max() > 5 * gaps.mean()  # heavy tail: bursts + long quiets
    # zipf popularity: the first-listed model is the hottest
    n_x = sum(r.model == "x" for r in a.requests)
    assert n_x > len(a.requests) // 2
    assert all(r.n_rows >= 1 for r in a.requests)


def test_trace_marks_and_stream_wrap():
    tr = make_trace(
        {"m": 10}, 50, seed=0, marks=[(0.5, "kill"), (0.0, "start")],
    )
    assert {m.name for m in tr.marks} == {"kill", "start"}
    assert all(0 <= r.row_start < 10 for r in tr.requests)
    assert tr.horizon_s >= tr.marks[0].t
    merged = tr.merged()
    assert len(merged) == 52
    assert all(
        merged[i].t <= merged[i + 1].t for i in range(len(merged) - 1)
    )


def test_replay_paces_and_fires_marks():
    # fake clock: sleep() advances time instantly -> submits land exactly
    # on the (speed-warped) schedule
    t = [0.0]
    trace = make_trace(["m"], 20, seed=3, mean_interval_s=1e-2,
                       marks=[(0.5, "mid")])
    seen = []
    fired = []
    res = replay_trace(
        lambda model, q: seen.append((t[0], q.shape[0])) or len(seen),
        trace, {"m": np.zeros((8, 4), np.int32)},
        speed=2.0,
        callbacks={"mid": lambda: fired.append(t[0])},
        clock=lambda: t[0],
        sleep=lambda d: t.__setitem__(0, t[0] + d),
    )
    assert res.submitted == 20 and res.shed == 0
    for (at, _), req in zip(seen, trace.requests):
        assert at == pytest.approx(req.t / 2.0)
    assert len(fired) == 1
    assert fired[0] == pytest.approx(trace.marks[0].t / 2.0)


# -- bit-equality vs the synchronous oracle -----------------------------------


def test_cluster_bit_equal_to_sync_loop(served):
    art, _, xb = served
    trace = make_trace(["m"], 120, seed=5, mean_interval_s=2e-4, mean_rows=1.5)
    oracle = _oracle_results(art, trace, xb)
    with _server() as srv:
        srv.register("m", art)
        res = replay_trace(srv.submit, trace, {"m": xb}, speed=0)
        srv.drain(timeout=60)
        stats = srv.stats("m")
        assert stats.n_requests == 120
        assert stats.n_rows == trace.n_rows
        assert stats.p99_ms >= stats.p50_ms >= 0.0
        for h, want in zip(res.handles, oracle):
            np.testing.assert_array_equal(h.result(5), want)


def test_cluster_margin_kind_close_to_oracle(served):
    art, _, xb = served
    trace = make_trace(["m"], 40, seed=6, mean_interval_s=2e-4)
    with _server(kind="margin") as srv:
        srv.register("m", art)
        res = replay_trace(srv.submit, trace, {"m": xb}, speed=0)
        srv.drain(timeout=60)
        eng = art.engine()
        for h, req in zip(res.handles, trace.requests):
            rows = np.take(
                xb, np.arange(req.row_start, req.row_start + req.n_rows),
                axis=0, mode="wrap",
            )
            # bucket shape changes XLA accumulation order (same tolerance
            # as the sync serving tests)
            np.testing.assert_allclose(
                h.result(5), np.asarray(eng.raw_margin(rows)),
                rtol=1e-5, atol=1e-6,
            )


# -- failure modes ------------------------------------------------------------


def test_heartbeat_timeout_failover_preserves_bits(served):
    art, _, xb = served
    trace = make_trace(["m"], 100, seed=8, mean_interval_s=2e-4)
    oracle = _oracle_results(art, trace, xb)
    with _server() as srv:
        srv.register("m", art)
        # warm both replicas, then go silent on one mid-traffic
        warm = replay_trace(srv.submit, trace, {"m": xb}, speed=0)
        srv.drain(timeout=60)
        srv.inject_hang(0)
        res = replay_trace(srv.submit, trace, {"m": xb}, speed=0)
        srv.drain(timeout=60)  # monitor must declare death + re-route
        rep = srv.report()
        assert rep["failovers"] >= 1
        assert rep["replicas"][0]["state"] == "dead"
        for h, want in zip(warm.handles, oracle):
            np.testing.assert_array_equal(h.result(5), want)
        for h, want in zip(res.handles, oracle):
            np.testing.assert_array_equal(h.result(5), want)


def test_crash_failover_mid_traffic(served):
    art, _, xb = served
    trace = make_trace(["m"], 100, seed=9, mean_interval_s=2e-4)
    oracle = _oracle_results(art, trace, xb)
    with _server() as srv:
        srv.register("m", art)
        srv.inject_crash(0)  # fail-stop on its first routed job
        res = replay_trace(srv.submit, trace, {"m": xb}, speed=0)
        srv.drain(timeout=60)
        rep = srv.report()
        assert rep["replicas"][0]["state"] == "dead"
        assert rep["failovers"] >= 1
        assert rep["replicas"][1]["served_requests"] == 100
        for h, want in zip(res.handles, oracle):
            np.testing.assert_array_equal(h.result(5), want)


def test_straggler_excluded_from_routing(served):
    art, _, xb = served
    # heartbeat_timeout_s must exceed worst-case flush time (workers beat
    # BETWEEN jobs): a 1s injected delay under a 0.6s timeout reads as
    # death, not straggling (DESIGN.md §12)
    with _server(
        straggler_threshold=3.0, straggler_strikes=2,
        heartbeat_timeout_s=10.0,
    ) as srv:
        srv.register("m", art)
        # warmup: enough flushes to pull the shared EWMA baseline down to
        # steady-state flush time (first flushes pay jit compiles)
        for _ in range(12):
            hs = [srv.submit("m", xb[i]) for i in range(16)]
            srv.drain(timeout=60)
            for h in hs:
                h.result(5)
        srv.inject_delay(0, 1.0)
        handles = []
        for _ in range(6):  # alternating routing feeds the slow replica
            hs = [srv.submit("m", xb[i]) for i in range(16)]
            srv.drain(timeout=60)
            handles.extend(hs)
        rep = srv.report()
        assert rep["replicas"][0]["state"] == "excluded"
        assert rep["straggler_events"] >= 2
        direct = np.asarray(art.engine().predict(xb[:16]))
        for i, h in enumerate(handles):  # slow != wrong
            j = i % 16
            np.testing.assert_array_equal(h.result(5), direct[j : j + 1])
        # excluded replica no longer receives new work
        before = srv.report()["replicas"][0]["flushes"]
        for i in range(16):
            srv.submit("m", xb[i])
        srv.drain(timeout=60)
        assert srv.report()["replicas"][0]["flushes"] == before


def test_elastic_restore_rejoins_rotation(served):
    art, _, xb = served
    with _server() as srv:
        srv.register("m", art)
        srv.kill_replica(0)
        assert srv.report()["replicas"][0]["state"] == "dead"
        hs = [srv.submit("m", xb[i]) for i in range(32)]
        srv.drain(timeout=60)
        with pytest.raises(ValueError):
            srv.restore_replica(1)  # still alive
        srv.restore_replica(0)
        hs2 = [srv.submit("m", xb[i]) for i in range(32)]
        srv.drain(timeout=60)
        rep = srv.report()
        assert rep["replicas"][0]["state"] == "alive"
        direct = np.asarray(art.engine().predict(xb[:32]))
        for i, h in enumerate([*hs, *hs2]):
            j = i % 32
            np.testing.assert_array_equal(h.result(5), direct[j : j + 1])


def test_hot_swap_under_live_traffic(served):
    art_a, art_b, xb = served
    pred_a = np.asarray(art_a.engine().predict(xb))
    pred_b = np.asarray(art_b.engine().predict(xb))
    assert (pred_a != pred_b).any()  # the swap must be observable
    with _server() as srv:
        srv.register("m", art_a)
        pre = [srv.submit("m", xb[i]) for i in range(48)]
        srv.register("m", art_b)  # hot swap on every replica, mid-traffic
        post = [srv.submit("m", xb[i]) for i in range(48)]
        srv.drain(timeout=60)
        # in-flight-at-swap requests are served by exactly one of the two
        # versions, never a torn mix
        for i, h in enumerate(pre):
            got = h.result(5)
            assert (
                np.array_equal(got, pred_a[i : i + 1])
                or np.array_equal(got, pred_b[i : i + 1])
            )
        # post-swap requests always see the new version
        for i, h in enumerate(post):
            np.testing.assert_array_equal(h.result(5), pred_b[i : i + 1])


# -- admission control --------------------------------------------------------


def test_overload_sheds_with_explicit_backpressure(served):
    art, _, xb = served
    with _server(
        flush_rows=1000, max_queue_rows=8,
        window=AdaptiveWindow(min_s=5.0, max_s=5.0),
    ) as srv:
        srv.register("m", art)
        handles, sheds = [], 0
        for i in range(12):  # queue bound is 8 rows -> 4 sheds
            try:
                handles.append(srv.submit("m", xb[i]))
            except ShedError:
                sheds += 1
        assert sheds == 4 and len(handles) == 8
        assert srv.report()["shed"] == {"m": 4}
        srv.drain(timeout=60)  # accepted requests still complete correctly
        direct = np.asarray(art.engine().predict(xb[:8]))
        for i, h in enumerate(handles):
            np.testing.assert_array_equal(h.result(5), direct[i : i + 1])


def test_submit_errors(served):
    art, _, xb = served
    srv = _server(n_replicas=1)
    srv.register("m", art)
    with pytest.raises(KeyError):
        srv.submit("ghost", xb[0])
    with pytest.raises(ValueError):
        srv.submit("m", np.zeros((0, xb.shape[1]), np.int32))
    srv.close()
    with pytest.raises(ClusterClosed):
        srv.submit("m", xb[0])
    srv.close()  # idempotent


# -- thread safety of the shared serving primitives ---------------------------


def test_microbatcher_concurrent_submit_flush(served):
    art, _, xb = served
    eng = art.engine()
    mb = MicroBatcher.for_engine(eng, max_batch=128)
    direct = np.asarray(eng.predict(xb))
    results: dict[int, np.ndarray] = {}
    res_lock = threading.Lock()
    rid_row: dict[int, int] = {}
    stop = threading.Event()

    def submitter(rows):
        for i in rows:
            rid = mb.submit(xb[i])
            with res_lock:
                rid_row[rid] = i
            time.sleep(0)

    def flusher():
        while not stop.is_set() or mb.pending_requests:
            out = mb.flush()
            with res_lock:
                results.update(out)

    threads = [
        threading.Thread(target=submitter, args=(range(k, 96, 4),))
        for k in range(4)
    ]
    fl = threading.Thread(target=flusher)
    fl.start()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    stop.set()
    fl.join()
    assert len(results) == 96  # nothing lost, nothing double-flushed
    for rid, row in rid_row.items():
        np.testing.assert_array_equal(results[rid], direct[row : row + 1])


def test_registry_concurrent_swap_and_lookup(served):
    art_a, art_b, xb = served
    reg = TableRegistry()
    reg.register("m", art_a)
    errors: list[BaseException] = []

    def swapper(artifact):
        try:
            for _ in range(10):
                reg.register("m", artifact)
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)

    def reader():
        try:
            for _ in range(50):
                entry = reg.get("m")
                # a reader sees a whole entry, never a torn one
                assert entry.engine is not None and entry.version >= 1
                assert reg.version("m") >= 1
        except BaseException as exc:  # noqa: BLE001
            errors.append(exc)

    threads = [
        threading.Thread(target=swapper, args=(art_a,)),
        threading.Thread(target=swapper, args=(art_b,)),
        threading.Thread(target=reader),
        threading.Thread(target=reader),
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errors
    assert reg.version("m") == 21  # 1 + 2 swappers x 10, no lost updates
