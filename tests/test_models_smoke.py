"""Per-arch smoke tests: reduced config of the same family, one forward /
train step on CPU, asserting output shapes + no NaNs; plus prefill+decode
consistency against the full forward (fp32, generous MoE capacity)."""

import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import build_model

SMOKE_MODULES = {
    "gemma3-1b": "repro.configs.gemma3_1b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini",
    "granite-20b": "repro.configs.granite_20b",
    "llama3.2-3b": "repro.configs.llama32_3b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3",
    "arctic-480b": "repro.configs.arctic_480b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral",
    "rwkv6-1.6b": "repro.configs.rwkv6_1p6b",
    "whisper-tiny": "repro.configs.whisper_tiny",
}
B, S = 2, 32


def _smoke_cfg(name):
    return importlib.import_module(SMOKE_MODULES[name]).smoke()


def _batch(cfg, rng, b=B, s=S):
    if cfg.is_encoder_decoder:
        return {
            "frames": jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s // 2)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s // 2)), jnp.int32),
        }
    if cfg.embeddings_input:
        return {
            "embeds": jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        }
    toks = rng.integers(0, cfg.vocab_size, (b, s))
    return {
        "tokens": jnp.asarray(toks, jnp.int32),
        "labels": jnp.asarray(np.roll(toks, -1, 1), jnp.int32),
    }


@pytest.mark.parametrize("name", sorted(SMOKE_MODULES))
def test_loss_finite_and_grads_flow(name):
    cfg = _smoke_cfg(name).replace(dtype="float32")
    m = build_model(cfg, flash_blk=16)
    params = m.init_params(jax.random.key(0))
    batch = _batch(cfg, np.random.default_rng(0))
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(m.loss_fn, has_aux=True)
    )(params, batch)
    assert bool(jnp.isfinite(loss)), name
    assert loss.shape == ()
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0.0, name


@pytest.mark.parametrize(
    "name",
    ["gemma3-1b", "granite-20b", "deepseek-v3-671b", "arctic-480b",
     "zamba2-2.7b", "rwkv6-1.6b", "whisper-tiny"],
)
def test_prefill_decode_matches_full_forward(name):
    cfg = _smoke_cfg(name).replace(dtype="float32", capacity_factor=8.0)
    m = build_model(cfg, flash_blk=16)
    params = m.init_params(jax.random.key(1))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    if cfg.is_encoder_decoder:
        frames = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        logits_pf, cache = jax.jit(m.prefill)(
            params, {"frames": frames, "tokens": toks[:, : S // 2]}
        )
        tok_next = toks[:, S // 2]
        cache = jax.tree.map(
            lambda c: jnp.pad(c, [(0, 0), (0, 0), (0, 8)] + [(0, 0)] * (c.ndim - 3))
            if c.ndim >= 4 and c.shape[2] == S // 2 else c,
            cache,
        )
        logits_dec, _ = jax.jit(m.decode_step)(params, cache, tok_next, jnp.int32(S // 2))
        logits_full, _ = jax.jit(m.prefill)(
            params,
            {"frames": frames,
             "tokens": jnp.concatenate([toks[:, : S // 2], tok_next[:, None]], 1)},
        )
    else:
        logits_pf, cache = jax.jit(m.prefill)(params, {"tokens": toks[:, :-1]})

        def grow(c):
            if c.ndim >= 3 and c.shape[2] == S - 1 and cfg.family != "ssm":
                pad = [(0, 0)] * c.ndim
                pad[2] = (0, 9)
                return jnp.pad(c, pad)
            return c

        cache = jax.tree.map(grow, cache)
        logits_dec, _ = jax.jit(m.decode_step)(params, cache, toks[:, -1], jnp.int32(S - 1))
        logits_full, _ = jax.jit(m.prefill)(params, {"tokens": toks})

    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-9
    assert err / scale < 2e-3, (name, err, scale)


def test_gemma3_window_meta():
    from repro.models.transformer import layer_meta

    cfg = _smoke_cfg("gemma3-1b")  # local_global_period=2, window 16
    windows, thetas = layer_meta(cfg, cfg.n_layers)
    w = np.asarray(windows)
    assert (w[0], w[1]) == (16, 0) and (w[2], w[3]) == (16, 0)


def test_full_configs_have_exact_assigned_dims():
    from repro.config import get_config

    spec = {
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    }
    for name, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), name
    ds = get_config("deepseek-v3-671b")
    assert (ds.n_experts, ds.moe_top_k, ds.moe_d_ff) == (256, 8, 2048)
    ar = get_config("arctic-480b")
    assert (ar.n_experts, ar.moe_top_k) == (128, 2)
    za = get_config("zamba2-2.7b")
    assert za.ssm_state == 64
