"""Kernel v3 (DESIGN.md §13): measured v1/v2 dispatch, fused epilogue,
sparsity-aware column clustering — every variant gated by the shared
differential oracle (tests/oracles.py).

The dispatch table is a PERFORMANCE artifact: whichever kernel version
the autotuner's timings pick for a bucket, the bound engine must stay
bit-equal to the v1 int32 oracle.  These tests therefore never assert on
timings (nondeterministic) — only that every reachable dispatch outcome
passes the oracle gate and that the plan round-trips byte-exactly
through the artifact sidecar.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from oracles import (
    assert_bit_equal_to_oracle,
    compact_problem,
    env_interpret,
    env_interpret_kernel,
    random_cam_table,
)

import jax.numpy as jnp

from repro.api import CompiledModel, build
from repro.core.compile import order_columns_by_activity
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.core.tune import TunePlan, autotune_kernel, kernel_version
from repro.kernels.cam_match import cam_match_pallas, full_tile_mask

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
FIXTURES = Path(__file__).parent / "fixtures" / "ingest"


# -- v1/v2 dispatch ------------------------------------------------------------


def test_kernel_version_axis():
    assert kernel_version("int32") == "v1"
    assert kernel_version("uint8") == "v2"
    assert kernel_version("uint16") == "v2"


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000), r=st.integers(2, 6), f=st.integers(4, 24))
def test_dispatched_kernel_bit_equal_and_plan_round_trips(seed, r, f):
    """Property across the v1/v2 crossover regime: whatever kernel the
    sweep's timings pick per bucket, the bound engine passes the oracle
    gate, and the persisted plan picks the SAME kernel version after a
    to_dict/from_dict round trip."""
    rng = np.random.default_rng(seed)
    table = random_cam_table(rng, r=32 * r, f=f, n_bins=256)
    plan = autotune_kernel(
        table,
        deploy=DeployConfig(backend="pallas", interpret=env_interpret()),
        batch=32, batches=(8, 96), b_blks=(32,), r_blks=(32, 64),
        warmup=1, iters=1, seed=seed,
    )
    assert [e["batch"] for e in plan.dispatch] == [8, 32, 96]
    restored = TunePlan.from_dict(json.loads(json.dumps(plan.to_dict())))
    assert restored == plan
    q = rng.integers(0, 256, size=(48, f))
    for b in (8, 32, 96):
        e = plan.dispatch_for(b)
        assert e["kernel"] == kernel_version(e["table_dtype"])
        assert restored.dispatch_for(b)["kernel"] == e["kernel"]
        cfg = plan.apply(
            DeployConfig(backend="pallas", interpret=env_interpret()), batch=b,
        )
        assert kernel_version(cfg.table_dtype) == e["kernel"]
        assert_bit_equal_to_oracle(table, q, cfg)


def test_handcrafted_dispatch_cold_start_binds_per_bucket(tmp_path):
    """Deterministic dispatch semantics, no timing dependence: a
    hand-written dispatch table must survive save -> load and bind the
    named kernel per serving bucket, from the artifact and from the
    registry."""
    from repro.core.trees import random_deep_ensemble
    from repro.serve.registry import TableRegistry

    ens = random_deep_ensemble(n_trees=6, depth=4, n_features=10,
                               n_bins=256, seed=0)
    cm = build(ens, deploy=DeployConfig(backend="pallas",
                                        interpret=env_interpret()))
    plan = TunePlan(
        b_blk=64, r_blk=64, table_dtype="uint8", mode="direct",
        backend="pallas", us_per_call=2.0, batch=256,
        dispatch=[
            {"batch": 16, "b_blk": 32, "r_blk": 64, "table_dtype": "int32",
             "mode": "direct", "kernel": "v1", "us_per_call": 1.0},
            {"batch": 256, "b_blk": 64, "r_blk": 64, "table_dtype": "uint8",
             "mode": "direct", "kernel": "v2", "us_per_call": 2.0},
        ],
    )
    cm.with_tuning(plan).save(tmp_path / "art")
    loaded = CompiledModel.load(tmp_path / "art")
    assert loaded.tune_plan() == plan

    e_small = loaded.engine(batch_hint=8)  # -> bucket 16: v1 int32
    e_large = loaded.engine(batch_hint=200)  # -> bucket 256: v2 uint8
    e_over = loaded.engine(batch_hint=10_000)  # beyond all -> largest
    assert (e_small.b_blk, e_small.table_dtype) == (32, "int32")
    assert (e_large.b_blk, e_large.table_dtype) == (64, "uint8")
    assert e_over is e_large  # same bucket -> memoized engine
    assert loaded.engine(batch_hint=16) is e_small

    reg = TableRegistry()
    reg.register("m", loaded)
    assert reg.engine_for_batch("m", 8).table_dtype == "int32"
    assert reg.engine_for_batch("m", 200).table_dtype == "uint8"
    # untuned artifacts keep the default engine
    reg.register("plain", cm)
    assert reg.engine_for_batch("plain", 8) is reg.engine("plain")

    # both bucket winners pass the oracle gate on the same queries
    rng = np.random.default_rng(1)
    q = rng.integers(0, 256, size=(24, 10))
    for b in (8, 200):
        cfg = plan.apply(loaded.deploy, batch=b)
        assert_bit_equal_to_oracle(loaded.table, q, cfg)


def test_schema_v1_plan_loads_with_dispatch_fallback():
    """Plans persisted before the dispatch table (schema v1) must load
    and resolve every batch to the synthesized top-level winner."""
    v1_dict = {
        "b_blk": 128, "r_blk": 256, "table_dtype": "uint8",
        "mode": "direct", "backend": "pallas", "us_per_call": 3.5,
        "batch": 256, "trials": [], "env": {}, "schema_version": 1,
    }
    plan = TunePlan.from_dict(v1_dict)
    assert plan.dispatch == []
    for b in (1, 256, 99_999):
        e = plan.dispatch_for(b)
        assert (e["b_blk"], e["table_dtype"], e["kernel"]) == (128, "uint8", "v2")
    cfg = plan.apply(DeployConfig(), batch=64)
    assert (cfg.b_blk, cfg.table_dtype) == (128, "uint8")


# -- tile-mask fallback (the silent-fallback fix) ------------------------------


def _mask_problem():
    rng = np.random.default_rng(21)
    return compact_problem(rng, 32, 64, 256, 4)


def test_none_mask_is_exactly_full_tile_mask():
    """tile_mask=None must be the EXPLICIT every-tile-active fallback:
    bit-identical output to passing full_tile_mask, never a silent skip."""
    q, low, high, leaf = _mask_problem()
    kw = dict(b_blk=32, r_blk=32, mode="inclusive",
              interpret=env_interpret_kernel())
    out_none = cam_match_pallas(
        jnp.asarray(q), jnp.asarray(low), jnp.asarray(high),
        jnp.asarray(leaf), None, **kw,
    )
    out_full = cam_match_pallas(
        jnp.asarray(q), jnp.asarray(low), jnp.asarray(high),
        jnp.asarray(leaf), full_tile_mask(2, 2), **kw,
    )
    np.testing.assert_array_equal(np.asarray(out_none), np.asarray(out_full))
    # the helper itself: all-ones int32 of the grid shape
    m = np.asarray(full_tile_mask(3, 5))
    assert m.shape == (3, 5) and m.dtype == np.int32 and (m == 1).all()


@pytest.mark.parametrize("bad_shape", [(1, 2), (2, 1), (4, 4), (2, 2, 1)])
def test_misshapen_tile_mask_rejected(bad_shape):
    """A wrong-shape mask used to slip through under interpret mode and
    silently skip live tiles; it must be rejected eagerly, naming the
    expected grid shape."""
    q, low, high, leaf = _mask_problem()
    with pytest.raises(ValueError, match=r"\(2, 2\)"):
        cam_match_pallas(
            jnp.asarray(q), jnp.asarray(low), jnp.asarray(high),
            jnp.asarray(leaf), jnp.ones(bad_shape, jnp.int32),
            b_blk=32, r_blk=32, mode="inclusive",
            interpret=env_interpret_kernel(),
        )


# -- fused epilogue ------------------------------------------------------------


def test_fused_epilogue_resolution_and_bit_equality():
    """'auto' fuses exactly on eligible engines (pallas, no mesh); fused
    margins are bit-equal to the unfused v1 oracle (same float order)."""
    rng = np.random.default_rng(31)
    table = random_cam_table(rng, r=64, f=12, n_bins=256)
    assert table.base_score != 0.0  # the fusion must actually add something
    q = rng.integers(0, 256, size=(40, 12))

    auto = XTimeEngine.from_config(
        table, DeployConfig(backend="pallas", b_blk=32, r_blk=32,
                            interpret=env_interpret()),
    )
    assert auto.fuse_epilogue is True
    jnp_eng = XTimeEngine.from_config(table, DeployConfig(backend="jnp"))
    assert jnp_eng.fuse_epilogue is False

    for fuse in (True, False, "auto"):
        cfg = DeployConfig(backend="pallas", b_blk=32, r_blk=32,
                           fuse_epilogue=fuse, interpret=env_interpret())
        assert_bit_equal_to_oracle(table, q, cfg)


def test_fuse_forced_on_ineligible_engine_raises():
    rng = np.random.default_rng(32)
    table = random_cam_table(rng, r=32, f=8)
    with pytest.raises(ValueError, match="fuse_epilogue"):
        XTimeEngine.from_config(
            table, DeployConfig(backend="jnp", fuse_epilogue=True),
        )
    with pytest.raises(ValueError):
        DeployConfig(fuse_epilogue="yes")


# -- column clustering ---------------------------------------------------------


def test_column_clustering_zero_cost_wildcard_features():
    """All-wildcard FEATURE columns must become skippable tiles after
    clustering — with margins bit-equal to the unclustered table (the
    match line is a boolean AND: column order cannot change any bit)."""
    rng = np.random.default_rng(41)
    table = random_cam_table(rng, r=64, f=32, n_bins=256, n_outputs=2)
    # constrain only 6 interleaved features; the rest are pure wildcards
    low, high = table.low.copy(), table.high.copy()
    low[:, :], high[:, :] = 0, 256
    keep = np.arange(0, 32, 5)
    low[:, keep], high[:, keep] = table.low[:, keep], table.high[:, keep]
    import dataclasses
    table = dataclasses.replace(table, low=low, high=high)

    clustered = order_columns_by_activity(table, f_blk=8)
    assert clustered.col_perm is not None
    assert clustered.tile_skip_fraction(32, 8) > table.tile_skip_fraction(32, 8)
    # active features all precede inactive ones in the permuted layout
    occ = clustered.feature_occupancy()
    n_active = int((table.feature_occupancy() > 0).sum())
    assert (occ[:n_active] > 0).all() and (occ[n_active:] == 0).all()

    q = rng.integers(0, 256, size=(24, 32))
    cfg = DeployConfig(backend="pallas", b_blk=8, r_blk=32, f_blk=8,
                       interpret=env_interpret())
    m_clustered = assert_bit_equal_to_oracle(clustered, q, cfg)
    m_plain = np.asarray(
        XTimeEngine.from_config(table, cfg).raw_margin(q)
    )
    np.testing.assert_array_equal(m_clustered, m_plain)


def test_xgb_deep_clustering_golden_save_load_bind(tmp_path):
    """The golden xgb_deep fixture (only 2 of 5 features ever split):
    cluster_columns build must move the 3 wildcard columns to trailing
    tiles, survive save -> load -> engine bind, and reproduce the frozen
    float record BIT-exactly (k/16 leaves: any order is exact)."""
    dump = FIXTURES / "xgb_deep.json"
    exp = json.loads(
        (FIXTURES / "xgb_deep.expected.json").read_text()
    )
    x = np.asarray(exp["x"], dtype=np.float64)
    record = np.asarray(exp["raw_margin"], dtype=np.float32)

    cfg = DeployConfig(backend="pallas", b_blk=8, r_blk=32, f_blk=2,
                       interpret=env_interpret())
    cm = build(str(dump), deploy=cfg, cluster_columns=True)
    perm = cm.table.col_perm
    assert perm is not None
    assert not np.array_equal(perm, np.arange(cm.table.n_cols))
    # the permuted layout packs both live features into the first tile
    assert (cm.table.feature_occupancy()[2:] == 0).all()

    xb = cm.quantizer.transform(x)
    np.testing.assert_array_equal(np.asarray(cm.engine().raw_margin(xb)), record)

    cm.save(tmp_path / "art")
    loaded = CompiledModel.load(tmp_path / "art")
    np.testing.assert_array_equal(loaded.table.col_perm, perm)
    np.testing.assert_array_equal(
        np.asarray(loaded.engine().raw_margin(loaded.quantizer.transform(x))), record,
    )
    assert_bit_equal_to_oracle(loaded.table, loaded.quantizer.transform(x), cfg)


_SHARD_CODE = """
import json
import numpy as np
from pathlib import Path
from repro.api import build
from repro.core.deploy import DeployConfig
from repro.launch.mesh import make_host_mesh

dump = Path({dump!r})
exp = json.loads(dump.with_name("xgb_deep.expected.json").read_text())
x = np.asarray(exp["x"], dtype=np.float64)
record = np.asarray(exp["raw_margin"], dtype=np.float32)

cm = build(str(dump), cluster_columns=True)
assert cm.table.col_perm is not None
xb = cm.quantizer.transform(x)
mesh = make_host_mesh()
out = {{}}
for spmd in ("shard_map", "gspmd"):
    eng = cm.engine(mesh=mesh, spmd=spmd)
    m = np.asarray(eng.raw_margin(xb))
    out[spmd] = {{
        "bit_equal": bool(np.array_equal(m, record)),
        "max_err": float(np.abs(m - record).max()),
    }}
print(json.dumps(out))
"""


def test_clustered_artifact_bit_equal_under_shard_map():
    """Column clustering is a query-side permutation — it must commute
    with BOTH spmd paths on 8 fake devices, reproducing the golden
    record (k/16 leaves make even psum reordering exact)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    code = _SHARD_CODE.format(dump=str(FIXTURES / "xgb_deep.json"))
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    for spmd, res in results.items():
        assert res["bit_equal"], (spmd, res)
