import os
import sys

# Single-device for unit tests (the dry-run sets its own 512-device flag
# in a separate process).  Keep CPU determinism.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
