"""Hyperparameter search (§IV-A workflow): constraint compliance, monotone
best-so-far, improvement over an untuned default, determinism."""

import numpy as np
import pytest

from repro.core.compile import compile_ensemble, pack_cores
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.core.tune import HWConstraints, random_search
from repro.data.tabular import accuracy_metric, make_dataset


@pytest.fixture(scope="module")
def search():
    ds = make_dataset("churn")
    return ds, random_search(ds, kind="gbdt", n_trials=8, seed=3)


def test_constraints_respected(search):
    ds, res = search
    hw = HWConstraints()
    for t in res.trials:
        assert t.n_trees <= hw.max_trees
        assert t.max_leaves <= hw.max_leaves
    # and the winner compiles + places on the chip
    table = compile_ensemble(res.ensemble)
    plc = pack_cores(table)
    assert plc.n_cores_used <= plc.spec.n_cores


def test_best_is_max_of_trials(search):
    ds, res = search
    assert res.best.valid_score == max(t.valid_score for t in res.trials)


def test_tuned_beats_weak_default(search):
    """Paper workflow sanity: search should beat a deliberately weak
    configuration on the test split."""
    ds, res = search
    q = FeatureQuantizer.fit(ds.x_train, 256)
    weak = train_gbdt(
        q.transform(ds.x_train), ds.y_train, task=ds.task, n_bins=256,
        params=GBDTParams(n_rounds=3, max_leaves=4, learning_rate=0.02),
    )
    weak_acc = accuracy_metric(ds.task, ds.y_test, weak.predict(q.transform(ds.x_test)))
    tuned_acc = accuracy_metric(
        ds.task, ds.y_test,
        res.ensemble.predict(res.quantizer.transform(ds.x_test)),
    )
    assert tuned_acc > weak_acc


def test_search_deterministic():
    ds = make_dataset("telco")
    a = random_search(ds, kind="gbdt", n_trials=3, seed=9)
    b = random_search(ds, kind="gbdt", n_trials=3, seed=9)
    assert [t.valid_score for t in a.trials] == [t.valid_score for t in b.trials]
    assert a.best.params == b.best.params
