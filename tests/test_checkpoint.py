"""Checkpointing: bitexact roundtrip, atomicity, retention, templates."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "embed": jnp.asarray(rng.normal(size=(16, 8)), jnp.bfloat16),
            "attn": (jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),),
        },
        "step": jnp.int32(7),
    }


def test_roundtrip_bitexact(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree)
    step, restored = restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: tree))
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_step_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (10, 20, 30):
        mgr.save(s, _tree(s))
    mgr.wait()
    assert latest_step(str(tmp_path)) == 30
    files = sorted(os.listdir(tmp_path))
    assert "step_00000010.npz" not in files  # gc'd
    assert "step_00000020.npz" in files and "step_00000030.npz" in files


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, _tree(1))
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1


def test_no_tmp_leftovers(tmp_path):
    save_checkpoint(str(tmp_path), 5, _tree())
    assert not [f for f in os.listdir(tmp_path) if f.startswith("tmp.")]


def test_restore_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    bad = _tree()
    bad["params"]["embed"] = jnp.zeros((4, 4), jnp.bfloat16)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), jax.eval_shape(lambda: bad))


def test_restore_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), _tree())
