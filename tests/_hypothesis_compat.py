"""Property-testing shim: real ``hypothesis`` when installed, a minimal
seeded-random fallback otherwise.

``hypothesis`` is a declared dev dependency (pyproject.toml), but the
tier-1 suite must COLLECT and run in images that ship only the runtime
stack.  The fallback implements exactly the subset this repo uses —
``@given`` with ``st.integers``/``st.builds`` keyword strategies and
``@settings`` — drawing ``max_examples`` samples from a fixed-seed
Generator (no shrinking, no database; deterministic by construction).

``deep_ensemble_params()`` is the shared strategy over
``repro.core.trees.random_deep_ensemble`` kwargs: deep complete trees
with duplicate-split paths that trained boosters never emit, the
adversarial population for the compression differential harness
(tests/test_compress.py).
"""

from __future__ import annotations

try:  # pragma: no cover - exercised implicitly when hypothesis exists
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _IntStrategy:
        def __init__(self, min_value: int, max_value: int) -> None:
            self.min_value = min_value
            self.max_value = max_value

        def draw(self, rng: "np.random.Generator") -> int:
            return int(rng.integers(self.min_value, self.max_value + 1))

    class _BuildsStrategy:
        """Mirrors ``st.builds``: draw each kwarg, call the target."""

        def __init__(self, target, **kwargs) -> None:
            self.target = target
            self.kwargs = kwargs

        def draw(self, rng: "np.random.Generator"):
            return self.target(**{k: s.draw(rng) for k, s in self.kwargs.items()})

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntStrategy:
            return _IntStrategy(min_value, max_value)

        @staticmethod
        def builds(target, **kwargs) -> _BuildsStrategy:
            return _BuildsStrategy(target, **kwargs)

    def settings(*, max_examples: int = 20, **_ignored):
        """Records ``max_examples`` on the (possibly @given-wrapped) test."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                n = getattr(wrapper, "_max_examples", 20)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # hide the strategy params from pytest's fixture resolution,
            # exactly as real hypothesis does
            params = [
                p
                for name, p in inspect.signature(fn).parameters.items()
                if name not in strats
            ]
            wrapper.__signature__ = inspect.Signature(params)
            return wrapper

        return deco


def deep_ensemble_params(
    *,
    max_trees: int = 10,
    max_depth: int = 7,
    max_features: int = 14,
    max_classes: int = 1,
):
    """Strategy over ``random_deep_ensemble`` kwargs (as a plain dict).

    ``p_dup`` is drawn as an integer percentage so the same strategy
    works under real hypothesis and the integer-only fallback; callers
    do ``kw = dict(params); kw["p_dup"] = kw.pop("p_dup_pct") / 100``.
    Depth starts at 2 (depth-1 trees have no prefix to share) and
    duplicate-split probability spans 0..100% so both clean and
    pathological (empty-interval-heavy) tables appear.
    """
    return strategies.builds(
        dict,
        seed=strategies.integers(min_value=0, max_value=10_000),
        n_trees=strategies.integers(min_value=1, max_value=max_trees),
        depth=strategies.integers(min_value=2, max_value=max_depth),
        n_features=strategies.integers(min_value=2, max_value=max_features),
        p_dup_pct=strategies.integers(min_value=0, max_value=100),
        n_classes=strategies.integers(min_value=1, max_value=max_classes),
    )
