"""Property-testing shim: real ``hypothesis`` when installed, a minimal
seeded-random fallback otherwise.

``hypothesis`` is a declared dev dependency (pyproject.toml), but the
tier-1 suite must COLLECT and run in images that ship only the runtime
stack.  The fallback implements exactly the subset this repo uses —
``@given`` with ``st.integers`` keyword strategies and ``@settings`` —
drawing ``max_examples`` samples from a fixed-seed Generator (no
shrinking, no database; deterministic by construction).
"""

from __future__ import annotations

try:  # pragma: no cover - exercised implicitly when hypothesis exists
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _IntStrategy:
        def __init__(self, min_value: int, max_value: int) -> None:
            self.min_value = min_value
            self.max_value = max_value

        def draw(self, rng: "np.random.Generator") -> int:
            return int(rng.integers(self.min_value, self.max_value + 1))

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntStrategy:
            return _IntStrategy(min_value, max_value)

    def settings(*, max_examples: int = 20, **_ignored):
        """Records ``max_examples`` on the (possibly @given-wrapped) test."""

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                n = getattr(wrapper, "_max_examples", 20)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # hide the strategy params from pytest's fixture resolution,
            # exactly as real hypothesis does
            params = [
                p
                for name, p in inspect.signature(fn).parameters.items()
                if name not in strats
            ]
            wrapper.__signature__ = inspect.Signature(params)
            return wrapper

        return deco
