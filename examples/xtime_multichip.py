"""Multi-chip scale-out walkthrough: the NoC plan as real collectives.

The paper's throughput comes from 4096 CAM cores behind an H-tree NoC
(§III-D).  On a JAX mesh that structure is the shard_map engine path
(DESIGN.md §8): CAM rows shard across devices like trees across cores,
and each NoC router program runs as an explicit collective —

    accumulate (Fig. 7a)  psum of partial margins over the `model` axis
    batch      (Fig. 7c)  replicated tables, query stream split over
                          every axis, no cross-device traffic
    hybrid     (2-D)      all_gather queries + psum_scatter margins, so
                          outputs stay sharded on large meshes

No accelerator needed: fake host devices give an 8-device CPU mesh
(the same recipe scripts/test.sh pins for the test suite).

Run:
    export XLA_FLAGS=--xla_force_host_platform_device_count=8
    export JAX_PLATFORMS=cpu
    PYTHONPATH=src python examples/xtime_multichip.py
"""

import os
import time

# must be set before jax initializes — a safety net for bare invocations
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.api import build  # noqa: E402
from repro.core.deploy import DeployConfig  # noqa: E402
from repro.core.noc import ENGINE_COLLECTIVES  # noqa: E402
from repro.core.quantize import FeatureQuantizer  # noqa: E402
from repro.core.trees import GBDTParams, train_gbdt  # noqa: E402
from repro.data.tabular import make_dataset  # noqa: E402


def main() -> None:
    devices = jax.devices()
    if len(devices) < 2 or len(devices) % 2:
        raise SystemExit(
            f"need an even number of >= 2 devices for the (2, n/2) mesh, "
            f"got {len(devices)} — export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8 "
            f"(before any other XLA_FLAGS value you may have set)"
        )
    print(f"[mesh]    {len(devices)} {devices[0].platform} devices")

    # 1. train + compile once — the artifact is mesh-agnostic
    ds = make_dataset("eye")
    quant = FeatureQuantizer.fit(ds.x_train, n_bins=256)
    xb = quant.transform(ds.x_test)[:512].astype(np.int32)
    ens = train_gbdt(
        quant.transform(ds.x_train), ds.y_train, task="multiclass",
        n_bins=256, n_classes=ds.n_classes,
        params=GBDTParams(n_rounds=20, max_leaves=64),
    )
    cm = build(ens, deploy=DeployConfig(backend="jnp"))
    print(f"[build]   {cm.table.n_rows} CAM rows, {cm.table.n_outputs} classes, "
          f"NoC '{cm.noc.config}'")

    # 2. single-device reference — the correctness anchor
    ref_engine = cm.engine()
    ref_margin = np.asarray(ref_engine.raw_margin(xb))
    ref_pred = np.asarray(ref_engine.predict(xb))

    # 3. a (data=2, model=4) mesh: `model` plays the role of CAM core
    #    groups, `data` of independent query streams
    n = len(devices)
    mesh = Mesh(np.asarray(devices).reshape(2, n // 2), ("data", "model"))
    print(f"[mesh]    axes {dict(mesh.shape)}")

    # 4. every NoC program, bound lazily off the same artifact.
    #    spmd='auto' resolves to shard_map on a mesh; pass spmd='gspmd'
    #    to compare against the implicit-partitioning oracle.
    for noc in ("accumulate", "batch", "hybrid"):
        engine = cm.engine(mesh=mesh, noc_config=noc)
        margin = np.asarray(engine.raw_margin(xb))
        pred = np.asarray(engine.predict(xb))
        t0 = time.perf_counter()
        for _ in range(5):
            np.asarray(engine.raw_margin(xb))
        us = (time.perf_counter() - t0) / 5 * 1e6
        print(f"[{noc:>10}] spmd={engine.spmd}  "
              f"collective: {ENGINE_COLLECTIVES[noc]:<26} "
              f"max|Δmargin| {np.abs(margin - ref_margin).max():.1e}  "
              f"pred equal: {(pred == ref_pred).all()}  {us:7.0f} us/batch")

    # 5. the bit-equivalence guarantee between the two partitioning modes
    g = cm.engine(mesh=mesh, spmd="gspmd")
    s = cm.engine(mesh=mesh, spmd="shard_map")
    same = (np.asarray(g.raw_margin(xb)) == np.asarray(s.raw_margin(xb))).all()
    print(f"[check]   gspmd vs shard_map margins bit-identical: {same}")


if __name__ == "__main__":
    main()
