"""X-TIME as an inference SERVICE: three models are compiled once into
portable ``CompiledModel`` artifacts (``repro.api.build``), written to
disk, and a fresh ``TableRegistry`` cold-starts from those files — no
trainer in the serve process, no recompilation.  Single-row requests
stream through the micro-batching ``ServeLoop``, and the measured p50/p99
latency is reported next to the paper's analytic chip numbers.  The
defect study (Fig. 9b) becomes a hot-swap demo: defective tables are
swapped in under the same model name while the loop keeps serving.

Run:  PYTHONPATH=src python examples/xtime_serving.py
"""

import shutil
import tempfile
from pathlib import Path

import numpy as np

from repro.api import CompiledModel, build
from repro.core.defects import inject_table_defects, relative_accuracy
from repro.core.deploy import DeployConfig
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import accuracy_metric, make_dataset
from repro.serve import ServeLoop, TableRegistry


def _train(name: str, n_rounds: int = 30):
    ds = make_dataset(name)
    quant = FeatureQuantizer.fit(ds.x_train, 256)
    ens = train_gbdt(
        quant.transform(ds.x_train), ds.y_train, task=ds.task, n_bins=256,
        n_classes=ds.n_classes,
        params=GBDTParams(n_rounds=n_rounds, max_leaves=64),
    )
    return ds, quant, ens


def main() -> None:
    # --- "trainer" process: compile each model once, ship the artifact ---
    tmp = Path(tempfile.mkdtemp(prefix="xtime-artifacts-"))
    datasets = {}
    for name, batching in (("rossmann", False), ("eye", False), ("telco", True)):
        ds, quant, ens = _train(name)
        cm = build(ens, deploy=DeployConfig(batching=batching))
        cm.save(tmp / name)
        datasets[name] = (ds, quant)
        print(f"[build]    {name:10s} {cm.table.n_rows} CAM rows, "
              f"{cm.noc.config} NoC "
              f"router_bits={''.join(map(str, cm.noc.router_bits))} "
              f"-> {name}.npz+.json")

    # --- serve process: cold-start the registry from disk artifacts ---
    registry = TableRegistry()
    loop = ServeLoop(registry, window_s=0.001, flush_rows=256)
    for name in datasets:
        entry = registry.register(name, CompiledModel.load(tmp / name))
        print(f"[register] {name:10s} v{entry.version} from artifact "
              f"(zero recompilation)")

    # single-row request traffic, round-robin over the three models
    streams = {
        name: quant.transform(ds.x_test).astype(np.int32)
        for name, (ds, quant) in datasets.items()
    }
    handles: dict[str, list] = {name: [] for name in streams}
    n_req = min(512, min(len(x) for x in streams.values()))
    for i in range(n_req):
        for name, xb in streams.items():
            handles[name].append(loop.submit(name, xb[i]))
    loop.drain()

    print(f"\n[serve] {3 * n_req} single-row requests:")
    for name, (ds, quant) in datasets.items():
        pred = np.concatenate([loop.result(h) for h in handles[name]])
        acc = accuracy_metric(ds.task, ds.y_test[:n_req], pred)
        rep = loop.report(name)
        m, c = rep["measured"], rep["xtime_chip_model"]
        print(f"  {name:10s} acc={acc:.4f} p50={m['p50_ms']:.2f}ms "
              f"p99={m['p99_ms']:.2f}ms {m['requests_per_s']:,.0f} req/s "
              f"({m['flushes']} flushes) | chip model: "
              f"{c['latency_ns']:.0f} ns, {c['throughput_msps']:,.0f} MS/s, "
              f"{c['energy_nj_per_dec']:.2f} nJ/dec [{c['bottleneck']}]")

    # defect robustness as hot-swap: serve the eye model with memristor
    # flips injected, swapping tables under live traffic (Fig. 9b)
    ds, quant = datasets["eye"]
    xb = quant.transform(ds.x_test).astype(np.int32)
    clean_table = registry.get("eye").table
    h = loop.submit("eye", xb[:256])
    loop.drain()
    ideal = accuracy_metric("multiclass", ds.y_test[:256], loop.result(h))
    print("\n[hot-swap] defect robustness on the live 'eye' service:")
    for frac in (0.002, 0.02, 0.1):
        accs = []
        for r in range(5):
            t2 = inject_table_defects(clean_table, frac, np.random.default_rng(r))
            entry = registry.swap("eye", t2)
            h = loop.submit("eye", xb[:256])
            loop.drain()
            pred = loop.result(h)
            accs.append(accuracy_metric("multiclass", ds.y_test[:256], pred))
        mean, std = relative_accuracy(ideal, accs)
        print(f"  {frac:5.1%} defects -> relative accuracy "
              f"{mean:.4f} +/- {std:.4f} (now v{entry.version})")
    registry.swap("eye", clean_table)
    shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
