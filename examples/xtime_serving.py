"""X-TIME as an inference service: batched tabular requests through the
CAM engine, all four NoC programs (§III-D), and the analog-defect
robustness study (Fig. 9b) on a live model.

Run:  PYTHONPATH=src python examples/xtime_serving.py
"""

import numpy as np

from repro.core.compile import compile_ensemble, pack_cores
from repro.core.defects import inject_table_defects, relative_accuracy
from repro.core.engine import XTimeEngine
from repro.core.noc import plan_noc
from repro.core.perfmodel import xtime_perf
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import accuracy_metric, make_dataset


def main() -> None:
    for name, label, batching in (("rossmann", "regression", False),
                                  ("eye", "multiclass", False),
                                  ("telco", "binary + input batching", True)):
        ds = make_dataset(name)
        q = FeatureQuantizer.fit(ds.x_train, 256)
        ens = train_gbdt(
            q.transform(ds.x_train), ds.y_train, task=ds.task, n_bins=256,
            n_classes=ds.n_classes,
            params=GBDTParams(n_rounds=30, max_leaves=64),
        )
        table = compile_ensemble(ens)
        plc = pack_cores(table)
        noc = plan_noc(table, plc, batching=batching)
        label = f"{label} ({noc.config} NoC)"
        eng = XTimeEngine(table, backend="jnp", noc_config=noc.engine_noc_config
                          if noc.engine_noc_config != "batch" else "accumulate")
        xb = q.transform(ds.x_test)
        pred = np.asarray(eng.predict(xb))
        acc = accuracy_metric(ds.task, ds.y_test, pred)
        rep = xtime_perf(table, plc, noc)
        print(f"{name:10s} {label:30s} acc={acc:.4f} "
              f"router_bits={''.join(map(str, noc.router_bits))} "
              f"tput={rep.throughput_msps:,.0f} MS/s "
              f"energy={rep.energy_nj_per_dec:.2f} nJ/dec")

    # defect robustness on the live multiclass service
    ds = make_dataset("eye")
    q = FeatureQuantizer.fit(ds.x_train, 256)
    ens = train_gbdt(q.transform(ds.x_train), ds.y_train, task="multiclass",
                     n_bins=256, n_classes=ds.n_classes,
                     params=GBDTParams(n_rounds=20, max_leaves=64))
    table = compile_ensemble(ens)
    xb = q.transform(ds.x_test)
    ideal = accuracy_metric("multiclass", ds.y_test,
                            np.asarray(XTimeEngine(table).predict(xb)))
    print("\ndefect robustness (memristor 1-level flips):")
    for frac in (0.002, 0.02, 0.1):
        accs = []
        for r in range(5):
            t2 = inject_table_defects(table, frac, np.random.default_rng(r))
            accs.append(accuracy_metric(
                "multiclass", ds.y_test,
                np.asarray(XTimeEngine(t2).predict(xb))))
        mean, std = relative_accuracy(ideal, accs)
        print(f"  {frac:5.1%} defects -> relative accuracy "
              f"{mean:.4f} +/- {std:.4f}")


if __name__ == "__main__":
    main()
