"""End-to-end training driver: train a llama-family model for a few
hundred steps on the synthetic Markov-token stream, with fault-tolerant
checkpointing.  Loss drops well below the unigram entropy as the model
learns the transition structure.

Default is CPU-sized (~7M params).  ``--hundred-m`` trains a ~100M-param
config (same code path; several hours on this 1-core container, minutes
on a real host).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

from repro.config import get_config
from repro.launch.train import train
from repro.optim.adamw import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--run-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--hundred-m", action="store_true")
    args = ap.parse_args()

    base = get_config("llama3.2-3b")
    if args.hundred_m:  # ~100M params: 12L x 768 x 12H, 8k vocab
        cfg = base.replace(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                           head_dim=0, d_ff=2048, vocab_size=8192, remat=False)
        batch, seq = 16, 512
    else:  # CPU-sized smoke of the same family
        cfg = base.replace(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                           head_dim=0, d_ff=688, vocab_size=512, remat=False)
        batch, seq = 8, 128

    hist = train(
        cfg, steps=args.steps, global_batch=batch, seq_len=seq,
        run_dir=args.run_dir, ckpt_every=50, log_every=20,
        opt_cfg=AdamWConfig(peak_lr=3e-3, warmup_steps=20,
                            decay_steps=args.steps),
    )
    first = hist[0]["loss"]
    last = min(h["loss"] for h in hist[-10:])
    print(f"\nloss {first:.3f} -> {last:.3f} over {len(hist)} steps "
          f"({'LEARNED' if last < first - 0.5 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
