"""X-TIME behind an elastic serving CLUSTER: two models are compiled once
(``repro.api.build``) and replicated onto a 2-replica ``ClusterServer``;
a seeded heavy-tailed traffic trace (``make_trace``) replays against it
with a 'kill' mark half-way — replica 0 dies mid-traffic, the heartbeat
monitor re-routes its queued work to the survivor, throughput degrades
but every accepted request still completes with predictions BIT-EQUAL to
a fresh single-replica pass over the same rows.  ``restore_replica``
then brings the dead slot back (elastic restart) and a second replay
shows the rotation healed (DESIGN.md §12).

Run:  PYTHONPATH=src python examples/xtime_cluster.py
"""

import numpy as np

from repro.api import build
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import make_dataset
from repro.serve import ClusterServer, make_trace, replay_trace


def _train(name: str, n_rounds: int = 25):
    ds = make_dataset(name)
    quant = FeatureQuantizer.fit(ds.x_train, 256)
    ens = train_gbdt(
        quant.transform(ds.x_train), ds.y_train, task=ds.task, n_bins=256,
        n_classes=ds.n_classes,
        params=GBDTParams(n_rounds=n_rounds, max_leaves=64),
    )
    return quant.transform(ds.x_test).astype(np.int32), build(ens)


def _replica_line(report: dict) -> str:
    return "  ".join(
        f"replica {rid}: {r['state']:8s} {r['served_requests']:4d} req "
        f"{r['flushes']:3d} flushes"
        for rid, r in sorted(report["replicas"].items())
    )


def main() -> None:
    print("[build] compiling two models once (artifacts replicate as-is)")
    streams, artifacts = {}, {}
    for name in ("churn", "telco"):
        streams[name], artifacts[name] = _train(name)
        print(f"[build]    {name:6s} {artifacts[name].table.n_rows} CAM rows")

    trace = make_trace(
        list(streams), 600, seed=7, mean_interval_s=5e-4, mean_rows=1.5,
        marks=[(0.5, "kill")],
    )
    print(f"[trace] {len(trace.requests)} requests / {trace.n_rows} rows, "
          f"seed={trace.seed}, kill mark at t={trace.marks[0].t * 1e3:.1f}ms")

    with ClusterServer(
        n_replicas=2, flush_rows=64, max_batch=128, heartbeat_timeout_s=5.0,
    ) as srv:
        for name, art in artifacts.items():
            entry = srv.register(name, art)
            print(f"[register] {name:6s} v{entry.version} on 2 replicas "
                  "(compile once, install twice)")

        # warm the coalescing buckets, then zero the SLO window
        replay_trace(srv.submit, trace, streams, speed=0)
        srv.drain(timeout=300)
        srv.reset_stats()

        print("\n[replay] burst replay with replica 0 killed half-way:")
        res = replay_trace(
            srv.submit, trace, streams, speed=0,
            callbacks={"kill": lambda: srv.kill_replica(0)},
        )
        srv.drain(timeout=300)
        rep = srv.report()
        m = rep["measured"]
        print(f"  completed {m['requests']}/{res.submitted} requests, "
              f"{rep['failovers']} failover(s), shed={sum(rep['shed'].values())}")
        print(f"  p50={m['p50_ms']:.1f}ms p99={m['p99_ms']:.1f}ms "
              f"{m['requests_per_s']:,.0f} req/s (degraded: one survivor)")
        print(f"  {_replica_line(rep)}")

        # correctness survived the failover: every handle matches a direct
        # single-replica pass over the same replayed rows
        checked = 0
        for h, req in zip(res.handles, trace.requests):
            rows = np.take(
                streams[req.model],
                np.arange(req.row_start, req.row_start + req.n_rows),
                axis=0, mode="wrap",
            )
            eng = artifacts[req.model].engine()
            np.testing.assert_array_equal(
                h.result(30), np.asarray(eng.predict(rows))
            )
            checked += 1
        print(f"  bit-equality: {checked}/{len(res.handles)} requests match "
              "the direct engine — failover lost nothing")

        print("\n[restore] elastic restart of the dead slot:")
        srv.restore_replica(0)
        srv.reset_stats()
        replay_trace(srv.submit, trace, streams, speed=0)
        srv.drain(timeout=300)
        rep = srv.report()
        m = rep["measured"]
        print(f"  {_replica_line(rep)}")
        print(f"  p50={m['p50_ms']:.1f}ms p99={m['p99_ms']:.1f}ms "
              f"{m['requests_per_s']:,.0f} req/s (both replicas serving)")
        assert rep["replicas"][0]["state"] == "alive"
        assert rep["replicas"][0]["flushes"] > 0, "restored replica got traffic"


if __name__ == "__main__":
    main()
