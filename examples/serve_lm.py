"""Batched LM serving: prefill a batch of prompts, decode with a KV cache,
sample.  Same decode path the dry-run lowers at 32k/500k scale.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.launch.serve import generate
from repro.models.registry import build_model


def main() -> None:
    cfg = get_config("llama3.2-3b").replace(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=0,
        d_ff=688, vocab_size=4096, remat=False,
    )
    bundle = build_model(cfg, flash_blk=64)
    params = bundle.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32)

    t0 = time.time()
    out = generate(bundle, params, prompts, max_new=32, temperature=0.8)
    dt = time.time() - t0
    print(f"batch=8 prompt=64 new=32 -> {8*32/dt:.1f} tok/s on CPU")
    print("greedy check:",
          (generate(bundle, params, prompts, max_new=8, temperature=0.0)
           == generate(bundle, params, prompts, max_new=8, temperature=0.0)).all())
    print("sample:", out[0][:12].tolist())


if __name__ == "__main__":
    main()
