"""Fault-tolerance demo: a training run is killed mid-flight and resumed
— the resumed loss trajectory is bit-identical to an uninterrupted run
(pure-function-of-step data + atomic checkpoints).

Run:  PYTHONPATH=src python examples/elastic_restart.py
"""

import shutil
import tempfile

import numpy as np

from repro.configs.llama32_3b import smoke
from repro.ft.runtime import InjectedFailure
from repro.launch.train import train


def main() -> None:
    cfg = smoke().replace(dtype="float32", remat=False)
    kw = dict(global_batch=4, seq_len=64, ckpt_every=5, seed=0, log_every=5)
    base = tempfile.mkdtemp(prefix="elastic_")
    try:
        print("== run A: crashes after step 12 ==")
        try:
            train(cfg, steps=25, run_dir=f"{base}/a", failure_at=12, **kw)
        except InjectedFailure as e:
            print(f"   !! {e}")
        print("== run A resumed (from step-10 checkpoint) ==")
        hist_a = train(cfg, steps=25, run_dir=f"{base}/a", **kw)
        print("== run B: uninterrupted reference ==")
        hist_b = train(cfg, steps=25, run_dir=f"{base}/b", **kw)
        ref = {h["step"]: h["loss"] for h in hist_b}
        worst = max(abs(h["loss"] - ref[h["step"]]) for h in hist_a)
        print(f"\nmax |loss_resumed - loss_reference| = {worst:.2e} "
              f"({'BIT-IDENTICAL' if worst == 0 else 'check determinism'})")
    finally:
        shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    main()
