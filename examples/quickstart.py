"""Quickstart: the complete X-TIME pipeline from the paper (Fig. 7d).

    dataset -> train GBDT -> 8-bit quantize -> repro.api.build (compile to
    CAM rows + place on cores + program the NoC + chip report) ->
    save/load the portable artifact -> bind the engine -> predictions

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile
from pathlib import Path

import numpy as np

from repro.api import CompiledModel, build
from repro.core.baselines import TraversalBaseline
from repro.core.deploy import DeployConfig
from repro.core.perfmodel import gpu_perf_model
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import accuracy_metric, make_dataset


def main() -> None:
    # 1. data + 8-bit feature grid (256 bins/feature, §III-B)
    ds = make_dataset("churn")
    quant = FeatureQuantizer.fit(ds.x_train, n_bins=256)
    xb_train, xb_test = quant.transform(ds.x_train), quant.transform(ds.x_test)

    # 2. train a gradient-boosted ensemble under the paper's HW constraints
    ens = train_gbdt(
        xb_train, ds.y_train, task="binary", n_bins=256,
        params=GBDTParams(n_rounds=50, max_leaves=256, max_depth=8),
    )
    acc = accuracy_metric("binary", ds.y_test, ens.predict(xb_test))
    print(f"[train]   {ens.n_trees} trees, max {ens.max_leaves} leaves, "
          f"test acc {acc:.4f}")

    # 3. compile ONCE into the deployable artifact: CAM rows, core
    #    placement, NoC router program, analytic chip report, exec config
    #    (batching=True: replicate the small model across cores, §III-D)
    cm = build(ens, deploy=DeployConfig(backend="jnp", batching=True))
    print(f"[build]   {cm.table.n_rows} CAM rows x {cm.table.n_features} "
          f"features, {cm.table.dont_care_fraction():.0%} don't-care cells")
    print(f"[place]   {cm.placement.n_cores_used} cores, "
          f"{cm.placement.max_trees_per_core} trees/core max, "
          f"replication x{cm.placement.replication}, NoC '{cm.noc.config}'")

    # 4. the artifact is the unit of deployment: npz + JSON sidecar,
    #    reloadable on any host with no trainer and no recompilation
    with tempfile.TemporaryDirectory() as tmp:
        sidecar = cm.save(Path(tmp) / "churn")
        loaded = CompiledModel.load(sidecar)
        print(f"[save]    {sidecar.name} + churn.npz "
              f"({sidecar.stat().st_size} B sidecar)")

        # 5. inference: one associative match replaces D dependent gathers
        engine = loaded.engine()  # binds backend/mesh on demand
        pred = np.asarray(engine.predict(xb_test))
        ref = TraversalBaseline(ens).predict(xb_test)
        print(f"[engine]  reloaded-artifact engine == traversal on "
              f"{len(pred)} samples: {(pred == ref).all()}")

    # 6. chip performance model (Eq. 4/5, Fig. 8 constants) rides along
    rep = cm.perf
    gpu = gpu_perf_model(n_trees=ens.n_trees, depth=8)
    print(f"[chip]    latency {rep.latency_ns:.0f} ns, throughput "
          f"{rep.throughput_msps:,.0f} MS/s, {rep.power_w:.1f} W, "
          f"{rep.energy_nj_per_dec:.2f} nJ/decision")
    print(f"[vs GPU]  latency x{gpu.latency_ns/rep.latency_ns:,.0f} lower, "
          f"throughput x{rep.throughput_msps/gpu.throughput_msps:,.0f} higher")


if __name__ == "__main__":
    main()
