"""Quickstart: the complete X-TIME pipeline from the paper (Fig. 7d).

    dataset -> train GBDT -> 8-bit quantize -> compile to CAM rows ->
    place on cores -> program the NoC -> run the engine -> chip report

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.baselines import TraversalBaseline
from repro.core.compile import compile_ensemble, pack_cores
from repro.core.engine import XTimeEngine
from repro.core.noc import plan_noc
from repro.core.perfmodel import gpu_perf_model, xtime_perf
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import accuracy_metric, make_dataset


def main() -> None:
    # 1. data + 8-bit feature grid (256 bins/feature, §III-B)
    ds = make_dataset("churn")
    quant = FeatureQuantizer.fit(ds.x_train, n_bins=256)
    xb_train, xb_test = quant.transform(ds.x_train), quant.transform(ds.x_test)

    # 2. train a gradient-boosted ensemble under the paper's HW constraints
    ens = train_gbdt(
        xb_train, ds.y_train, task="binary", n_bins=256,
        params=GBDTParams(n_rounds=50, max_leaves=256, max_depth=8),
    )
    acc = accuracy_metric("binary", ds.y_test, ens.predict(xb_test))
    print(f"[train]   {ens.n_trees} trees, max {ens.max_leaves} leaves, "
          f"test acc {acc:.4f}")

    # 3. compile: every root-to-leaf path -> one CAM row of [low, high) ranges
    table = compile_ensemble(ens)
    print(f"[compile] {table.n_rows} CAM rows x {table.n_features} features, "
          f"{table.dont_care_fraction():.0%} don't-care cells")

    # 4. placement + NoC program (accumulate/forward/batch, §III-D)
    placement = pack_cores(table)
    noc = plan_noc(table, placement)
    print(f"[place]   {placement.n_cores_used} cores, "
          f"{placement.max_trees_per_core} trees/core max, "
          f"replication x{placement.replication}, NoC config '{noc.config}'")

    # 5. inference: one associative match replaces D dependent gathers
    engine = XTimeEngine(table, backend="jnp")
    pred = np.asarray(engine.predict(xb_test))
    ref = TraversalBaseline(ens).predict(xb_test)
    print(f"[engine]  engine==traversal on {len(pred)} samples: "
          f"{(pred == ref).all()}")

    # 6. chip performance model (Eq. 4/5, Fig. 8 constants)
    rep = xtime_perf(table, placement, noc)
    gpu = gpu_perf_model(n_trees=ens.n_trees, depth=8)
    print(f"[chip]    latency {rep.latency_ns:.0f} ns, throughput "
          f"{rep.throughput_msps:,.0f} MS/s, {rep.power_w:.1f} W, "
          f"{rep.energy_nj_per_dec:.2f} nJ/decision")
    print(f"[vs GPU]  latency x{gpu.latency_ns/rep.latency_ns:,.0f} lower, "
          f"throughput x{rep.throughput_msps/gpu.throughput_msps:,.0f} higher")


if __name__ == "__main__":
    main()
