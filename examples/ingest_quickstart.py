"""Ingestion quickstart: serve a model this repo never trained.

The paper's deployment story (§II-D) starts from ensembles trained in
standard libraries.  This example plays the model owner AND the serving
side with no xgboost installed anywhere:

    1. write an XGBoost-JSON dump (here: exported from a native model,
       standing in for any real ``Booster.save_model('m.json')`` file)
    2. ingest it: parse -> threshold-grid lowering -> compile -> place
       (``repro.api.build`` accepts the dump path directly)
    3. save the CompiledModel artifact, cold-start a TableRegistry from
       it, and serve FLOAT queries in one call — ``served.predict(x)``
       bins with the artifact's own grid and dispatches the
       batch-hinted engine internally

Run:  PYTHONPATH=src python examples/ingest_quickstart.py
"""

import json
import tempfile
from pathlib import Path

import numpy as np

from repro.api import CompiledModel, build
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, train_gbdt
from repro.data.tabular import make_dataset
from repro.ingest import load_model, to_xgboost_json
from repro.serve import TableRegistry


def main() -> None:
    with tempfile.TemporaryDirectory() as td:
        # 1. the "model owner": any XGBoost-JSON dump works here
        ds = make_dataset("churn")
        quant = FeatureQuantizer.fit(ds.x_train, n_bins=256)
        ens = train_gbdt(
            quant.transform(ds.x_train), ds.y_train, task="binary",
            n_bins=256, params=GBDTParams(n_rounds=30, max_leaves=64),
        )
        dump = Path(td) / "model.json"
        dump.write_text(json.dumps(to_xgboost_json(ens, quant)))
        print(f"[dump]    {dump.name}: {dump.stat().st_size // 1024} KiB "
              "XGBoost-JSON (no xgboost involved)")

        # 2. ingest + compile in one call; the sidecar records the grid
        imported = load_model(dump)  # or: build(str(dump)) directly
        cm = build(imported)
        rep = cm.ingest
        print(f"[ingest]  {rep['source']}: {rep['n_source_trees']} trees, "
              f"{cm.table.n_rows} CAM rows, exact={rep['exact']}")
        print(f"[grid]    {sum(1 for g in rep['grid'] if g['thresholds'])}"
              f"/{rep['n_features']} features split, "
              f"n_bins={rep['n_bins']}")

        # 3. artifact -> disk -> registry cold start -> predictions
        cm.save(Path(td) / "artifacts" / "churn")
        served = CompiledModel.load(Path(td) / "artifacts" / "churn")
        reg = TableRegistry()
        reg.register("churn", served)

        x = ds.x_test[:256]  # FLOAT queries: the artifact bins them
        pred = served.predict(x)
        native = ens.predict(quant.transform(x))
        print(f"[serve]   {len(x)} float queries -> "
              f"{int((pred == native).sum())}/{len(x)} predictions "
              "identical to the native model")
        assert bool(np.all(pred == native))


if __name__ == "__main__":
    main()
