"""Config system: one frozen dataclass drives model build, sharding,
launcher, dry-run and smoke tests for every architecture (incl. the
paper's own `xtime-tabular` workload).

Shape cells (assignment): train_4k / prefill_32k / decode_32k / long_500k.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio | xtime
    # transformer dims
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0  # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab_size: int = 0
    # attention details
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # gemma3: different theta for global layers
    sliding_window: int = 0  # 0 -> full attention
    local_global_period: int = 0  # gemma3: 1 global layer every N (5 local : 1 global)
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    # activation / norm
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # leading dense layers (deepseek-v3: 3)
    dense_d_ff: int = 0  # d_ff of those dense layers
    moe_dense_residual: bool = False  # arctic: parallel dense FFN residual
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # MLA (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MTP (deepseek)
    mtp_depth: int = 0
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 0  # P
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    shared_attn_period: int = 0  # zamba2: shared attn block every N mamba layers
    # RWKV6
    rwkv_head_dim: int = 0
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    max_decoder_len: int = 0  # decoder positions for enc-dec shapes
    # modality frontend stub (vlm / audio): inputs are precomputed embeddings
    embeddings_input: bool = False
    # training
    dtype: str = "bfloat16"
    remat: bool = True
    # long-context applicability (assignment: skip long_500k for pure full attn)
    supports_long_context: bool = False
    # free-form notes (applicability, simplifications)
    notes: str = ""
    # source citation
    source: str = ""

    # -- derived ------------------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def shapes(self) -> list[ShapeCell]:
        """The assigned shape cells applicable to this architecture."""
        out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
        if self.supports_long_context:
            out.append(SHAPES["long_500k"])
        return out

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class XTimeConfig:
    """The paper's own workload as a framework config (11th arch)."""

    name: str = "xtime-tabular"
    family: str = "xtime"
    n_trees: int = 4096  # the paper's maximum ensemble constraint
    max_leaves: int = 256
    n_features: int = 130
    n_bins: int = 256
    n_classes: int = 8
    task: str = "multiclass"
    notes: str = "CAM rows sharded on `model`, batch on `data`(x`pod`)"

    def shapes(self) -> list[ShapeCell]:
        # serving batches: the engine is inference-only (as in the paper)
        return [
            ShapeCell("serve_32k", 1, 32768, "xtime"),
            ShapeCell("serve_1m", 1, 1_048_576, "xtime"),
        ]


# populated by repro.configs at import time
_REGISTRY: dict[str, Any] = {}


def register(cfg: Any) -> Any:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> Any:
    import repro.configs  # noqa: F401  (trigger registration)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
