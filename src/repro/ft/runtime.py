"""Fault-tolerance runtime: checkpoint/restart driver, heartbeats,
straggler detection.

At 1000+ nodes the relevant failure envelope is: (a) a worker dies
mid-step (hardware), (b) a worker heartbeats but runs slow (straggler —
thermal, network, preemption), (c) the coordinator must restart the job on
fewer/more nodes (elastic).  The pieces here compose those behaviours and
are integration-tested on CPU by injecting failures:

  * ``Heartbeat`` — per-worker liveness file with a monotonic counter;
    ``dead_workers`` flags anything past the timeout (the file protocol is
    what a real multi-host deployment would put on shared storage).
  * ``StragglerMonitor`` — per-step wall-time outlier detector.  The
    default baseline is a rolling-window median (training path); the
    serving tier uses ``ewma_alpha`` for an O(1) EWMA baseline that
    excludes flagged samples, so a persistently slow replica cannot
    drag its own baseline up and hide.  The trainer's response is to
    record the event and (in the elastic driver) exclude the worker on
    the next restart boundary; the serving cluster
    (``repro.serve.cluster``) excludes the replica from routing after
    ``straggler_strikes`` flags — on TPU pods the equivalent production
    response is re-slicing.
  * ``FaultTolerantRunner`` — wraps a step function with periodic async
    checkpoints and replays from the latest checkpoint after a (simulated
    or real) crash; data is a pure function of step so the resumed loss
    trajectory is bit-identical (tested).

Both ``Heartbeat`` and ``StragglerMonitor`` are shared with the serving
path: ``repro.serve.cluster`` replicas beat the same liveness files and
feed flush wall times into one shared EWMA monitor (DESIGN.md §12).
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.checkpoint.ckpt import CheckpointManager, latest_step, restore_checkpoint


class Heartbeat:
    def __init__(self, run_dir: str, worker_id: int, timeout_s: float = 60.0):
        self.dir = os.path.join(run_dir, "heartbeats")
        os.makedirs(self.dir, exist_ok=True)
        self.worker_id = worker_id
        self.timeout_s = timeout_s
        self._count = 0

    def beat(self) -> None:
        self._count += 1
        path = os.path.join(self.dir, f"worker_{self.worker_id}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"count": self._count, "time": time.time()}, f)
        os.replace(tmp, path)

    def last_seen(self) -> dict[int, float]:
        """worker_id -> seconds since its last recorded beat.

        Reads every worker file in the run dir (not just this worker's),
        so any participant can observe the whole cluster; a file caught
        mid-``os.replace`` or half-written by a dying process is skipped
        rather than crashing the monitor.
        """
        now = time.time()
        ages: dict[int, float] = {}
        for fn in os.listdir(self.dir):
            if not fn.startswith("worker_") or not fn.endswith(".json"):
                continue
            try:
                with open(os.path.join(self.dir, fn)) as f:
                    info = json.load(f)
            except (OSError, ValueError):  # pragma: no cover - torn write
                continue
            ages[int(fn.split("_")[1].split(".")[0])] = now - info["time"]
        return ages

    def dead_workers(self) -> list[int]:
        return sorted(
            wid for wid, age in self.last_seen().items()
            if age > self.timeout_s
        )


@dataclass
class StragglerMonitor:
    """Wall-time outlier detector with two baseline flavours.

    ``ewma_alpha=None`` (default, training path): baseline is the median
    of the last ``window`` samples.  ``ewma_alpha=a`` (serving path):
    baseline is an exponentially-weighted moving average updated only
    with UN-flagged samples, so a replica that turns slow keeps being
    flagged instead of normalizing its own baseline.  Either way the
    first ``min_samples`` observations are warmup and never flag.
    """

    threshold: float = 3.0
    window: int = 32
    ewma_alpha: float | None = None
    min_samples: int = 8
    times: list[float] = field(default_factory=list)
    events: list[dict] = field(default_factory=list)
    _ewma: float | None = None

    @property
    def baseline(self) -> float | None:
        """Current comparison baseline (None during warmup)."""
        if self.ewma_alpha is not None:
            return self._ewma
        hist = self.times[-self.window:]
        return float(np.median(hist)) if hist else None

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        if self.ewma_alpha is None:
            hist = self.times[-self.window:]
            self.times.append(dt)
            if len(hist) < self.min_samples:
                return False
            med = float(np.median(hist))
            if dt > self.threshold * med:
                self.events.append({"step": step, "dt": dt, "median": med})
                return True
            return False
        base = self._ewma
        self.times.append(dt)
        if base is None:
            self._ewma = float(dt)
            return False
        flagged = (
            len(self.times) >= self.min_samples and dt > self.threshold * base
        )
        if flagged:
            self.events.append({"step": step, "dt": dt, "baseline": base})
        else:
            a = self.ewma_alpha
            self._ewma = a * float(dt) + (1.0 - a) * base
        return flagged


class InjectedFailure(RuntimeError):
    pass


class FaultTolerantRunner:
    """Checkpoint/restart training driver.

    step_fn: (state, step) -> (state, metrics); state is a pytree.
    The runner checkpoints every ``ckpt_every`` steps (async), restores
    from the latest checkpoint on (re)start, and records straggler events.
    ``failure_at`` injects a crash after that step completes (tests).
    """

    def __init__(
        self,
        run_dir: str,
        step_fn: Callable[[Any, int], tuple[Any, dict]],
        init_state: Callable[[], Any],
        *,
        ckpt_every: int = 10,
        keep: int = 3,
        worker_id: int = 0,
    ):
        self.run_dir = run_dir
        self.step_fn = step_fn
        self.init_state = init_state
        self.ckpt_every = ckpt_every
        self.mgr = CheckpointManager(os.path.join(run_dir, "ckpt"), keep=keep)
        self.heartbeat = Heartbeat(run_dir, worker_id)
        self.straggler = StragglerMonitor()

    def resume_or_init(self, placer: Callable | None = None) -> tuple[int, Any]:
        ckpt_dir = os.path.join(self.run_dir, "ckpt")
        step = latest_step(ckpt_dir)
        template = self.init_state()
        if step is None:
            return 0, template
        step, state = restore_checkpoint(ckpt_dir, template, step, placer)
        return step, state

    def run(
        self,
        n_steps: int,
        *,
        failure_at: int | None = None,
        placer: Callable | None = None,
        on_metrics: Callable[[int, dict], None] | None = None,
    ) -> tuple[Any, list[dict]]:
        start, state = self.resume_or_init(placer)
        history: list[dict] = []
        for step in range(start, n_steps):
            t0 = time.time()
            state, metrics = self.step_fn(state, step)
            dt = time.time() - t0
            flagged = self.straggler.record(step, dt)
            metrics = {**metrics, "step": step, "dt": dt, "straggler": flagged}
            history.append(metrics)
            if on_metrics:
                on_metrics(step, metrics)
            self.heartbeat.beat()
            done = step + 1
            if done % self.ckpt_every == 0 or done == n_steps:
                self.mgr.save(done, state, extra={"metrics": {
                    k: float(v) for k, v in metrics.items() if isinstance(v, (int, float))
                }})
            if failure_at is not None and done == failure_at:
                self.mgr.wait()
                raise InjectedFailure(f"injected crash after step {failure_at}")
        self.mgr.wait()
        return state, history
