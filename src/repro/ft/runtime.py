"""Fault-tolerance runtime: checkpoint/restart driver, heartbeats,
straggler detection.

At 1000+ nodes the relevant failure envelope is: (a) a worker dies
mid-step (hardware), (b) a worker heartbeats but runs slow (straggler —
thermal, network, preemption), (c) the coordinator must restart the job on
fewer/more nodes (elastic).  The pieces here compose those behaviours and
are integration-tested on CPU by injecting failures:

  * ``Heartbeat`` — per-worker liveness file with a monotonic counter;
    ``dead_workers`` flags anything past the timeout (the file protocol is
    what a real multi-host deployment would put on shared storage).
  * ``StragglerMonitor`` — per-step wall-time EWMA; a step slower than
    ``threshold`` x median flags the step.  The trainer's response is to
    record the event and (in the elastic driver) exclude the worker on
    the next restart boundary; on TPU pods the equivalent production
    response is re-slicing.
  * ``FaultTolerantRunner`` — wraps a step function with periodic async
    checkpoints and replays from the latest checkpoint after a (simulated
    or real) crash; data is a pure function of step so the resumed loss
    trajectory is bit-identical (tested).
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.checkpoint.ckpt import CheckpointManager, latest_step, restore_checkpoint


class Heartbeat:
    def __init__(self, run_dir: str, worker_id: int, timeout_s: float = 60.0):
        self.dir = os.path.join(run_dir, "heartbeats")
        os.makedirs(self.dir, exist_ok=True)
        self.worker_id = worker_id
        self.timeout_s = timeout_s
        self._count = 0

    def beat(self) -> None:
        self._count += 1
        path = os.path.join(self.dir, f"worker_{self.worker_id}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"count": self._count, "time": time.time()}, f)
        os.replace(tmp, path)

    def dead_workers(self) -> list[int]:
        now = time.time()
        dead = []
        for fn in os.listdir(self.dir):
            if not fn.startswith("worker_"):
                continue
            with open(os.path.join(self.dir, fn)) as f:
                info = json.load(f)
            if now - info["time"] > self.timeout_s:
                dead.append(int(fn.split("_")[1].split(".")[0]))
        return sorted(dead)


@dataclass
class StragglerMonitor:
    threshold: float = 3.0
    window: int = 32
    times: list[float] = field(default_factory=list)
    events: list[dict] = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) < 8:
            return False
        med = float(np.median(hist))
        if dt > self.threshold * med:
            self.events.append({"step": step, "dt": dt, "median": med})
            return True
        return False


class InjectedFailure(RuntimeError):
    pass


class FaultTolerantRunner:
    """Checkpoint/restart training driver.

    step_fn: (state, step) -> (state, metrics); state is a pytree.
    The runner checkpoints every ``ckpt_every`` steps (async), restores
    from the latest checkpoint on (re)start, and records straggler events.
    ``failure_at`` injects a crash after that step completes (tests).
    """

    def __init__(
        self,
        run_dir: str,
        step_fn: Callable[[Any, int], tuple[Any, dict]],
        init_state: Callable[[], Any],
        *,
        ckpt_every: int = 10,
        keep: int = 3,
        worker_id: int = 0,
    ):
        self.run_dir = run_dir
        self.step_fn = step_fn
        self.init_state = init_state
        self.ckpt_every = ckpt_every
        self.mgr = CheckpointManager(os.path.join(run_dir, "ckpt"), keep=keep)
        self.heartbeat = Heartbeat(run_dir, worker_id)
        self.straggler = StragglerMonitor()

    def resume_or_init(self, placer: Callable | None = None) -> tuple[int, Any]:
        ckpt_dir = os.path.join(self.run_dir, "ckpt")
        step = latest_step(ckpt_dir)
        template = self.init_state()
        if step is None:
            return 0, template
        step, state = restore_checkpoint(ckpt_dir, template, step, placer)
        return step, state

    def run(
        self,
        n_steps: int,
        *,
        failure_at: int | None = None,
        placer: Callable | None = None,
        on_metrics: Callable[[int, dict], None] | None = None,
    ) -> tuple[Any, list[dict]]:
        start, state = self.resume_or_init(placer)
        history: list[dict] = []
        for step in range(start, n_steps):
            t0 = time.time()
            state, metrics = self.step_fn(state, step)
            dt = time.time() - t0
            flagged = self.straggler.record(step, dt)
            metrics = {**metrics, "step": step, "dt": dt, "straggler": flagged}
            history.append(metrics)
            if on_metrics:
                on_metrics(step, metrics)
            self.heartbeat.beat()
            done = step + 1
            if done % self.ckpt_every == 0 or done == n_steps:
                self.mgr.save(done, state, extra={"metrics": {
                    k: float(v) for k, v in metrics.items() if isinstance(v, (int, float))
                }})
            if failure_at is not None and done == failure_at:
                self.mgr.wait()
                raise InjectedFailure(f"injected crash after step {failure_at}")
        self.mgr.wait()
        return state, history
