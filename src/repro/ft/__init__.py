from repro.ft.runtime import FaultTolerantRunner, StragglerMonitor, Heartbeat  # noqa: F401
