from repro.checkpoint.ckpt import (  # noqa: F401
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    CheckpointManager,
)
