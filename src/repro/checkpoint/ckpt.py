"""Checkpointing: atomic, async-capable, mesh-elastic.

Format: one ``.npz`` per checkpoint step holding every leaf under its
pytree key-path string, plus a small JSON manifest.  Leaves are gathered
to host (logical/unsharded) arrays, so a checkpoint written on one mesh
restores onto *any* mesh — ``restore_checkpoint`` re-places each leaf with
the shardings derived for the new mesh ("elastic" resume; integration-
tested by killing a run and resuming on a different topology).

Atomicity: write to ``<dir>/tmp.<step>`` then ``os.replace`` into place —
a crash mid-write never corrupts the latest checkpoint.  ``async_save``
snapshots to host memory synchronously (cheap) and writes on a background
thread (the training loop is not blocked by disk).
"""

from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Callable

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind not in "biufc":  # ml_dtypes (bfloat16, fp8): not
            arr = arr.astype(np.float32)  # npz-able; f32 roundtrips lossless
        flat[jax.tree_util.keystr(path)] = arr
    return flat


def _unflatten_into(template: Any, data: dict[str, np.ndarray]) -> Any:
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in paths:
        key = jax.tree_util.keystr(path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {tmpl.shape}"
            )
        leaves.append(arr.astype(tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, extra: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.npz")
    final = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, final)
    manifest = {"step": step, "n_leaves": len(flat), **(extra or {})}
    mtmp = os.path.join(ckpt_dir, f"tmp.{step}.json")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(ckpt_dir, f"step_{step:08d}.json"))
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for fn in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)\.npz", fn))
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    ckpt_dir: str,
    template: Any,
    step: int | None = None,
    placer: Callable[[Any], Any] | None = None,
) -> tuple[int, Any]:
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  ``placer`` re-shards leaves for the current mesh
    (elastic resume); identity when None."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as z:
        data = {k: z[k] for k in z.files}
    tree = _unflatten_into(template, data)
    if placer is not None:
        tree = placer(tree)
    return step, tree


class CheckpointManager:
    """Keeps the last ``keep`` checkpoints; optional async writes."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_save: bool = True):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()
        # snapshot to host synchronously (consistent view), write async
        flat_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            try:
                save_checkpoint(self.dir, step, flat_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
            self.wait()

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1))
            for fn in os.listdir(self.dir)
            if (m := re.fullmatch(r"step_(\d+)\.npz", fn))
        )
        for s in steps[: -self.keep]:
            for ext in ("npz", "json"):
                try:
                    os.remove(os.path.join(self.dir, f"step_{s:08d}.{ext}"))
                except FileNotFoundError:
                    pass
