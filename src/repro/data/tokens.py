"""Deterministic, resumable synthetic token pipeline for LM training.

The stream is a pure function of (seed, step): restart-at-step-k replays
the exact same batches — the property the fault-tolerance tests rely on.
Content is a learnable order-2 Markov chain over the vocabulary with
long-range copy segments, so a small transformer's loss drops well below
the unigram entropy within a few hundred steps (used by the e2e example).

For multi-host production: each host materializes only its slice via
``host_batch`` (slicing is by global batch index, so any host count that
divides the global batch yields identical global content).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TokenPipeline:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        k = min(64, v)  # transition fan-out
        # sparse order-2-ish transition table: next = table[cur, rand<k]
        self._table = rng.integers(0, v, size=(v, k), dtype=np.int64)
        self._start = rng.integers(0, v, size=(4096,), dtype=np.int64)

    def batch(self, step: int) -> dict:
        """Global batch {'tokens' (B,S), 'labels' (B,S)} for one step."""
        return self.host_batch(step, host_id=0, n_hosts=1)

    def host_batch(self, step: int, host_id: int, n_hosts: int) -> dict:
        b = self.global_batch // n_hosts
        rows = []
        for i in range(b):
            g = host_id * b + i  # global row index
            rows.append(self._row(step, g))
        tokens = np.stack(rows)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = tokens[:, 0]
        return {"tokens": tokens.astype(np.int32), "labels": labels.astype(np.int32)}

    def _row(self, step: int, row: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row])
        )
        s = self.seq_len
        out = np.empty((s,), dtype=np.int64)
        cur = int(self._start[rng.integers(0, len(self._start))])
        # geometric successor choice: skewed transitions => low conditional
        # entropy (~1.7 nats) so a small LM demonstrably beats the unigram
        # floor within a few hundred CPU steps (examples/train_lm.py)
        choices = np.minimum(rng.geometric(0.35, size=s) - 1,
                             self._table.shape[1] - 1)
        noise = rng.random(s)
        for t in range(s):
            out[t] = cur
            if noise[t] < 0.05:  # 5% resets keep the chain mixing
                cur = int(self._start[choices[t] % len(self._start)])
            else:
                cur = int(self._table[cur, choices[t]])
        # long-range copy: second half repeats a slice of the first half
        if s >= 64 and rng.random() < 0.5:
            ln = s // 4
            src = int(rng.integers(0, s // 2 - ln))
            out[-ln:] = out[src : src + ln]
        return out


@dataclass
class EmbeddingPipeline:
    """Synthetic (B, S, d) embedding batches for VLM/audio stub frontends."""

    d_model: int
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    decoder_ratio: int = 8  # enc-dec: decoder tokens per frame

    def batch(self, step: int, kind: str = "vlm") -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        b, s, d = self.global_batch, self.seq_len, self.d_model
        embeds = rng.normal(size=(b, s, d)).astype(np.float32) * (d ** -0.5)
        labels = rng.integers(0, self.vocab_size, size=(b, s)).astype(np.int32)
        if kind == "audio":
            sd = max(64, s // self.decoder_ratio)
            tokens = rng.integers(0, self.vocab_size, size=(b, sd)).astype(np.int32)
            labels = np.roll(tokens, -1, axis=1)
            return {"frames": embeds, "tokens": tokens, "labels": labels}
        return {"embeds": embeds, "labels": labels}
