"""Data pipelines: synthetic tabular datasets (paper benchmarks, Table II
analogs) and a deterministic, resumable synthetic token pipeline for the
LM substrate."""

from repro.data.tabular import TabularDataset, make_dataset, PAPER_DATASETS  # noqa: F401
