"""Synthetic tabular datasets shaped like the paper's benchmark (Table II).

The Kaggle/UCI datasets used by the paper are not downloadable in this
offline container, so we generate synthetic analogs with matched
(n_samples, N_feat, N_classes, task).  The generator builds a ground truth
that is *piecewise axis-aligned* (a random shallow tree ensemble plus
feature interactions and label noise), i.e. exactly the function class
tree models excel at — so accuracy deltas between FP / 8-bit / 4-bit /
RF-only reproduce the paper's qualitative Fig. 9 claims.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np


@dataclass
class TabularDataset:
    name: str
    task: str  # regression | binary | multiclass
    x_train: np.ndarray
    y_train: np.ndarray
    x_valid: np.ndarray
    y_valid: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    n_classes: int

    @property
    def n_features(self) -> int:
        return int(self.x_train.shape[1])


# name -> (task, n_samples, n_feat, n_classes)  [Table II]
PAPER_DATASETS: dict[str, tuple[str, int, int, int]] = {
    "churn": ("binary", 10000, 10, 2),
    "eye": ("multiclass", 10936, 26, 3),
    "forest": ("multiclass", 20000, 54, 7),  # subsampled from 581k for CPU budget
    "gas": ("multiclass", 13910, 129, 6),
    "gesture": ("multiclass", 9873, 32, 5),
    "telco": ("binary", 7032, 19, 2),
    "rossmann": ("regression", 20000, 29, 1),  # subsampled from 610k
}


def _random_tree_logits(
    x: np.ndarray, n_trees: int, depth: int, n_out: int, rng: np.random.Generator
) -> np.ndarray:
    """Ground-truth generator: sum of random axis-aligned decision trees."""
    n, F = x.shape
    out = np.zeros((n, n_out))
    for _ in range(n_trees):
        # a random balanced tree of the given depth: route by thresholds
        leaf = np.zeros(n, dtype=np.int64)
        for d in range(depth):
            f = int(rng.integers(0, F))
            thr = rng.uniform(np.quantile(x[:, f], 0.2), np.quantile(x[:, f], 0.8))
            leaf = leaf * 2 + (x[:, f] >= thr)
        leaf_vals = rng.normal(size=(2**depth, n_out))
        out += leaf_vals[leaf]
    return out / np.sqrt(n_trees)


def make_dataset(name: str, seed: int = 0) -> TabularDataset:
    if name not in PAPER_DATASETS:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(PAPER_DATASETS)}")
    task, n, n_feat, n_classes = PAPER_DATASETS[name]
    # zlib.crc32, NOT hash(): python string hashing is per-process salted,
    # which silently made every dataset (and borderline accuracy tests)
    # differ between runs.
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 2**31)

    # features: mixture of continuous (correlated gaussians), heavy-tailed,
    # and low-cardinality integer-coded categoricals — typical tabular mix.
    n_cat = max(1, n_feat // 5)
    n_cont = n_feat - n_cat
    A = rng.normal(size=(n_cont, n_cont)) / np.sqrt(n_cont)
    x_cont = rng.normal(size=(n, n_cont)) @ (np.eye(n_cont) + 0.3 * A)
    heavy = rng.integers(0, n_cont, size=max(1, n_cont // 4))
    x_cont[:, heavy] = np.sign(x_cont[:, heavy]) * np.abs(x_cont[:, heavy]) ** 1.7
    x_cat = rng.integers(0, 8, size=(n, n_cat)).astype(np.float64)
    x = np.concatenate([x_cont, x_cat], axis=1)

    n_out = n_classes if task == "multiclass" else 1
    logits = _random_tree_logits(x, n_trees=24, depth=5, n_out=n_out, rng=rng)
    # mild smooth interaction term so the problem is not *exactly* a tree
    w = rng.normal(size=(n_feat, n_out)) / np.sqrt(n_feat)
    logits = logits + 0.25 * np.tanh(x @ w)

    if task == "regression":
        y = logits[:, 0] + 0.1 * rng.normal(size=n)
        y = (y - y.mean()) / (y.std() + 1e-9)
    elif task == "binary":
        p = 1 / (1 + np.exp(-2.0 * logits[:, 0]))
        y = (rng.uniform(size=n) < p).astype(np.int64)
    else:
        g = 2.0 * logits + rng.gumbel(size=(n, n_out)) * 0.25
        y = np.argmax(g, axis=1).astype(np.int64)

    # 70/15/15 split, same protocol as the paper's pipeline (§IV-A)
    perm = rng.permutation(n)
    i1, i2 = int(0.7 * n), int(0.85 * n)
    tr, va, te = perm[:i1], perm[i1:i2], perm[i2:]
    return TabularDataset(
        name=name,
        task=task,
        x_train=x[tr].astype(np.float32),
        y_train=y[tr],
        x_valid=x[va].astype(np.float32),
        y_valid=y[va],
        x_test=x[te].astype(np.float32),
        y_test=y[te],
        n_classes=n_classes,
    )


def accuracy_metric(task: str, y_true: np.ndarray, pred: np.ndarray) -> float:
    """The paper's per-task metric: accuracy, or R^2-style score for regression."""
    if task == "regression":
        ss_res = float(np.sum((y_true - pred) ** 2))
        ss_tot = float(np.sum((y_true - y_true.mean()) ** 2)) + 1e-12
        return 1.0 - ss_res / ss_tot
    return float(np.mean(y_true == pred))
