"""Shape-bucketed micro-batching for the CAM serving path.

Production tabular traffic arrives as many small, ragged query batches
(typically a single row per request).  Feeding those shapes straight into
``XTimeEngine`` would trigger one ``jax.jit`` compilation per distinct
request size and pay a full dispatch per request.  Instead the batcher:

  1. coalesces pending requests (arrival order) into one query block,
  2. pads the block to the smallest admissible BUCKET — powers of two up
     to ``b_blk``, then ``b_blk`` multiples up to ``max_batch`` — so the
     engine compiles once per bucket, ``O(log max_batch)`` programs total,
  3. runs the engine's donated ``padded_fn`` once per flush,
  4. un-pads and splits the outputs back to the individual requests in
     their original order.

Batches larger than ``max_batch`` still get served: the fallback bucket is
the next ``b_blk`` multiple (an uncached compile — logged, not fatal),
mirroring how the chip handles over-capacity models by spilling to
multi-chip placement rather than rejecting them (DESIGN.md §6).
"""

from __future__ import annotations

import logging
import threading
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

log = logging.getLogger(__name__)


def _ceil_to(x: int, m: int) -> int:
    return int(np.ceil(x / m)) * m


@dataclass(frozen=True)
class BucketSpec:
    """The admissible padded batch sizes for one served model.

    ``multiple`` comes from ``XTimeEngine.batch_multiple``: 1 for the jnp
    oracle (power-of-two buckets allowed below ``b_blk``), ``b_blk`` for
    the Pallas kernel whose grid tiles the batch, and the mesh batch-shard
    count for distributed engines (which can exceed ``b_blk`` — e.g. 256
    shards on the 16x16 production mesh with the 'batch' NoC config).
    Large buckets step by ``lcm(b_blk, multiple)`` so every constraint
    holds simultaneously.
    """

    b_blk: int = 128
    max_batch: int = 1024
    multiple: int = 1

    def __post_init__(self) -> None:
        if self.multiple < 1 or self.b_blk < 1:
            raise ValueError("b_blk and multiple must be >= 1")
        if self.max_batch < self._step():
            raise ValueError(
                f"max_batch={self.max_batch} must be >= the smallest large "
                f"bucket lcm(b_blk={self.b_blk}, multiple={self.multiple})"
                f"={self._step()}"
            )

    def _step(self) -> int:
        return int(np.lcm(self.b_blk, self.multiple))

    def sizes(self) -> list[int]:
        """All cached bucket sizes, ascending: power-of-two multiples of
        ``multiple`` below the large-bucket step, then step multiples."""
        step = self._step()
        out = []
        p = self.multiple
        while p < step:
            out.append(p)
            p *= 2
        out.extend(range(step, self.max_batch + 1, step))
        return out

    def select(self, n: int) -> int:
        """Smallest bucket holding ``n`` rows (over-max falls back to the
        next step multiple — admissible but uncached)."""
        if n <= 0:
            raise ValueError("empty batch")
        for s in self.sizes():
            if n <= s:
                return s
        fallback = _ceil_to(n, self._step())
        log.warning(
            "batch of %d rows exceeds max_batch=%d; using uncached bucket %d",
            n, self.max_batch, fallback,
        )
        return fallback


@dataclass
class PendingRequest:
    """One enqueued query batch awaiting a flush."""

    request_id: int
    q_bins: np.ndarray  # (b, F) int
    t_enqueue: float = 0.0

    @property
    def n_rows(self) -> int:
        return int(self.q_bins.shape[0])


@dataclass
class MicroBatcher:
    """Coalesces requests for ONE engine into bucket-padded flushes.

    The batcher owns ordering: requests are concatenated in arrival order
    and results are handed back keyed by request id, so interleaving or
    re-submitting out of order cannot mis-route rows.

    Thread safety: ``submit``/``flush``/queue inspection may be called
    from concurrent threads (the async cluster tier drives one batcher
    from intake and worker threads at once).  The queue is mutated only
    under ``_lock``; a flush atomically takes the whole pending list and
    runs the engine OUTSIDE the lock, so submits keep landing while a
    flush computes and two racing flushes serve disjoint batches.
    """

    # XTimeEngine (duck-typed: padded_fn/arrays/batch_multiple/select_features)
    engine: "object"
    bucket: BucketSpec = field(default_factory=BucketSpec)
    kind: str = "predict"
    _pending: list[PendingRequest] = field(default_factory=list)
    _next_id: int = 0
    _lock: threading.RLock = field(
        default_factory=threading.RLock, repr=False, compare=False
    )

    @classmethod
    def for_engine(cls, engine, *, max_batch: int = 1024, kind: str = "predict"):
        return cls(
            engine=engine,
            bucket=BucketSpec(
                b_blk=engine.b_blk,
                max_batch=max_batch,
                multiple=engine.batch_multiple,
            ),
            kind=kind,
        )

    # -- queue ---------------------------------------------------------------

    def submit(
        self,
        q_bins: np.ndarray,
        *,
        t_enqueue: float = 0.0,
        request_id: int | None = None,
    ) -> int:
        """Enqueue one request batch; returns its request id.

        ``request_id`` lets an owner (ServeLoop) allocate ids globally so
        handles stay unique across batcher replacements (hot swap).
        """
        # copy: the queue may hold this until a much later flush, and the
        # caller is free to reuse/overwrite its buffer after submit()
        q = np.array(q_bins)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2 or q.shape[0] == 0:
            raise ValueError(f"expected (b, F) query rows, got shape {q.shape}")
        with self._lock:
            if request_id is None:
                request_id = self._next_id
                self._next_id += 1
            else:
                self._next_id = max(self._next_id, request_id + 1)
            self._pending.append(PendingRequest(request_id, q, t_enqueue))
        return request_id

    @property
    def pending_rows(self) -> int:
        with self._lock:
            return sum(p.n_rows for p in self._pending)

    @property
    def pending_requests(self) -> int:
        with self._lock:
            return len(self._pending)

    def oldest_enqueue_time(self) -> float | None:
        with self._lock:
            return self._pending[0].t_enqueue if self._pending else None

    # -- flush ---------------------------------------------------------------

    def flush(self) -> dict[int, np.ndarray]:
        """Run one coalesced engine call; returns {request_id: outputs}.

        Output rows per request exactly match what a direct
        ``engine.predict``/``raw_margin`` call on that request would give
        (the correctness contract tested in tests/test_serving.py).
        """
        with self._lock:
            if not self._pending:
                return {}
            batch, self._pending = self._pending, []
        n = sum(p.n_rows for p in batch)
        size = self.bucket.select(n)
        q = np.concatenate([p.q_bins for p in batch], axis=0)
        # compressed tables dropped wildcard columns: narrow the full-width
        # request rows to the stored columns BEFORE padding to f_pad —
        # padding first would bake misaligned columns into the bucket
        q_sel = self.engine.select_features(jnp.asarray(q))
        q_padded = kops.pad_to_bucket(
            q_sel, size, self.engine.arrays.f_pad,
            dtype=self.engine.table_dtype,
        )
        out = np.asarray(self.engine.padded_fn(self.kind)(q_padded))
        results: dict[int, np.ndarray] = {}
        row = 0
        for p in batch:
            results[p.request_id] = out[row : row + p.n_rows]
            row += p.n_rows
        return results
