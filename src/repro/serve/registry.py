"""Multi-model table registry for the serving engine.

One serving process holds MANY compiled models (one per customer table /
model version) on one device mesh.  Each entry is a ``ServedModel``
wrapped around a ``repro.api.CompiledModel`` artifact — the registry
accepts a trained ``Ensemble`` (compiles it), a raw ``CAMTable`` (places
it), or a ``CompiledModel`` loaded from disk (the cold-start path:
installed as-is, zero recompilation, no training imports), and binds the
artifact's ``DeployConfig`` to the registry's mesh.  On a multi-device
mesh that binding resolves ``spmd='auto'`` to the shard_map scale-out
path (explicit NoC-plan collectives, DESIGN.md §8) with no caller
changes; serving buckets stay correct because the batcher keys off
``XTimeEngine.batch_multiple``.  An autotuned artifact
(``CompiledModel.with_tuning``, DESIGN.md §10) cold-starts straight
into its tuned kernel configuration — block sizes and packed table
dtype come from the persisted plan, no re-search on reload.

Hot swap: re-registering a name atomically replaces its engine and bumps
the version; in-flight flushes keep the old engine object (Python
reference semantics) and the next flush picks up the new table.  Serving
settings (``batching``, deploy overrides) carry over across swaps unless
explicitly overridden, so a swap changes the TABLE, not the
configuration.

Thread safety: every registry operation (register/swap/unregister and
all lookups) runs under one re-entrant lock, so the async cluster tier
(``repro.serve.cluster``) can hot-swap from a control thread while
worker threads resolve entries — a reader sees either the old or the
new ``ServedModel``, never a torn one.  ``register`` holds the lock
across its read-modify-write (version bump + settings carry-over), which
serializes concurrent swaps of the same name; compiles are slow but
swaps are rare, so serialization beats a torn version chain.
"""

from __future__ import annotations

import threading
import warnings
from dataclasses import dataclass, field

from jax.sharding import Mesh

from repro.api import CompiledModel, build
from repro.core.compile import CAMTable, ChipSpec, CorePlacement
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.core.noc import NoCPlan
from repro.core.perfmodel import PerfReport
from repro.core.trees import Ensemble


@dataclass
class ServedModel:
    """One registry entry: the live engine around its compiled artifact."""

    name: str
    version: int
    artifact: CompiledModel
    engine: XTimeEngine
    batching: bool = False  # retained across hot swaps
    engine_overrides: dict | None = field(default=None)  # retained across hot swaps

    # artifact views (kept as properties so the artifact stays the single
    # source of truth; ``entry.table`` etc. remain stable public names)

    @property
    def table(self) -> CAMTable:
        return self.artifact.table

    @property
    def placement(self) -> CorePlacement:
        return self.artifact.placement

    @property
    def noc(self) -> NoCPlan:
        return self.artifact.noc

    @property
    def perf(self) -> PerfReport:
        """Analytic chip numbers for this exact mapping."""
        return self.artifact.perf

    @property
    def deploy(self) -> DeployConfig:
        return self.artifact.deploy

    @property
    def tuning(self) -> dict | None:
        """Persisted autotune plan the engine was cold-started with
        (``repro.core.tune.autotune_kernel`` → ``CompiledModel.with_tuning``);
        None when the artifact was never autotuned."""
        return self.artifact.tuning

    @property
    def compression(self) -> dict | None:
        """``CompressionReport`` dict of the pass that produced this
        table (``repro.core.compress`` via ``build(compress=...)``);
        None when the artifact was built with compress='off'.  Hot swaps
        keep each artifact's own report — compression is baked into the
        table, so ``with_deploy`` pins the carried-over ``compress``
        knob to the incoming artifact's actual level."""
        return self.artifact.compression


class TableRegistry:
    """Compile/load, hold and hot-swap named models sharing one mesh."""

    def __init__(
        self,
        *,
        mesh: Mesh | None = None,
        chip_spec: ChipSpec | None = None,
        deploy: DeployConfig | None = None,
        **engine_kwargs,
    ) -> None:
        if engine_kwargs:
            warnings.warn(
                "loose TableRegistry engine kwargs are deprecated; pass "
                "deploy=DeployConfig(...)",
                DeprecationWarning,
                stacklevel=2,
            )
            deploy = (deploy or DeployConfig()).replace(**engine_kwargs)
        self.mesh = mesh
        self.chip_spec = chip_spec
        self.deploy = deploy  # None => per-model defaults / artifact config
        self._models: dict[str, ServedModel] = {}
        self._lock = threading.RLock()

    # -- registration --------------------------------------------------------

    def register(
        self,
        name: str,
        model: Ensemble | CAMTable | CompiledModel,
        *,
        batching: bool | None = None,
        deploy: DeployConfig | None = None,
        **engine_overrides,
    ) -> ServedModel:
        """Install ``model`` under ``name`` (compiling only if needed).

        ``Ensemble`` / ``CAMTable`` inputs run the compiler pipeline via
        ``repro.api.build`` (as does an ``repro.ingest.ImportedEnsemble``
        or a dump path, which ``build`` lowers first); a
        ``CompiledModel`` is installed as-is — the serve cold-start path
        recompiles nothing.  Registering an existing
        name is the hot-swap path: the entry is replaced atomically and
        its version incremented, with the previous registration's
        ``batching``/deploy settings carried over unless overridden.

        ``engine_overrides`` (loose ``backend=...`` kwargs) are deprecated
        in favor of ``deploy=DeployConfig(...)`` but still honored.
        """
        if engine_overrides:
            warnings.warn(
                "loose register() engine kwargs are deprecated; pass "
                "deploy=DeployConfig(...)",
                DeprecationWarning,
                stacklevel=2,
            )
        with self._lock:
            return self._register_locked(
                name, model, batching=batching, deploy=deploy,
                **engine_overrides,
            )

    def _register_locked(
        self,
        name: str,
        model: Ensemble | CAMTable | CompiledModel,
        *,
        batching: bool | None = None,
        deploy: DeployConfig | None = None,
        **engine_overrides,
    ) -> ServedModel:
        prev = self._models.get(name)
        if prev is not None and deploy is None:
            # carry the previous loose overrides forward — but an explicit
            # deploy= is a full reset, so stale kwargs must not outrank it
            # (guard: manually constructed entries may carry overrides=None)
            engine_overrides = {**(prev.engine_overrides or {}), **engine_overrides}

        # base config precedence: explicit deploy > carried-over previous
        # registration > the artifact's own config > registry default
        if deploy is not None:
            base = deploy
        elif prev is not None:
            base = prev.deploy
        elif isinstance(model, CompiledModel):
            base = model.deploy
        else:
            base = self.deploy or DeployConfig()
        if batching is None:
            batching = base.batching
        cfg = base.replace(batching=batching, **engine_overrides)

        if isinstance(model, CompiledModel):
            artifact = model.with_deploy(cfg)  # never recompiles the table
        else:
            artifact = build(model, deploy=cfg, chip=self.chip_spec)

        entry = ServedModel(
            name=name,
            version=self.version(name) + 1,
            artifact=artifact,
            engine=artifact.engine(mesh=self.mesh),
            batching=batching,
            engine_overrides=dict(engine_overrides),
        )
        self._models[name] = entry
        return entry

    def swap(
        self, name: str, model: Ensemble | CAMTable | CompiledModel, **kw
    ) -> ServedModel:
        """Hot-swap: like ``register`` but the name must already exist."""
        with self._lock:
            if name not in self._models:
                raise KeyError(f"cannot swap unknown model {name!r}")
            return self._register_locked(name, model, **kw)

    def unregister(self, name: str) -> None:
        with self._lock:
            try:
                del self._models[name]
            except KeyError:
                raise KeyError(
                    f"unknown model {name!r}; registered: {sorted(self._models)}"
                ) from None

    # -- lookup --------------------------------------------------------------

    def get(self, name: str) -> ServedModel:
        with self._lock:
            try:
                return self._models[name]
            except KeyError:
                raise KeyError(
                    f"unknown model {name!r}; registered: {sorted(self._models)}"
                ) from None

    def engine(self, name: str) -> XTimeEngine:
        return self.get(name).engine

    def engine_for_batch(self, name: str, batch: int) -> XTimeEngine:
        """The engine serving ``batch``-sized requests of ``name``.

        A tuned artifact (kernel v3) carries a measured per-batch-bucket
        dispatch table in its ``TunePlan``; this binds (and memoizes, via
        the artifact's engine cache) the winning kernel configuration for
        the bucket covering ``batch``.  Untuned artifacts fall back to
        the entry's default engine.
        """
        entry = self.get(name)
        if entry.artifact.tuning is None:
            return entry.engine
        return entry.artifact.engine(mesh=self.mesh, batch_hint=int(batch))

    def artifact(self, name: str) -> CompiledModel:
        return self.get(name).artifact

    def version(self, name: str) -> int:
        """Current version of ``name`` (0 if never registered)."""
        with self._lock:
            entry = self._models.get(name)
            return entry.version if entry is not None else 0

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._models)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._models

    def __len__(self) -> int:
        with self._lock:
            return len(self._models)
