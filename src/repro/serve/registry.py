"""Multi-model table registry for the serving engine.

One serving process holds MANY compiled ensembles (one per customer table
/ model version) on one device mesh.  The registry owns the
ensemble -> CAMTable -> XTimeEngine pipeline plus the chip-side placement
artifacts (``pack_cores`` / ``plan_noc`` / ``xtime_perf``) so the serve
loop can report measured latency against the paper's analytic numbers for
the exact same model mapping.

Hot swap: re-registering a name atomically replaces its engine and bumps
the version; in-flight flushes keep the old engine object (Python
reference semantics) and the next flush picks up the new table — no
draining or locking needed in the synchronous loop.
"""

from __future__ import annotations

from dataclasses import dataclass

from jax.sharding import Mesh

from repro.core.compile import CAMTable, ChipSpec, compile_ensemble, pack_cores
from repro.core.engine import XTimeEngine
from repro.core.noc import NoCPlan, plan_noc
from repro.core.perfmodel import PerfReport, xtime_perf
from repro.core.trees import Ensemble


@dataclass
class ServedModel:
    """One registry entry: the live engine plus its chip-model artifacts."""

    name: str
    version: int
    table: CAMTable
    engine: XTimeEngine
    placement: object  # CorePlacement
    noc: NoCPlan
    perf: PerfReport  # analytic chip numbers for this exact mapping
    batching: bool = False  # retained across hot swaps
    engine_overrides: dict | None = None  # retained across hot swaps


class TableRegistry:
    """Compile, hold and hot-swap named ensembles sharing one mesh."""

    def __init__(
        self,
        *,
        mesh: Mesh | None = None,
        chip_spec: ChipSpec | None = None,
        **engine_kwargs,
    ) -> None:
        self.mesh = mesh
        self.chip_spec = chip_spec
        self.engine_kwargs = engine_kwargs
        self._models: dict[str, ServedModel] = {}

    # -- registration --------------------------------------------------------

    def register(
        self,
        name: str,
        model: Ensemble | CAMTable,
        *,
        batching: bool | None = None,
        **engine_overrides,
    ) -> ServedModel:
        """Compile (if needed) and install ``model`` under ``name``.

        Registering an existing name is the hot-swap path: the entry is
        replaced atomically and its version incremented.  Settings from
        the previous registration (``batching``, engine overrides) carry
        over unless explicitly overridden, so a swap changes the TABLE,
        not the serving configuration.
        """
        prev = self._models.get(name)
        if batching is None:
            batching = prev.batching if prev is not None else False
        if prev is not None:
            engine_overrides = {**prev.engine_overrides, **engine_overrides}
        table = model if isinstance(model, CAMTable) else compile_ensemble(model)
        placement = pack_cores(table, self.chip_spec)
        noc = plan_noc(table, placement, batching=batching)
        kwargs = {**self.engine_kwargs, **engine_overrides}
        # 'batch' replication is a chip-side concept; the engine's mesh
        # analogue is still the accumulate collective (see noc.py).
        noc_cfg = noc.engine_noc_config
        if noc_cfg == "batch" and self.mesh is None:
            noc_cfg = "accumulate"
        engine = XTimeEngine(table, mesh=self.mesh, noc_config=noc_cfg, **kwargs)
        version = self.version(name) + 1
        entry = ServedModel(
            name=name,
            version=version,
            table=table,
            engine=engine,
            placement=placement,
            noc=noc,
            perf=xtime_perf(table, placement, noc),
            batching=batching,
            engine_overrides=dict(engine_overrides),
        )
        self._models[name] = entry
        return entry

    def swap(self, name: str, model: Ensemble | CAMTable, **kw) -> ServedModel:
        """Hot-swap: like ``register`` but the name must already exist."""
        if name not in self._models:
            raise KeyError(f"cannot swap unknown model {name!r}")
        return self.register(name, model, **kw)

    def unregister(self, name: str) -> None:
        del self._models[name]

    # -- lookup --------------------------------------------------------------

    def get(self, name: str) -> ServedModel:
        try:
            return self._models[name]
        except KeyError:
            raise KeyError(
                f"unknown model {name!r}; registered: {sorted(self._models)}"
            ) from None

    def engine(self, name: str) -> XTimeEngine:
        return self.get(name).engine

    def version(self, name: str) -> int:
        """Current version of ``name`` (0 if never registered)."""
        entry = self._models.get(name)
        return entry.version if entry is not None else 0

    def names(self) -> list[str]:
        return sorted(self._models)

    def __contains__(self, name: str) -> bool:
        return name in self._models

    def __len__(self) -> int:
        return len(self._models)
