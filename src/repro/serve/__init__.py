"""Production serving layer over the X-TIME CAM engine (DESIGN.md §6).

    TableRegistry  — compile/hold/hot-swap many named ensembles, one mesh
    MicroBatcher   — shape-bucketed request coalescing per engine
    ServeLoop      — synchronous driver with p50/p99 latency accounting
"""

from repro.serve.batching import BucketSpec, MicroBatcher
from repro.serve.loop import LatencyStats, RequestRecord, ServeLoop
from repro.serve.registry import ServedModel, TableRegistry

__all__ = [
    "BucketSpec",
    "LatencyStats",
    "MicroBatcher",
    "RequestRecord",
    "ServeLoop",
    "ServedModel",
    "TableRegistry",
]
