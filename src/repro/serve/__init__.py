"""Production serving layer over the X-TIME CAM engine (DESIGN.md §6-§7).

    TableRegistry  — hold/hot-swap many named models, one mesh; accepts a
                     trained Ensemble, a CAMTable, or a CompiledModel
                     artifact (disk cold-start, zero recompilation)
    MicroBatcher   — shape-bucketed request coalescing per engine
    ServeLoop      — synchronous driver with p50/p99 latency accounting
"""

from repro.serve.batching import BucketSpec, MicroBatcher
from repro.serve.loop import LatencyStats, RequestRecord, ServeLoop
from repro.serve.registry import ServedModel, TableRegistry

__all__ = [
    "BucketSpec",
    "LatencyStats",
    "MicroBatcher",
    "RequestRecord",
    "ServeLoop",
    "ServedModel",
    "TableRegistry",
]
