"""Production serving layer over the X-TIME CAM engine (DESIGN.md §6, §12).

    TableRegistry  — hold/hot-swap many named models, one mesh; accepts a
                     trained Ensemble, a CAMTable, or a CompiledModel
                     artifact (disk cold-start, zero recompilation);
                     thread-safe for concurrent swap/lookup
    MicroBatcher   — shape-bucketed request coalescing per engine
                     (thread-safe enqueue/flush)
    ServeLoop      — synchronous single-threaded driver with p50/p99
                     latency accounting; the deterministic oracle the
                     async tier is bit-equality-tested against
    ClusterServer  — the async production tier: concurrent intake over
                     per-model queues, adaptive flush deadlines,
                     admission control with explicit shedding, and
                     replicated fault tolerance (heartbeat failover,
                     straggler exclusion, elastic restore) wired to
                     repro.ft.runtime
    TrafficTrace   — seeded heavy-tailed replay load generation
                     (make_trace / replay_trace) for SLO gating
"""

from repro.serve.batching import BucketSpec, MicroBatcher
from repro.serve.cluster import (
    AdaptiveWindow,
    ClusterClosed,
    ClusterHandle,
    ClusterServer,
    FailedRequest,
    ShedError,
)
from repro.serve.loop import LatencyStats, RequestRecord, ServeLoop
from repro.serve.registry import ServedModel, TableRegistry
from repro.serve.traffic import (
    ReplayResult,
    TrafficMark,
    TrafficRequest,
    TrafficTrace,
    make_trace,
    replay_trace,
)

__all__ = [
    "AdaptiveWindow",
    "BucketSpec",
    "ClusterClosed",
    "ClusterHandle",
    "ClusterServer",
    "FailedRequest",
    "LatencyStats",
    "MicroBatcher",
    "ReplayResult",
    "RequestRecord",
    "ServeLoop",
    "ServedModel",
    "ShedError",
    "TableRegistry",
    "TrafficMark",
    "TrafficRequest",
    "TrafficTrace",
    "make_trace",
    "replay_trace",
]
