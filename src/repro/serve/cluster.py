"""Async serving tier: replicated, fault-tolerant cluster over the CAM
engine (DESIGN.md §12).

``ClusterServer`` is the production layer the synchronous ``ServeLoop``
deliberately deferred: the same flush discipline (full coalescing bucket
OR expired latency window), but with

  * concurrent intake — ``submit`` is called from any number of client
    threads and returns a ``ClusterHandle`` future; per-model queues are
    drained by a dispatcher thread and executed on replica worker
    threads (thread-based producer/consumer);
  * per-model ADAPTIVE flush deadlines — an EWMA of request
    inter-arrival time sizes the window to "the expected time to fill a
    coalescing bucket", clamped between bounds (``AdaptiveWindow``), so
    hot models flush on full buckets and cold models stop holding single
    requests for the maximum window;
  * admission control — each model's queue is bounded
    (``max_queue_rows``); an overloaded queue sheds the request with an
    explicit ``ShedError`` (the HTTP-503 of this tier) instead of
    queueing unbounded latency, and sheds are counted per model;
  * replicated fault tolerance — every replica holds a full
    ``TableRegistry`` copy of each registered artifact (RETENTION-style
    bounded shards that degrade THROUGHPUT, not correctness).  Replicas
    beat ``repro.ft.runtime.Heartbeat`` liveness files; a monitor marks
    a silent replica dead after the timeout and re-routes its queued and
    in-flight work to survivors.  Per-ROW flush wall times (batch sizes
    vary wildly between paced and burst regimes) feed one shared
    EWMA ``StragglerMonitor``; a replica flagged ``straggler_strikes``
    times is excluded from routing (the serving analogue of re-slicing).
    ``restore_replica`` is the elastic boundary: a fresh replica
    re-registers the current catalog and rejoins the rotation.

Correctness contract: predictions are BIT-EQUAL to the synchronous
``ServeLoop`` on the same request stream, before/during/after any
failover — every replica binds an engine over the same compiled
artifact, a request is completed exactly once (first writer wins), and a
re-routed request re-executes the same deterministic computation on a
survivor (tests/test_cluster.py).

Fault-injection hooks (``inject_crash`` / ``inject_hang`` /
``inject_delay`` / ``restore_replica``) make every degradation mode
testable on the 8-fake-device CPU harness — no real hardware needs to
die to exercise the failover state machine.
"""

from __future__ import annotations

import itertools
import logging
import queue
import shutil
import tempfile
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.ft.runtime import Heartbeat, StragglerMonitor
from repro.serve.batching import MicroBatcher
from repro.serve.loop import LatencyStats, RequestRecord
from repro.serve.registry import ServedModel, TableRegistry

log = logging.getLogger(__name__)

# replica lifecycle: ALIVE -> (EXCLUDED <-> ALIVE) -> DEAD -> (restore)
ALIVE, EXCLUDED, DEAD = "alive", "excluded", "dead"


class ShedError(RuntimeError):
    """Admission control rejected the request (bounded queue overflowed).

    The explicit backpressure signal of the cluster tier: callers retry
    with backoff or divert, exactly like an HTTP 503 — the queue never
    absorbs unbounded latency.
    """


class ClusterClosed(RuntimeError):
    """Submitted to a server after ``close()``."""


class FailedRequest(RuntimeError):
    """The request exhausted its retry budget (every replica failed it)."""


@dataclass
class AdaptiveWindow:
    """Per-model flush deadline from an EWMA of inter-arrival times.

    The window targets "expected time for ``target_rows`` more rows to
    arrive": at high arrival rate it shrinks toward ``min_s`` (the
    bucket fills anyway; don't add latency), at low rate it grows toward
    ``max_s`` (wait for coalescing partners, but bounded).  Before any
    interval is observed the window is ``max_s``.
    """

    min_s: float = 5e-4
    max_s: float = 0.02
    target_rows: int = 256
    alpha: float = 0.2
    _ewma_dt: float | None = None
    _last_arrival: float | None = None

    def observe(self, now: float, n_rows: int = 1) -> None:
        if self._last_arrival is not None:
            dt = max(now - self._last_arrival, 0.0) / max(1, n_rows)
            self._ewma_dt = (
                dt if self._ewma_dt is None
                else self.alpha * dt + (1.0 - self.alpha) * self._ewma_dt
            )
        self._last_arrival = now

    @property
    def window_s(self) -> float:
        if self._ewma_dt is None:
            return self.max_s
        return float(
            min(self.max_s, max(self.min_s, self.target_rows * self._ewma_dt))
        )


class ClusterHandle:
    """Future for one submitted request; completed exactly once."""

    __slots__ = ("model", "request_id", "n_rows", "_event", "_lock",
                 "_value", "_error")

    def __init__(self, model: str, request_id: int, n_rows: int) -> None:
        self.model = model
        self.request_id = request_id
        self.n_rows = n_rows
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._value: np.ndarray | None = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> np.ndarray:
        """Block until the request completes; raises its failure if any."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.model}:{self.request_id} not completed "
                f"within {timeout}s"
            )
        if self._error is not None:
            raise self._error
        return self._value

    # first writer wins: a re-routed request may race its original
    # replica (kill mid-flush); both compute identical bits, but counters
    # and records must tally it once.
    def _complete(self, value: np.ndarray) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._value = value
            self._event.set()
            return True

    def _fail(self, error: BaseException) -> bool:
        with self._lock:
            if self._event.is_set():
                return False
            self._error = error
            self._event.set()
            return True


@dataclass
class _Pending:
    """One admitted request waiting in a model queue (or in a job)."""

    handle: ClusterHandle
    q_bins: np.ndarray
    t_enqueue: float


@dataclass
class _Job:
    """A coalesced batch of requests routed to one replica."""

    model: str
    requests: list[_Pending]
    attempt: int = 0

    @property
    def n_rows(self) -> int:
        return sum(p.handle.n_rows for p in self.requests)


class _InjectedCrash(RuntimeError):
    pass


class Replica:
    """One serving replica: replicated registry + worker thread + liveness.

    The worker drains ``inbox`` jobs, flushes them through a per-model
    ``MicroBatcher`` (rebuilt on hot-swap version bumps, same discipline
    as ``ServeLoop``), and beats its heartbeat file between jobs and on
    idle wakeups.  Injection flags simulate the failure envelope:
    ``crash`` raises on the next job (fail-stop with a live supervisor),
    ``hang`` stops both processing and beating (silent death — only the
    heartbeat timeout discovers it), ``delay_s`` slows every flush
    (straggler).
    """

    def __init__(
        self,
        server: "ClusterServer",
        replica_id: int,
        run_dir: str,
        *,
        heartbeat_timeout_s: float,
        beat_interval_s: float,
    ) -> None:
        self.id = replica_id
        self.registry = TableRegistry(
            mesh=server.mesh, chip_spec=server.chip_spec, deploy=server.deploy
        )
        self.state = ALIVE
        self.inbox: queue.Queue = queue.Queue()
        self.heartbeat = Heartbeat(
            run_dir, replica_id, timeout_s=heartbeat_timeout_s
        )
        self.served_requests = 0
        self.served_rows = 0
        self.n_flushes = 0
        self.delay_s = 0.0
        self._beat_interval_s = beat_interval_s
        self._server = server
        self._crash = threading.Event()
        self._hang = threading.Event()
        self._inflight: _Job | None = None
        self._batchers: dict[str, MicroBatcher] = {}
        self._versions: dict[str, int] = {}
        self._thread = threading.Thread(
            target=self._run, name=f"xtime-replica-{replica_id}", daemon=True
        )

    def start(self) -> None:
        self.heartbeat.beat()
        self._thread.start()

    # -- worker loop ---------------------------------------------------------

    def _run(self) -> None:
        while True:
            if self._hang.is_set():
                # silent death: stop beating AND stop draining the inbox;
                # the monitor's heartbeat timeout is the only way out
                time.sleep(self._beat_interval_s)
                continue
            try:
                job = self.inbox.get(timeout=self._beat_interval_s)
            except queue.Empty:
                self.heartbeat.beat()
                continue
            if job is None:  # shutdown sentinel
                return
            if self._hang.is_set():
                # hung between get() and processing: hand the job back
                self._server._requeue_job(job)
                continue
            if self._crash.is_set():
                self._server._replica_failed(
                    self, job, _InjectedCrash(f"replica {self.id} crashed")
                )
                return  # fail-stop: the thread dies with the "process"
            self._inflight = job
            try:
                self._process(job)
            except Exception as exc:  # noqa: BLE001 - any failure fails over
                self._inflight = None
                self._server._replica_failed(self, job, exc)
                return
            self._inflight = None
            self.heartbeat.beat()

    def _batcher(self, model: str) -> tuple[MicroBatcher, ServedModel]:
        entry = self.registry.get(model)
        # hot swap: a version bump invalidates the cached batcher (it
        # holds the old engine).  Jobs are flushed whole, so there is
        # never pending state to migrate.
        if (
            model not in self._batchers
            or self._versions.get(model) != entry.version
        ):
            self._batchers[model] = MicroBatcher.for_engine(
                entry.engine,
                max_batch=self._server.max_batch,
                kind=self._server.kind,
            )
            self._versions[model] = entry.version
        return self._batchers[model], entry

    def _process(self, job: _Job) -> None:
        t0 = time.perf_counter()
        if self.delay_s > 0.0:
            time.sleep(self.delay_s)  # injected straggler: counts as flush time
        batcher, _ = self._batcher(job.model)
        for p in job.requests:
            batcher.submit(
                p.q_bins, t_enqueue=p.t_enqueue,
                request_id=p.handle.request_id,
            )
        results = batcher.flush()  # blocks until device results are ready
        dt = time.perf_counter() - t0
        self.n_flushes += 1
        self._server._job_done(self, job, results, dt)


class ClusterServer:
    """Replicated async serving cluster (see module docstring).

    Args:
      n_replicas: serving replicas, each with a full registry copy.
      mesh / chip_spec / deploy: forwarded to every replica's
        ``TableRegistry`` (replicas may share one mesh — the fake-device
        harness — or, in a real deployment, bind per-host meshes).
      kind: 'predict' (bit-equal contract) or 'margin'.
      flush_rows: coalescing bucket target — a model's queue flushes when
        it holds this many rows (same meaning as ``ServeLoop``).
      max_batch: per-flush row cap and the batcher's bucket ceiling.
      window: ``AdaptiveWindow`` template; each model gets its own copy
        (``target_rows`` defaults to ``flush_rows``).
      max_queue_rows: per-model admission bound; beyond it ``submit``
        raises ``ShedError``.
      heartbeat_timeout_s: silence threshold after which a replica is
        declared dead.  Workers beat every ``heartbeat_timeout_s / 4``.
      straggler: shared EWMA ``StragglerMonitor`` settings (per-row
        flush times); a replica collecting ``straggler_strikes``
        CONSECUTIVE flags is excluded from routing.
      max_attempts: retry budget per job across replica failures.
    """

    def __init__(
        self,
        *,
        n_replicas: int = 2,
        mesh=None,
        chip_spec=None,
        deploy=None,
        kind: str = "predict",
        flush_rows: int = 256,
        max_batch: int = 1024,
        window: AdaptiveWindow | None = None,
        max_queue_rows: int = 8192,
        heartbeat_timeout_s: float = 2.0,
        straggler_threshold: float = 5.0,
        straggler_alpha: float = 0.2,
        straggler_strikes: int = 3,
        monitor_interval_s: float = 0.05,
        max_attempts: int = 3,
        run_dir: str | None = None,
        clock: Callable[[], float] = time.perf_counter,
        history: int = 100_000,
    ) -> None:
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self.mesh = mesh
        self.chip_spec = chip_spec
        self.deploy = deploy
        self.kind = kind
        self.flush_rows = flush_rows
        self.max_batch = max_batch
        self.max_queue_rows = max_queue_rows
        self.max_attempts = max_attempts
        self.clock = clock
        self._window_template = window or AdaptiveWindow(target_rows=flush_rows)
        self._owns_run_dir = run_dir is None
        self.run_dir = run_dir or tempfile.mkdtemp(prefix="xtime-cluster-")
        self._hb_timeout_s = heartbeat_timeout_s
        self._beat_interval_s = heartbeat_timeout_s / 4.0
        self._monitor_interval_s = monitor_interval_s
        # shared across replicas: a straggler is slow vs the CLUSTER's
        # recent flush times, not vs its own (self-referenced baselines
        # let a uniformly slow replica hide)
        self.straggler = StragglerMonitor(
            threshold=straggler_threshold, ewma_alpha=straggler_alpha
        )
        self.straggler_strikes = straggler_strikes
        self._strikes: dict[int, int] = {}
        self._flush_seq = itertools.count()

        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._queues: dict[str, deque[_Pending]] = {}
        self._queue_rows: dict[str, int] = {}
        self._windows: dict[str, AdaptiveWindow] = {}
        self._shed: dict[str, int] = {}
        self._records: deque[RequestRecord] = deque(maxlen=history)
        self._n_flushes: dict[str, int] = {}
        self._outstanding = 0
        self._failovers = 0
        self._next_rid = itertools.count()
        self._closed = False
        # catalog of live registrations, for elastic restore: name ->
        # (artifact, deploy, batching) as registered on the primary
        self._catalog: dict[str, tuple] = {}

        # liveness observer (reads every worker file in run_dir)
        self._observer = Heartbeat(self.run_dir, -1, timeout_s=heartbeat_timeout_s)
        self.replicas: dict[int, Replica] = {}
        for rid in range(n_replicas):
            self.replicas[rid] = self._new_replica(rid)
        self._rr = itertools.cycle(sorted(self.replicas))
        for r in self.replicas.values():
            r.start()
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="xtime-dispatch", daemon=True
        )
        self._dispatcher.start()

    def _new_replica(self, rid: int) -> Replica:
        return Replica(
            self, rid, self.run_dir,
            heartbeat_timeout_s=self._hb_timeout_s,
            beat_interval_s=self._beat_interval_s,
        )

    # -- lifecycle -----------------------------------------------------------

    def __enter__(self) -> "ClusterServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Stop dispatcher and workers; outstanding handles are failed."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            pending = [
                p for qd in self._queues.values() for p in qd
            ]
            self._queues.clear()
            self._queue_rows.clear()
            self._cond.notify_all()
        for p in pending:
            if p.handle._fail(ClusterClosed("server closed")):
                with self._lock:
                    self._outstanding -= 1
        for r in self.replicas.values():
            r.inbox.put(None)
        self._dispatcher.join(timeout=5.0)
        for r in self.replicas.values():
            r._thread.join(timeout=1.0)  # hung replicas are daemon threads
        if self._owns_run_dir:
            shutil.rmtree(self.run_dir, ignore_errors=True)

    # -- registration (replicated) -------------------------------------------

    def register(self, name: str, model, **kw) -> ServedModel:
        """Install ``model`` on EVERY replica (compile once, install N).

        The first live replica is the primary: it runs the full
        ``TableRegistry.register`` path (compiling if needed); the
        resulting artifact is installed as-is on the other replicas —
        same table bits, so any replica serves bit-equal predictions.
        """
        with self._lock:
            if self._closed:
                raise ClusterClosed("server closed")
            order = [
                r for r in self.replicas.values() if r.state != DEAD
            ]
            if not order:
                raise RuntimeError("no live replicas to register on")
            primary, rest = order[0], order[1:]
        entry = primary.registry.register(name, model, **kw)
        for r in rest:
            r.registry.register(
                name, entry.artifact, batching=entry.batching,
                deploy=entry.deploy,
            )
        with self._lock:
            self._catalog[name] = (entry.artifact, entry.deploy, entry.batching)
            self._windows.setdefault(
                name,
                AdaptiveWindow(
                    min_s=self._window_template.min_s,
                    max_s=self._window_template.max_s,
                    target_rows=self._window_template.target_rows,
                    alpha=self._window_template.alpha,
                ),
            )
        return entry

    def models(self) -> list[str]:
        with self._lock:
            return sorted(self._catalog)

    # -- fault injection / elasticity ---------------------------------------

    def inject_crash(self, replica_id: int) -> None:
        """Fail-stop the replica on its next job (supervised crash)."""
        self.replicas[replica_id]._crash.set()

    def inject_hang(self, replica_id: int) -> None:
        """Silence the replica: no processing, no heartbeats.  Only the
        heartbeat timeout discovers it (the unsupervised death mode)."""
        self.replicas[replica_id]._hang.set()

    def inject_delay(self, replica_id: int, delay_s: float) -> None:
        """Slow every flush on the replica by ``delay_s`` (straggler)."""
        self.replicas[replica_id].delay_s = float(delay_s)

    def kill_replica(self, replica_id: int) -> None:
        """Immediately declare the replica dead and re-route its work."""
        with self._lock:
            replica = self.replicas[replica_id]
            replica._hang.set()  # stop it touching anything further
            self._mark_dead_locked(replica)
            self._cond.notify_all()

    def restore_replica(self, replica_id: int) -> Replica:
        """Elastic restart boundary: bring a dead/excluded replica back.

        A FRESH replica object re-registers the current catalog (the
        artifacts live registrations point at — not whatever the dead
        registry last held) and rejoins the routing rotation.
        """
        with self._lock:
            if self._closed:
                raise ClusterClosed("server closed")
            old = self.replicas.get(replica_id)
            if old is not None and old.state == ALIVE:
                raise ValueError(f"replica {replica_id} is already alive")
            catalog = dict(self._catalog)
        replica = self._new_replica(replica_id)
        for name, (artifact, deploy, batching) in catalog.items():
            replica.registry.register(
                name, artifact, deploy=deploy, batching=batching
            )
        replica.start()
        with self._lock:
            self.replicas[replica_id] = replica
            self._strikes[replica_id] = 0
            self._cond.notify_all()
        return replica

    # -- intake --------------------------------------------------------------

    def submit(self, model: str, q_bins: np.ndarray) -> ClusterHandle:
        """Admit one request; returns a ``ClusterHandle`` future.

        Raises ``ShedError`` when the model's queue is at capacity
        (explicit backpressure), ``KeyError`` for an unregistered model,
        ``ClusterClosed`` after shutdown.  Never blocks on the engine.
        """
        q = np.array(q_bins)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2 or q.shape[0] == 0:
            raise ValueError(f"expected (b, F) query rows, got shape {q.shape}")
        now = self.clock()
        with self._lock:
            if self._closed:
                raise ClusterClosed("server closed")
            if model not in self._catalog:
                raise KeyError(
                    f"unknown model {model!r}; registered: {self.models()}"
                )
            rows = self._queue_rows.get(model, 0)
            if rows + q.shape[0] > self.max_queue_rows:
                self._shed[model] = self._shed.get(model, 0) + 1
                raise ShedError(
                    f"model {model!r} queue at {rows}/{self.max_queue_rows} "
                    f"rows; request of {q.shape[0]} rows shed"
                )
            handle = ClusterHandle(model, next(self._next_rid), q.shape[0])
            self._queues.setdefault(model, deque()).append(
                _Pending(handle, q, now)
            )
            self._queue_rows[model] = rows + q.shape[0]
            self._windows[model].observe(now, q.shape[0])
            self._outstanding += 1
            self._cond.notify_all()
        return handle

    def drain(self, timeout: float = 30.0) -> None:
        """Force-flush every queue and block until nothing is outstanding."""
        deadline = time.monotonic() + timeout
        with self._lock:
            self._force_flush = True
            self._cond.notify_all()
            while self._outstanding > 0:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"{self._outstanding} requests still outstanding "
                        f"after {timeout}s"
                    )
                self._cond.wait(timeout=min(remaining, 0.05))
            self._force_flush = False

    _force_flush = False

    # -- dispatcher ----------------------------------------------------------

    def _live_replicas(self) -> list[Replica]:
        return [r for r in self.replicas.values() if r.state == ALIVE]

    def _route_locked(self, job: _Job) -> bool:
        """Round-robin the job to the next live replica; False if none."""
        live = self._live_replicas()
        if not live:
            return False
        for _ in range(len(self.replicas)):
            rid = next(self._rr)
            replica = self.replicas.get(rid)
            if replica is not None and replica.state == ALIVE:
                replica.inbox.put(job)
                return True
        live[0].inbox.put(job)  # rotation missed (membership changed)
        return True

    def _pop_jobs_locked(self, now: float) -> list[_Job]:
        if not self._live_replicas():
            return []  # park everything until a restore/monitor pass
        jobs: list[_Job] = []
        for model, qd in self._queues.items():
            if any(p.handle.done() for p in qd):
                # requeued copies that lost the completion race to their
                # original replica: drop them instead of re-serving
                qd = self._queues[model] = deque(
                    p for p in qd if not p.handle.done()
                )
                self._queue_rows[model] = sum(p.handle.n_rows for p in qd)
            if not qd:
                continue
            window = self._windows[model].window_s
            rows = self._queue_rows.get(model, 0)
            expired = now - qd[0].t_enqueue >= window
            if not (rows >= self.flush_rows or expired or self._force_flush):
                continue
            while qd:
                batch: list[_Pending] = [qd.popleft()]
                n = batch[0].handle.n_rows
                while qd and n + qd[0].handle.n_rows <= self.max_batch:
                    p = qd.popleft()
                    batch.append(p)
                    n += p.handle.n_rows
                jobs.append(_Job(model, batch))
                # below the flush target and not forced: leave the rest
                # to coalesce further (only the expired/full head goes)
                remaining = sum(p.handle.n_rows for p in qd)
                if remaining < self.flush_rows and not self._force_flush:
                    break
            self._queue_rows[model] = sum(p.handle.n_rows for p in qd)
        return jobs

    def _next_deadline_locked(self, now: float) -> float:
        timeout = self._monitor_interval_s
        if not self._live_replicas():
            return timeout  # nothing to dispatch to; just keep monitoring
        for model, qd in self._queues.items():
            if qd:
                due = qd[0].t_enqueue + self._windows[model].window_s - now
                timeout = min(timeout, max(due, 0.0))
        return timeout

    def _dispatch_loop(self) -> None:
        last_monitor = 0.0
        while True:
            try:
                with self._cond:
                    if self._closed:
                        return
                    timeout = self._next_deadline_locked(self.clock())
                    if timeout > 0:
                        self._cond.wait(timeout=timeout)
                    if self._closed:
                        return
                    for job in self._pop_jobs_locked(self.clock()):
                        if not self._route_locked(job):
                            # no live replica: park the job at the front
                            qd = self._queues.setdefault(job.model, deque())
                            qd.extendleft(reversed(job.requests))
                            self._queue_rows[job.model] = sum(
                                p.handle.n_rows for p in qd
                            )
                now = time.monotonic()
                if now - last_monitor >= self._monitor_interval_s:
                    last_monitor = now
                    self._monitor_liveness()
            except Exception:  # noqa: BLE001 - dispatcher must survive
                log.exception("dispatcher iteration failed; continuing")
                time.sleep(self._monitor_interval_s)

    # -- failure handling ----------------------------------------------------

    def _monitor_liveness(self) -> None:
        """Heartbeat sweep: declare silent replicas dead, re-route work."""
        dead = set(self._observer.dead_workers())
        if not dead:
            return
        with self._lock:
            for rid in dead:
                replica = self.replicas.get(rid)
                if replica is not None and replica.state == ALIVE:
                    log.warning(
                        "replica %d heartbeat stale > %.2fs: failover",
                        rid, self._hb_timeout_s,
                    )
                    self._mark_dead_locked(replica)
            self._cond.notify_all()

    def _mark_dead_locked(self, replica: Replica) -> None:
        replica.state = DEAD
        self._failovers += 1
        # reclaim everything the replica was holding: queued inbox jobs
        # and the in-flight job (incomplete requests only — completed
        # handles are first-writer-guarded)
        reclaimed: list[_Job] = []
        inflight = replica._inflight
        if inflight is not None:
            reclaimed.append(inflight)
        while True:
            try:
                job = replica.inbox.get_nowait()
            except queue.Empty:
                break
            if job is not None:
                reclaimed.append(job)
        for job in reclaimed:
            self._requeue_job_locked(job)

    def _replica_failed(
        self, replica: Replica, job: _Job, exc: BaseException
    ) -> None:
        """Worker-thread callback: fail-stop crash during/with a job."""
        log.warning("replica %d failed (%s): failover", replica.id, exc)
        with self._lock:
            if replica.state == ALIVE:
                self._mark_dead_locked(replica)
            self._requeue_job_locked(job)
            self._cond.notify_all()

    def _requeue_job(self, job: _Job) -> None:
        with self._lock:
            self._requeue_job_locked(job)
            self._cond.notify_all()

    def _requeue_job_locked(self, job: _Job) -> None:
        """Return a job's incomplete requests to the FRONT of the queue.

        Requeued work bypasses admission control — the request was
        already accepted; shedding it now would turn a replica failure
        into a correctness-visible loss.  ``max_attempts`` bounds the
        retries instead.
        """
        job.attempt += 1
        alive = [p for p in job.requests if not p.handle.done()]
        if not alive:
            return
        if job.attempt >= self.max_attempts:
            for p in alive:
                if p.handle._fail(
                    FailedRequest(
                        f"request {p.handle.request_id} failed on "
                        f"{job.attempt} replicas"
                    )
                ):
                    self._outstanding -= 1
            self._cond.notify_all()
            return
        qd = self._queues.setdefault(job.model, deque())
        qd.extendleft(reversed(alive))
        self._queue_rows[job.model] = sum(p.handle.n_rows for p in qd)

    # -- completion ----------------------------------------------------------

    def _job_done(
        self,
        replica: Replica,
        job: _Job,
        results: dict[int, np.ndarray],
        flush_dt: float,
    ) -> None:
        t_done = self.clock()
        completed = 0
        records = []
        for p in job.requests:
            out = results.get(p.handle.request_id)
            if out is None:  # pragma: no cover - batcher contract violation
                continue
            if p.handle._complete(out):
                completed += 1
                records.append(
                    RequestRecord(
                        job.model, p.handle.request_id, p.handle.n_rows,
                        p.t_enqueue, t_done,
                    )
                )
        with self._lock:
            replica.served_requests += completed
            replica.served_rows += sum(r.n_rows for r in records)
            self._records.extend(records)
            self._n_flushes[job.model] = self._n_flushes.get(job.model, 0) + 1
            self._outstanding -= completed
            # shared straggler accounting, normalized PER ROW: flush wall
            # time scales with batch size, so a raw-dt baseline set by
            # small paced flushes would false-flag every big burst flush
            if self.straggler.record(
                next(self._flush_seq), flush_dt / max(1, job.n_rows)
            ):
                # strikes must be CONSECUTIVE: sporadic blips (a jit
                # compile for a cold bucket) reset below; a genuinely
                # slow replica flags on every flush and keeps the streak
                strikes = self._strikes.get(replica.id, 0) + 1
                self._strikes[replica.id] = strikes
                if (
                    strikes >= self.straggler_strikes
                    and replica.state == ALIVE
                    and len(self._live_replicas()) > 1
                ):
                    log.warning(
                        "replica %d excluded after %d straggler flags "
                        "(last flush %.4fs/row vs EWMA %.4fs/row)",
                        replica.id, strikes,
                        flush_dt / max(1, job.n_rows),
                        self.straggler.baseline or 0.0,
                    )
                    replica.state = EXCLUDED
            else:
                self._strikes[replica.id] = 0
            self._cond.notify_all()

    # -- accounting ----------------------------------------------------------

    def reset_stats(self) -> None:
        """Zero the SLO accounting window (e.g. after a warmup pass, so
        compile-time latencies don't pollute the gated percentiles)."""
        with self._lock:
            self._records.clear()
            self._n_flushes.clear()

    def stats(self, model: str | None = None) -> LatencyStats:
        """p50/p99 accounting, same type the synchronous loop reports."""
        with self._lock:
            records = [
                r for r in self._records if model is None or r.model == model
            ]
            n_flushes = (
                sum(self._n_flushes.values())
                if model is None
                else self._n_flushes.get(model, 0)
            )
        return LatencyStats.from_records(records, n_flushes)

    def report(self, model: str | None = None) -> dict:
        """Cluster health + SLO accounting in one dict."""
        s = self.stats(model)
        with self._lock:
            return {
                "model": model,
                "measured": {
                    "requests": s.n_requests,
                    "rows": s.n_rows,
                    "p50_ms": round(s.p50_ms, 3),
                    "p99_ms": round(s.p99_ms, 3),
                    "mean_ms": round(s.mean_ms, 3),
                    "requests_per_s": round(s.requests_per_s, 1),
                    "samples_per_s": round(s.samples_per_s, 1),
                    "flushes": s.n_flushes,
                },
                "shed": dict(self._shed),
                "failovers": self._failovers,
                "straggler_events": len(self.straggler.events),
                "windows_ms": {
                    m: round(w.window_s * 1e3, 3)
                    for m, w in self._windows.items()
                },
                "queue_rows": {
                    m: n for m, n in self._queue_rows.items() if n
                },
                "replicas": {
                    r.id: {
                        "state": r.state,
                        "served_requests": r.served_requests,
                        "served_rows": r.served_rows,
                        "flushes": r.n_flushes,
                    }
                    for r in self.replicas.values()
                },
            }
