"""Deterministic traffic-replay load generation for the serving tier.

A ``TrafficTrace`` is a seeded, fully pre-computed request schedule —
heavy-tailed inter-arrival times (Lomax/Pareto-II: bursty with a long
quiet tail, the "millions of users" shape rather than a uniform drip),
Zipf-ian popularity across many models, geometric request sizes (mostly
single rows), plus named MARKS at chosen points (hot-swap a model, kill
a replica, restore one).  The same seed always yields the same trace, so
a load test is a replayable experiment: the async tier and the
synchronous ``ServeLoop`` oracle can be driven with IDENTICAL request
streams and compared bit-for-bit (tests/test_cluster.py), and the bench
(benchmarks/serve_async_bench.py) gates p50/p99 SLOs on a schedule that
cannot drift between runs.

``replay_trace`` drives any ``submit(model, q_bins)``-shaped target —
``ClusterServer.submit``, ``ServeLoop.submit``, or a lambda — pacing
arrivals to the trace offsets time-warped by ``speed`` (``speed=0``
replays as fast as possible, for throughput measurement), and fires
``callbacks[name]()`` when a mark's offset passes.  SLO accounting stays
in ``LatencyStats`` (``repro.serve.loop``): the replay returns handles;
the server's ``stats()``/``report()`` own the percentiles.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

import numpy as np


@dataclass(frozen=True)
class TrafficRequest:
    """One scheduled request: ``n_rows`` rows of ``model``'s replay
    stream starting at ``row_start``, submitted at offset ``t``."""

    t: float
    model: str
    row_start: int
    n_rows: int


@dataclass(frozen=True)
class TrafficMark:
    """A named point in the schedule (swap/kill/restore hooks)."""

    t: float
    name: str


@dataclass(frozen=True)
class TrafficTrace:
    """A reproducible request schedule (see module docstring)."""

    requests: tuple[TrafficRequest, ...]
    marks: tuple[TrafficMark, ...] = ()
    seed: int = 0

    @property
    def horizon_s(self) -> float:
        """Offset of the last scheduled event."""
        last_req = self.requests[-1].t if self.requests else 0.0
        last_mark = max((m.t for m in self.marks), default=0.0)
        return max(last_req, last_mark)

    @property
    def n_rows(self) -> int:
        return sum(r.n_rows for r in self.requests)

    def merged(self) -> list["TrafficRequest | TrafficMark"]:
        """All events in time order; marks sort before requests at a tie
        (a kill scheduled 'at' a request happens first, determinism)."""
        return sorted(
            [*self.marks, *self.requests],
            key=lambda e: (e.t, isinstance(e, TrafficRequest)),
        )


def make_trace(
    models: Sequence[str] | Mapping[str, int],
    n_requests: int,
    *,
    seed: int,
    mean_interval_s: float = 1e-3,
    tail_alpha: float = 1.8,
    zipf_exponent: float = 1.1,
    mean_rows: float = 1.3,
    max_rows: int = 8,
    stream_len: int = 1 << 30,
    marks: Sequence[tuple[float, str]] = (),
) -> TrafficTrace:
    """Build a seeded heavy-tailed trace over ``models``.

    Args:
      models: model names; a mapping gives each model its own replay
        stream length (``row_start`` wraps inside it), a sequence uses
        ``stream_len`` for all.
      n_requests: number of requests to schedule.
      seed: RNG seed — same seed, same trace, bit-for-bit.
      mean_interval_s: mean inter-arrival time.  Arrivals are Lomax
        (Pareto-II) with shape ``tail_alpha``: scale-free bursts and a
        heavy quiet tail, normalized so the MEAN stays as requested
        (requires ``tail_alpha > 1``).
      zipf_exponent: popularity skew across models (first model listed
        is the hottest); 0 = uniform.
      mean_rows / max_rows: request sizes are 1 + Geometric, capped —
        mostly single rows, occasional small batches.
      marks: ``(fraction_of_schedule, name)`` pairs; each becomes a
        ``TrafficMark`` at that fraction of the request schedule's span.
    """
    if n_requests < 1:
        raise ValueError("n_requests must be >= 1")
    if tail_alpha <= 1.0:
        raise ValueError("tail_alpha must be > 1 (finite mean)")
    if mean_rows < 1.0:
        raise ValueError("mean_rows must be >= 1")
    names = list(models)
    lengths = (
        {m: int(models[m]) for m in names}
        if isinstance(models, Mapping)
        else {m: int(stream_len) for m in names}
    )
    rng = np.random.default_rng(seed)

    # Lomax(alpha) has mean 1/(alpha-1); rescale to the requested mean.
    gaps = rng.pareto(tail_alpha, size=n_requests)
    gaps *= mean_interval_s * (tail_alpha - 1.0)
    t = np.cumsum(gaps)

    ranks = np.arange(1, len(names) + 1, dtype=np.float64)
    probs = ranks ** -float(zipf_exponent)
    probs /= probs.sum()
    which = rng.choice(len(names), size=n_requests, p=probs)

    # Geometric(1/mean_rows) has mean mean_rows and support {1, 2, ...}:
    # mostly single rows with an occasional small batch, capped
    p = min(1.0, 1.0 / max(mean_rows, 1.0 + 1e-9))
    sizes = np.clip(rng.geometric(p, size=n_requests), 1, max_rows)

    cursor = dict.fromkeys(names, 0)
    requests = []
    for i in range(n_requests):
        model = names[which[i]]
        n = int(sizes[i])
        start = cursor[model] % lengths[model]
        cursor[model] += n
        requests.append(TrafficRequest(float(t[i]), model, start, n))

    span = float(t[-1])
    mark_events = tuple(
        TrafficMark(float(frac) * span, name) for frac, name in marks
    )
    return TrafficTrace(tuple(requests), mark_events, seed)


@dataclass
class ReplayResult:
    """Outcome of one replay: per-request handles aligned with
    ``trace.requests`` (None where the submit target shed/raised) and
    wall-clock accounting for throughput math."""

    handles: list
    shed: int
    errors: list[tuple[int, BaseException]]
    wall_s: float
    submitted: int = field(init=False)

    def __post_init__(self) -> None:
        self.submitted = sum(1 for h in self.handles if h is not None)


def replay_trace(
    submit: Callable[[str, np.ndarray], object],
    trace: TrafficTrace,
    streams: Mapping[str, np.ndarray],
    *,
    speed: float = 1.0,
    callbacks: Mapping[str, Callable[[], object]] | None = None,
    shed_exceptions: tuple[type[BaseException], ...] = (),
    clock: Callable[[], float] = time.perf_counter,
    sleep: Callable[[float], None] = time.sleep,
) -> ReplayResult:
    """Drive ``submit`` with the trace's schedule.

    Args:
      submit: ``(model, q_bins) -> handle`` — ``ClusterServer.submit``
        and ``ServeLoop.submit`` both fit.
      streams: per-model ``(N, F)`` replay data; request rows are taken
        at ``row_start`` (wrapping) so the same trace always replays the
        same bits.
      speed: time-warp factor — 2.0 replays twice as fast as recorded,
        0 disables pacing entirely (as-fast-as-possible throughput mode).
      callbacks: ``{mark_name: fn}`` fired as the schedule passes each
        mark; unknown marks are ignored (a trace with a 'kill' mark can
        also drive the oracle, which simply has nothing to kill).
      shed_exceptions: exception types counted as sheds (admission
        control) rather than re-raised — pass ``(ShedError,)`` when
        driving an overloaded cluster.
    """
    callbacks = callbacks or {}
    handles: list = []
    errors: list[tuple[int, BaseException]] = []
    shed = 0
    t0 = clock()
    for ev in trace.merged():
        if speed > 0:
            delay = (t0 + ev.t / speed) - clock()
            if delay > 0:
                sleep(delay)
        if isinstance(ev, TrafficMark):
            cb = callbacks.get(ev.name)
            if cb is not None:
                cb()
            continue
        xs = streams[ev.model]
        rows = np.take(
            xs, np.arange(ev.row_start, ev.row_start + ev.n_rows),
            axis=0, mode="wrap",
        )
        try:
            handles.append(submit(ev.model, rows))
        except shed_exceptions as exc:  # noqa: PERF203 - explicit 503 path
            shed += 1
            errors.append((len(handles), exc))
            handles.append(None)
    return ReplayResult(handles, shed, errors, clock() - t0)
