"""Synchronous serving loop: request intake -> micro-batch flush -> stats.

``ServeLoop`` is the production-style driver over a ``TableRegistry``: it
keeps one ``MicroBatcher`` per registered model, admits requests
one-at-a-time (the "millions of users" traffic shape from ROADMAP.md),
and flushes a model's queue when either

  * the queue holds ``flush_rows`` rows (a full coalescing bucket), or
  * the oldest request has waited ``window_s`` seconds (latency bound).

Every request gets wall-clock latency accounting (enqueue -> results
materialized, ``block_until_ready`` semantics via ``np.asarray``), and
``stats()`` reports p50/p99 latency + requests/s + samples/s next to the
``perfmodel`` analytic numbers for the same model mapping, so the measured
JAX path can be sanity-checked against the paper's chip model
(DESIGN.md §6).

The loop is deliberately synchronous — single-threaded, deterministic,
testable.  The async production tier exists: ``repro.serve.cluster``
layers concurrent intake, adaptive flush deadlines, admission control
and replicated failover on exactly this flush discipline, and this loop
is the ORACLE it is bit-equality-tested against on identical request
streams (tests/test_cluster.py, DESIGN.md §12).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.serve.batching import MicroBatcher
from repro.serve.registry import TableRegistry


@dataclass
class RequestRecord:
    """Completed-request accounting."""

    model: str
    request_id: int
    n_rows: int
    t_enqueue: float
    t_done: float

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_enqueue


@dataclass
class LatencyStats:
    """Aggregate serving statistics for one model (or the whole loop)."""

    n_requests: int
    n_rows: int
    p50_ms: float
    p99_ms: float
    mean_ms: float
    requests_per_s: float
    samples_per_s: float
    n_flushes: int

    @classmethod
    def from_records(
        cls, records: "list[RequestRecord] | deque", n_flushes: int
    ) -> "LatencyStats":
        records = list(records)
        if not records:
            return cls(0, 0, 0.0, 0.0, 0.0, 0.0, 0.0, n_flushes)
        lat_ms = np.array([r.latency_s for r in records]) * 1e3
        span = max(r.t_done for r in records) - min(r.t_enqueue for r in records)
        span = max(span, 1e-9)
        return cls(
            n_requests=len(records),
            n_rows=sum(r.n_rows for r in records),
            p50_ms=float(np.percentile(lat_ms, 50)),
            p99_ms=float(np.percentile(lat_ms, 99)),
            mean_ms=float(lat_ms.mean()),
            requests_per_s=len(records) / span,
            samples_per_s=sum(r.n_rows for r in records) / span,
            n_flushes=n_flushes,
        )


class ServeLoop:
    """Micro-batching request driver over a ``TableRegistry``."""

    def __init__(
        self,
        registry: TableRegistry,
        *,
        window_s: float = 0.002,
        flush_rows: int = 256,
        max_batch: int = 1024,
        kind: str = "predict",
        clock: Callable[[], float] = time.perf_counter,
        history: int = 100_000,
    ) -> None:
        self.registry = registry
        self.window_s = window_s
        self.flush_rows = flush_rows
        self.max_batch = max_batch
        self.kind = kind
        self.clock = clock
        self._batchers: dict[str, MicroBatcher] = {}
        self._versions: dict[str, int] = {}
        self._results: dict[tuple[str, int], np.ndarray] = {}
        # latency accounting is a rolling window so a long-lived loop stays
        # bounded; completed OUTPUTS are popped by result() — callers that
        # never fetch a handle leak it, by design (there is no TTL yet)
        self._records: deque[RequestRecord] = deque(maxlen=history)
        self._inflight: dict[str, list[tuple[int, int, float]]] = {}
        self._n_flushes: dict[str, int] = {}
        # loop-global id allocation: handles stay unique even when a hot
        # swap replaces a model's batcher (whose local counter restarts)
        self._next_rid: int = 0

    # -- internals -----------------------------------------------------------

    def _batcher(self, model: str) -> MicroBatcher:
        entry = self.registry.get(model)
        # hot swap: a version bump invalidates the cached batcher (it holds
        # the old engine); pending requests of the old version still flush
        # through the old batcher before it is dropped.
        if (
            model not in self._batchers
            or self._versions.get(model) != entry.version
        ):
            old = self._batchers.get(model)
            if old is not None and old.pending_requests:
                self._flush(model, old)
            self._batchers[model] = MicroBatcher.for_engine(
                entry.engine, max_batch=self.max_batch, kind=self.kind
            )
            self._versions[model] = entry.version
        return self._batchers[model]

    def _flush(self, model: str, batcher: MicroBatcher | None = None) -> int:
        batcher = batcher if batcher is not None else self._batchers.get(model)
        if batcher is None or not batcher.pending_requests:
            return 0
        results = batcher.flush()  # np.asarray inside => blocks until ready
        t_done = self.clock()
        self._n_flushes[model] = self._n_flushes.get(model, 0) + 1
        inflight = self._inflight.get(model, [])
        done = [x for x in inflight if x[0] in results]
        self._inflight[model] = [x for x in inflight if x[0] not in results]
        for rid, n_rows, t_enq in done:
            self._results[(model, rid)] = results[rid]
            self._records.append(
                RequestRecord(model, rid, n_rows, t_enq, t_done)
            )
        return len(done)

    # -- request API ---------------------------------------------------------

    def submit(self, model: str, q_bins: np.ndarray) -> tuple[str, int]:
        """Enqueue one request; returns its (model, request_id) handle.

        May trigger a flush of the model's queue (full bucket or expired
        window) — admission and service share the single thread.
        """
        now = self.clock()
        batcher = self._batcher(model)
        q = np.asarray(q_bins)
        if q.ndim == 1:
            q = q[None, :]
        rid = batcher.submit(q, t_enqueue=now, request_id=self._next_rid)
        self._next_rid += 1
        self._inflight.setdefault(model, []).append((rid, q.shape[0], now))
        oldest = batcher.oldest_enqueue_time()
        if batcher.pending_rows >= self.flush_rows or (
            oldest is not None and now - oldest >= self.window_s
        ):
            self._flush(model)
        return model, rid

    def poll(self) -> int:
        """Flush every queue whose coalescing window has expired."""
        now = self.clock()
        done = 0
        for model, batcher in list(self._batchers.items()):
            oldest = batcher.oldest_enqueue_time()
            if oldest is not None and now - oldest >= self.window_s:
                done += self._flush(model, batcher)
        return done

    def drain(self) -> int:
        """Flush everything pending regardless of window; returns #done."""
        done = 0
        for model in list(self._batchers):
            done += self._flush(model)
        return done

    def result(self, handle: tuple[str, int]) -> np.ndarray:
        """Fetch (and forget) a completed request's outputs."""
        if handle not in self._results:
            self._flush(handle[0])
        try:
            return self._results.pop(handle)
        except KeyError:
            raise KeyError(f"request {handle} not completed") from None

    # -- accounting ----------------------------------------------------------

    def stats(self, model: str | None = None) -> LatencyStats:
        records = [
            r for r in self._records if model is None or r.model == model
        ]
        n_flushes = (
            sum(self._n_flushes.values())
            if model is None
            else self._n_flushes.get(model, 0)
        )
        return LatencyStats.from_records(records, n_flushes)

    def report(self, model: str) -> dict:
        """Measured serving stats side-by-side with the chip model."""
        s = self.stats(model)
        entry = self.registry.get(model)
        perf = entry.perf
        deploy = entry.deploy
        return {
            "model": model,
            "version": entry.version,
            "deploy": {
                "backend": deploy.backend,
                "mode": deploy.mode,
                "noc_config": entry.engine.noc_config,
                "spmd": entry.engine.spmd,
                "batching": entry.batching,
            },
            "measured": {
                "requests": s.n_requests,
                "rows": s.n_rows,
                "p50_ms": round(s.p50_ms, 3),
                "p99_ms": round(s.p99_ms, 3),
                "mean_ms": round(s.mean_ms, 3),
                "requests_per_s": round(s.requests_per_s, 1),
                "samples_per_s": round(s.samples_per_s, 1),
                "flushes": s.n_flushes,
            },
            "xtime_chip_model": {
                "latency_ns": round(perf.latency_ns, 1),
                "throughput_msps": round(perf.throughput_msps, 2),
                "energy_nj_per_dec": round(perf.energy_nj_per_dec, 3),
                "bottleneck": perf.bottleneck,
            },
        }
