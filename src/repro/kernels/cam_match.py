"""Pallas TPU kernel for the X-TIME CAM search + leaf accumulation.

This is the compute hot-spot the paper implements in analog hardware: a
massively parallel range compare between a query tile and every stored CAM
row, AND-reduced over feature columns (the match line), followed by the
leaf-value accumulation (MMR + SRAM + ACC path).

TPU adaptation (see DESIGN.md §2):
  * the (B_blk x R_blk x F_blk) range compare is VPU work, evaluated in
    VMEM one feature chunk at a time with a running AND so the working set
    stays at (B_blk x R_blk x F_chunk) int32 instead of the full feature
    axis;
  * the leaf lookup-and-accumulate becomes an MXU matmul
    ``match(B_blk, R_blk) @ leaf(R_blk, C)`` accumulated across row tiles
    in the output block — the systolic replacement for the analog
    wired-OR / sequential MMR (a strict improvement over the paper's
    Eq. 5 bubbles, documented as such);
  * grid = (B/B_blk, R/R_blk); the row axis is ``arbitrary`` (sequential)
    so the output tile accumulates in place; the batch axis is parallel.

The ``mode`` switch selects the cell-level comparison:
  'direct'    — ideal 8/16-bit compare (TPU-native, the optimized form),
  'msb_lsb'   — the paper's Eq. 3 macro-cell arithmetic (faithful mode),
  'two_cycle' — Table-I cycle-accurate discharge semantics.
All three are bit-equivalent (property-tested); on TPU 'direct' is fastest
since there is no 4-bit device constraint — that *difference* vs the paper
is a hardware-adaptation note, not a behavioural one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import precision

_CELL_MATCH = {
    "direct": precision.match_direct,
    "inclusive": precision.match_inclusive,  # compact uint8 tables (§Perf X1)
    "msb_lsb": precision.match_msb_lsb,
    "two_cycle": precision.match_two_cycle,
}

# feature-axis chunk for the running AND; 128 lanes wide, small enough that
# the (B_blk, R_blk, F_CHUNK) int32 compare temp stays ~2 MiB in VMEM.
F_CHUNK = 128


def _cam_match_kernel(
    q_ref,  # (B_blk, F_pad) int32
    low_ref,  # (R_blk, F_pad) int32
    high_ref,  # (R_blk, F_pad) int32
    leaf_ref,  # (R_blk, C_pad) float32
    out_ref,  # (B_blk, C_pad) float32
    *,
    mode: str,
    f_pad: int,
):
    j = pl.program_id(1)
    cell = _CELL_MATCH[mode]

    q = q_ref[...]  # (B_blk, F_pad)
    low = low_ref[...]  # (R_blk, F_pad)
    high = high_ref[...]
    match = None
    for f0 in range(0, f_pad, F_CHUNK):
        sl = slice(f0, f0 + F_CHUNK)
        qc = q[:, None, sl]  # (B_blk, 1, fc)
        lo = low[None, :, sl]  # (1, R_blk, fc)
        hi = high[None, :, sl]
        ok = jnp.all(cell(qc, lo, hi), axis=-1)  # (B_blk, R_blk)
        match = ok if match is None else (match & ok)

    partial = jax.lax.dot(
        match.astype(jnp.float32),
        leaf_ref[...],
        preferred_element_type=jnp.float32,
    )  # (B_blk, C_pad) on the MXU

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnames=("b_blk", "r_blk", "mode", "interpret")
)
def cam_match_pallas(
    q: jnp.ndarray,  # (B, F_pad) int32 — pre-padded (see ops.py)
    low: jnp.ndarray,  # (R, F_pad) int32
    high: jnp.ndarray,  # (R, F_pad) int32
    leaf: jnp.ndarray,  # (R, C_pad) float32
    *,
    b_blk: int = 128,
    r_blk: int = 256,
    mode: str = "direct",
    interpret: bool = True,
) -> jnp.ndarray:
    """(B, C_pad) accumulated logits.  All dims must divide their blocks."""
    B, F_pad = q.shape
    R = low.shape[0]
    C_pad = leaf.shape[1]
    if B % b_blk or R % r_blk:
        raise ValueError(f"B={B} R={R} must be multiples of ({b_blk}, {r_blk})")
    if F_pad % F_CHUNK:
        raise ValueError(f"F_pad={F_pad} must be a multiple of {F_CHUNK}")

    grid = (B // b_blk, R // r_blk)
    kernel = functools.partial(_cam_match_kernel, mode=mode, f_pad=F_pad)

    compiler_params = None
    if not interpret:
        try:  # batch axis parallel, row axis sequential (in-place accumulate)
            from jax.experimental.pallas import tpu as pltpu

            compiler_params = pltpu.CompilerParams(
                dimension_semantics=("parallel", "arbitrary")
            )
        except (ImportError, AttributeError):  # pragma: no cover
            compiler_params = None

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_blk, F_pad), lambda i, j: (i, 0)),  # query tile
            pl.BlockSpec((r_blk, F_pad), lambda i, j: (j, 0)),  # CAM rows (low)
            pl.BlockSpec((r_blk, F_pad), lambda i, j: (j, 0)),  # CAM rows (high)
            pl.BlockSpec((r_blk, C_pad), lambda i, j: (j, 0)),  # leaf matrix
        ],
        out_specs=pl.BlockSpec((b_blk, C_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C_pad), jnp.float32),
        compiler_params=compiler_params,
        interpret=interpret,
    )(q, low, high, leaf)
