"""Pallas TPU kernel for the X-TIME CAM search + leaf accumulation (v2).

This is the compute hot-spot the paper implements in analog hardware: a
massively parallel range compare between a query tile and every stored CAM
row, AND-reduced over feature columns (the match line), followed by the
leaf-value accumulation (MMR + SRAM + ACC path).

Kernel v2 (DESIGN.md §10) differs from the v1 layout in three ways:

  * **compact dtypes** — the threshold tables stream in the narrowest
    dtype the bin grid permits (uint8 for the paper's native 256 bins,
    uint16 to 65536, int32 beyond / for the faithful cell modes).  Packed
    tables store INCLUSIVE upper bounds so [0, n_bins) fits the dtype;
    the compare runs natively (no upcast) — 4x less VMEM traffic than
    the v1 int32 tables at identical results;
  * **feature grid dimension** — the in-kernel Python loop over feature
    chunks is replaced by a third (feature) grid axis.  The running AND
    accumulates in a (b_blk, r_blk) VMEM scratch across feature tiles,
    so the working set is (r_blk, f_blk) instead of (r_blk, F_pad);
  * **wildcard tile skipping** — a per-(row-tile, feature-tile) activity
    mask lets the kernel skip the compare for tiles that are all
    wildcards (an all-wildcard tile matches everything).  The compiler's
    wildcard-aware row ordering maximizes such tiles.

Grid = (B/b_blk, R/r_blk, F_pad/f_blk); the batch axis is parallel, the
row and feature axes are ``arbitrary`` (sequential) so the scratch AND
and the output row-accumulation run in place.  The leaf matmul
``match(B_blk, R_blk) @ leaf(R_blk, C)`` fires once per row tile, on the
MXU — the systolic replacement for the analog wired-OR / sequential MMR.

The ``mode`` switch selects the cell-level comparison:
  'direct'    — ideal 8/16-bit compare on exclusive-high int32 tables,
  'inclusive' — the packed-table compare (low <= q <= high, native dtype),
  'msb_lsb'   — the paper's Eq. 3 macro-cell arithmetic (faithful mode),
  'two_cycle' — Table-I cycle-accurate discharge semantics,
  'soft'      — sigmoid match SCORES on float32 soft-encoded tables
                (DESIGN.md §15): the scratch carries a running SUM of
                per-cell log-scores (the additive twin of the running
                AND; a skipped all-wildcard tile adds exactly 0), and
                the final exp lands on the MXU dot as the (B_blk, R_blk)
                score matrix.  ``tau`` (static, bin units) sets the
                boundary temperature; tau=0 is the exact hard indicator,
                bit-equal to 'direct' margins at identical tile sizes.
The four hard modes are bit-equivalent on equivalently-encoded tables
(property-tested); 'soft' at tau=0 joins that equivalence class.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import precision

_CELL_MATCH = {
    "direct": precision.match_direct,
    "inclusive": precision.match_inclusive,  # compact tables (§Perf X1)
    "msb_lsb": precision.match_msb_lsb,
    "two_cycle": precision.match_two_cycle,
}

# default feature-axis tile; 128 lanes wide, small enough that the
# (b_blk, r_blk, f_blk) compare temp stays well under VMEM budget.
F_CHUNK = 128


def default_interpret() -> bool:
    """Resolve the 'auto' interpret policy: compiled on TPU, interpreter
    everywhere else (running the interpreter on real hardware silently
    costs orders of magnitude — the old ``interpret=True`` default bug)."""
    return jax.default_backend() != "tpu"


def pallas_available() -> bool:
    """Can the v2 kernel run here?  The VMEM scratch accumulator needs
    ``jax.experimental.pallas.tpu``; a jaxlib without it cannot run the
    kernel even in interpret mode — the engine falls back to the jnp
    oracle instead (same bits)."""
    try:
        from jax.experimental.pallas import tpu as pltpu  # noqa: F401

        return hasattr(pltpu, "VMEM")
    except ImportError:  # pragma: no cover - jaxlib-build dependent
        return False


def _cam_match_kernel(
    mask_ref,  # (1, 1) int32 — tile activity for this (row, feature) tile
    q_ref,  # (B_blk, f_blk) table dtype
    low_ref,  # (R_blk, f_blk) table dtype
    high_ref,  # (R_blk, f_blk) table dtype
    leaf_ref,  # (R_blk, C_pad) float32
    *refs,  # [bias_ref (1, C_pad) float32 when fused,] out_ref, acc_ref
    mode: str,
    n_f_tiles: int,
    n_r_tiles: int,
    fuse_bias: bool,
    tau: float,
):
    if fuse_bias:
        bias_ref, out_ref, acc_ref = refs
    else:
        out_ref, acc_ref = refs
        bias_ref = None
    j = pl.program_id(1)
    k = pl.program_id(2)
    soft = mode == "soft"
    cell = None if soft else _CELL_MATCH[mode]

    @pl.when(k == 0)
    def _precharge():  # the match line starts charged (all-match)
        if soft:  # log-score 0 == score 1 (the charged analog line)
            acc_ref[...] = jnp.zeros_like(acc_ref[...])
        else:
            acc_ref[...] = jnp.ones_like(acc_ref[...])

    @pl.when(mask_ref[0, 0] != 0)
    def _compare():  # skipped for all-wildcard tiles (they match everything)
        q = q_ref[...][:, None, :]  # (B_blk, 1, f_blk)
        lo = low_ref[...][None, :, :]  # (1, R_blk, f_blk)
        hi = high_ref[...][None, :, :]
        if soft:
            logs = precision.soft_cell_logscore(q, lo, hi, tau)
            acc_ref[...] += jnp.sum(logs, axis=-1)  # (B_blk, R_blk)
        else:
            ok = jnp.all(cell(q, lo, hi), axis=-1)  # (B_blk, R_blk)
            acc_ref[...] = acc_ref[...] & ok.astype(jnp.int32)

    @pl.when(k == n_f_tiles - 1)
    def _accumulate():  # MXU leaf gather once the match line is final
        match = (
            jnp.exp(acc_ref[...]) if soft
            else acc_ref[...].astype(jnp.float32)
        )
        partial = jax.lax.dot(
            match,
            leaf_ref[...],
            preferred_element_type=jnp.float32,
        )  # (B_blk, C_pad)

        @pl.when(j == 0)
        def _init():
            out_ref[...] = partial

        @pl.when(j > 0)
        def _acc():
            out_ref[...] += partial

        if fuse_bias:
            # fused epilogue: the base score lands on the LAST visit of
            # this output tile (row axis is sequential, so j runs in
            # order), AFTER the final partial — the same float order as
            # the separate epilogue pass ((p_0 + ... + p_last) + base),
            # hence bit-identical, without its extra HBM round-trip.
            @pl.when(j == n_r_tiles - 1)
            def _bias():
                out_ref[...] += bias_ref[...]


def full_tile_mask(n_r_tiles: int, n_f_tiles: int) -> jnp.ndarray:
    """The every-tile-active mask — the EXPLICIT form of 'no mask given'.

    ``cam_match_pallas(tile_mask=None)`` builds exactly this, so callers
    without wildcard analysis pay the full compare on every tile (never a
    silent skip).  Kept public so tests and callers can assert the
    fallback's shape/semantics instead of shape-inferring it.
    """
    return jnp.ones((n_r_tiles, n_f_tiles), dtype=jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("b_blk", "r_blk", "f_blk", "mode", "interpret", "tau"),
)
def cam_match_pallas(
    q: jnp.ndarray,  # (B, F_pad) table dtype — pre-padded (see ops.py)
    low: jnp.ndarray,  # (R, F_pad) table dtype
    high: jnp.ndarray,  # (R, F_pad) table dtype
    leaf: jnp.ndarray,  # (R, C_pad) float32
    tile_mask: jnp.ndarray | None = None,  # (R/r_blk, F_pad/f_blk) int32
    bias: jnp.ndarray | None = None,  # (1, C_pad) float32 fused epilogue
    *,
    b_blk: int = 128,
    r_blk: int = 256,
    f_blk: int = F_CHUNK,
    mode: str = "direct",
    interpret: bool | None = None,
    tau: float = 0.0,
) -> jnp.ndarray:
    """(B, C_pad) accumulated logits.  All dims must divide their blocks.

    ``tile_mask[j, k] == 0`` marks an all-wildcard (always-match) tile the
    compare may skip; ``None`` falls back EXPLICITLY to
    :func:`full_tile_mask` (every tile compared), and a mask of the wrong
    shape is rejected here — under interpret mode a misshapen mask would
    otherwise read out-of-bounds activity bits and silently skip live
    tiles.  ``bias`` fuses the epilogue's base-score add into the last
    (row, feature) visit of each output tile — bit-identical to adding it
    after the kernel (same float order), one less HBM round-trip.
    ``interpret=None`` resolves via :func:`default_interpret` (compiled on
    TPU only).
    """
    B, F_pad = q.shape
    R = low.shape[0]
    C_pad = leaf.shape[1]
    if interpret is None:
        interpret = default_interpret()
    if B % b_blk or R % r_blk:
        raise ValueError(f"B={B} R={R} must be multiples of ({b_blk}, {r_blk})")
    if F_pad % f_blk:
        raise ValueError(f"F_pad={F_pad} must be a multiple of f_blk={f_blk}")
    n_f_tiles = F_pad // f_blk
    n_r_tiles = R // r_blk
    if tile_mask is None:
        tile_mask = full_tile_mask(n_r_tiles, n_f_tiles)
    elif tuple(tile_mask.shape) != (n_r_tiles, n_f_tiles):
        raise ValueError(
            f"tile_mask shape {tuple(tile_mask.shape)} does not tile "
            f"(R={R}, F_pad={F_pad}) by (r_blk={r_blk}, f_blk={f_blk}); "
            f"expected ({n_r_tiles}, {n_f_tiles}) — pass None for the "
            "explicit every-tile-active fallback (full_tile_mask)"
        )
    if tile_mask.dtype != jnp.int32:
        tile_mask = tile_mask.astype(jnp.int32)
    if bias is not None and tuple(bias.shape) != (1, C_pad):
        raise ValueError(
            f"bias shape {tuple(bias.shape)} must be (1, C_pad={C_pad})"
        )

    grid = (B // b_blk, R // r_blk, n_f_tiles)
    kernel = functools.partial(
        _cam_match_kernel, mode=mode, n_f_tiles=n_f_tiles,
        n_r_tiles=n_r_tiles, fuse_bias=bias is not None, tau=float(tau),
    )

    if not pallas_available():  # pragma: no cover - jaxlib-build dependent
        raise RuntimeError(
            "pallas TPU scratch allocation unavailable on this jaxlib; "
            "use the jnp backend (the engine falls back automatically)"
        )
    from jax.experimental.pallas import tpu as pltpu

    # the running accumulator: wired-AND bits for the hard modes, the
    # running log-score sum for 'soft'
    acc_dtype = jnp.float32 if mode == "soft" else jnp.int32
    scratch = [pltpu.VMEM((b_blk, r_blk), acc_dtype)]
    compiler_params = None
    if not interpret:
        try:
            # batch axis parallel; row + feature axes sequential (the
            # scratch AND and output tile accumulate in place)
            compiler_params = pltpu.CompilerParams(
                dimension_semantics=("parallel", "arbitrary", "arbitrary")
            )
        except AttributeError:  # pragma: no cover - older pltpu API
            compiler_params = None

    in_specs = [
        pl.BlockSpec((1, 1), lambda i, j, k: (j, k)),  # tile activity
        pl.BlockSpec((b_blk, f_blk), lambda i, j, k: (i, k)),  # queries
        pl.BlockSpec((r_blk, f_blk), lambda i, j, k: (j, k)),  # CAM low
        pl.BlockSpec((r_blk, f_blk), lambda i, j, k: (j, k)),  # CAM high
        pl.BlockSpec((r_blk, C_pad), lambda i, j, k: (j, 0)),  # leaf matrix
    ]
    operands = [tile_mask, q, low, high, leaf]
    if bias is not None:  # fused epilogue bias, one (1, C_pad) row
        in_specs.append(pl.BlockSpec((1, C_pad), lambda i, j, k: (0, 0)))
        operands.append(bias)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((b_blk, C_pad), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C_pad), jnp.float32),
        scratch_shapes=scratch,
        compiler_params=compiler_params,
        interpret=interpret,
    )(*operands)
