"""Pure-jnp oracle for the cam_match kernel.

Semantics (the whole X-TIME datapath between DAC and router, §III-A):

    match[b, r] = AND_f ( low[r, f] <= q[b, f] < high[r, f] )
    out[b, c]   = SUM_r match[b, r] * leaf_matrix[r, c]

Exactly one row per tree matches any query (the leaves of a tree partition
feature space), so the masked sum over a tree's rows equals that tree's
leaf lookup; summing over all rows is the in-core ACC + NoC reduction.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import precision


def cam_match_ref(
    q: jnp.ndarray,  # (B, F) integer bins (float32 for mode='soft')
    low: jnp.ndarray,  # (R, F) inclusive lower bin bounds
    high: jnp.ndarray,  # (R, F) exclusive upper bin bounds
    leaf_matrix: jnp.ndarray,  # (R, C) leaf values routed to class channels
    *,
    mode: str = "direct",  # any repro.core.precision.CELL_MODES name
    tau: float = 0.0,  # soft-mode boundary temperature (ignored otherwise)
) -> jnp.ndarray:
    """Returns (B, C) accumulated logits/votes.

    ``mode='soft'`` expects the float32 soft-encoded bounds
    (``precision.encode_soft_bounds``) and aggregates sigmoid match
    SCORES instead of a boolean match line — the (B, R) score matrix
    multiplies the leaf matrix exactly like the hard 0/1 match, so at
    ``tau=0`` the two paths are the same dot product over the same
    operand shapes (bit-equal margins).
    """
    if mode == "soft":
        match = precision.soft_match_scores(q, low, high, tau)  # (B, R)
        return match @ leaf_matrix  # (B, C)
    qe = q[:, None, :].astype(jnp.int32)  # (B, 1, F)
    lo = low[None, :, :].astype(jnp.int32)  # (1, R, F)
    hi = high[None, :, :].astype(jnp.int32)
    if mode == "direct":
        cell = precision.match_direct(qe, lo, hi)
    elif mode == "inclusive":
        cell = precision.match_inclusive(
            q[:, None, :], low[None, :, :], high[None, :, :]
        )
    elif mode == "msb_lsb":
        cell = precision.match_msb_lsb(qe, lo, hi)
    elif mode == "two_cycle":
        cell = precision.match_two_cycle(qe, lo, hi)
    else:
        raise ValueError(
            f"unknown mode {mode!r}; registered modes: {precision.mode_names()}"
        )
    match = jnp.all(cell, axis=-1)  # (B, R) — the MAL wired-AND over columns
    return match.astype(leaf_matrix.dtype) @ leaf_matrix  # (B, C)


def cam_match_bits_ref(
    q: jnp.ndarray, low: jnp.ndarray, high: jnp.ndarray, *, mode: str = "direct"
) -> jnp.ndarray:
    """(B, R) boolean match lines only (for MMR / debug paths)."""
    if mode == "inclusive":  # packed tables compare in their native dtype
        return jnp.all(
            precision.match_inclusive(
                q[:, None, :], low[None, :, :], high[None, :, :]
            ),
            axis=-1,
        )
    qe = q[:, None, :].astype(jnp.int32)
    lo = low[None, :, :].astype(jnp.int32)
    hi = high[None, :, :].astype(jnp.int32)
    fn = {
        "direct": precision.match_direct,
        "msb_lsb": precision.match_msb_lsb,
        "two_cycle": precision.match_two_cycle,
    }[mode]
    return jnp.all(fn(qe, lo, hi), axis=-1)
