"""jit'd public wrappers around the cam_match Pallas kernel.

Handles the padding contract so callers can pass ragged real-world shapes:
  * batch  -> multiple of b_blk          (pad queries with zeros)
  * rows   -> multiple of r_blk          (pad with never-match ranges)
  * feats  -> multiple of F_CHUNK lanes  (pad with always-match ranges)
  * chans  -> multiple of 8              (pad leaf channels with zeros)
and strips the padding from the output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cam_match import F_CHUNK, cam_match_pallas
from repro.kernels.ref import cam_match_ref


def _ceil_to(x: int, m: int) -> int:
    return int(np.ceil(x / m)) * m


def pad_tables(
    low: np.ndarray,
    high: np.ndarray,
    leaf_matrix: np.ndarray,
    *,
    r_blk: int = 256,
    c_mult: int = 8,
    n_bins: int | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad the compiled CAM table to kernel-friendly shapes (host-side)."""
    R, F = low.shape
    C = leaf_matrix.shape[1]
    R_pad, F_pad, C_pad = _ceil_to(R, r_blk), _ceil_to(F, F_CHUNK), _ceil_to(C, c_mult)
    big = np.int32(n_bins if n_bins is not None else (int(high.max()) + 1))

    lo = np.zeros((R_pad, F_pad), dtype=np.int32)
    hi = np.full((R_pad, F_pad), big, dtype=np.int32)  # always-match columns
    lo[:R, :F] = low
    hi[:R, :F] = high
    lo[R:, :] = 1  # never-match rows: low=1 > high=0
    hi[R:, :] = 0

    lm = np.zeros((R_pad, C_pad), dtype=np.float32)
    lm[:R, :C] = leaf_matrix
    return lo, hi, lm


def pad_queries(q: np.ndarray | jnp.ndarray, f_pad: int, b_blk: int = 128) -> jnp.ndarray:
    B, _ = q.shape
    return pad_to_bucket(q, _ceil_to(B, b_blk), f_pad)


def pad_to_bucket(
    q: np.ndarray | jnp.ndarray, bucket_b: int, f_pad: int
) -> jnp.ndarray:
    """Pad a coalesced query batch to an explicit serving-bucket shape.

    Batch rows beyond ``B`` are zero vectors — they produce garbage margins
    that the serving un-padder discards; feature columns beyond ``F`` are
    zero, which the always-match column padding of ``pad_tables`` ignores.
    Keeping the target shape explicit (instead of the next ``b_blk``
    multiple) is what lets the serving layer hit one ``jax.jit`` cache
    entry per bucket rather than one per request shape.
    """
    B, F = q.shape
    if B > bucket_b:
        raise ValueError(f"batch {B} exceeds bucket {bucket_b}")
    if F > f_pad:
        raise ValueError(f"features {F} exceed padded width {f_pad}")
    out = jnp.zeros((bucket_b, f_pad), dtype=jnp.int32)
    return out.at[:B, :F].set(q.astype(jnp.int32))


@functools.partial(
    jax.jit, static_argnames=("b_blk", "r_blk", "mode", "interpret", "out_b", "out_c")
)
def cam_match(
    q_padded: jnp.ndarray,
    low: jnp.ndarray,
    high: jnp.ndarray,
    leaf: jnp.ndarray,
    *,
    out_b: int,
    out_c: int,
    b_blk: int = 128,
    r_blk: int = 256,
    mode: str = "direct",
    interpret: bool = True,
) -> jnp.ndarray:
    """Kernel entry on pre-padded operands; returns unpadded (out_b, out_c)."""
    out = cam_match_pallas(
        q_padded, low, high, leaf,
        b_blk=b_blk, r_blk=r_blk, mode=mode, interpret=interpret,
    )
    return out[:out_b, :out_c]


@jax.jit
def cam_match_jnp(
    q: jnp.ndarray, low: jnp.ndarray, high: jnp.ndarray, leaf_matrix: jnp.ndarray
) -> jnp.ndarray:
    """XLA-fused fallback (no Pallas) — used by the distributed engine where
    the row axis is mesh-sharded and by CPU-only paths."""
    return cam_match_ref(q, low, high, leaf_matrix, mode="direct")
