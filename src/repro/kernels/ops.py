"""jit'd public wrappers around the cam_match Pallas kernel.

Handles the padding contract so callers can pass ragged real-world shapes:
  * batch  -> multiple of b_blk          (pad queries with zeros)
  * rows   -> multiple of r_blk          (pad with never-match ranges)
  * feats  -> multiple of f_blk lanes    (pad with always-match ranges)
  * chans  -> multiple of 8              (pad leaf channels with zeros)
and strips the padding from the output.

Kernel v2 additions (DESIGN.md §10): ``pack_tables`` converts the padded
exclusive-high int32 layout into the compact inclusive-high form in a
narrow unsigned dtype, and ``wildcard_tile_mask`` precomputes the
per-(row-tile, feature-tile) activity map the kernel uses to skip
all-wildcard compare tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cam_match import F_CHUNK, cam_match_pallas
from repro.kernels.ref import cam_match_ref


def _ceil_to(x: int, m: int) -> int:
    return int(np.ceil(x / m)) * m


def pad_tables(
    low: np.ndarray,
    high: np.ndarray,
    leaf_matrix: np.ndarray,
    *,
    r_blk: int = 256,
    c_mult: int = 8,
    n_bins: int | None = None,
    f_blk: int = F_CHUNK,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad the compiled CAM table to kernel-friendly shapes (host-side).

    Output stays in the canonical exclusive-high int32 layout; use
    :func:`pack_tables` for the compact-dtype kernel form.
    """
    R, F = low.shape
    C = leaf_matrix.shape[1]
    R_pad, F_pad, C_pad = _ceil_to(R, r_blk), _ceil_to(F, f_blk), _ceil_to(C, c_mult)
    big = np.int32(n_bins if n_bins is not None else (int(high.max()) + 1))

    lo = np.zeros((R_pad, F_pad), dtype=np.int32)
    hi = np.full((R_pad, F_pad), big, dtype=np.int32)  # always-match columns
    lo[:R, :F] = low
    hi[:R, :F] = high
    lo[R:, :] = 1  # never-match rows: low=1 > high=0
    hi[R:, :] = 0

    lm = np.zeros((R_pad, C_pad), dtype=np.float32)
    lm[:R, :C] = leaf_matrix
    return lo, hi, lm


def pack_tables(
    low: np.ndarray,
    high: np.ndarray,
    leaf_matrix: np.ndarray,
    *,
    r_blk: int = 256,
    c_mult: int = 8,
    n_bins: int | None = None,
    f_blk: int = F_CHUNK,
    dtype: str = "int32",
    inclusive: bool | None = None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, bool]:
    """Pad + pack the CAM table for the kernel; returns (lo, hi, leaf, incl).

    ``dtype`` is the kernel table dtype.  The packed (unsigned) dtypes
    always store INCLUSIVE upper bounds so the full grid [0, n_bins)
    fits (n_bins=256 would overflow uint8 as an exclusive bound);
    ``inclusive=True`` forces the inclusive encoding for int32 too (the
    engine's mode='inclusive').  Encoding map:

      real cells        low,  high-1       (int32 keeps high-1 exactly,
                                            so degenerate high=0 cells
                                            stay unmatchable at -1)
      always-match pad  0,    n_bins-1
      never-match rows  1,    0            (low > high, unmatchable)

    An unsigned dtype additionally requires every table value to fit its
    range — compile-generated tables always do (high >= low+1 >= 1);
    perturbed ones (defect injection) must use the int32 layout.

    ``dtype='float32'`` is the SOFT cell layout instead: half-integer
    bounds with wildcard cells at (-inf, +inf) and never-match cells at
    (+inf, -inf) (``precision.encode_soft_bounds``), padded with the
    same always-match columns / never-match rows semantics.  Returned
    with ``inclusive=False`` (the soft compare is open-interval on the
    shifted bounds, the exclusive-high family).
    """
    dt = np.dtype(dtype)
    if dt.kind == "f":
        return _pack_tables_soft(
            low, high, leaf_matrix,
            r_blk=r_blk, c_mult=c_mult, n_bins=n_bins, f_blk=f_blk,
        )
    if inclusive is None:
        inclusive = dt.kind == "u"
    if dt.kind == "u" and not inclusive:
        raise ValueError("packed unsigned tables require the inclusive encoding")

    hi_enc = (high.astype(np.int64) - 1) if inclusive else high.astype(np.int64)
    lo_enc = low.astype(np.int64)
    if dt.kind == "u":
        lo_b = int(lo_enc.min(initial=0)), int(lo_enc.max(initial=0))
        hi_b = int(hi_enc.min(initial=0)), int(hi_enc.max(initial=0))
        top = np.iinfo(dt).max
        if lo_b[0] < 0 or hi_b[0] < 0 or lo_b[1] > top or hi_b[1] > top:
            raise ValueError(
                f"table values (low in {lo_b}, inclusive high in {hi_b}) "
                f"do not fit table dtype {dtype!r}; use 'int32' for "
                "perturbed/out-of-grid tables"
            )

    R, F = low.shape
    C = leaf_matrix.shape[1]
    R_pad, F_pad, C_pad = _ceil_to(R, r_blk), _ceil_to(F, f_blk), _ceil_to(C, c_mult)
    big = n_bins if n_bins is not None else (int(high.max(initial=0)) + 1)

    lo = np.zeros((R_pad, F_pad), dtype=np.int64)
    hi = np.full(  # always-match columns in the chosen encoding
        (R_pad, F_pad), big - 1 if inclusive else big, dtype=np.int64
    )
    lo[:R, :F] = lo_enc
    hi[:R, :F] = hi_enc
    lo[R:, :] = 1  # never-match rows: low=1 > high=0 in both encodings
    hi[R:, :] = 0

    lm = np.zeros((R_pad, C_pad), dtype=np.float32)
    lm[:R, :C] = leaf_matrix
    out_dt = dt if dt.kind == "u" else np.int32
    return lo.astype(out_dt), hi.astype(out_dt), lm, inclusive


def _pack_tables_soft(
    low: np.ndarray,
    high: np.ndarray,
    leaf_matrix: np.ndarray,
    *,
    r_blk: int,
    c_mult: int,
    n_bins: int | None,
    f_blk: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, bool]:
    """The float32 soft-mode layout: pad in the canonical int32 form,
    then apply ``precision.encode_soft_bounds`` so padding columns become
    exact wildcards (log-score 0) and padding rows exact never-matches
    (score 0) — no soft weight ever leaks out of the real table."""
    from repro.core.precision import encode_soft_bounds

    bins = int(n_bins) if n_bins is not None else (int(high.max(initial=0)) + 1)
    lo, hi, lm = pad_tables(
        low, high, leaf_matrix,
        r_blk=r_blk, c_mult=c_mult, n_bins=bins, f_blk=f_blk,
    )
    lo_f, hi_f = encode_soft_bounds(lo, hi, bins)
    return lo_f, hi_f, lm, False


def wildcard_tile_mask(
    low: np.ndarray,
    high: np.ndarray,
    *,
    r_blk: int,
    f_blk: int,
    n_bins: int,
    inclusive: bool,
) -> np.ndarray:
    """(R/r_blk, F/f_blk) int32 — 0 marks an all-wildcard compare tile.

    Operates on PADDED (and possibly packed) tables: a wildcard cell is
    the full range [0, n_bins) in whichever encoding ``inclusive``
    names; on float32 soft-encoded tables it is the exact (-inf, +inf)
    cell (log-score 0, so a skipped tile contributes nothing to the
    kernel's running log-sum — skipping stays semantics-free).
    Never-match padding rows are not wildcards, so their tiles stay
    active and keep their rows unmatchable.
    """
    R, F = low.shape
    if R % r_blk or F % f_blk:
        raise ValueError(f"padded shape ({R}, {F}) must tile by ({r_blk}, {f_blk})")
    if np.dtype(low.dtype).kind == "f":
        act = ~(np.isneginf(low) & np.isposinf(high))
    else:
        top = n_bins - 1 if inclusive else n_bins
        act = ~((low.astype(np.int64) == 0) & (high.astype(np.int64) >= top))
    tiles = act.reshape(R // r_blk, r_blk, F // f_blk, f_blk).any(axis=(1, 3))
    return tiles.astype(np.int32)


def pad_queries(
    q: np.ndarray | jnp.ndarray,
    f_pad: int,
    b_blk: int = 128,
    dtype: str = "int32",
) -> jnp.ndarray:
    B, _ = q.shape
    return pad_to_bucket(q, _ceil_to(B, b_blk), f_pad, dtype=dtype)


def check_query_range(q: np.ndarray | jnp.ndarray, dtype: str) -> None:
    """Reject bins a narrowing cast would WRAP (eager, host-side).

    The v1 int32 compare was accidentally lenient with out-of-range bins
    (value >= high fails every cell); a packed engine casting 300 to
    uint8 would wrap it to 44 and match rows it must not.  Callers
    binning with the model's own quantizer never trip this.
    """
    dt = np.dtype(dtype)
    if dt.kind != "u" or q.size == 0:
        return
    if np.dtype(q.dtype).kind == "u" and np.dtype(q.dtype).itemsize <= dt.itemsize:
        return  # widening or same-width unsigned: no wrap possible
    mn, mx = int(q.min()), int(q.max())
    if mn < 0 or mx > np.iinfo(dt).max:
        raise ValueError(
            f"query bins in [{mn}, {mx}] do not fit table dtype {dtype!r} "
            f"(max {np.iinfo(dt).max}); were these binned with the model's "
            "quantizer?"
        )


def pad_to_bucket(
    q: np.ndarray | jnp.ndarray, bucket_b: int, f_pad: int, dtype: str = "int32"
) -> jnp.ndarray:
    """Pad a coalesced query batch to an explicit serving-bucket shape.

    Batch rows beyond ``B`` are zero vectors — they produce garbage margins
    that the serving un-padder discards; feature columns beyond ``F`` are
    zero, which the always-match column padding of ``pad_tables`` ignores.
    Keeping the target shape explicit (instead of the next ``b_blk``
    multiple) is what lets the serving layer hit one ``jax.jit`` cache
    entry per bucket rather than one per request shape.  ``dtype`` is the
    engine's table dtype — queries compare natively against packed tables.
    """
    B, F = q.shape
    if B > bucket_b:
        raise ValueError(f"batch {B} exceeds bucket {bucket_b}")
    if F > f_pad:
        raise ValueError(f"features {F} exceed padded width {f_pad}")
    check_query_range(q, dtype)
    out = jnp.zeros((bucket_b, f_pad), dtype=np.dtype(dtype))
    return out.at[:B, :F].set(q.astype(np.dtype(dtype)))


@functools.partial(
    jax.jit,
    static_argnames=(
        "b_blk", "r_blk", "f_blk", "mode", "interpret", "out_b", "out_c",
        "tau",
    ),
)
def cam_match(
    q_padded: jnp.ndarray,
    low: jnp.ndarray,
    high: jnp.ndarray,
    leaf: jnp.ndarray,
    tile_mask: jnp.ndarray | None = None,
    bias: jnp.ndarray | None = None,
    *,
    out_b: int,
    out_c: int,
    b_blk: int = 128,
    r_blk: int = 256,
    f_blk: int = F_CHUNK,
    mode: str = "direct",
    interpret: bool | None = None,
    tau: float = 0.0,
) -> jnp.ndarray:
    """Kernel entry on pre-padded operands; returns unpadded (out_b, out_c).

    ``bias`` is the optional (1, C_pad) fused-epilogue row added inside
    the kernel on each output tile's last visit (kernel v3); callers
    fusing it must NOT add the base score again downstream.  ``tau`` is
    the soft-mode temperature (static, like ``mode`` — it selects the
    compiled trace); hard modes ignore it.
    """
    out = cam_match_pallas(
        q_padded, low, high, leaf, tile_mask, bias,
        b_blk=b_blk, r_blk=r_blk, f_blk=f_blk, mode=mode, interpret=interpret,
        tau=tau,
    )
    return out[:out_b, :out_c]


@jax.jit
def cam_match_jnp(
    q: jnp.ndarray, low: jnp.ndarray, high: jnp.ndarray, leaf_matrix: jnp.ndarray
) -> jnp.ndarray:
    """XLA-fused fallback (no Pallas) — used by the distributed engine where
    the row axis is mesh-sharded and by CPU-only paths."""
    return cam_match_ref(q, low, high, leaf_matrix, mode="direct")
