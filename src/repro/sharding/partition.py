"""PartitionSpec rules: TP on heads / ffn / experts / vocab, FSDP wrap on
the data axis, EP for MoE — derived from parameter *names* (pytree paths)
with shape-aware fallbacks, and fitted for divisibility (axes that do not
divide a dim are dropped from its spec rather than producing uneven
shards).

Conventions (single-pod mesh ("data", "model"); multi-pod adds a leading
"pod" axis used as extra data parallelism / FSDP):

  embed (V, d)            -> (tp, fsdp)        vocab-sharded embedding
  lm_head (d, V)          -> (fsdp, tp)
  wq/wk/wv (d, H*hd)      -> (fsdp, tp)        column parallel
  wo (H*hd, d)            -> (tp, fsdp)        row parallel
  ffn w_gate/w_up (d, f)  -> (fsdp, tp)
  ffn w_down (f, d)       -> (tp, fsdp)
  moe router (d, E)       -> (fsdp, None)
  moe w_* (E, d, f)       -> (EP on E, fsdp, None)
  1-D / scalar leaves     -> replicated

Layer-stack leading axes (scan segments) get None prepended automatically
(detected by comparing leaf rank to the rule's expected core rank).

KV caches (decode): batch over data(+pod); heads on model when divisible
(gemma3/granite have 1 KV head), otherwise the *sequence* axis is sharded
on model — the flash-decode partial-softmax layout (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class MeshAxes:
    """Resolved axis names + sizes for the active mesh."""

    def __init__(self, mesh: Mesh, *, fsdp: bool = True):
        names = mesh.axis_names
        sizes = dict(zip(names, np.shape(mesh.devices)))
        self.sizes = sizes
        self.model = "model" if "model" in names else None
        self.data = "data" if "data" in names else None
        self.pod = "pod" if "pod" in names else None
        self.fsdp_enabled = fsdp
        if not fsdp:
            self.fsdp: Any = None
        elif self.pod and self.data:
            self.fsdp = ("pod", "data")
        else:
            self.fsdp = self.data

    def axis_size(self, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            return int(np.prod([self.sizes[a] for a in axis]))
        return int(self.sizes.get(axis, 1))

    def batch_axes(self) -> tuple:
        return tuple(a for a in (self.pod, self.data) if a)

    def fit(self, spec: tuple, shape: tuple) -> P:
        """Drop axes that do not evenly divide their dim."""
        out = []
        for axis, dim in zip(spec, shape):
            if axis is None:
                out.append(None)
            elif dim % self.axis_size(axis) == 0:
                out.append(axis)
            elif isinstance(axis, tuple):
                # try a prefix of the composite axis (e.g. just 'data')
                kept = None
                for cut in range(len(axis) - 1, 0, -1):
                    sub = axis[:cut]
                    if dim % self.axis_size(sub) == 0:
                        kept = sub if len(sub) > 1 else sub[0]
                        break
                out.append(kept)
            else:
                out.append(None)
        return P(*out)


_ROW_PARALLEL = {"wo", "w_down", "out_proj", "cv", "wuv"}  # contraction dim sharded
_COL_PARALLEL = {
    "wq", "wk", "wv", "w_gate", "w_up", "in_proj", "wuq", "wuk",
    "wr", "wg", "ck", "cr", "w1", "wdq", "wdkv", "wkr", "proj",
}
_REPLICATED_2D = {"conv_w", "w_lora_a", "w_lora_b"}
_VECTOR_NAMES = {
    "ln1", "ln2", "ln_x", "post_ln1", "post_ln2", "norm", "q_ln", "kv_ln",
    "mamba_ln", "ln_scale", "ln_bias", "b1", "b2", "conv_b", "a_log",
    "d_skip", "dt_bias", "u", "w0", "final_norm", "enc_norm", "ln_in",
    "ln_in_b", "ln",
}


def _leaf_name(path) -> str:
    last = path[-1]
    if hasattr(last, "name"):
        return str(last.name)
    if hasattr(last, "key"):
        return str(last.key)
    return str(last)


def _core_rank(name: str, shape: tuple, cfg) -> int:
    """Rank of the per-layer (unstacked) parameter for this name."""
    if name in _VECTOR_NAMES or name.startswith("mu_"):
        return 1
    if name == "w2":
        return 2
    if cfg is not None and getattr(cfg, "n_experts", 0):
        if name in ("w_gate", "w_up", "w_down") and cfg.n_experts in shape:
            return 3  # (E, d, f)
    if name == "conv_w":
        return 2
    return 2


def _core_spec(name: str, shape: tuple, cfg, axes: MeshAxes) -> tuple:
    tp, fsdp = axes.model, axes.fsdp
    nd = len(shape)
    if nd == 1:
        return (None,)
    if nd == 3:
        return (tp, fsdp, None)  # expert weights: EP + FSDP
    if nd == 2:
        v = getattr(cfg, "vocab_size", -1) if cfg is not None else -1
        if name == "embed" and shape[0] == v:
            return (tp, fsdp)
        if name == "lm_head":
            return (fsdp, tp)
        if name in _REPLICATED_2D:
            return (None, None)
        if name in _ROW_PARALLEL or name == "w2":
            return (tp, fsdp)
        if name in _COL_PARALLEL:
            return (fsdp, tp)
        if name == "router":
            return (fsdp, None)
        return (fsdp, tp) if shape[1] >= shape[0] else (tp, fsdp)
    return tuple(None for _ in shape)


def _spec_for_leaf(path, leaf, cfg, axes: MeshAxes) -> P:
    name = _leaf_name(path)
    shape = tuple(leaf.shape)
    if len(shape) == 0:
        return P()
    core = _core_rank(name, shape, cfg)
    stack = max(0, len(shape) - core)
    spec = _core_spec(name, shape[stack:], cfg, axes)
    return axes.fit(tuple([None] * stack) + tuple(spec), shape)


def param_pspecs(params_shape: Any, cfg, axes: MeshAxes):
    """Pytree of PartitionSpec matching a params (shape-)pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_leaf(path, leaf, cfg, axes), params_shape
    )


def batch_pspec(axes: MeshAxes) -> P:
    b = axes.batch_axes()
    return P(b if len(b) > 1 else (b[0] if b else None))


def _cache_spec(path, leaf, cfg, axes: MeshAxes) -> P:
    shape = tuple(leaf.shape)
    name = _leaf_name(path)
    b = axes.batch_axes()
    bspec = b if len(b) > 1 else (b[0] if b else None)
    tp = axes.model
    tp_size = axes.axis_size(tp)
    if name == "conv" and len(shape) == 5:  # (G, P, B, W-1, C) zamba conv tail
        spec = (None, None, bspec, None, None)
    elif len(shape) == 5 and shape[3] == shape[4]:  # (L, B, H, dk, dv) rwkv state
        spec = (None, bspec, tp, None, None)
    elif len(shape) == 5:  # (L, B, S, KV, D) attention cache
        if shape[3] % tp_size == 0:
            spec = (None, bspec, None, tp, None)
        else:
            spec = (None, bspec, tp, None, None)  # sequence-sharded KV
    elif len(shape) == 6:  # (G, P, B, H, Pd, N) zamba ssm state
        spec = (None, None, bspec, tp, None, None)
    elif len(shape) == 4:
        if name == "ssm" or shape[-1] == shape[-2]:  # rwkv (L,B,hd,hd)-ish state
            spec = (None, bspec, None, None)
        else:  # (L, B, S, lora) MLA compressed cache: shard sequence
            spec = (None, bspec, tp, None)
    elif len(shape) == 3:
        spec = (None, bspec, None)
    elif len(shape) == 2:
        spec = (bspec, None)
    else:
        spec = tuple(None for _ in shape)
    return axes.fit(spec, shape)


def cache_pspecs(cache_shape: Any, cfg, axes: MeshAxes):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_spec(path, leaf, cfg, axes), cache_shape
    )


def activation_sharder(mesh: Mesh, axes: MeshAxes | None = None):
    """Returns shard_x(t): a with_sharding_constraint for activations.

    Layout (Megatron-SP style): batch over (pod, data); for full-sequence
    activations (B, S, d) the *sequence* axis is sharded on `model`
    between blocks — attention/FFN internals re-gather as needed
    (all-gather / reduce-scatter pairs inserted by GSPMD), and the scan
    carries + remat residuals stay 1/model-size.  Without this constraint
    GSPMD replicates the batch dim of scan residuals (measured: 21 GiB of
    f32 per device on llama3.2-3b train_4k — see EXPERIMENTS.md §Perf).
    """
    axes = axes or MeshAxes(mesh)
    b = axes.batch_axes()
    bspec = b if len(b) > 1 else (b[0] if b else None)
    tp = axes.model
    tp_size = axes.axis_size(tp)

    def shard_x(t):
        if t.ndim == 3:
            if t.shape[1] > 1 and t.shape[1] % tp_size == 0:
                spec = P(bspec, tp, None)  # sequence-parallel between blocks
            else:
                spec = P(bspec, None, None)
        elif t.ndim == 2:
            spec = P(bspec, None)
        else:
            return t
        spec = axes.fit(tuple(spec), tuple(t.shape))
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    return shard_x


def attach(mesh: Mesh, tree_shape: Any, specs: Any):
    """ShapeDtypeStructs with NamedShardings attached (for .lower())."""
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
        ),
        tree_shape,
        specs,
    )
