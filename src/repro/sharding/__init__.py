from repro.sharding.partition import (  # noqa: F401
    param_pspecs,
    batch_pspec,
    cache_pspecs,
    attach,
    MeshAxes,
)
