"""Mamba-2 (SSD) block — chunked parallel scan for train/prefill, O(1)
recurrent state for decode (the reason zamba2 runs the long_500k cell).

Implementation follows the SSD minimal formulation (Dao & Gu 2024,
arXiv:2405.21060, Listing 1), with the chunk loop expressed as a
``lax.scan`` carrying the inter-chunk state so the (Q x Q) intra-chunk
decay matrix is the only quadratic-in-chunk temp (Q = cfg.ssm_chunk).

Single group (n_groups=1): B and C are shared across heads.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common


class Mamba2Params(NamedTuple):
    in_proj: jnp.ndarray  # (d, 2*di + 2*N + H)
    conv_w: jnp.ndarray  # (W, conv_dim) depthwise causal conv
    conv_b: jnp.ndarray  # (conv_dim,)
    a_log: jnp.ndarray  # (H,)
    d_skip: jnp.ndarray  # (H,)
    dt_bias: jnp.ndarray  # (H,)
    norm: jnp.ndarray  # (di,) gated RMSNorm scale
    out_proj: jnp.ndarray  # (di, d)


def dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    heads = di // cfg.ssm_head_dim
    conv_dim = di + 2 * cfg.ssm_state
    return di, heads, conv_dim


def init_mamba2_params(key, cfg, dtype) -> Mamba2Params:
    di, h, conv_dim = dims(cfg)
    ks = jax.random.split(key, 4)
    return Mamba2Params(
        in_proj=common.dense_init(ks[0], (cfg.d_model, 2 * di + 2 * cfg.ssm_state + h), dtype),
        conv_w=common.dense_init(ks[1], (cfg.ssm_conv_width, conv_dim), dtype),
        conv_b=jnp.zeros((conv_dim,), dtype),
        a_log=jnp.log(
            jax.random.uniform(ks[2], (h,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        d_skip=jnp.ones((h,), jnp.float32),
        dt_bias=jnp.log(
            jnp.exp(
                jax.random.uniform(ks[3], (h,), jnp.float32, minval=1e-3, maxval=0.1)
            )
            - 1.0
        ),  # inverse softplus of U(1e-3, 0.1)
        norm=jnp.zeros((di,), dtype),
        out_proj=common.dense_init(jax.random.fold_in(key, 7), (di, cfg.d_model), dtype),
    )


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv via explicit shifts (width is small).

    x: (B, S, C), w: (W, C) -> (B, S, C).
    """
    wsize = w.shape[0]
    out = x * w[-1]
    for i in range(1, wsize):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[-1 - i]
    return out + b


def _ssd_chunked(
    xh: jnp.ndarray,  # (B, S, H, P) inputs (already dt-scaled NOT applied)
    dt: jnp.ndarray,  # (B, S, H) softplus'd step sizes
    a: jnp.ndarray,  # (H,) negative decay rates (A = -exp(a_log))
    bmat: jnp.ndarray,  # (B, S, N)
    cmat: jnp.ndarray,  # (B, S, N)
    chunk: int,
    h0: jnp.ndarray | None = None,  # (B, H, P, N) initial state
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    if s % chunk:  # fall back to the largest divisor (exactness over speed)
        chunk = next(c for c in range(min(chunk, s), 0, -1) if s % c == 0)
    nc = s // chunk
    q = chunk

    xc = xh.reshape(b, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    ac = dtc * a[None, None, None, :]  # (B, nc, Q, H) log-decay increments

    # move chunk axis first for scan
    xc, dtc, bc, cc, ac = (t.transpose(1, 0, *range(2, t.ndim)) for t in (xc, dtc, bc, cc, ac))

    state0 = (
        jnp.zeros((b, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )

    def per_chunk(state, xs):
        xq, dq, bq, cq, aq = xs  # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N), (B,Q,H)
        cs = jnp.cumsum(aq, axis=1)  # (B,Q,H) running log-decay
        total = cs[:, -1]  # (B,H)

        # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j (per head)
        li = cs[:, :, None, :] - cs[:, None, :, :]  # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((q, q), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(li), 0.0)
        cb = jnp.einsum("bqn,bjn->bqj", cq, bq)  # (B,Q,Q) shared across heads
        y_diag = jnp.einsum("bqj,bqjh,bjh,bjhp->bqhp", cb, L, dq, xq)

        # inter-chunk contribution from the carried state
        decay_in = jnp.exp(cs)  # (B,Q,H)
        y_off = jnp.einsum("bqn,bhpn,bqh->bqhp", cq, state, decay_in)

        # end-of-chunk state
        decay_out = jnp.exp(total[:, None, :] - cs)  # (B,Q,H)
        state_new = state * jnp.exp(total)[:, :, None, None] + jnp.einsum(
            "bqn,bqh,bqhp->bhpn", bq, decay_out * dq, xq
        )
        return state_new, y_diag + y_off

    state, ys = jax.lax.scan(per_chunk, state0, (xc, dtc, bc, cc, ac))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, state


def mamba2_forward(
    prm: Mamba2Params,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
    h0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.

    Returns (out (B,S,d), final ssm state (B,H,P,N), conv tail
    (B, W-1, conv_dim)) — the latter two seed the decode cache.
    """
    di, h, conv_dim = dims(cfg)
    n = cfg.ssm_state
    b, s, _ = x.shape

    zxbcdt = x @ prm.in_proj  # (B, S, 2di + 2N + H)
    z, xbc_raw, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, prm.conv_w, prm.conv_b))
    xin, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    xh = xin.reshape(b, s, h, cfg.ssm_head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + prm.dt_bias)  # (B,S,H)
    a = -jnp.exp(prm.a_log)  # (H,)

    y, state = _ssd_chunked(xh, dt, a, bmat, cmat, cfg.ssm_chunk, h0)
    y = y + xh.astype(jnp.float32) * prm.d_skip[None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = common.rms_norm(y * jax.nn.silu(z), prm.norm, cfg.norm_eps)
    conv_tail = xbc_raw[:, -(cfg.ssm_conv_width - 1):, :]
    return y @ prm.out_proj, state, conv_tail


def mamba2_decode(
    prm: Mamba2Params,
    x: jnp.ndarray,  # (B, 1, d)
    ssm_state: jnp.ndarray,  # (B, H, P, N)
    conv_state: jnp.ndarray,  # (B, W-1, conv_dim)
    cfg,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token recurrent step.  Returns (out, ssm_state, conv_state)."""
    di, h, conv_dim = dims(cfg)
    n = cfg.ssm_state
    b = x.shape[0]
    p = cfg.ssm_head_dim

    zxbcdt = x[:, 0] @ prm.in_proj  # (B, 2di+2N+H)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)

    # conv over (conv_state ++ xbc)
    hist = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B, W, C)
    xbc_c = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, prm.conv_w) + prm.conv_b)
    conv_state = hist[:, 1:]

    xin, bvec, cvec = jnp.split(xbc_c, [di, di + n], axis=-1)
    xh = xin.reshape(b, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + prm.dt_bias)  # (B,H)
    decay = jnp.exp(dt * (-jnp.exp(prm.a_log))[None, :])  # (B,H)

    ssm_state = ssm_state * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, bvec.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, cvec.astype(jnp.float32))
    y = y + xh * prm.d_skip[None, :, None]
    y = y.reshape(b, di).astype(x.dtype)
    y = common.rms_norm(y * jax.nn.silu(z), prm.norm, cfg.norm_eps)
    return (y @ prm.out_proj)[:, None, :], ssm_state, conv_state
