"""Mixture-of-Experts FFN with capacity-based cumsum-rank dispatch.

Design (see DESIGN.md §5 and EXPERIMENTS.md §Perf for the measured
motivation):
  * top-k softmax routing (+ optional always-on shared experts);
  * rank-within-expert via an exclusive **cumsum over the one-hot routing
    matrix** — no global argsort: every intermediate stays in the
    token-major (T, ...) layout, which keeps GSPMD sharding propagation
    intact (tokens on `data`(x`pod`)).  The first argsort-based version
    replicated the (T*k, d) gather on every device — 747 GiB/device on
    deepseek-v3 train_4k;
  * dispatch into a dense (E, C, d) buffer with capacity
    C = ceil(T*k/E * capacity_factor); tokens beyond capacity are dropped
    (GShard semantics) via out-of-bounds scatter drop.  The scatter from
    token-sharded source to expert-sharded buffer is the EP all-to-all;
  * expert compute is a grouped SwiGLU einsum (E,C,d)x(E,d,f): compiled
    FLOPs = tokens*topk*cf*6*d*f — the exact MoE model FLOPs (x capacity
    slack);
  * combine is a (T, k, d) reshape-sum — token-major order makes the
    inverse scatter unnecessary.

``set_shard_hooks`` installs with_sharding_constraint callables (token-dim
and expert-dim layouts) from the launcher; identity when unset (smoke
tests, single device).
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.ffn import FFNParams, ffn_forward, init_ffn_params

# launcher-installed sharding hooks (identity by default)
_HOOKS: dict[str, Callable] = {
    "tokens": lambda x: x,
    "experts": lambda x: x,
    "weights": lambda x: x,
    "impl": None,  # optional whole-layer override (moe_shardmap)
}


def set_shard_hooks(tokens: Callable | None, experts: Callable | None,
                    weights: Callable | None = None) -> None:
    _HOOKS["tokens"] = tokens or (lambda x: x)
    _HOOKS["experts"] = experts or (lambda x: x)
    _HOOKS["weights"] = weights or (lambda x: x)


def set_impl(fn: Callable | None) -> None:
    """Install a drop-in moe_forward override (e.g. the shard_map
    all-to-all implementation from moe_shardmap.make_shardmap_moe)."""
    _HOOKS["impl"] = fn


class MoEParams(NamedTuple):
    router: jnp.ndarray  # (d, E) fp32 for routing stability
    w_gate: jnp.ndarray  # (E, d, f)
    w_up: jnp.ndarray  # (E, d, f)
    w_down: jnp.ndarray  # (E, f, d)
    shared: FFNParams | None  # always-on shared expert(s)


def init_moe_params(
    key, d_model: int, d_ff: int, n_experts: int, n_shared: int, dtype
) -> MoEParams:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    ex = lambda k, shape: common.dense_init(k, shape, dtype, in_axis=1)
    return MoEParams(
        router=common.dense_init(k1, (d_model, n_experts), jnp.float32),
        w_gate=ex(k2, (n_experts, d_model, d_ff)),
        w_up=ex(k3, (n_experts, d_model, d_ff)),
        w_down=common.dense_init(k4, (n_experts, d_ff, d_model), in_axis=1, dtype=dtype),
        shared=(
            init_ffn_params(k5, d_model, d_ff * n_shared, dtype) if n_shared else None
        ),
    )


def moe_forward(
    p: MoEParams,
    x: jnp.ndarray,  # (B, S, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), aux load-balance loss scalar)."""
    if _HOOKS["impl"] is not None:
        return _HOOKS["impl"](p, x, top_k=top_k, capacity_factor=capacity_factor,
                              act=act)
    b, s, d = x.shape
    e = p.router.shape[1]
    t = b * s
    st = _HOOKS["tokens"]
    se = _HOOKS["experts"]
    xt = st(x.reshape(t, d))

    logits = st((xt.astype(jnp.float32) @ p.router))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # -- aux loss (Switch-style) --
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (T, k, E)
    dispatch_frac = onehot.sum(axis=(0, 1)) / (t * top_k)
    prob_frac = probs.mean(axis=0)
    aux = e * jnp.sum(dispatch_frac * prob_frac)

    # -- two-level cumsum ranking (token-major; no global sort) --
    # Level 1: rank within a block of tokens; level 2: cumsum of per-block
    # expert counts.  A monolithic (T*k, E) cumsum materializes globally
    # under GSPMD (measured 16 GiB on deepseek train_4k); blocked form
    # keeps every temp sharded on the block axis (§Perf log).
    capacity = int(max(1, round(t * top_k / e * capacity_factor)))
    tk = t * top_k
    blk = 4096 if tk % 4096 == 0 else next(
        b for b in (2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1) if tk % b == 0
    )
    nb = tk // blk
    oh_blocks = st(onehot.reshape(nb, blk, e).astype(jnp.int32))
    local_cum = jnp.cumsum(oh_blocks, axis=1)  # (nb, blk, E) within-block
    block_counts = local_cum[:, -1, :]  # (nb, E)
    block_offsets = jnp.cumsum(block_counts, axis=0) - block_counts  # exclusive
    flat_expert = gate_idx.reshape(tk)
    rank_local = jnp.take_along_axis(
        local_cum.reshape(tk, e), flat_expert[:, None], axis=1
    )[:, 0] - 1
    offs = jnp.take_along_axis(
        jnp.repeat(block_offsets, blk, axis=0), flat_expert[:, None], axis=1
    )[:, 0]
    rank = rank_local + offs
    keep = rank < capacity
    dest = jnp.where(keep, flat_expert * capacity + rank, e * capacity)

    # -- dispatch (token-sharded -> expert-sharded: the EP all-to-all) --
    # scatter only the narrow token ids, then gather rows: a full-width
    # (T*k, d) scatter lowers to u32[T*k, d] index broadcasts (280 GiB/dev
    # measured on deepseek train_4k); the id scatter is (E*C,) int32.
    flat_token = jnp.arange(tk, dtype=jnp.int32) // top_k
    buf_tok = (
        jnp.full((e * capacity,), tk, jnp.int32).at[dest].set(flat_token, mode="drop")
    )
    valid = (buf_tok < tk)[:, None]
    buf = jnp.where(
        valid, jnp.take(xt, jnp.minimum(buf_tok, t - 1), axis=0), 0
    ).astype(x.dtype)
    buf = se(buf.reshape(e, capacity, d))

    # -- grouped expert SwiGLU --
    # weight-gathered FSDP (§Perf D1): contract over the FULL d/f dims by
    # un-sharding the expert weights' fsdp axis right before use (EP stays
    # on `model`).  Contracting over the fsdp-sharded d instead emits
    # activation-sized partial-sum all-reduces — measured 8.7 TB/device
    # per step on deepseek-v3 train_4k.
    sw = _HOOKS["weights"]
    a = common.act_fn(act)
    w_gate, w_up, w_down = sw(p.w_gate), sw(p.w_up), sw(p.w_down)
    h = a(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, w_up
    )
    out_buf = se(jnp.einsum("ecf,efd->ecd", h, w_down)).reshape(e * capacity, d)

    # -- combine (expert-sharded -> token-sharded) --
    gathered = jnp.take(out_buf, jnp.minimum(dest, e * capacity - 1), axis=0)
    gathered = st(gathered * (gate_vals.reshape(-1) * keep)[:, None].astype(x.dtype))
    out = gathered.reshape(t, top_k, d).sum(axis=1)  # token-major inverse

    if p.shared is not None:
        out = out + ffn_forward(p.shared, xt, act)
    return out.reshape(b, s, d), aux


def moe_expert_flops(t: int, d: int, f: int, top_k: int, cf: float) -> float:
    """Compiled expert GEMM FLOPs for a (B*S = t)-token forward."""
    return 6.0 * t * top_k * cf * d * f
