"""LM substrate: model definitions for the assigned architectures.

Everything is plain JAX — params are pytrees of jnp arrays, layers are
pure functions, layer stacks run under ``jax.lax.scan`` (bounded HLO for
61-layer 512-device dry-runs), and sharding is applied via PartitionSpec
rules in ``repro.sharding``.
"""

from repro.models.registry import build_model  # noqa: F401
