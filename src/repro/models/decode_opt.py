"""Optimized decode paths.

``flash_decode_shardmap``: explicit partial-softmax merge for a KV cache
sharded along the *sequence* axis of the mesh `model` dimension — the
layout the partitioner picks when KV heads cannot be sharded (granite /
gemma3 have kv=1).  Each shard attends over its local cache slice and the
shards combine with the numerically-exact flash merge:

    m_g   = pmax(m_loc)
    out_g = psum(exp(m_loc - m_g) * num_loc) / psum(exp(m_loc - m_g) * den_loc)

vs the baseline pjit path where XLA inserts generic softmax collectives.
One all-reduce of (B, H, D)+(B, H)+(B, H) per layer instead of
full-score-width reductions — the decode collective term drops from
O(S/shards) to O(1) bytes in the cache length.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def flash_decode_shardmap(
    mesh: Mesh,
    q: jnp.ndarray,  # (B, 1, H, D) — replicated over `model`
    k_cache: jnp.ndarray,  # (B, S, KV, D) — S sharded over `model`
    v_cache: jnp.ndarray,
    pos,  # () int32, number of valid positions - 1
    *,
    axis: str = "model",
) -> jnp.ndarray:
    """Exact decode attention with per-shard partial softmax."""
    b, _, h, d = q.shape
    n_kv = k_cache.shape[2]
    g = h // n_kv
    s_total = k_cache.shape[1]
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    s_loc = s_total // n_shards

    def local(qb, kb, vb, pos_):
        # qb: (B,1,H,D) full; kb/vb: (B, S_loc, KV, D) local slice
        idx = jax.lax.axis_index(axis)
        base = idx * s_loc
        qq = qb.reshape(b, n_kv, g, d).astype(jnp.float32) * (d ** -0.5)
        scores = jnp.einsum("bkgd,bskd->bkgs", qq, kb.astype(jnp.float32))
        mask = (jnp.arange(s_loc) + base) <= pos_
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        m_loc = scores.max(axis=-1)  # (B, KV, G)
        p = jnp.exp(scores - m_loc[..., None])
        num = jnp.einsum("bkgs,bskd->bkgd", p, vb.astype(jnp.float32))
        den = p.sum(axis=-1)  # (B, KV, G)
        # exact flash merge across shards
        m_g = jax.lax.pmax(m_loc, axis)
        scale = jnp.exp(m_loc - m_g)
        num_g = jax.lax.psum(num * scale[..., None], axis)
        den_g = jax.lax.psum(den * scale, axis)
        out = num_g / jnp.maximum(den_g, 1e-30)[..., None]
        return out.reshape(b, 1, h, d).astype(qb.dtype)

    fn = shard_map(
        functools.partial(local),
        mesh=mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None), P()),
        out_specs=P(),
        check_rep=False,
    )
    return fn(q, k_cache, v_cache, jnp.asarray(pos, jnp.int32))
