"""Attention: GQA/MQA with RoPE, sliding windows, flash-style chunking.

Three code paths, all pure JAX:

  * ``flash_attention`` — train/prefill.  Python-unrolled query blocks ×
    ``lax.scan`` over the causal KV prefix with online softmax, so (a)
    compiled FLOPs match the causal model FLOPs (no wasted upper-triangle
    work — this matters for the roofline's useful-FLOP ratio), and (b) the
    working set per step is (B, H, blk, blk) instead of (B, H, S, S),
    which is what makes prefill_32k compile inside v5e HBM.
  * ``decode_attention`` — single new token vs a (possibly sequence-
    sharded) KV cache.  Softmax statistics reduce over the sharded axis
    via XLA's automatic collectives (baseline) — the shard_map
    flash-decode merge is a §Perf variant in launch/serve.py.
  * ``full_attention`` — reference/smoke path for short sequences.

``window`` and ``rope theta`` may be *traced* per-layer scalars so that
heterogeneous stacks (gemma3 5:1 local:global) still run under one
``lax.scan`` over layers.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common


class AttnParams(NamedTuple):
    wq: jnp.ndarray  # (d, H*D)
    wk: jnp.ndarray  # (d, KV*D)
    wv: jnp.ndarray  # (d, KV*D)
    wo: jnp.ndarray  # (H*D, d)
    q_norm: jnp.ndarray | None  # (D,) rms scales (qk_norm)
    k_norm: jnp.ndarray | None


def init_attn_params(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                     dtype, qk_norm: bool = False) -> AttnParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return AttnParams(
        wq=common.dense_init(k1, (d_model, n_heads * head_dim), dtype),
        wk=common.dense_init(k2, (d_model, n_kv * head_dim), dtype),
        wv=common.dense_init(k3, (d_model, n_kv * head_dim), dtype),
        wo=common.dense_init(k4, (n_heads * head_dim, d_model), dtype),
        q_norm=jnp.zeros((head_dim,), dtype) if qk_norm else None,
        k_norm=jnp.zeros((head_dim,), dtype) if qk_norm else None,
    )


def _split_heads(x: jnp.ndarray, n: int) -> jnp.ndarray:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def _gqa_expand(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """(B, S, H, D) -> (B, S, KV, G, D) where G = H // KV."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


# ---------------------------------------------------------------------------
# Full attention (short sequences / smoke)
# ---------------------------------------------------------------------------


def full_attention(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, KV, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window=0,
    q_offset: int = 0,
    logit_softcap: float = 0.0,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    dv = v.shape[-1]  # may differ from d (MLA)
    n_kv = k.shape[2]
    qq = _gqa_expand(q, n_kv) * (d ** -0.5)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qq.astype(jnp.float32), k.astype(jnp.float32))
    scores = common.softcap(scores, logit_softcap)
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= kj <= qi
    mask &= kj > qi - jnp.where(window > 0, window, jnp.int32(2**30))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash-style chunked attention (train / prefill)
# ---------------------------------------------------------------------------


def flash_attention(
    q: jnp.ndarray,  # (B, S, H, D)
    k: jnp.ndarray,  # (B, S, KV, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window=0,
    logit_softcap: float = 0.0,
    blk: int = 512,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    sk = k.shape[1]  # may differ from sq (cross-attention)
    dv = v.shape[-1]  # may differ from d (MLA)
    n_kv = k.shape[2]
    if causal and sq != sk:
        raise ValueError(f"causal flash requires sq == sk, got {sq} vs {sk}")
    if sq <= blk or sq % blk or sk % blk:
        return full_attention(
            q, k, v, causal=causal, window=window, logit_softcap=logit_softcap
        )
    n_blocks = sq // blk
    n_kv_blocks = sk // blk
    g = h // n_kv
    scale = d ** -0.5
    window_eff = jnp.where(window > 0, window, jnp.int32(2**30))

    # (nb, B, blk, KV, G, D) query blocks, fp32 math inside
    qb = _gqa_expand(q, n_kv).reshape(b, n_blocks, blk, n_kv, g, d).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(b, n_kv_blocks, blk, n_kv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, n_kv_blocks, blk, n_kv, dv).transpose(1, 0, 2, 3, 4)

    outs = []
    for i in range(n_blocks):
        qi = (qb[i] * scale).astype(jnp.float32)  # (B, blk, KV, G, D)
        q_pos = i * blk + jnp.arange(blk)

        n_kv_chunks = (i + 1) if causal else n_kv_blocks
        kv_k = kb[:n_kv_chunks]  # (nc, B, blk, KV, D)
        kv_v = vb[:n_kv_chunks]
        chunk_ids = jnp.arange(n_kv_chunks)

        def step(carry, xs):
            m, l, acc = carry
            kc, vc, cid = xs  # (B, blk, KV, D), (B, blk, KV, D), ()
            sc = jnp.einsum("bqkgd,bskd->bkgqs", qi, kc.astype(jnp.float32))
            sc = common.softcap(sc, logit_softcap)
            k_pos = cid * blk + jnp.arange(blk)
            mask = jnp.ones((blk, blk), dtype=bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            mask &= k_pos[None, :] > q_pos[:, None] - window_eff
            sc = jnp.where(mask[None, None, None], sc, -1e30)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, n_kv, g, blk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, blk), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, blk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kv_k, kv_v, chunk_ids))
        o = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, blk, Dv)
        outs.append(o.transpose(0, 3, 1, 2, 4).reshape(b, blk, h, dv))

    return jnp.concatenate(outs, axis=1).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode (single token, KV cache)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, D)
    k_cache: jnp.ndarray,  # (B, S, KV, D)
    v_cache: jnp.ndarray,
    pos,  # () current position (number of valid cache entries - 1)
    *,
    window=0,
    logit_softcap: float = 0.0,
) -> jnp.ndarray:
    b, _, h, d = q.shape
    n_kv = k_cache.shape[2]
    qq = _gqa_expand(q, n_kv)[:, 0] * (d ** -0.5)  # (B, KV, G, D)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qq.astype(jnp.float32), k_cache.astype(jnp.float32)
    )
    scores = common.softcap(scores, logit_softcap)
    kj = jnp.arange(k_cache.shape[1])
    mask = kj <= pos
    mask &= kj > pos - jnp.where(window > 0, window, jnp.int32(2**30))
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Block-level forward (projection + rope + attend + out-proj)
# ---------------------------------------------------------------------------


def attention_forward(
    p: AttnParams,
    x: jnp.ndarray,  # (B, S, d)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta,
    positions: jnp.ndarray,  # (B, S) or (S,)
    causal: bool = True,
    window=0,
    logit_softcap: float = 0.0,
    norm_eps: float = 1e-6,
    flash_blk: int = 512,
    kv_override: tuple[jnp.ndarray, jnp.ndarray] | None = None,  # cross-attn
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Returns (output (B,S,d), (k, v) for cache)."""
    q = _split_heads(x @ p.wq, n_heads)
    if kv_override is None:
        k = _split_heads(x @ p.wk, n_kv)
        v = _split_heads(x @ p.wv, n_kv)
    else:
        k, v = kv_override
    if p.q_norm is not None:
        q = common.rms_norm(q, p.q_norm, norm_eps)
        k = common.rms_norm(k, p.k_norm, norm_eps) if kv_override is None else k
    if rope_theta is not None:
        if positions.ndim == 1:
            positions = positions[None, :]
        q = common.apply_rope(q, positions, rope_theta)
        if kv_override is None:
            k = common.apply_rope(k, positions, rope_theta)
    out = flash_attention(
        q, k, v, causal=causal, window=window, logit_softcap=logit_softcap, blk=flash_blk
    )
    return out.reshape(*x.shape[:2], -1) @ p.wo, (k, v)


def attention_decode(
    p: AttnParams,
    x: jnp.ndarray,  # (B, 1, d)
    k_cache: jnp.ndarray,  # (B, S, KV, D)
    v_cache: jnp.ndarray,
    pos,  # () int32 write/read position
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    rope_theta,
    window=0,
    logit_softcap: float = 0.0,
    norm_eps: float = 1e-6,
    update_cache: bool = True,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    q = _split_heads(x @ p.wq, n_heads)
    if update_cache:
        k_new = _split_heads(x @ p.wk, n_kv)
        v_new = _split_heads(x @ p.wv, n_kv)
        if p.q_norm is not None:
            k_new = common.rms_norm(k_new, p.k_norm, norm_eps)
        if rope_theta is not None:
            k_new = common.apply_rope(k_new, jnp.full((1, 1), pos), rope_theta)
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new.astype(k_cache.dtype), pos, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new.astype(v_cache.dtype), pos, 1)
    if p.q_norm is not None:
        q = common.rms_norm(q, p.q_norm, norm_eps)
    if rope_theta is not None:
        q = common.apply_rope(q, jnp.full((1, 1), pos), rope_theta)
    out = decode_attention(
        q, k_cache, v_cache, pos, window=window, logit_softcap=logit_softcap
    )
    return out.reshape(x.shape[0], 1, -1) @ p.wo, (k_cache, v_cache)
