"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free linear
recurrence with data-dependent per-channel decay.

Faithful parts: the WKV6 recurrence S <- diag(w_t) S + k_t v_t^T with
bonus u, data-dependent decay w_t = exp(-exp(w0 + tanh(m @ A) B)), token
shift, per-head group norm, squared-ReLU channel mixing.
Simplification (noted in DESIGN.md): token-shift interpolation uses static
per-channel mu (RWKV-5 style) instead of the full 5-way ddlerp LoRA; the
decay — the architecture's signature — keeps its full data-dependent form.

State per layer: (S (B,H,D,D), x_prev_att (B,d), x_prev_ffn (B,d)) — O(1)
in sequence length, which is why rwkv6 runs the long_500k cell.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common


class RWKV6Params(NamedTuple):
    # time mixing
    mu_r: jnp.ndarray  # (d,)
    mu_k: jnp.ndarray
    mu_v: jnp.ndarray
    mu_g: jnp.ndarray
    mu_w: jnp.ndarray
    w0: jnp.ndarray  # (d,) base decay
    w_lora_a: jnp.ndarray  # (d, 64)
    w_lora_b: jnp.ndarray  # (64, d)
    wr: jnp.ndarray  # (d, d)
    wk: jnp.ndarray
    wv: jnp.ndarray
    wg: jnp.ndarray
    wo: jnp.ndarray
    u: jnp.ndarray  # (d,) per-channel bonus
    ln_scale: jnp.ndarray  # (d,) per-head group norm
    ln_bias: jnp.ndarray
    # channel mixing
    mu_ck: jnp.ndarray  # (d,)
    mu_cr: jnp.ndarray
    ck: jnp.ndarray  # (d, d_ff)
    cv: jnp.ndarray  # (d_ff, d)
    cr: jnp.ndarray  # (d, d)


def init_rwkv6_params(key, cfg, dtype) -> RWKV6Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 10)
    mu = lambda k: jax.random.uniform(k, (d,), jnp.float32)
    return RWKV6Params(
        mu_r=mu(ks[0]), mu_k=mu(jax.random.fold_in(ks[0], 1)),
        mu_v=mu(jax.random.fold_in(ks[0], 2)), mu_g=mu(jax.random.fold_in(ks[0], 3)),
        mu_w=mu(jax.random.fold_in(ks[0], 4)),
        w0=jnp.full((d,), -2.0, jnp.float32),
        w_lora_a=common.dense_init(ks[1], (d, 64), jnp.float32),
        w_lora_b=jnp.zeros((64, d), jnp.float32),
        wr=common.dense_init(ks[2], (d, d), dtype),
        wk=common.dense_init(ks[3], (d, d), dtype),
        wv=common.dense_init(ks[4], (d, d), dtype),
        wg=common.dense_init(ks[5], (d, d), dtype),
        wo=common.dense_init(ks[6], (d, d), dtype),
        u=jnp.zeros((d,), jnp.float32),
        ln_scale=jnp.ones((d,), jnp.float32),
        ln_bias=jnp.zeros((d,), jnp.float32),
        mu_ck=mu(jax.random.fold_in(ks[0], 5)),
        mu_cr=mu(jax.random.fold_in(ks[0], 6)),
        ck=common.dense_init(ks[7], (d, f), dtype),
        cv=common.dense_init(ks[8], (f, d), dtype),
        cr=common.dense_init(ks[9], (d, d), dtype),
    )


def _shift(x: jnp.ndarray, x_prev: jnp.ndarray | None = None) -> jnp.ndarray:
    """Token shift: x_prev feeds position 0 (zeros at sequence start)."""
    pad = jnp.zeros_like(x[:, :1]) if x_prev is None else x_prev[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


MAX_LOG_DECAY = 4.0  # per-step |log w| cap: keeps the chunked form's
# exp(+cum) factors inside fp32 range (chunk 16 x 4.0 = 64 < log(f32max)≈88)
# while w >= e^-4 ≈ 0.018/step still halves context every ~0.2 tokens at the
# floor — no expressiveness lost in practice.  The cap is part of the model
# definition, so the scan and chunked paths are bit-consistent.


def _decay(prm: RWKV6Params, mw: jnp.ndarray) -> jnp.ndarray:
    """Data-dependent decay in (0,1): exp(-exp(w0 + tanh(m A) B))."""
    lora = jnp.tanh(mw.astype(jnp.float32) @ prm.w_lora_a) @ prm.w_lora_b
    return jnp.exp(-jnp.minimum(jnp.exp(prm.w0 + lora), MAX_LOG_DECAY))


def _group_norm(y: jnp.ndarray, scale, bias, n_heads: int, eps: float) -> jnp.ndarray:
    b, s, d = y.shape
    yh = y.reshape(b, s, n_heads, d // n_heads).astype(jnp.float32)
    mu_ = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu_) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(b, s, d) * scale + bias).astype(y.dtype)


def _wkv_scan(r, k, v, w, u, hd: int, s0=None):
    """The WKV6 recurrence.  r/k/v/w: (B, S, d) fp32.  Returns (y, S_final).

    Per head: y_t = r_t^T (S + diag(u) k_t v_t^T);  S <- diag(w_t) S + k_t v_t^T
    """
    b, s, d = r.shape
    h = d // hd
    rh = r.reshape(b, s, h, hd).transpose(1, 0, 2, 3)
    kh = k.reshape(b, s, h, hd).transpose(1, 0, 2, 3)
    vh = v.reshape(b, s, h, hd).transpose(1, 0, 2, 3)
    wh = w.reshape(b, s, h, hd).transpose(1, 0, 2, 3)
    uh = u.reshape(h, hd)

    state0 = jnp.zeros((b, h, hd, hd), jnp.float32) if s0 is None else s0

    def step(S, xs):
        rt, kt, vt, wt = xs  # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + uh[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    S, ys = jax.lax.scan(step, state0, (rh, kh, vh, wh))
    return ys.transpose(1, 0, 2, 3).reshape(b, s, d), S


def _wkv_chunked(r, k, v, w, u, hd: int, s0=None, chunk: int = 16):
    """Chunk-parallel WKV6 (GLA-style), exactly equal to ``_wkv_scan``.

    Within a chunk (length C, relative to chunk start; cum = cumulative
    log-decay, cum[-1] := 0):

        A[t, j] = sum_c r[t,c] e^{cum[t-1,c]} * k[j,c] e^{-cum[j,c]}   (j < t)
        A[t, t] = sum_c r[t,c] u[c] k[t,c]                             (bonus)
        y       = A @ v + (r ⊙ e^{cum_prev}) S_0
        S_end   = diag(e^{cum_end}) S_0 + (k ⊙ e^{cum_end - cum})^T v

    The state materializes once per CHUNK instead of once per token — a
    C-fold cut in HBM traffic for the state stream (the dominant term of
    the rwkv6 train_4k roofline), and the intra-chunk work becomes (C x C)
    MXU matmuls.  e^{+cum} stays bounded because per-step log-decay is
    capped at MAX_LOG_DECAY and C * MAX_LOG_DECAY < log(f32_max).
    """
    b, s, d = r.shape
    if s % chunk:
        return _wkv_scan(r, k, v, w, u, hd, s0)
    h = d // hd
    nc = s // chunk
    c = chunk

    def to_chunks(x):  # (B,S,d) -> (nc, B, H, C, hd)
        return (x.reshape(b, nc, c, h, hd).transpose(1, 0, 3, 2, 4))

    rh, kh, vh = to_chunks(r), to_chunks(k), to_chunks(v)
    logw = jnp.log(to_chunks(w))  # (nc, B, H, C, hd), entries in [-MAX, 0)
    uh = u.reshape(h, hd)

    cum = jnp.cumsum(logw, axis=3)  # inclusive cumulative log-decay
    cum_prev = cum - logw  # exclusive (cum[t-1], with cum[-1] = 0)
    cum_end = cum[:, :, :, -1:, :]  # (nc, B, H, 1, hd)

    # fp32 streams throughout: a bf16-stream variant was tried and
    # REFUTED — the extra convert ops add fusion boundaries and *raised*
    # the measured memory term 113->149 s (§Perf R3).
    r_in = rh * jnp.exp(cum_prev)  # bounded <= |r|
    k_in = kh * jnp.exp(-cum)  # bounded by exp(C * MAX_LOG_DECAY)
    k_out = kh * jnp.exp(cum_end - cum)  # bounded <= |k|

    # intra-chunk attention with strict lower-triangular mask + u diagonal
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    a_intra = jnp.einsum("nbhtc,nbhjc->nbhtj", r_in, k_in)
    a_intra = jnp.where(tri[None, None, None], a_intra, 0.0)
    diag = jnp.einsum("nbhtc,nbhtc->nbht", rh, kh * uh[None, None, :, None, :])
    y_intra = jnp.einsum("nbhtj,nbhjc->nbhtc", a_intra, vh)
    y_intra = y_intra + diag[..., None] * vh

    state0 = jnp.zeros((b, h, hd, hd), jnp.float32) if s0 is None else s0

    def per_chunk(S, xs):
        r_i, k_o, v_i, ce = xs  # (B,H,C,hd) x3, (B,H,1,hd)
        y_off = jnp.einsum("bhtc,bhcd->bhtd", r_i, S)
        S_new = jnp.exp(ce[:, :, 0])[:, :, :, None] * S + jnp.einsum(
            "bhtc,bhtd->bhcd", k_o, v_i
        )
        return S_new, y_off

    S, y_off = jax.lax.scan(per_chunk, state0, (r_in, k_out, vh, cum_end))
    y = y_intra + y_off  # (nc, B, H, C, hd)
    y = y.transpose(1, 0, 3, 2, 4).reshape(b, s, d)
    return y, S


def rwkv6_time_mix(prm: RWKV6Params, x: jnp.ndarray, cfg, state=None):
    """x: (B,S,d).  state: (S0, x_prev) or None.  Returns (out, new_state)."""
    s0, x_prev = (None, None) if state is None else state
    xs = _shift(x, x_prev)
    mr, mk, mv, mg, mw = (
        _lerp(x, xs, prm.mu_r), _lerp(x, xs, prm.mu_k), _lerp(x, xs, prm.mu_v),
        _lerp(x, xs, prm.mu_g), _lerp(x, xs, prm.mu_w),
    )
    r = (mr @ prm.wr).astype(jnp.float32)
    k = (mk @ prm.wk).astype(jnp.float32)
    v = (mv @ prm.wv).astype(jnp.float32)
    g = jax.nn.silu(mg @ prm.wg)
    w = _decay(prm, mw)  # (B,S,d) in (0,1)
    if x.shape[1] > 1 and x.shape[1] % 16 == 0:
        y, s_new = _wkv_chunked(r, k, v, w, prm.u, cfg.rwkv_head_dim, s0)
    else:
        y, s_new = _wkv_scan(r, k, v, w, prm.u, cfg.rwkv_head_dim, s0)
    y = _group_norm(y.astype(x.dtype), prm.ln_scale, prm.ln_bias,
                    cfg.d_model // cfg.rwkv_head_dim, cfg.norm_eps)
    return (y * g) @ prm.wo, (s_new, x[:, -1, :])


def rwkv6_channel_mix(prm: RWKV6Params, x: jnp.ndarray, x_prev=None):
    xs = _shift(x, x_prev)
    mk = _lerp(x, xs, prm.mu_ck)
    mr = _lerp(x, xs, prm.mu_cr)
    k = jnp.square(jax.nn.relu(mk @ prm.ck))
    return jax.nn.sigmoid(mr @ prm.cr) * (k @ prm.cv), x[:, -1, :]
