"""Gated feed-forward (SwiGLU / GeGLU) blocks."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common


class FFNParams(NamedTuple):
    w_gate: jnp.ndarray  # (d, f)
    w_up: jnp.ndarray  # (d, f)
    w_down: jnp.ndarray  # (f, d)


def init_ffn_params(key, d_model: int, d_ff: int, dtype) -> FFNParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return FFNParams(
        w_gate=common.dense_init(k1, (d_model, d_ff), dtype),
        w_up=common.dense_init(k2, (d_model, d_ff), dtype),
        w_down=common.dense_init(k3, (d_ff, d_model), dtype),
    )


def ffn_forward(p: FFNParams, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    a = common.act_fn(act)
    return (a(x @ p.w_gate) * (x @ p.w_up)) @ p.w_down


class MLPParams(NamedTuple):
    """Ungated two-matrix MLP (whisper-style fc1/fc2)."""

    w1: jnp.ndarray  # (d, f)
    b1: jnp.ndarray  # (f,)
    w2: jnp.ndarray  # (f, d)
    b2: jnp.ndarray  # (d,)


def init_mlp_params(key, d_model: int, d_ff: int, dtype) -> MLPParams:
    k1, k2 = jax.random.split(key)
    return MLPParams(
        w1=common.dense_init(k1, (d_model, d_ff), dtype),
        b1=jnp.zeros((d_ff,), dtype),
        w2=common.dense_init(k2, (d_ff, d_model), dtype),
        b2=jnp.zeros((d_model,), dtype),
    )


def mlp_forward(p: MLPParams, x: jnp.ndarray, act: str = "gelu") -> jnp.ndarray:
    a = common.act_fn(act)
    return a(x @ p.w1 + p.b1) @ p.w2 + p.b2
