"""Shared building blocks: norms, RoPE, embeddings, init, dtype policy."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initializers (all params created in the config dtype)
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis: int = 0) -> jnp.ndarray:
    """Truncated-normal fan-in scaling (maxtext-style)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis])
    )
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jnp.ndarray:
    """std = 1/sqrt(d_model): keeps tied-head logits O(1) at init."""
    std = 1.0 / np.sqrt(shape[1])
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm in fp32 math, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies.  ``theta`` may be a traced scalar
    (per-layer theta under scan, e.g. gemma3 local vs global)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta) -> jnp.ndarray:
    """x: (..., S, H, D) with D even; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # (..., S, 1, D/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray | None = None):
    """Token-mean cross entropy in fp32; logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
