"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, T, d) for the encoder.  The
transformer backbone is faithful in shape: pre-LN blocks, sinusoidal
(encoder) / learned-style (decoder) absolute positions approximated with
fixed sinusoids, ungated GELU MLPs, bidirectional encoder self-attention,
causal decoder self-attention + cross-attention.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import common
from repro.models.attention import (
    attention_decode,
    attention_forward,
    decode_attention,
    init_attn_params,
    _split_heads,
)
from repro.models.ffn import init_mlp_params, mlp_forward


def sinusoid_positions(length: int, dim: int) -> np.ndarray:
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / dim)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


def sinusoid_at(pos, dim: int) -> jnp.ndarray:
    """(dim,) sinusoid embedding at a traced position."""
    i = jnp.arange(dim // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDecLM:
    def __init__(self, cfg: ModelConfig, flash_blk: int = 512):
        self.cfg = cfg
        self.flash_blk = flash_blk
        self.shard_x = lambda t: t  # activation sharding hook (launcher-set)

    def _init_block(self, key, cross: bool):
        cfg = self.cfg
        dtype = common.dtype_of(cfg.dtype)
        k1, k2, k3 = jax.random.split(key, 3)
        p = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attn_params(
                k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
            ),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": init_mlp_params(k2, cfg.d_model, cfg.d_ff, dtype),
        }
        if cross:
            p["ln_x"] = jnp.zeros((cfg.d_model,), dtype)
            p["xattn"] = init_attn_params(
                k3, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
            )
        return p

    def init_params(self, key):
        cfg = self.cfg
        dtype = common.dtype_of(cfg.dtype)
        ks = jax.random.split(key, 4)
        enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
        dec_keys = jax.random.split(ks[1], cfg.n_layers)
        return {
            "embed": common.embed_init(ks[2], (cfg.vocab_size, cfg.d_model), dtype),
            "enc": jax.vmap(lambda k: self._init_block(k, cross=False))(enc_keys),
            "dec": jax.vmap(lambda k: self._init_block(k, cross=True))(dec_keys),
            "enc_norm": jnp.zeros((cfg.d_model,), dtype),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
            # lm head tied to embed (whisper ties)
        }

    # -- encoder --------------------------------------------------------------

    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """frames: (B, T, d) stub frame embeddings -> encoder states."""
        cfg = self.cfg
        t = frames.shape[1]
        x = frames + jnp.asarray(sinusoid_positions(t, cfg.d_model), frames.dtype)[None]
        positions = jnp.arange(t)

        def body(h, prm):
            a, _ = attention_forward(
                prm["attn"], common.rms_norm(h, prm["ln1"], cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=None,
                positions=positions, causal=False, window=0,
                norm_eps=cfg.norm_eps, flash_blk=self.flash_blk,
            )
            h = h + a
            h = h + mlp_forward(prm["mlp"], common.rms_norm(h, prm["ln2"], cfg.norm_eps))
            return self.shard_x(h), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x = self.shard_x(x)
        x, _ = jax.lax.scan(body_fn, x, params["enc"])
        return common.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # -- decoder --------------------------------------------------------------

    def _decoder_states(self, params, tokens, enc, collect_cache: bool = False):
        cfg = self.cfg
        s = tokens.shape[1]
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x + jnp.asarray(sinusoid_positions(s, cfg.d_model), x.dtype)[None]
        positions = jnp.arange(s)

        def body(h, prm):
            a, kv = attention_forward(
                prm["attn"], common.rms_norm(h, prm["ln1"], cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=None,
                positions=positions, causal=True, window=0,
                norm_eps=cfg.norm_eps, flash_blk=self.flash_blk,
            )
            h = h + a
            # cross attention over encoder states (kv projected per layer)
            xk = _split_heads(enc @ prm["xattn"].wk, cfg.n_kv_heads)
            xv = _split_heads(enc @ prm["xattn"].wv, cfg.n_kv_heads)
            c, _ = attention_forward(
                prm["xattn"], common.rms_norm(h, prm["ln_x"], cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=None,
                positions=positions, causal=False, window=0,
                norm_eps=cfg.norm_eps, flash_blk=self.flash_blk,
                kv_override=(xk, xv),
            )
            h = h + c
            h = h + mlp_forward(prm["mlp"], common.rms_norm(h, prm["ln2"], cfg.norm_eps))
            return self.shard_x(h), (kv, (xk, xv)) if collect_cache else None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x = self.shard_x(x)
        x, cache = jax.lax.scan(body_fn, x, params["dec"])
        return common.rms_norm(x, params["final_norm"], cfg.norm_eps), cache

    # -- public API -------------------------------------------------------------

    def loss_fn(self, params, batch):
        """batch: {'frames' (B,T,d), 'tokens' (B,S), 'labels' (B,S)}."""
        enc = self.encode(params, batch["frames"])
        hidden, _ = self._decoder_states(params, batch["tokens"], enc)
        from repro.models.transformer import _chunked_ce

        loss = _chunked_ce(hidden, params["embed"].T, batch["labels"])
        return loss, {"ce": loss, "loss": loss}

    def prefill(self, params, batch):
        enc = self.encode(params, batch["frames"])
        hidden, cache = self._decoder_states(
            params, batch["tokens"], enc, collect_cache=True
        )
        logits = hidden[:, -1, :] @ params["embed"].T
        kv, xkv = cache
        return logits.astype(jnp.float32), {"k": kv[0], "v": kv[1],
                                            "xk": xkv[0], "xv": xkv[1]}

    def init_cache(self, batch: int, seq: int, enc_len: int | None = None):
        cfg = self.cfg
        dtype = common.dtype_of(cfg.dtype)
        el = enc_len if enc_len is not None else seq
        kvh = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.resolved_head_dim)
        xvh = (cfg.n_layers, batch, el, cfg.n_kv_heads, cfg.resolved_head_dim)
        return {
            "k": jnp.zeros(kvh, dtype), "v": jnp.zeros(kvh, dtype),
            "xk": jnp.zeros(xvh, dtype), "xv": jnp.zeros(xvh, dtype),
        }

    def decode_step(self, params, cache, token, pos):
        cfg = self.cfg
        x = jnp.take(params["embed"], token[:, None], axis=0)
        x = x + sinusoid_at(pos, cfg.d_model).astype(x.dtype)[None, None, :]

        def body(h, xs):
            prm, kc, vc, xk, xv = xs
            a, (kc2, vc2) = attention_decode(
                prm["attn"], common.rms_norm(h, prm["ln1"], cfg.norm_eps),
                kc, vc, pos,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=None,
                norm_eps=cfg.norm_eps,
            )
            h = h + a
            q = _split_heads(
                common.rms_norm(h, prm["ln_x"], cfg.norm_eps) @ prm["xattn"].wq,
                cfg.n_heads,
            )
            c = decode_attention(q, xk, xv, jnp.int32(xk.shape[1] - 1))
            h = h + c.reshape(h.shape[0], 1, -1) @ prm["xattn"].wo
            h = h + mlp_forward(prm["mlp"], common.rms_norm(h, prm["ln2"], cfg.norm_eps))
            return h, (kc2, vc2)

        x, (k2, v2) = jax.lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"])
        )
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, 0, :] @ params["embed"].T
        return logits.astype(jnp.float32), {"k": k2, "v": v2,
                                            "xk": cache["xk"], "xv": cache["xv"]}
