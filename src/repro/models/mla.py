"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill use the naive (decompressed) path; decode uses the
weight-absorbed path with a compressed cache of (kv_lora + qk_rope) floats
per token — the property that makes deepseek-v3 decode memory-light.

Shapes (deepseek-v3): d=7168, q_lora=1536, kv_lora=512, qk_nope=128,
qk_rope=64, v_head=128, H=128.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.attention import flash_attention


class MLAParams(NamedTuple):
    wdq: jnp.ndarray  # (d, q_lora)
    q_ln: jnp.ndarray  # (q_lora,)
    wuq: jnp.ndarray  # (q_lora, H*(nope+rope))
    wdkv: jnp.ndarray  # (d, kv_lora)
    kv_ln: jnp.ndarray  # (kv_lora,)
    wuk: jnp.ndarray  # (kv_lora, H*nope)
    wuv: jnp.ndarray  # (kv_lora, H*v_dim)
    wkr: jnp.ndarray  # (d, rope)
    wo: jnp.ndarray  # (H*v_dim, d)


def init_mla_params(key, cfg, dtype) -> MLAParams:
    ks = jax.random.split(key, 7)
    h = cfg.n_heads
    return MLAParams(
        wdq=common.dense_init(ks[0], (cfg.d_model, cfg.q_lora_rank), dtype),
        q_ln=jnp.zeros((cfg.q_lora_rank,), dtype),
        wuq=common.dense_init(
            ks[1], (cfg.q_lora_rank, h * (cfg.qk_nope_dim + cfg.qk_rope_dim)), dtype
        ),
        wdkv=common.dense_init(ks[2], (cfg.d_model, cfg.kv_lora_rank), dtype),
        kv_ln=jnp.zeros((cfg.kv_lora_rank,), dtype),
        wuk=common.dense_init(ks[3], (cfg.kv_lora_rank, h * cfg.qk_nope_dim), dtype),
        wuv=common.dense_init(ks[4], (cfg.kv_lora_rank, h * cfg.v_head_dim), dtype),
        wkr=common.dense_init(ks[5], (cfg.d_model, cfg.qk_rope_dim), dtype),
        wo=common.dense_init(ks[6], (h * cfg.v_head_dim, cfg.d_model), dtype),
    )


def _project_q(p: MLAParams, x, cfg, positions):
    b, s, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = common.rms_norm(x @ p.wdq, p.q_ln, cfg.norm_eps)
    q = (cq @ p.wuq).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(
    p: MLAParams,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
    positions: jnp.ndarray,  # (S,) or (B, S)
    *,
    flash_blk: int = 512,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Naive decompressed MLA for train/prefill.

    Returns (out, (ckv_normed, k_rope)) — the compressed-cache entries.
    """
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if positions.ndim == 1:
        positions = positions[None, :]

    q_nope, q_rope = _project_q(p, x, cfg, positions)
    ckv = common.rms_norm(x @ p.wdkv, p.kv_ln, cfg.norm_eps)  # (B, S, kv_lora)
    k_nope = (ckv @ p.wuk).reshape(b, s, h, dn)
    v = (ckv @ p.wuv).reshape(b, s, h, dv)
    k_rope = common.apply_rope((x @ p.wkr)[:, :, None, :], positions, cfg.rope_theta)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B, S, H, dn+dr)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
    out = flash_attention(q, k, v, causal=True, window=0, blk=flash_blk)
    out = out.reshape(b, s, h * dv) @ p.wo
    return out, (ckv, k_rope[:, :, 0, :])


def mla_decode(
    p: MLAParams,
    x: jnp.ndarray,  # (B, 1, d)
    ckv_cache: jnp.ndarray,  # (B, S, kv_lora) — rms-normed compressed kv
    kr_cache: jnp.ndarray,  # (B, S, rope)
    pos,  # () int32
    cfg,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Weight-absorbed decode: scores and context live in the latent space.

    score_h(t) = q_nope_h^T Wuk_h ckv_t + q_rope^T kr_t
    ctx_h      = sum_t p_t ckv_t          (B, H, kv_lora)
    out        = concat_h(ctx_h Wuv_h) Wo
    """
    b = x.shape[0]
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lr = cfg.kv_lora_rank
    positions = jnp.full((b, 1), pos)

    # update caches with this token's compressed kv
    ckv_new = common.rms_norm(x @ p.wdkv, p.kv_ln, cfg.norm_eps)  # (B, 1, lr)
    kr_new = common.apply_rope((x @ p.wkr)[:, :, None, :], positions, cfg.rope_theta)[
        :, :, 0, :
    ]
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, ckv_new.astype(ckv_cache.dtype), pos, 1
    )
    kr_cache = jax.lax.dynamic_update_slice_in_dim(
        kr_cache, kr_new.astype(kr_cache.dtype), pos, 1
    )

    q_nope, q_rope = _project_q(p, x, cfg, positions)  # (B, 1, H, dn/dr)
    # absorb Wuk into the query: (B, H, lr)
    wuk = p.wuk.reshape(lr, h, dn)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(jnp.float32),
                       wuk.astype(jnp.float32))

    scale = (dn + dr) ** -0.5
    scores = (
        jnp.einsum("bhl,bsl->bhs", q_lat, ckv_cache.astype(jnp.float32))
        + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                     kr_cache.astype(jnp.float32))
    ) * scale
    mask = jnp.arange(ckv_cache.shape[1]) <= pos
    scores = jnp.where(mask[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)

    ctx = jnp.einsum("bhs,bsl->bhl", probs, ckv_cache.astype(jnp.float32))  # (B,H,lr)
    wuv = p.wuv.reshape(lr, h, dv)
    out_h = jnp.einsum("bhl,lhv->bhv", ctx, wuv.astype(jnp.float32))  # (B,H,dv)
    out = out_h.reshape(b, 1, h * dv).astype(x.dtype) @ p.wo
    return out, (ckv_cache, kr_cache)
