"""Decoder-only transformer LM covering the dense / MoE / MLA / VLM
architectures (gemma3, phi3, granite, llama3.2, deepseek-v3, arctic,
llava-next).

Structure: the layer stack is split into homogeneous *segments* (e.g.
deepseek-v3 = 3 dense layers + 58 MoE layers); each segment's params are
stacked on a leading layer axis and executed under ``jax.lax.scan`` with
optional remat — this keeps HLO size and CPU compile time bounded for the
61-layer 512-device dry-runs.  Per-layer heterogeneity *within* a segment
(gemma3's 5:1 local:global attention, dual RoPE thetas) is expressed as
scanned metadata arrays (window sizes, thetas), so one traced block body
serves every layer.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import common
from repro.models.attention import (
    AttnParams,
    attention_decode,
    attention_forward,
    init_attn_params,
)
from repro.models.ffn import FFNParams, ffn_forward, init_ffn_params
from repro.models.mla import init_mla_params, mla_decode, mla_forward
from repro.models.moe import MoEParams, init_moe_params, moe_forward

Params = Any


# ---------------------------------------------------------------------------
# Per-layer metadata (windows / thetas) for heterogeneous stacks
# ---------------------------------------------------------------------------


def layer_meta(cfg: ModelConfig, n_layers: int, offset: int = 0):
    """(windows (L,), thetas (L,)) as numpy — scanned alongside params."""
    windows = np.zeros((n_layers,), np.int32)
    thetas = np.full((n_layers,), cfg.rope_theta, np.float32)
    if cfg.local_global_period > 0 and cfg.sliding_window > 0:
        for i in range(n_layers):
            gi = i + offset
            is_global = (gi + 1) % cfg.local_global_period == 0
            windows[i] = 0 if is_global else cfg.sliding_window
            thetas[i] = (
                cfg.rope_theta_global if (is_global and cfg.rope_theta_global) else cfg.rope_theta
            )
    elif cfg.sliding_window > 0:
        windows[:] = cfg.sliding_window
    return jnp.asarray(windows), jnp.asarray(thetas)


# ---------------------------------------------------------------------------
# Block definitions
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, kind: str) -> dict:
    """One layer's params.  kind: 'dense' | 'moe'."""
    k1, k2 = jax.random.split(key)
    dtype = common.dtype_of(cfg.dtype)
    p: dict = {"ln1": jnp.zeros((cfg.d_model,), dtype), "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if cfg.use_mla:
        p["attn"] = init_mla_params(k1, cfg, dtype)
    else:
        p["attn"] = init_attn_params(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
            dtype, cfg.qk_norm,
        )
    if kind == "moe":
        p["ffn"] = init_moe_params(
            k2, cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts,
            cfg.n_shared_experts, dtype,
        )
        if cfg.moe_dense_residual:
            p["dense_ffn"] = init_ffn_params(
                jax.random.fold_in(k2, 1), cfg.d_model, cfg.d_ff, dtype
            )
    else:
        ff = cfg.dense_d_ff if (cfg.dense_d_ff and cfg.is_moe) else cfg.d_ff
        p["ffn"] = init_ffn_params(k2, cfg.d_model, ff, dtype)
    if cfg.name.startswith("gemma"):  # gemma3 sandwich norms
        p["post_ln1"] = jnp.zeros((cfg.d_model,), dtype)
        p["post_ln2"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _block_forward(
    cfg: ModelConfig,
    kind: str,
    x: jnp.ndarray,
    prm: dict,
    window,
    theta,
    positions,
    flash_blk: int,
):
    """Full-sequence block.  Returns (x, (k, v) cache entry, aux loss)."""
    h = common.rms_norm(x, prm["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        h, kv = mla_forward(prm["attn"], h, cfg, positions, flash_blk=flash_blk)
    else:
        h, kv = attention_forward(
            prm["attn"], h,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=theta, positions=positions, causal=True, window=window,
            logit_softcap=cfg.attn_logit_softcap, norm_eps=cfg.norm_eps,
            flash_blk=flash_blk,
        )
    if "post_ln1" in prm:
        h = common.rms_norm(h, prm["post_ln1"], cfg.norm_eps)
    x = x + h

    f_in = common.rms_norm(x, prm["ln2"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if kind == "moe":
        f, aux = moe_forward(
            prm["ffn"], f_in, top_k=cfg.moe_top_k,
            capacity_factor=cfg.capacity_factor, act=cfg.act,
        )
        if cfg.moe_dense_residual:
            f = f + ffn_forward(prm["dense_ffn"], f_in, cfg.act)
    else:
        f = ffn_forward(prm["ffn"], f_in, cfg.act)
    if "post_ln2" in prm:
        f = common.rms_norm(f, prm["post_ln2"], cfg.norm_eps)
    return x + f, kv, aux


def _block_decode(
    cfg: ModelConfig, kind: str, x, prm, cache, window, theta, pos
):
    """Single-token block.  cache: family-specific tuple."""
    h = common.rms_norm(x, prm["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        h, cache = mla_decode(prm["attn"], h, cache[0], cache[1], pos, cfg)
    else:
        h, cache = attention_decode(
            prm["attn"], h, cache[0], cache[1], pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=theta, window=window,
            logit_softcap=cfg.attn_logit_softcap, norm_eps=cfg.norm_eps,
        )
    if "post_ln1" in prm:
        h = common.rms_norm(h, prm["post_ln1"], cfg.norm_eps)
    x = x + h

    f_in = common.rms_norm(x, prm["ln2"], cfg.norm_eps)
    if kind == "moe":
        f, _ = moe_forward(
            prm["ffn"], f_in, top_k=cfg.moe_top_k,
            capacity_factor=4.0, act=cfg.act,  # decode: tiny T, generous capacity
        )
        if cfg.moe_dense_residual:
            f = f + ffn_forward(prm["dense_ffn"], f_in, cfg.act)
    else:
        f = ffn_forward(prm["ffn"], f_in, cfg.act)
    if "post_ln2" in prm:
        f = common.rms_norm(f, prm["post_ln2"], cfg.norm_eps)
    return x + f, cache


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------


class TransformerLM:
    def __init__(self, cfg: ModelConfig, flash_blk: int = 512):
        self.cfg = cfg
        self.flash_blk = flash_blk
        self.shard_x = lambda t: t  # activation sharding hook (launcher-set)
        # segments: list of (kind, n_layers, global_layer_offset)
        if cfg.is_moe and cfg.first_dense_layers > 0:
            self.segments = [
                ("dense", cfg.first_dense_layers, 0),
                ("moe", cfg.n_layers - cfg.first_dense_layers, cfg.first_dense_layers),
            ]
        elif cfg.is_moe:
            self.segments = [("moe", cfg.n_layers, 0)]
        else:
            self.segments = [("dense", cfg.n_layers, 0)]

    # -- params ------------------------------------------------------------

    def init_params(self, key) -> Params:
        cfg = self.cfg
        dtype = common.dtype_of(cfg.dtype)
        keys = jax.random.split(key, len(self.segments) + 3)
        params: dict = {
            "embed": common.embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dtype),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = common.dense_init(
                keys[1], (cfg.d_model, cfg.vocab_size), dtype
            )
        for si, (kind, n, _off) in enumerate(self.segments):
            seg_keys = jax.random.split(keys[2 + si], n)
            params[f"seg{si}"] = jax.vmap(
                lambda k: _init_block(k, cfg, kind)
            )(seg_keys)
        if cfg.mtp_depth > 0:
            k = keys[-1]
            params["mtp"] = {
                "proj": common.dense_init(k, (2 * cfg.d_model, cfg.d_model), dtype),
                "block": jax.vmap(lambda kk: _init_block(kk, cfg, "dense"))(
                    jax.random.split(jax.random.fold_in(k, 1), 1)
                ),
                "ln": jnp.zeros((cfg.d_model,), dtype),
            }
        return params

    def _head(self, params):
        return (
            params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        )

    def embed_tokens(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.cfg.name.startswith("gemma"):
            x = x * jnp.asarray(np.sqrt(self.cfg.d_model), x.dtype)
        return x

    # -- forward (train / prefill) ------------------------------------------

    def hidden_states(self, params, x, positions, collect_cache: bool = False):
        """x: (B, S, d) embeddings.  Returns (hidden, caches, aux_sum)."""
        cfg = self.cfg
        caches = []
        aux_total = jnp.float32(0.0)
        x = self.shard_x(x)
        for si, (kind, n, off) in enumerate(self.segments):
            windows, thetas = layer_meta(cfg, n, off)

            def body(h, xs, _kind=kind):
                prm, window, theta = xs
                h2, kv, aux = _block_forward(
                    cfg, _kind, h, prm, window, theta, positions, self.flash_blk
                )
                out = (kv, aux) if collect_cache else (None, aux)
                return self.shard_x(h2), out

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, (kv, aux) = jax.lax.scan(body_fn, x, (params[f"seg{si}"], windows, thetas))
            aux_total = aux_total + jnp.sum(aux)
            if collect_cache:
                caches.append(kv)
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, caches, aux_total

    # -- losses --------------------------------------------------------------

    def loss_fn(self, params, batch) -> tuple[jnp.ndarray, dict]:
        """batch: {'tokens' (B,S) | 'embeds' (B,S,d), 'labels' (B,S)}."""
        cfg = self.cfg
        if cfg.embeddings_input:
            x = batch["embeds"]
        else:
            x = self.embed_tokens(params, batch["tokens"])
        b, s = x.shape[:2]
        positions = jnp.arange(s)
        hidden, _, aux = self.hidden_states(params, x, positions)
        head = self._head(params)
        loss = _chunked_ce(hidden, head, batch["labels"])
        metrics = {"ce": loss, "aux": aux}
        if cfg.is_moe:
            loss = loss + cfg.router_aux_coef * aux
        if cfg.mtp_depth > 0 and not cfg.embeddings_input:
            mtp_loss = self._mtp_loss(params, hidden, batch, positions)
            loss = loss + 0.3 * mtp_loss
            metrics["mtp"] = mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, hidden, batch, positions):
        """DeepSeek-V3 multi-token prediction (depth 1): one extra block over
        [h_t ; emb(t+1)] predicting token t+2."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        emb_next = self.embed_tokens(params, jnp.roll(tokens, -1, axis=1))
        h = jnp.concatenate([hidden, emb_next], axis=-1) @ params["mtp"]["proj"]
        windows, thetas = layer_meta(cfg, 1)
        prm1 = jax.tree.map(lambda a: a[0], params["mtp"]["block"])
        h, _, _ = _block_forward(
            cfg, "dense", h, prm1, windows[0], thetas[0], positions, self.flash_blk
        )
        h = common.rms_norm(h, params["mtp"]["ln"], cfg.norm_eps)
        labels2 = jnp.roll(labels, -1, axis=1)
        mask = jnp.ones_like(labels2, jnp.float32).at[:, -2:].set(0.0)
        return _chunked_ce(h, self._head(params), labels2, mask=mask)

    # -- serving --------------------------------------------------------------

    def prefill(self, params, batch):
        """Returns (last-token logits (B, V), cache pytree)."""
        cfg = self.cfg
        x = (
            batch["embeds"] if cfg.embeddings_input
            else self.embed_tokens(params, batch["tokens"])
        )
        positions = jnp.arange(x.shape[1])
        hidden, caches, _ = self.hidden_states(params, x, positions, collect_cache=True)
        logits = hidden[:, -1, :] @ self._head(params)
        return logits.astype(jnp.float32), caches

    def init_cache(self, batch: int, seq: int):
        cfg = self.cfg
        dtype = common.dtype_of(cfg.dtype)
        caches = []
        for _si, (_kind, n, _off) in enumerate(self.segments):
            if cfg.use_mla:
                caches.append(
                    (
                        jnp.zeros((n, batch, seq, cfg.kv_lora_rank), dtype),
                        jnp.zeros((n, batch, seq, cfg.qk_rope_dim), dtype),
                    )
                )
            else:
                kvh = (n, batch, seq, cfg.n_kv_heads, cfg.resolved_head_dim)
                caches.append((jnp.zeros(kvh, dtype), jnp.zeros(kvh, dtype)))
        return caches

    def decode_step(self, params, cache, token, pos):
        """token: (B,) int32 (or (B,1,d) embeds); pos: () int32.
        Returns (logits (B, V) fp32, new cache)."""
        cfg = self.cfg
        if cfg.embeddings_input and token.ndim == 3:
            x = token
        else:
            x = self.embed_tokens(params, token[:, None])
        new_caches = []
        x = self.shard_x(x)
        for si, (kind, n, off) in enumerate(self.segments):
            windows, thetas = layer_meta(cfg, n, off)

            def body(h, xs, _kind=kind):
                prm, c, window, theta = xs
                h2, c2 = _block_decode(cfg, _kind, h, prm, c, window, theta, pos)
                return self.shard_x(h2), c2

            x, c2 = jax.lax.scan(body, x, (params[f"seg{si}"], cache[si], windows, thetas))
            new_caches.append(c2)
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, 0, :] @ self._head(params)
        return logits.astype(jnp.float32), new_caches


def _chunked_ce(hidden, head, labels, mask=None, chunk: int = 512):
    """Cross entropy with the (B, chunk, V) logits block scanned over the
    sequence so the full (B, S, V) logits tensor never materializes
    (vocab up to 262 K)."""
    b, s, d = hidden.shape
    if s <= chunk or s % chunk:
        logits = hidden @ head
        return common.cross_entropy(logits, labels, mask)
    nc = s // chunk
    hs = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    ms = (
        mask.reshape(b, nc, chunk).transpose(1, 0, 2)
        if mask is not None
        else jnp.ones((nc, b, chunk), jnp.float32)
    )

    def step(acc, xs):
        hc, lc, mc = xs
        logits = (hc @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
