"""Explicit shard_map MoE: per-device routing + all_to_all dispatch.

Motivation (EXPERIMENTS.md §Perf D1): under pjit, GSPMD lowers the
token<->expert cross-shard gathers of `moe.moe_forward` as
mask + all-reduce of full (T*k, d) tensors — 7.3 TB/device/step on
deepseek-v3 train_4k.  The communication-optimal form is an all-to-all
over the `model` (EP) axis of only the dispatched capacity buffers.
shard_map expresses it directly:

  * tokens are blocked over every mesh axis (batch over data(+pod), seq
    over model): each device routes its own T_dev tokens locally;
  * each device builds a (E, C_dev, d) send buffer (local capacity
    C_dev = ceil(T_dev*k/E * cf) — GShard drop semantics per device);
  * one `lax.all_to_all` over `model` redistributes buffers so the owner
    of each expert shard receives its experts' tokens from all peers:
    bytes/device = 2 * E * C_dev * d — GB-scale, not TB-scale;
  * expert SwiGLU runs on the local (E_loc, M*C_dev, d) block with the
    locally-owned weights; the inverse all_to_all returns outputs; the
    combine is a local gather + (T_dev, k, d) reshape-sum.

Expert weights arrive as P('model', None, None) blocks (EP); router and
shared-expert weights are replicated — shard_map's transpose inserts the
correct psum for their gradients.

Capacity-semantics note: dropping is per-device here vs global in the
pjit path, so outputs are identical whenever nothing drops (verified in
tests with a generous capacity factor) and differ only in which
over-capacity tokens drop — both are valid GShard-style policies.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models import common
from repro.models.ffn import ffn_forward
from repro.models.moe import MoEParams


def _local_moe(
    xt,  # (T_dev, d) this device's tokens
    router,  # (d, E) replicated
    w_gate,  # (E_loc, d, f) this device's experts
    w_up,
    w_down,
    shared,  # FFNParams or None, replicated
    *,
    model_axis: str,
    all_axes: tuple,
    top_k: int,
    capacity_factor: float,
    act: str,
):
    t_dev, d = xt.shape
    e = router.shape[1]
    e_loc = w_gate.shape[0]
    m = e // e_loc  # model-axis group size

    logits = xt.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux loss over ALL tokens: pmean the FRACTIONS over every mesh axis
    # first, then form the product (product-of-global-means, matching the
    # pjit path; per-group products would average differently).
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)
    dispatch_frac = jax.lax.pmean(
        onehot.sum(axis=(0, 1)) / (t_dev * top_k), all_axes
    )
    prob_frac = jax.lax.pmean(probs.mean(axis=0), all_axes)
    aux = e * jnp.sum(dispatch_frac * prob_frac)

    # local rank-within-expert (small: T_dev*k x E ints)
    tk = t_dev * top_k
    flat_expert = gate_idx.reshape(tk)
    cum = jnp.cumsum(onehot.reshape(tk, e).astype(jnp.int32), axis=0)
    rank = jnp.take_along_axis(cum, flat_expert[:, None], axis=1)[:, 0] - 1
    c_dev = int(max(1, round(t_dev * top_k / e * capacity_factor)))
    keep = rank < c_dev
    dest = jnp.where(keep, flat_expert * c_dev + rank, e * c_dev)

    # narrow scatter of token ids -> gather rows (send buffer)
    flat_token = jnp.arange(tk, dtype=jnp.int32) // top_k
    buf_tok = (
        jnp.full((e * c_dev,), tk, jnp.int32).at[dest].set(flat_token, mode="drop")
    )
    valid = (buf_tok < tk)[:, None]
    send = jnp.where(
        valid, jnp.take(xt, jnp.minimum(buf_tok, t_dev - 1), axis=0), 0
    ).astype(xt.dtype)

    # dispatch: peer-transpose on axis 0 (symmetric split=concat=0 form —
    # the asymmetric-axes VJP mis-transposes in current jax)
    send = send.reshape(m, e_loc, c_dev, d)
    recv = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=0,
                              tiled=False)  # recv[j] = peer j's tokens for us
    recv = recv.transpose(1, 0, 2, 3).reshape(e_loc, m * c_dev, d)

    a = common.act_fn(act)
    h = a(jnp.einsum("ecd,edf->ecf", recv, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", recv, w_up
    )
    out = jnp.einsum("ecf,efd->ecd", h, w_down)  # (E_loc, M*C_dev, d)

    # inverse peer-transpose back to the senders
    out = out.reshape(e_loc, m, c_dev, d).transpose(1, 0, 2, 3)
    back = jax.lax.all_to_all(out, model_axis, split_axis=0, concat_axis=0,
                              tiled=False)
    back = back.reshape(e * c_dev, d)

    gathered = jnp.take(back, jnp.minimum(dest, e * c_dev - 1), axis=0)
    gathered = gathered * (gate_vals.reshape(-1) * keep)[:, None].astype(xt.dtype)
    y = gathered.reshape(t_dev, top_k, d).sum(axis=1)

    if shared is not None:
        y = y + ffn_forward(shared, xt, act)
    return y, aux


def make_shardmap_moe(mesh: Mesh, *, model_axis: str = "model") -> Callable:
    """Returns moe_forward(p, x, *, top_k, capacity_factor, act) drop-in.

    x must be (B, S, d) with batch over the data axes and seq over
    `model` — the activation_sharder layout.
    """
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    bspec = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)

    def moe_forward(p: MoEParams, x, *, top_k: int, capacity_factor: float = 1.25,
                    act: str = "silu"):
        b, s, d = x.shape

        all_axes = (model_axis,) + data_axes

        def block(xb, router, wg, wu, wd, shared):
            t_dev = xb.shape[0] * xb.shape[1]
            y, aux = _local_moe(
                xb.reshape(t_dev, d), router, wg, wu, wd, shared,
                model_axis=model_axis, all_axes=all_axes, top_k=top_k,
                capacity_factor=capacity_factor, act=act,
            )
            return y.reshape(xb.shape), aux

        fn = shard_map(
            block,
            mesh=mesh,
            in_specs=(
                P(bspec, model_axis, None),  # x
                P(None, None),  # router (replicated)
                P(model_axis, None, None),  # expert weights (EP)
                P(model_axis, None, None),
                P(model_axis, None, None),
                jax.tree.map(lambda _: P(None, None), p.shared),  # replicated
            ),
            out_specs=(P(bspec, model_axis, None), P()),
            check_rep=False,
        )
        return fn(x, p.router, p.w_gate, p.w_up, p.w_down, p.shared)

    return moe_forward
