"""Uniform model bundle: config -> (init, loss, prefill, decode, specs).

``build_model(cfg)`` returns an ``LMBundle`` whose members are what the
launcher, dry-run, trainer and server consume.  ``input_specs`` yields the
ShapeDtypeStruct stand-ins for every input of the given shape cell —
weak-type-correct, shardable, no device allocation.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeCell
from repro.models import common
from repro.models.encdec import EncDecLM
from repro.models.hybrid import HybridLM
from repro.models.rwkv_model import RWKVLM
from repro.models.transformer import TransformerLM


@dataclass
class LMBundle:
    cfg: ModelConfig
    model: Any
    init_params: Callable
    loss_fn: Callable  # (params, batch) -> (loss, metrics)
    prefill: Callable  # (params, batch) -> (logits, cache)
    decode_step: Callable  # (params, cache, token, pos) -> (logits, cache)
    init_cache: Callable  # (batch, seq) -> cache pytree

    # -- dry-run inputs -------------------------------------------------------

    def params_shape(self):
        return jax.eval_shape(self.init_params, jax.random.key(0))

    def cache_shape(self, batch: int, seq: int):
        return jax.eval_shape(lambda: self.init_cache(batch, seq))

    def input_specs(self, cell: ShapeCell) -> dict:
        """Shape stand-ins for one (arch x shape) dry-run cell."""
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        dt = common.dtype_of(cfg.dtype)
        i32 = jnp.int32
        if cell.kind == "train":
            if cfg.is_encoder_decoder:
                sd = max(64, s // 8)  # decoder tokens per frame window
                return {
                    "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                    "tokens": jax.ShapeDtypeStruct((b, sd), i32),
                    "labels": jax.ShapeDtypeStruct((b, sd), i32),
                }
            if cfg.embeddings_input:
                return {
                    "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                    "labels": jax.ShapeDtypeStruct((b, s), i32),
                }
            return {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        if cell.kind == "prefill":
            if cfg.is_encoder_decoder:
                sd = max(64, s // 8)
                return {
                    "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                    "tokens": jax.ShapeDtypeStruct((b, sd), i32),
                }
            if cfg.embeddings_input:
                return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
            return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        # decode: one new token against a seq_len cache
        return {
            "cache": self.cache_shape(b, s),
            "token": jax.ShapeDtypeStruct((b,), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }


def build_model(cfg: ModelConfig, flash_blk: int = 512) -> LMBundle:
    if cfg.family == "hybrid":
        m: Any = HybridLM(cfg, flash_blk)
    elif cfg.family == "ssm":
        m = RWKVLM(cfg)
    elif cfg.family == "audio":
        m = EncDecLM(cfg, flash_blk)
    else:  # dense | moe | vlm
        m = TransformerLM(cfg, flash_blk)
    return LMBundle(
        cfg=cfg,
        model=m,
        init_params=m.init_params,
        loss_fn=m.loss_fn,
        prefill=m.prefill,
        decode_step=m.decode_step,
        init_cache=m.init_cache,
    )
