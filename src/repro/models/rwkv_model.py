"""RWKV-6 language model: stacked (time-mix + channel-mix) blocks under
``lax.scan``; O(1) recurrent cache for decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import common
from repro.models.rwkv6 import (
    init_rwkv6_params,
    rwkv6_channel_mix,
    rwkv6_time_mix,
)


class RWKVLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.shard_x = lambda t: t  # activation sharding hook (launcher-set)

    def init_params(self, key):
        cfg = self.cfg
        dtype = common.dtype_of(cfg.dtype)
        k_embed, k_layers, k_head = jax.random.split(key, 3)
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        return {
            "embed": common.embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
            "ln_in": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_in_b": jnp.zeros((cfg.d_model,), jnp.float32),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
            "lm_head": common.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype),
            "layers": jax.vmap(lambda k: init_rwkv6_params(k, cfg, dtype))(layer_keys),
            "ln1": jnp.zeros((cfg.n_layers, cfg.d_model), dtype),
            "ln2": jnp.zeros((cfg.n_layers, cfg.d_model), dtype),
        }

    def hidden_states(self, params, x, collect_cache: bool = False):
        cfg = self.cfg

        def body(h, xs):
            prm, ln1, ln2 = xs
            a, (s_new, xp_att) = rwkv6_time_mix(
                prm, common.rms_norm(h, ln1, cfg.norm_eps), cfg
            )
            h = h + a
            f, xp_ffn = rwkv6_channel_mix(prm, common.rms_norm(h, ln2, cfg.norm_eps))
            h = h + f
            out = (s_new, xp_att, xp_ffn) if collect_cache else None
            return self.shard_x(h), out

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x = self.shard_x(x)
        x, cache = jax.lax.scan(body_fn, x, (params["layers"], params["ln1"], params["ln2"]))
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, cache

    def loss_fn(self, params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = common.layer_norm(x, params["ln_in"], params["ln_in_b"], self.cfg.norm_eps)
        hidden, _ = self.hidden_states(params, x)
        from repro.models.transformer import _chunked_ce

        loss = _chunked_ce(hidden, params["lm_head"], batch["labels"])
        return loss, {"ce": loss, "loss": loss}

    # -- serving ---------------------------------------------------------------

    def init_cache(self, batch: int, seq: int):
        cfg = self.cfg
        h = cfg.d_model // cfg.rwkv_head_dim
        return (
            jnp.zeros((cfg.n_layers, batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                      jnp.float32),
            jnp.zeros((cfg.n_layers, batch, cfg.d_model), common.dtype_of(cfg.dtype)),
            jnp.zeros((cfg.n_layers, batch, cfg.d_model), common.dtype_of(cfg.dtype)),
        )

    def prefill(self, params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = common.layer_norm(x, params["ln_in"], params["ln_in_b"], self.cfg.norm_eps)
        hidden, cache = self.hidden_states(params, x, collect_cache=True)
        logits = hidden[:, -1, :] @ params["lm_head"]
        return logits.astype(jnp.float32), cache

    def decode_step(self, params, cache, token, pos):
        cfg = self.cfg
        x = jnp.take(params["embed"], token[:, None], axis=0)
        x = common.layer_norm(x, params["ln_in"], params["ln_in_b"], cfg.norm_eps)

        def body(h, xs):
            prm, ln1, ln2, s0, xp_att, xp_ffn = xs
            a, (s_new, xp_att2) = rwkv6_time_mix(
                prm, common.rms_norm(h, ln1, cfg.norm_eps), cfg, state=(s0, xp_att)
            )
            h = h + a
            f, xp_ffn2 = rwkv6_channel_mix(
                prm, common.rms_norm(h, ln2, cfg.norm_eps), x_prev=xp_ffn
            )
            h = h + f
            return h, (s_new, xp_att2, xp_ffn2)

        x, cache = jax.lax.scan(
            body, x, (params["layers"], params["ln1"], params["ln2"], *cache)
        )
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, 0, :] @ params["lm_head"]
        return logits.astype(jnp.float32), cache
