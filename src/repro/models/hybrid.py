"""Zamba2-style hybrid: Mamba-2 backbone with a *shared* attention block
applied every ``cfg.shared_attn_period`` layers (arXiv:2411.15242).

Simplifications vs the HF checkpoint (noted in DESIGN.md): the shared
block reuses one full parameter set (the original adds per-invocation LoRA
deltas and concatenates the initial embeddings into its input); rotary
positions are used in the shared block.

Execution: outer ``lax.scan`` over groups (period mamba layers + one
shared-attn application), inner ``lax.scan`` over the mamba layers of the
group — params are stacked (G, P, ...) so HLO stays one group body.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import common
from repro.models.attention import attention_decode, attention_forward, init_attn_params
from repro.models.ffn import ffn_forward, init_ffn_params
from repro.models.mamba2 import (
    dims as mamba_dims,
    init_mamba2_params,
    mamba2_decode,
    mamba2_forward,
)


class HybridLM:
    def __init__(self, cfg: ModelConfig, flash_blk: int = 512):
        assert cfg.shared_attn_period > 0
        assert cfg.n_layers % cfg.shared_attn_period == 0
        self.cfg = cfg
        self.flash_blk = flash_blk
        self.n_groups = cfg.n_layers // cfg.shared_attn_period
        self.period = cfg.shared_attn_period
        self.shard_x = lambda t: t  # activation sharding hook (launcher-set)

    def init_params(self, key):
        cfg = self.cfg
        dtype = common.dtype_of(cfg.dtype)
        k_embed, k_mamba, k_attn, k_ffn, k_head = jax.random.split(key, 5)
        mamba_keys = jax.random.split(k_mamba, (self.n_groups, self.period))
        params = {
            "embed": common.embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
            "lm_head": common.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype),
            "mamba": jax.vmap(
                jax.vmap(lambda k: init_mamba2_params(k, cfg, dtype))
            )(mamba_keys),
            "mamba_ln": jnp.zeros((self.n_groups, self.period, cfg.d_model), dtype),
            # shared transformer block (one param set, applied n_groups times)
            "shared": {
                "ln1": jnp.zeros((cfg.d_model,), dtype),
                "attn": init_attn_params(
                    k_attn, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim, dtype,
                ),
                "ln2": jnp.zeros((cfg.d_model,), dtype),
                "ffn": init_ffn_params(k_ffn, cfg.d_model, cfg.d_ff, dtype),
            },
        }
        return params

    # -- full sequence -------------------------------------------------------

    def _shared_block(self, shared, x, positions):
        cfg = self.cfg
        h, kv = attention_forward(
            shared["attn"], common.rms_norm(x, shared["ln1"], cfg.norm_eps),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta, positions=positions, causal=True, window=0,
            norm_eps=cfg.norm_eps, flash_blk=self.flash_blk,
        )
        x = x + h
        x = x + ffn_forward(shared["ffn"], common.rms_norm(x, shared["ln2"], cfg.norm_eps))
        return x, kv

    def hidden_states(self, params, x, positions, collect_cache: bool = False):
        cfg = self.cfg
        shared = params["shared"]

        def group_body(h, xs):
            mparams, mlns = xs

            def mamba_body(hh, mxs):
                prm, ln = mxs
                out, state, tail = mamba2_forward(
                    prm, common.rms_norm(hh, ln, cfg.norm_eps), cfg
                )
                return hh + out, (state, tail) if collect_cache else None

            mamba_body_fn = jax.checkpoint(mamba_body) if cfg.remat else mamba_body
            h, states = jax.lax.scan(mamba_body_fn, h, (mparams, mlns))
            h, kv = self._shared_block(shared, h, positions)
            out = (states, kv) if collect_cache else None
            return self.shard_x(h), out

        x = self.shard_x(x)
        x, caches = jax.lax.scan(group_body, x, (params["mamba"], params["mamba_ln"]))
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, caches

    def loss_fn(self, params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        positions = jnp.arange(x.shape[1])
        hidden, _ = self.hidden_states(params, x, positions)
        from repro.models.transformer import _chunked_ce

        loss = _chunked_ce(hidden, params["lm_head"], batch["labels"])
        return loss, {"ce": loss, "loss": loss}

    # -- serving ---------------------------------------------------------------

    def init_cache(self, batch: int, seq: int):
        cfg = self.cfg
        dtype = common.dtype_of(cfg.dtype)
        di, h, conv_dim = mamba_dims(cfg)
        g, p = self.n_groups, self.period
        return {
            "ssm": jnp.zeros((g, p, batch, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((g, p, batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
            "k": jnp.zeros((g, batch, seq, cfg.n_kv_heads, cfg.resolved_head_dim), dtype),
            "v": jnp.zeros((g, batch, seq, cfg.n_kv_heads, cfg.resolved_head_dim), dtype),
        }

    def prefill(self, params, batch):
        cfg = self.cfg
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        positions = jnp.arange(x.shape[1])
        hidden, caches = self.hidden_states(params, x, positions, collect_cache=True)
        (states, tails), kv = caches  # (G,P,B,H,Pd,N), (G,P,B,W-1,C); ((G,B,S,KV,D) x2)
        logits = hidden[:, -1, :] @ params["lm_head"]
        cache = {"ssm": states, "conv": tails, "k": kv[0], "v": kv[1]}
        return logits.astype(jnp.float32), cache

    def decode_step(self, params, cache, token, pos):
        cfg = self.cfg
        shared = params["shared"]
        x = jnp.take(params["embed"], token[:, None], axis=0)

        def group_body(h, xs):
            mparams, mlns, ssm, conv, kc, vc = xs

            def mamba_body(hh, mxs):
                prm, ln, s1, c1 = mxs
                out, s2, c2 = mamba2_decode(
                    prm, common.rms_norm(hh, ln, cfg.norm_eps), s1, c1, cfg
                )
                return hh + out, (s2, c2)

            h, (ssm2, conv2) = jax.lax.scan(mamba_body, h, (mparams, mlns, ssm, conv))
            a, (kc2, vc2) = attention_decode(
                shared["attn"], common.rms_norm(h, shared["ln1"], cfg.norm_eps),
                kc, vc, pos,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                norm_eps=cfg.norm_eps,
            )
            h = h + a
            h = h + ffn_forward(shared["ffn"], common.rms_norm(h, shared["ln2"], cfg.norm_eps))
            return h, (ssm2, conv2, kc2, vc2)

        x, (ssm, conv, k, v) = jax.lax.scan(
            group_body, x,
            (params["mamba"], params["mamba_ln"], cache["ssm"], cache["conv"],
             cache["k"], cache["v"]),
        )
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, 0, :] @ params["lm_head"]
        return logits.astype(jnp.float32), {"ssm": ssm, "conv": conv, "k": k, "v": v}
