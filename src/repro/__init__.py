"""repro — X-TIME (CAM-based tree-ensemble inference) rebuilt as a JAX framework.

Public API surface:
    repro.core       the paper's contribution (tree training, CAM compile, engine)
    repro.kernels    Pallas TPU kernels (cam_match) + jnp oracles
    repro.models     LM substrate for the assigned architectures
    repro.configs    architecture registry (``get_config(name)``)
    repro.launch     mesh / dryrun / train / serve entry points
"""

__version__ = "1.0.0"
