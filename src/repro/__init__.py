"""repro — X-TIME (CAM-based tree-ensemble inference) rebuilt as a JAX framework.

Public API surface:
    repro.api        compiled-artifact API: ``build`` -> ``CompiledModel``
                     (save/load/engine) + ``DeployConfig``
    repro.ingest     zero-dependency importers: XGBoost-JSON / LightGBM-text /
                     sklearn-dict dumps -> ``ImportedEnsemble`` -> ``build``
    repro.core       the paper's contribution (tree training, CAM compile, engine)
    repro.kernels    Pallas TPU kernels (cam_match) + jnp oracles
    repro.serve      multi-model registry + micro-batching serve loop
    repro.models     LM substrate for the assigned architectures
    repro.configs    architecture registry (``get_config(name)``)
    repro.launch     mesh / dryrun / train / serve entry points

The artifact names resolve lazily (PEP 562) so ``import repro`` stays
dependency-free; ``repro.build(...)`` / ``repro.CompiledModel`` work
without importing jax until an engine is bound.
"""

__version__ = "1.0.0"

_LAZY = {
    "build": "repro.api",
    "CompiledModel": "repro.api",
    "DeployConfig": "repro.core.deploy",
}


def __getattr__(name: str):
    import importlib

    if name in _LAZY:
        value = getattr(importlib.import_module(_LAZY[name]), name)
        globals()[name] = value
        return value
    if name in ("api", "ingest"):
        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(_LAZY) | {"api", "ingest"})
