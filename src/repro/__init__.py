"""repro — X-TIME (CAM-based tree-ensemble inference) rebuilt as a JAX framework.

Public API surface (every name below is importable from ``repro``
directly; the README module map mirrors this list):

    repro.api        compiled-artifact API: ``build`` -> ``CompiledModel``
                     (save/load/predict/engine) + ``DeployConfig``
    repro.ingest     zero-dependency importers: XGBoost-JSON / LightGBM-text /
                     sklearn-dict dumps -> ``ImportedEnsemble`` -> ``build``
    repro.score      streaming offline batch scoring: artifact × columnar
                     file -> predictions at max rows/s (``score_file``)
    repro.core       the paper's contribution (tree training, CAM compile, engine)
    repro.kernels    Pallas TPU kernels (cam_match) + jnp oracles
    repro.serve      multi-model registry, micro-batching serve loop, and the
                     async ``ClusterServer`` tier with traffic replay
    repro.models     LM substrate for the assigned architectures
    repro.configs    architecture registry (``get_config(name)``)
    repro.launch     mesh / dryrun / train / serve entry points

The artifact names resolve lazily (PEP 562) so ``import repro`` stays
dependency-free; ``repro.build(...)`` / ``repro.CompiledModel`` work
without importing jax until an engine is bound.
"""

__version__ = "1.0.0"

# name -> defining module; resolved on first attribute access (PEP 562)
_LAZY = {
    # artifact API
    "build": "repro.api",
    "CompiledModel": "repro.api",
    "DeployConfig": "repro.core.deploy",
    "ChipSpec": "repro.core.compile",
    # cell-mode registry (hard + soft comparison modes, repro.core.precision)
    "CellMode": "repro.core.precision",
    "get_cell_mode": "repro.core.precision",
    # engine + tuning
    "XTimeEngine": "repro.core.engine",
    "autotune_kernel": "repro.core.tune",
    "TunePlan": "repro.core.tune",
    # quantization grid
    "FeatureQuantizer": "repro.core.quantize",
    # ingestion
    "load_model": "repro.ingest",
    # offline scoring
    "score_file": "repro.score",
    "ScoreResult": "repro.score",
    "open_columnar": "repro.score",
    # serving
    "TableRegistry": "repro.serve",
    "MicroBatcher": "repro.serve",
    "ServeLoop": "repro.serve",
    "ClusterServer": "repro.serve",
    "make_trace": "repro.serve",
    "replay_trace": "repro.serve",
}

#: submodules reachable as ``repro.<name>`` without an explicit import
_SUBMODULES = ("api", "ingest", "score", "serve", "core", "kernels", "launch")

__all__ = sorted(_LAZY) + ["__version__"]


def __getattr__(name: str):
    import importlib

    if name in _LAZY:
        value = getattr(importlib.import_module(_LAZY[name]), name)
        globals()[name] = value
        return value
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(_LAZY) | set(_SUBMODULES))
