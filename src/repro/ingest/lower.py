"""Threshold-grid lowering: ``ImportedEnsemble`` -> binned ``Ensemble``.

The paper's §III-B mapping, run in reverse of the native training path:
instead of quantile-binning data and training on bins, the imported
model's OWN split points become the per-feature grid
(``FeatureQuantizer.from_thresholds``), every float split ``x < v`` is
rewritten as the bin split ``bin < t`` with ``edges[t-1] == v``, and the
result is the exact ``Ensemble`` the X-TIME compiler already ingests.
On an unmerged grid the lowering is bit-exact:

    lowered.raw_margin(quantizer.transform(x)) == imported.raw_margin(x)

for every finite float query ``x`` (same float32 leaf values, same
float64 accumulation order).  When a feature carries more distinct
thresholds than the grid has edges, thresholds are merged
(nearest-edge remap) or the model is rejected — ``IngestReport``
records per-feature occupancy and every merged/remapped split, and
``repro.api.build`` attaches it to the artifact sidecar.

Per-channel base scores lower exactly: a uniform base becomes
``Ensemble.base_score`` (added once post-reduction by the engine), and
non-uniform bases become one single-leaf bias tree per nonzero channel
— an all-wildcard CAM row that matches every query.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.quantize import FeatureQuantizer
from repro.core.trees import Ensemble, Tree
from repro.ingest.ir import ImportedEnsemble, IngestError


@dataclass
class IngestReport:
    """Validation record of one lowering — serialized into the artifact
    sidecar so a served model carries its own provenance."""

    source: str  # importer that produced the IR
    source_kind: str  # gbdt | rf | dart
    task: str
    n_trees: int  # trees in the lowered ensemble (incl. bias/replicas)
    n_source_trees: int  # trees in the dump
    n_features: int
    n_bins: int
    exact: bool  # True => binned == float inference bit-for-bit
    merged_thresholds: int  # grid edges dropped to fit n_bins
    remapped_splits: int  # tree splits moved to a nearest kept edge
    bias_rows: int  # wildcard rows realizing per-channel base scores
    # per feature: {"feature", "thresholds", "capacity", "merged"}
    grid: list[dict] = field(default_factory=list)
    notes: list[str] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "source": self.source,
            "source_kind": self.source_kind,
            "task": self.task,
            "n_trees": self.n_trees,
            "n_source_trees": self.n_source_trees,
            "n_features": self.n_features,
            "n_bins": self.n_bins,
            "exact": self.exact,
            "merged_thresholds": self.merged_thresholds,
            "remapped_splits": self.remapped_splits,
            "bias_rows": self.bias_rows,
            "grid": self.grid,
            "notes": list(self.notes),
        }

    def occupancy_summary(self) -> str:
        used = [g for g in self.grid if g["thresholds"]]
        if not used:
            return "no splits"
        peak = max(g["thresholds"] for g in used)
        return (f"{len(used)}/{self.n_features} features split, "
                f"peak {peak}/{self.n_bins - 1} edges"
                + ("" if self.exact else
                   f", {self.remapped_splits} splits remapped (INEXACT)"))


def lower_to_ensemble(
    imported: ImportedEnsemble,
    n_bins: int = 256,
    on_overflow: str = "merge",
) -> tuple[Ensemble, FeatureQuantizer, IngestReport]:
    """Lower a parsed model onto an ``n_bins`` grid built from its own
    thresholds.  Returns ``(ensemble, quantizer, report)``."""
    thresholds = imported.thresholds_per_feature()
    try:
        quantizer, merged = FeatureQuantizer.from_thresholds(
            thresholds, n_bins=n_bins, on_overflow=on_overflow
        )
    except ValueError as e:
        raise IngestError(f"{imported.source}: {e}") from None

    remapped = 0
    trees: list[Tree] = []
    for tree in imported.trees:
        bin_t = np.zeros(tree.n_nodes, dtype=np.int32)
        for j in np.flatnonzero(tree.feature >= 0):
            t, exact = quantizer.bin_of_threshold(
                int(tree.feature[j]), float(tree.threshold[j])
            )
            bin_t[j] = t
            remapped += not exact
        trees.append(Tree(
            feature=tree.feature.copy(),
            threshold=bin_t,
            left=tree.left.copy(),
            right=tree.right.copy(),
            value=tree.value.astype(np.float32),
        ))
    tree_class = imported.tree_class.copy()

    # base scores: scalar if uniform, wildcard bias rows otherwise
    bias_rows = 0
    if imported.uniform_base:
        base = float(imported.base_score[0])
    else:
        base = 0.0
        from repro.ingest.ir import single_leaf_tree

        bias_classes = []
        for c in range(imported.n_outputs):
            if imported.base_score[c] != 0.0:
                bias = single_leaf_tree(float(imported.base_score[c]))
                trees.append(Tree(
                    feature=bias.feature, threshold=np.zeros(1, np.int32),
                    left=bias.left, right=bias.right,
                    value=bias.value.astype(np.float32),
                ))
                bias_classes.append(c)
                bias_rows += 1
        tree_class = np.concatenate(
            [tree_class, np.asarray(bias_classes, dtype=np.int32)]
        )

    ensemble = Ensemble(
        trees=trees,
        n_features=imported.n_features,
        n_bins=quantizer.n_bins,
        task=imported.task,  # type: ignore[arg-type]
        kind="gbdt",  # imported margins are always sums (ir.py docstring)
        n_classes=imported.n_classes,
        tree_class=tree_class,
        base_score=base,
        leaf_class_mode="tree",
        n_outputs_override=imported.n_outputs,
    )

    cap = quantizer.n_bins - 1
    report = IngestReport(
        source=imported.source,
        source_kind=imported.source_kind,
        task=imported.task,
        n_trees=len(trees),
        n_source_trees=imported.n_trees,
        n_features=imported.n_features,
        n_bins=quantizer.n_bins,
        exact=(remapped == 0),
        merged_thresholds=int(sum(merged)),
        remapped_splits=remapped,
        bias_rows=bias_rows,
        grid=[
            {"feature": f, "thresholds": int(th.shape[0]), "capacity": cap,
             "merged": int(m)}
            for f, (th, m) in enumerate(zip(thresholds, merged))
        ],
        notes=list(imported.notes),
    )
    return ensemble, quantizer, report
