"""XGBoost JSON importer/exporter (``Booster.save_model('model.json')``).

Zero-dependency: parses the documented JSON schema directly — the
container never needs xgboost installed.  Supported:

  * boosters: ``gbtree`` and ``dart`` (per-tree ``weight_drop`` folded
    into the leaf values at import, so DART inference is exact).
  * objectives: ``reg:squarederror``/``reg:linear`` (regression),
    ``reg:logistic``/``binary:logistic`` (single-logit binary; the saved
    probability-space ``base_score`` is mapped to margin space with
    logit, mirroring ``ObjFunction::ProbToMargin``), ``binary:logitraw``,
    ``multi:softmax``/``multi:softprob`` (one tree per class per round,
    classes from ``tree_info``).

Rejected with a clear ``IngestError``: categorical splits
(``split_type != 0`` / non-empty ``categories_nodes`` — XGBoost's
partition sets are not representable on the threshold grid without the
library's category codes), ``gblinear``, ranking objectives, and
multi-target leaf vectors (``size_leaf_vector > 1``).

Split convention: XGBoost descends LEFT when ``x < split_condition``
(strict), which is already the IR convention — thresholds pass through
untouched.  Missing-value ``default_left`` routing is NOT modeled: the
engine serves finite features (the quantizer bins NaN to the lowest
bin), so importers record a note instead of silently diverging.

``to_xgboost_json`` is the inverse: it exports a native binned
``Ensemble`` (optionally through a ``FeatureQuantizer`` for float-space
thresholds) into this same schema — the round-trip property test and
the golden-fixture generator both use it.
"""

from __future__ import annotations

import json
import math
from pathlib import Path

import numpy as np

from repro.ingest.ir import ImportedEnsemble, ImportedTree, IngestError

_REGRESSION = ("reg:squarederror", "reg:linear", "reg:squaredlogerror",
               "reg:pseudohubererror", "reg:absoluteerror")
_LOGISTIC = ("binary:logistic", "reg:logistic")
_BINARY_RAW = ("binary:logitraw",)
_MULTI = ("multi:softmax", "multi:softprob")


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise IngestError(f"xgboost-json: {msg}")


def _parse_tree(t: dict, idx: int, weight: float) -> ImportedTree:
    _require(isinstance(t, dict), f"tree {idx} is not an object")
    for key in ("left_children", "right_children", "split_indices",
                "split_conditions"):
        _require(key in t, f"tree {idx} missing {key!r}")
    left = np.asarray(t["left_children"], dtype=np.int32)
    right = np.asarray(t["right_children"], dtype=np.int32)
    split_idx = np.asarray(t["split_indices"], dtype=np.int64)
    cond = np.asarray(t["split_conditions"], dtype=np.float64)
    if t.get("categories_nodes") or any(st != 0 for st in t.get("split_type", ())):
        raise IngestError(
            "xgboost-json: categorical splits (split_type=1) are not "
            "supported — export the model with numeric-encoded features"
        )
    size_leaf = int(t.get("tree_param", {}).get("size_leaf_vector", "1") or 1)
    _require(size_leaf <= 1, f"tree {idx}: multi-target leaf vectors unsupported")
    is_leaf = left < 0
    # split_conditions doubles as the leaf value at leaf nodes
    feature = np.where(is_leaf, -1, split_idx).astype(np.int32)
    threshold = np.where(is_leaf, 0.0, cond)
    value = np.where(is_leaf, cond * weight, 0.0)
    return ImportedTree(
        feature=feature,
        threshold=threshold,
        left=left,
        right=np.where(is_leaf, -1, right).astype(np.int32),
        value=value,
    )


def import_xgboost_json(doc: dict | str | Path) -> ImportedEnsemble:
    """Parse an XGBoost ``save_model`` JSON document (dict, text, or path)."""
    if isinstance(doc, (str, Path)):
        p = Path(doc)
        text = p.read_text() if p.exists() else str(doc)
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as e:
            raise IngestError(f"xgboost-json: not valid JSON ({e})") from None
    _require(isinstance(doc, dict) and "learner" in doc,
             "missing top-level 'learner' (is this a Booster.save_model dump?)")
    learner = doc["learner"]
    booster = learner.get("gradient_booster", {})
    name = booster.get("name", "gbtree")

    weights: np.ndarray | None = None
    if name == "dart":
        weights = np.asarray(booster.get("weight_drop", ()), dtype=np.float64)
        booster = booster.get("gbtree", booster)
        name = "dart"
    elif name != "gbtree":
        raise IngestError(
            f"xgboost-json: booster {name!r} unsupported (gbtree/dart only)"
        )
    model = booster.get("model", booster)
    trees_json = model.get("trees")
    _require(isinstance(trees_json, list) and trees_json,
             "no trees under gradient_booster.model.trees")
    if weights is not None:
        _require(len(weights) == len(trees_json),
                 "dart weight_drop length != number of trees")

    mp = learner.get("learner_model_param", {})
    n_features = int(mp.get("num_feature", 0) or 0)
    num_class = int(mp.get("num_class", 0) or 0)
    base_raw = float(mp.get("base_score", 0.0) or 0.0)
    objective = learner.get("objective", {}).get("name", "reg:squarederror")

    if objective in _REGRESSION:
        task, n_outputs, base = "regression", 1, base_raw
    elif objective in _LOGISTIC:
        _require(0.0 < base_raw < 1.0,
                 f"base_score {base_raw} outside (0,1) for {objective}")
        task, n_outputs = "binary", 1
        base = math.log(base_raw / (1.0 - base_raw))  # ProbToMargin
    elif objective in _BINARY_RAW:
        task, n_outputs, base = "binary", 1, base_raw
    elif objective in _MULTI:
        _require(num_class >= 2, f"{objective} needs num_class >= 2")
        task, n_outputs, base = "multiclass", num_class, base_raw
    else:
        raise IngestError(
            f"xgboost-json: objective {objective!r} unsupported "
            f"(supported: {_REGRESSION + _LOGISTIC + _BINARY_RAW + _MULTI})"
        )

    tree_info = model.get("tree_info") or [0] * len(trees_json)
    _require(len(tree_info) == len(trees_json),
             "tree_info length != number of trees")
    trees = [
        _parse_tree(t, i, float(weights[i]) if weights is not None else 1.0)
        for i, t in enumerate(trees_json)
    ]
    if not n_features:  # older dumps leave num_feature=0; infer from splits
        n_features = 1 + max(
            (int(t.feature.max(initial=-1)) for t in trees), default=-1
        )
        _require(n_features > 0, "cannot infer num_feature (no splits)")

    notes = []
    if any(t.get("default_left") and any(t["default_left"]) for t in trees_json):
        notes.append("default_left missing-value routing ignored "
                     "(serve finite features)")
    if weights is not None:
        notes.append(f"dart: {len(weights)} weight_drop factors folded into leaves")
    return ImportedEnsemble(
        trees=trees,
        n_features=n_features,
        task=task,
        n_outputs=n_outputs,
        tree_class=np.asarray(tree_info, dtype=np.int32),
        base_score=np.full(n_outputs, base, dtype=np.float64),
        source="xgboost-json",
        source_kind="dart" if weights is not None else "gbdt",
        n_classes=(num_class if task == "multiclass"
                   else (2 if task == "binary" else 1)),
        notes=notes,
    )


# ---------------------------------------------------------------------------
# Export: native binned Ensemble -> the same JSON schema
# ---------------------------------------------------------------------------


def to_xgboost_json(ens, quantizer=None) -> dict:
    """Serialize a native GBDT ``Ensemble`` as an XGBoost-JSON dump.

    Bin-split ``bin < t`` becomes float split ``x < thr`` with
    ``thr = quantizer.threshold_value(f, t)`` when a quantizer is given
    (float-space export), else ``thr = float(t)`` (bin indices are the
    feature space).  Re-importing yields bit-identical margins — the
    hypothesis round-trip in tests/test_ingest.py.
    """
    if ens.kind != "gbdt" or ens.leaf_class_mode != "tree":
        raise IngestError("to_xgboost_json: only GBDT tree-class ensembles")
    if ens.task == "regression":
        objective, base, num_class = "reg:squarederror", ens.base_score, 0
    elif ens.task == "binary":
        objective, num_class = "binary:logitraw", 0
        base = ens.base_score  # logitraw keeps margin space: exact round trip
    else:
        objective, base, num_class = "multi:softprob", ens.base_score, ens.n_classes

    trees_json = []
    for tree in ens.trees:
        is_leaf = tree.feature < 0
        cond = np.where(
            is_leaf,
            tree.value.astype(np.float64),
            [0.0 if lf else (
                float(quantizer.threshold_value(int(f), int(t))) if quantizer
                else float(t))
             for lf, f, t in zip(is_leaf, tree.feature, tree.threshold)],
        )
        n = tree.n_nodes
        trees_json.append({
            "base_weights": [0.0] * n,
            "categories": [], "categories_nodes": [],
            "categories_segments": [], "categories_sizes": [],
            "default_left": [0] * n,
            "id": len(trees_json),
            "left_children": tree.left.tolist(),
            "loss_changes": [0.0] * n,
            "parents": [2147483647] * n,
            "right_children": tree.right.tolist(),
            "split_conditions": [float(c) for c in cond],
            "split_indices": np.maximum(tree.feature, 0).tolist(),
            "split_type": [0] * n,
            "sum_hessian": [0.0] * n,
            "tree_param": {
                "num_deleted": "0", "num_feature": str(ens.n_features),
                "num_nodes": str(n), "size_leaf_vector": "1",
            },
        })
    tree_class = (ens.tree_class if ens.tree_class is not None
                  else np.zeros(ens.n_trees, dtype=np.int32))
    return {
        "learner": {
            "attributes": {},
            "feature_names": [], "feature_types": [],
            "gradient_booster": {
                "model": {
                    "gbtree_model_param": {
                        "num_parallel_tree": "1",
                        "num_trees": str(len(trees_json)),
                    },
                    "tree_info": [int(c) for c in tree_class],
                    "trees": trees_json,
                },
                "name": "gbtree",
            },
            "learner_model_param": {
                "base_score": repr(float(base)),
                "boost_from_average": "1",
                "num_class": str(num_class),
                "num_feature": str(ens.n_features),
                "num_target": "1",
            },
            "objective": {"name": objective},
        },
        "version": [2, 0, 0],
    }
