"""LightGBM model-text importer (``Booster.save_model('model.txt')``).

Zero-dependency parser for the key=value text format: a header block
(``num_class``, ``num_tree_per_iteration``, ``max_feature_idx``,
``objective``), one ``Tree=i`` block per tree, terminated by
``end of trees``.

Node encoding (LightGBM internal): internal nodes are indexed
``0..num_leaves-2``; a negative child ``c`` means leaf ``~c``.  Numerical
splits descend LEFT when ``x <= threshold`` — normalized to the IR's
strict ``<`` via ``nextafter(threshold, +inf)`` (exact: no double lies
between them).

Categorical splits (``decision_type & 1``) are LOWERED TO THRESHOLD
SETS: the bitset of member categories (``cat_threshold`` words sliced by
``cat_boundaries``) is decomposed into maximal runs of consecutive
integer codes ``[a, b]``, and the split node is rewritten as a chain of
interval tests ``(x < a-0.5 ? nonmember : x < b+0.5 ? member : next
run)``.  Subtrees referenced by several chain nodes are duplicated when
the nested structure is flattened back to arrays — each duplicated leaf
is one extra CAM row, the exact §III-A cost of a union-of-intervals
match, and the ingest report records the expansion.

Shrinkage is already folded into ``leaf_value`` by LightGBM; multiclass
models interleave classes (``tree_class[i] = i % num_tree_per_iteration``).
Missing-value default directions are ignored (finite-feature serving),
recorded as a note.
"""

from __future__ import annotations

from pathlib import Path

import numpy as np

from repro.ingest.ir import ImportedEnsemble, ImportedTree, IngestError


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise IngestError(f"lightgbm-text: {msg}")


def _kv_block(lines: list[str], where: str) -> dict[str, str]:
    out: dict[str, str] = {}
    for ln in lines:
        if "=" in ln:
            k, _, v = ln.partition("=")
            out[k.strip()] = v.strip()
        elif ln.strip() and where == "header":
            out.setdefault("_magic", ln.strip())
    return out


def _ints(s: str) -> np.ndarray:
    return np.asarray(s.split(), dtype=np.int64) if s else np.zeros(0, np.int64)


def _floats(s: str) -> np.ndarray:
    return np.asarray(s.split(), dtype=np.float64) if s else np.zeros(0, np.float64)


def _member_categories(bitset: np.ndarray) -> np.ndarray:
    """Decode a LightGBM uint32-word bitset into sorted category codes."""
    cats = []
    for w, word in enumerate(bitset):
        word = int(word) & 0xFFFFFFFF
        while word:
            b = (word & -word).bit_length() - 1
            cats.append(w * 32 + b)
            word &= word - 1
    return np.asarray(cats, dtype=np.int64)


def _runs(cats: np.ndarray) -> list[tuple[int, int]]:
    """Maximal runs [a, b] of consecutive integers."""
    runs: list[tuple[int, int]] = []
    for c in cats:
        if runs and c == runs[-1][1] + 1:
            runs[-1] = (runs[-1][0], int(c))
        else:
            runs.append((int(c), int(c)))
    return runs


def _categorical_chain(runs: list[tuple[int, int]], member, nonmember) -> dict:
    """Nested threshold nodes testing membership in a union of integer
    runs.  ``member``/``nonmember`` subtrees are shared by reference here;
    flattening duplicates them."""
    node: dict = nonmember  # falls through every run => not a member
    for a, b in reversed(runs):
        inside = {"f": None, "t": b + 0.5, "l": member, "r": node}
        node = {"f": None, "t": a - 0.5, "l": nonmember, "r": inside}
    return node


class _TreeBuilder:
    """Parses one Tree= block into nested dict nodes, then flattens
    (duplicating shared categorical subtrees) into an ImportedTree."""

    def __init__(self, block: dict[str, str], idx: int) -> None:
        self.idx = idx
        self.n_expanded = 0
        for key in ("num_leaves", "leaf_value"):
            _require(key in block, f"Tree={idx} missing {key!r}")
        self.num_leaves = int(block["num_leaves"])
        self.leaf_value = _floats(block["leaf_value"])
        _require(self.leaf_value.shape[0] == self.num_leaves,
                 f"Tree={idx}: leaf_value length != num_leaves")
        n_int = self.num_leaves - 1
        self.split_feature = _ints(block.get("split_feature", ""))
        self.threshold = _floats(block.get("threshold", ""))
        self.decision_type = _ints(block.get("decision_type", "")) \
            if block.get("decision_type") else np.zeros(n_int, np.int64)
        self.left = _ints(block.get("left_child", ""))
        self.right = _ints(block.get("right_child", ""))
        for name, arr in (("split_feature", self.split_feature),
                          ("threshold", self.threshold),
                          ("decision_type", self.decision_type),
                          ("left_child", self.left),
                          ("right_child", self.right)):
            _require(arr.shape[0] == n_int,
                     f"Tree={idx}: {name} length {arr.shape[0]} != {n_int}")
        self.cat_boundaries = _ints(block.get("cat_boundaries", ""))
        self.cat_threshold = _ints(block.get("cat_threshold", ""))

    def _child(self, c: int) -> dict:
        if c < 0:
            return {"leaf": float(self.leaf_value[~c])}
        return self._node(int(c))

    def _node(self, j: int) -> dict:
        _require(0 <= j < self.num_leaves - 1,
                 f"Tree={self.idx}: internal node index {j} out of range")
        f = int(self.split_feature[j])
        left, right = self._child(int(self.left[j])), self._child(int(self.right[j]))
        if int(self.decision_type[j]) & 1:  # categorical
            cat_idx = int(self.threshold[j])
            _require(0 <= cat_idx and cat_idx + 2 <= len(self.cat_boundaries),
                     f"Tree={self.idx}: cat_boundaries missing slot {cat_idx}")
            lo, hi = int(self.cat_boundaries[cat_idx]), int(self.cat_boundaries[cat_idx + 1])
            cats = _member_categories(self.cat_threshold[lo:hi])
            _require(cats.size > 0,
                     f"Tree={self.idx}: empty categorical bitset at node {j}")
            runs = _runs(cats)
            self.n_expanded += 1
            chain = _categorical_chain(runs, member=left, nonmember=right)
            return {"f": f, "t": chain["t"], "l": chain["l"], "r": chain["r"]}
        # numerical: x <= t goes left  ->  x < nextafter(t, +inf)
        return {"f": f, "t": float(np.nextafter(self.threshold[j], np.inf)),
                "l": left, "r": right}

    def build(self) -> ImportedTree:
        if self.num_leaves == 1:  # constant tree
            root: dict = {"leaf": float(self.leaf_value[0])}
        else:
            root = self._node(0)
        feature, threshold, left, right, value = [], [], [], [], []

        def emit(node: dict, cat_f: int | None = None) -> int:
            pos = len(feature)
            feature.append(-1); threshold.append(0.0)
            left.append(-1); right.append(-1); value.append(0.0)
            if "leaf" in node:
                value[pos] = node["leaf"]
                return pos
            f = node["f"] if node["f"] is not None else cat_f
            feature[pos] = int(f)
            threshold[pos] = float(node["t"])
            # chain nodes created by the categorical expansion carry f=None
            # and inherit the categorical split's feature index
            left[pos] = emit(node["l"], cat_f=f)
            right[pos] = emit(node["r"], cat_f=f)
            return pos

        emit(root)
        return ImportedTree(
            feature=np.asarray(feature, dtype=np.int32),
            threshold=np.asarray(threshold, dtype=np.float64),
            left=np.asarray(left, dtype=np.int32),
            right=np.asarray(right, dtype=np.int32),
            value=np.asarray(value, dtype=np.float64),
        )


def import_lightgbm_text(doc: str | Path) -> ImportedEnsemble:
    """Parse a LightGBM ``save_model`` text dump (text or path)."""
    if isinstance(doc, Path) or (isinstance(doc, str) and "\n" not in doc
                                 and Path(doc).exists()):
        doc = Path(doc).read_text()
    lines = doc.splitlines()
    _require(any(ln.strip() == "tree" for ln in lines[:5]),
             "missing 'tree' magic in header (is this Booster.save_model text?)")

    # split into blank-line-separated blocks; Tree=i blocks carry trees
    blocks: list[list[str]] = [[]]
    for ln in lines:
        if ln.strip():
            blocks[-1].append(ln)
        elif blocks[-1]:
            blocks.append([])
    header = _kv_block(blocks[0], "header")
    tree_blocks = [b for b in blocks if b and b[0].startswith("Tree=")]
    _require(bool(tree_blocks), "no Tree= blocks found")
    _require(any(ln.strip() == "end of trees" for b in blocks for ln in b),
             "missing 'end of trees' terminator (truncated dump?)")

    n_features = int(header.get("max_feature_idx", -1)) + 1
    _require(n_features > 0, "missing max_feature_idx")
    num_class = int(header.get("num_class", 1))
    per_iter = int(header.get("num_tree_per_iteration", 1))
    objective = header.get("objective", "regression")

    if objective.startswith(("binary",)):
        task, n_outputs = "binary", 1
    elif objective.startswith(("multiclass", "multiclassova")):
        _require(num_class >= 2, "multiclass objective with num_class < 2")
        task, n_outputs = "multiclass", num_class
    elif objective.startswith(("regression", "mape", "huber", "fair",
                               "poisson", "quantile", "gamma", "tweedie")):
        task, n_outputs = "regression", 1
    else:
        raise IngestError(
            f"lightgbm-text: objective {objective!r} unsupported "
            "(binary / multiclass / regression families only)"
        )

    trees, n_expanded = [], 0
    for i, b in enumerate(tree_blocks):
        builder = _TreeBuilder(_kv_block(b, f"Tree={i}"), i)
        trees.append(builder.build())
        n_expanded += builder.n_expanded
    tree_class = (np.arange(len(trees)) % per_iter if n_outputs > 1
                  else np.zeros(len(trees))).astype(np.int32)
    _require(n_outputs == 1 or per_iter == n_outputs,
             f"num_tree_per_iteration={per_iter} != num_class={num_class}")

    notes = []
    if n_expanded:
        notes.append(f"{n_expanded} categorical splits lowered to "
                     "threshold-interval chains")
    if any(int(d) & ~1 for b in tree_blocks
           for d in _kv_block(b, "t").get("decision_type", "").split()):
        notes.append("missing-value default directions ignored "
                     "(serve finite features)")
    return ImportedEnsemble(
        trees=trees,
        n_features=n_features,
        task=task,
        n_outputs=n_outputs,
        tree_class=tree_class,
        base_score=np.zeros(n_outputs, dtype=np.float64),
        source="lightgbm-text",
        source_kind="gbdt",
        n_classes=(num_class if task == "multiclass"
                   else (2 if task == "binary" else 1)),
        notes=notes,
    )
