"""Ingestion frontend: serialized model dumps -> the X-TIME pipeline.

Zero-dependency importers for the three dump formats real tabular
models ship in — none of the source libraries is needed at runtime:

  * :func:`import_xgboost_json`  — ``xgb.Booster.save_model('m.json')``
    (gbtree + dart, reg/binary/multiclass objectives, base_score)
  * :func:`import_lightgbm_text` — ``lgb.Booster.save_model('m.txt')``
    (numerical + categorical splits, the latter lowered to threshold
    interval chains)
  * :func:`import_sklearn_dict`  — the documented ``sklearn-forest``
    JSON schema over the public ``tree_`` arrays (RF averaging and
    GBDT summing)

Each importer yields the float-threshold :class:`ImportedEnsemble` IR;
:func:`lower_to_ensemble` maps it bit-exactly onto a binned ``Ensemble``
via a grid built from the model's own split points (§III-B), ready for
``repro.api.build`` — which also accepts the IR or a dump path
directly.  ``scripts/ingest.py`` is the CLI over the same pipeline.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.ingest.ir import ImportedEnsemble, ImportedTree, IngestError
from repro.ingest.lightgbm_text import import_lightgbm_text
from repro.ingest.lower import IngestReport, lower_to_ensemble
from repro.ingest.sklearn_dict import import_sklearn_dict
from repro.ingest.xgboost_json import import_xgboost_json, to_xgboost_json

__all__ = [
    "ImportedEnsemble",
    "ImportedTree",
    "IngestError",
    "IngestReport",
    "detect_format",
    "import_lightgbm_text",
    "import_sklearn_dict",
    "import_xgboost_json",
    "load_model",
    "lower_to_ensemble",
    "to_xgboost_json",
]

FORMATS = ("xgboost-json", "lightgbm-text", "sklearn-dict")

_IMPORTERS = {
    "xgboost-json": import_xgboost_json,
    "lightgbm-text": import_lightgbm_text,
    "sklearn-dict": import_sklearn_dict,
}


def _detect(text: str, where: str) -> tuple[str, dict | str]:
    """(format, parsed-or-raw payload) from dump content.

    Content decides, not the extension: a JSON booster saved as ``.txt``
    still routes to the JSON parsers.  Returns the parsed dict for JSON
    formats so callers parse the (possibly huge) dump exactly once.
    """
    head = text[:4096].lstrip()
    if head.startswith("{"):
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as e:
            raise IngestError(f"{where}: not valid JSON ({e})") from None
        if "learner" in doc:
            return "xgboost-json", doc
        if doc.get("format") == "sklearn-forest":
            return "sklearn-dict", doc
        raise IngestError(
            f"{where}: JSON dump is neither xgboost-json (no 'learner') "
            "nor sklearn-forest (no matching 'format')"
        )
    if head.startswith("tree"):
        return "lightgbm-text", text
    raise IngestError(f"{where}: unrecognized dump format")


def detect_format(path: str | Path) -> str:
    """Sniff a dump's format from its content."""
    p = Path(path)
    return _detect(p.read_text(errors="replace"), str(p))[0]


def load_model(path: str | Path, format: str = "auto") -> ImportedEnsemble:
    """Parse a model dump into the ingestion IR (format auto-detected).

    The file is read (and, for JSON formats, parsed) exactly once.
    """
    p = Path(path)
    if not p.exists():
        raise IngestError(f"model dump not found: {p}")
    if format != "auto" and format not in _IMPORTERS:
        raise IngestError(
            f"unknown format {format!r}; expected one of {FORMATS} or 'auto'"
        )
    text = p.read_text(errors="replace")
    if format == "auto":
        fmt, payload = _detect(text, str(p))
    else:  # an explicit format is a contract; skip the sniffer entirely
        fmt = format
        if fmt == "lightgbm-text":
            payload: dict | str = text
        else:
            try:
                payload = json.loads(text)
            except json.JSONDecodeError as e:
                raise IngestError(f"{p}: not valid JSON ({e})") from None
    return _IMPORTERS[fmt](payload)
