"""Common ingestion IR: float-threshold trees from any source library.

Every importer (XGBoost JSON, LightGBM text, sklearn dict) parses its
dump into one ``ImportedEnsemble`` — trees over *float* feature space
with a single normalized split convention:

    x[feature] < threshold  ->  left child

Library conventions are normalized at parse time: XGBoost already splits
on strict ``<``; LightGBM and sklearn split on ``<=``, which parsers
rewrite as ``x < nextafter(t, +inf)`` (exact — no float value lies
between ``t`` and its successor).  Categorical splits are expanded into
threshold chains by the LightGBM parser (see ``lightgbm_text``), so the
IR itself is purely numerical.

Aggregation is always a SUM over trees plus per-channel ``base_score``:
averaging sources (random forests) pre-scale their leaf values by
``1/n_trees`` at parse time, so ``raw_margin`` has identical semantics
for every source.  ``raw_margin``/``predict`` here are the float-space
*reference* traversal; the bit-exact serving path is the lowering in
``ingest.lower`` onto the binned ``Ensemble`` + CAM engine.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


class IngestError(ValueError):
    """A model dump that cannot be parsed or lowered."""


@dataclass
class ImportedTree:
    """One tree in normalized float space (strict-< splits)."""

    feature: np.ndarray  # (n_nodes,) int32, -1 => leaf
    threshold: np.ndarray  # (n_nodes,) float64, split: x < threshold
    left: np.ndarray  # (n_nodes,) int32
    right: np.ndarray  # (n_nodes,) int32
    value: np.ndarray  # (n_nodes,) float64 leaf contribution

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_leaves(self) -> int:
        return int((self.feature < 0).sum())

    def validate(self, n_features: int, where: str = "tree") -> None:
        """Structural checks shared by every parser (clear errors beat
        downstream index crashes on malformed dumps)."""
        n = self.n_nodes
        arrays = (self.feature, self.threshold, self.left, self.right, self.value)
        if n == 0 or any(a.shape != (n,) for a in arrays):
            raise IngestError(f"{where}: node arrays empty or length-mismatched")
        internal = self.feature >= 0
        if np.any(self.feature[internal] >= n_features):
            raise IngestError(
                f"{where}: split feature index >= n_features={n_features}"
            )
        kids = np.concatenate([self.left[internal], self.right[internal]])
        if kids.size and (kids.min(initial=0) < 0 or kids.max(initial=0) >= n):
            raise IngestError(f"{where}: child index out of range [0, {n})")
        if not np.all(np.isfinite(self.threshold[internal])):
            raise IngestError(f"{where}: non-finite split threshold")
        # every node reachable exactly once from the root => it is a tree
        seen = np.zeros(n, dtype=bool)
        stack = [0]
        while stack:
            j = stack.pop()
            if seen[j]:
                raise IngestError(f"{where}: node {j} reached twice (cycle/DAG)")
            seen[j] = True
            if self.feature[j] >= 0:
                stack.append(int(self.left[j]))
                stack.append(int(self.right[j]))
        if not seen.all():
            raise IngestError(f"{where}: {int((~seen).sum())} unreachable nodes")

    def leaf_ids(self, x: np.ndarray) -> np.ndarray:
        """Float-space traversal: leaf node index per row of ``x``."""
        node = np.zeros(x.shape[0], dtype=np.int32)
        active = self.feature[node] >= 0
        while active.any():
            f = self.feature[node]
            t = self.threshold[node]
            go_left = x[np.arange(x.shape[0]), np.maximum(f, 0)] < t
            nxt = np.where(go_left, self.left[node], self.right[node])
            node = np.where(active, nxt, node)
            active = self.feature[node] >= 0
        return node


def single_leaf_tree(value: float) -> ImportedTree:
    """A constant tree (one all-wildcard CAM row after lowering) — used to
    realize per-class base scores exactly."""
    return ImportedTree(
        feature=np.asarray([-1], dtype=np.int32),
        threshold=np.zeros(1, dtype=np.float64),
        left=np.asarray([-1], dtype=np.int32),
        right=np.asarray([-1], dtype=np.int32),
        value=np.asarray([value], dtype=np.float64),
    )


@dataclass
class ImportedEnsemble:
    """A parsed model dump, normalized and ready for grid lowering.

    ``tree_class[i]`` is the margin channel tree ``i`` sums into.
    ``base_score`` is per-channel (scalar bases broadcast); sources with
    per-class intercepts (sklearn GBDT ``init``) keep them exact here and
    the lowering emits one wildcard CAM row per distinct extra channel.
    """

    trees: list[ImportedTree]
    n_features: int
    task: str  # 'regression' | 'binary' | 'multiclass'
    n_outputs: int  # margin channels (1 logit, or C probability/vote lanes)
    tree_class: np.ndarray  # (n_trees,) int32
    base_score: np.ndarray  # (n_outputs,) float64
    source: str  # 'xgboost-json' | 'lightgbm-text' | 'sklearn-dict'
    source_kind: str = "gbdt"  # provenance: 'gbdt' | 'rf' | 'dart'
    n_classes: int = 1
    notes: list[str] = field(default_factory=list)

    def __post_init__(self) -> None:
        if self.task not in ("regression", "binary", "multiclass"):
            raise IngestError(f"unsupported task {self.task!r}")
        if len(self.trees) == 0:
            raise IngestError(f"{self.source}: model has no trees")
        self.tree_class = np.asarray(self.tree_class, dtype=np.int32)
        if self.tree_class.shape != (len(self.trees),):
            raise IngestError("tree_class must have one entry per tree")
        if self.tree_class.size and (
            self.tree_class.min() < 0 or self.tree_class.max() >= self.n_outputs
        ):
            raise IngestError("tree_class entry outside [0, n_outputs)")
        self.base_score = np.broadcast_to(
            np.asarray(self.base_score, dtype=np.float64), (self.n_outputs,)
        ).copy()
        for i, t in enumerate(self.trees):
            t.validate(self.n_features, where=f"{self.source} tree {i}")

    @property
    def n_trees(self) -> int:
        return len(self.trees)

    def thresholds_per_feature(self) -> list[np.ndarray]:
        """Sorted unique split thresholds per feature — the input to
        ``FeatureQuantizer.from_thresholds`` (§III-B grid mapping)."""
        per: list[list[float]] = [[] for _ in range(self.n_features)]
        for t in self.trees:
            for f, v in zip(t.feature, t.threshold):
                if f >= 0:
                    per[int(f)].append(float(v))
        return [np.unique(np.asarray(v, dtype=np.float64)) for v in per]

    @property
    def uniform_base(self) -> bool:
        """True when every channel shares one base score — lowered as the
        scalar ``Ensemble.base_score``; otherwise each nonzero channel
        becomes a float32 wildcard bias row (``single_leaf_tree``)."""
        return bool(np.all(self.base_score == self.base_score[0]))

    def effective_base(self) -> np.ndarray:
        """Per-channel base as the lowered path realizes it (float64
        scalar broadcast, or float32-rounded bias rows)."""
        if self.uniform_base:
            return self.base_score
        return self.base_score.astype(np.float32).astype(np.float64)

    # -- float-space reference (validation only; serving goes via lowering) --

    def raw_margin(self, x: np.ndarray) -> np.ndarray:
        """(n, n_outputs) float32 margins, float64 accumulation — the same
        accumulation order/width as ``Ensemble.raw_margin`` so the lowered
        binned path is bit-identical."""
        x = np.asarray(x, dtype=np.float64)
        out = np.zeros((x.shape[0], self.n_outputs), dtype=np.float64)
        for i, tree in enumerate(self.trees):
            vals = tree.value.astype(np.float32)[tree.leaf_ids(x)]
            out[:, int(self.tree_class[i])] += vals
        out += self.effective_base()
        return out.astype(np.float32)

    def predict(self, x: np.ndarray) -> np.ndarray:
        margin = self.raw_margin(x)
        if self.task == "regression":
            return margin[:, 0]
        if margin.shape[1] == 1:  # single-logit binary
            return (margin[:, 0] > 0.0).astype(np.int32)
        return np.argmax(margin, axis=1).astype(np.int32)
