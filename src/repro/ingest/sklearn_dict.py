"""Generic sklearn-forest dict importer.

scikit-learn has no portable dump format, so this repo defines one — a
JSON document mirroring the public ``tree_`` arrays, producible with a
five-line export loop and no sklearn on the serving side:

    {"format": "sklearn-forest",
     "kind": "rf" | "gbdt",
     "task": "regression" | "binary" | "multiclass",
     "n_features": F, "n_classes": C,
     "learning_rate": 0.1,          # gbdt only (default 1.0)
     "init": 0.0 | [b_0, ..., b_C],  # gbdt intercept(s) (default 0)
     "trees": [
       {"feature": tree_.feature,            # < 0 (sklearn: -2) => leaf
        "threshold": tree_.threshold,        # x <= threshold -> left
        "children_left": tree_.children_left,
        "children_right": tree_.children_right,
        "value": tree_.value,   # (n_nodes,) scalar, or (n_nodes, C)
                                # class counts/probabilities for rf
        "class": 0}]}           # gbdt multiclass: channel of this tree

Lowering semantics (all exact):

  * ``gbdt``: leaf = value * learning_rate, summed; per-class ``init``
    intercepts become base scores (wildcard bias rows when they differ).
  * ``rf`` regression: leaf = value / n_trees, summed == forest mean.
  * ``rf`` classification: each tree's per-leaf class-count rows are
    normalized to probabilities and the tree is REPLICATED per class —
    class c's copy carries leaf = p(c) / n_trees on channel c.  The
    summed margins equal sklearn's averaged ``predict_proba`` exactly,
    so ``argmax`` matches ``predict``; CAM rows grow by the factor C
    (recorded in the ingest report).

``<=`` splits are normalized to strict ``<`` with nextafter, like the
LightGBM importer.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.ingest.ir import ImportedEnsemble, ImportedTree, IngestError

FORMAT = "sklearn-forest"


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise IngestError(f"sklearn-dict: {msg}")


def _tree_arrays(t: dict, idx: int) -> tuple[np.ndarray, ...]:
    for key in ("feature", "threshold", "children_left", "children_right",
                "value"):
        _require(key in t, f"tree {idx} missing {key!r}")
    feature = np.asarray(t["feature"], dtype=np.int32)
    feature = np.where(feature < 0, -1, feature)  # sklearn leaf marker is -2
    threshold = np.asarray(t["threshold"], dtype=np.float64)
    left = np.asarray(t["children_left"], dtype=np.int32)
    right = np.asarray(t["children_right"], dtype=np.int32)
    value = np.asarray(t["value"], dtype=np.float64)
    # x <= t -> left  ==>  x < nextafter(t, +inf) -> left
    threshold = np.where(feature >= 0, np.nextafter(threshold, np.inf), 0.0)
    return feature, threshold, left, right, value


def import_sklearn_dict(doc: dict | str | Path) -> ImportedEnsemble:
    """Parse a sklearn-forest dict dump (dict, JSON text, or path)."""
    if isinstance(doc, (str, Path)):
        p = Path(doc)
        text = p.read_text() if p.exists() else str(doc)
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as e:
            raise IngestError(f"sklearn-dict: not valid JSON ({e})") from None
    _require(isinstance(doc, dict), "dump is not a JSON object")
    _require(doc.get("format") == FORMAT,
             f"format {doc.get('format')!r} != {FORMAT!r}")
    kind = doc.get("kind")
    task = doc.get("task")
    _require(kind in ("rf", "gbdt"), f"kind {kind!r} not in ('rf', 'gbdt')")
    _require(task in ("regression", "binary", "multiclass"),
             f"task {task!r} unsupported")
    n_features = int(doc.get("n_features", 0))
    _require(n_features > 0, "missing/zero n_features")
    n_classes = int(doc.get("n_classes", 1))
    _require(task != "multiclass" or n_classes >= 2,
             "task 'multiclass' needs n_classes >= 2")
    trees_json = doc.get("trees")
    _require(isinstance(trees_json, list) and trees_json, "no trees")
    lr = float(doc.get("learning_rate", 1.0))
    n_trees = len(trees_json)
    notes: list[str] = []

    trees: list[ImportedTree] = []
    tree_class: list[int] = []

    if kind == "rf" and task != "regression":
        C = max(2, n_classes)
        n_outputs = C
        for i, t in enumerate(trees_json):
            feature, threshold, left, right, value = _tree_arrays(t, i)
            _require(value.ndim == 2 and value.shape[1] == C,
                     f"tree {i}: rf classifier value must be (n_nodes, "
                     f"{C}) class counts")
            row_sum = value.sum(axis=1, keepdims=True)
            _require(bool(np.all(row_sum[feature < 0] > 0)),
                     f"tree {i}: leaf with empty class-count row")
            proba = value / np.where(row_sum > 0, row_sum, 1.0)
            for c in range(C):  # one channel-c copy per class
                trees.append(ImportedTree(
                    feature=feature, threshold=threshold, left=left,
                    right=right,
                    value=np.where(feature < 0, proba[:, c] / n_trees, 0.0),
                ))
                tree_class.append(c)
        base = np.zeros(n_outputs)
        notes.append(
            f"rf classifier: {n_trees} trees replicated x{C} classes "
            "(margins == averaged predict_proba)"
        )
        source_kind = "rf"
    else:
        n_outputs = n_classes if task == "multiclass" else 1
        scale = lr if kind == "gbdt" else 1.0 / n_trees
        for i, t in enumerate(trees_json):
            feature, threshold, left, right, value = _tree_arrays(t, i)
            if value.ndim == 2:
                _require(value.shape[1] == 1,
                         f"tree {i}: expected scalar leaf values")
                value = value[:, 0]
            trees.append(ImportedTree(
                feature=feature, threshold=threshold, left=left, right=right,
                value=np.where(feature < 0, value * scale, 0.0),
            ))
            c = int(t.get("class", 0))
            _require(0 <= c < n_outputs,
                     f"tree {i}: class {c} outside [0, {n_outputs})")
            tree_class.append(c)
        init = doc.get("init", 0.0) if kind == "gbdt" else 0.0
        base = np.broadcast_to(
            np.asarray(init, dtype=np.float64), (n_outputs,)
        ).copy()
        if kind == "rf":
            notes.append(f"rf regression: leaves pre-scaled by 1/{n_trees} "
                         "(margins == forest mean)")
        source_kind = kind

    return ImportedEnsemble(
        trees=trees,
        n_features=n_features,
        task=task,
        n_outputs=n_outputs,
        tree_class=np.asarray(tree_class, dtype=np.int32),
        base_score=base,
        source="sklearn-dict",
        source_kind=source_kind,
        n_classes=(n_classes if task == "multiclass"
                   else (2 if task == "binary" else 1)),
        notes=notes,
    )
