"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 — 5:1 local:global interleaving, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,  # gemma3 decouples head_dim from d_model/n_heads
    d_ff=6912,
    vocab_size=262144,
    rope_theta=10_000.0,  # local layers
    rope_theta_global=1_000_000.0,  # global layers
    sliding_window=512,
    local_global_period=6,  # 5 local : 1 global
    qk_norm=True,
    act="gelu",
    tie_embeddings=True,
    supports_long_context=True,  # 25/26 layers are 512-window; 1/6 global
    notes=(
        "long_500k runs: local layers cap their KV at the 512-token window; "
        "global layers hold the full cache, sequence-sharded on `model`."
    ),
    source="hf:google/gemma-3-1b-pt",
))


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, sliding_window=16, local_global_period=2,
        remat=False,
    )
