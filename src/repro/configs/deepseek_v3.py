"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff(expert)=2048
vocab=129280, MoE 256e top-8 — MLA, 1 shared + 256 routed experts, MTP.
[arXiv:2412.19437; hf]
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,  # dense layers' ffn (first 3)
    vocab_size=129280,
    rope_theta=10_000.0,
    act="silu",
    # MoE
    n_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    dense_d_ff=18432,
    capacity_factor=1.25,
    # MLA
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    # MTP
    mtp_depth=1,
    supports_long_context=False,
    notes=(
        "long_500k skipped: full (MLA) attention. Decode uses the "
        "weight-absorbed MLA path with the compressed (512+64)/token cache, "
        "sequence-sharded on `model`. MTP = depth-1 extra block (aux loss)."
    ),
    source="arXiv:2412.19437",
))


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, n_experts=8, n_shared_experts=1, moe_top_k=2,
        moe_d_ff=32, first_dense_layers=1, dense_d_ff=128,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, mtp_depth=1, remat=False,
    )
