"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (shared attn) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    rope_theta=10_000.0,
    act="silu",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=128,
    shared_attn_period=6,  # one shared attn block every 6 mamba layers
    supports_long_context=True,  # SSM state is O(1) in sequence length
    notes=(
        "Shared attention block reuses one param set every 6 mamba layers "
        "(HF adds per-invocation LoRA deltas + embedding concat — "
        "simplified, see DESIGN.md). long_500k runs via the recurrent path."
    ),
    source="arXiv:2411.15242",
))


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
        shared_attn_period=2, remat=False,
    )
