"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 — 128 experts top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,  # dense residual branch
    vocab_size=32000,
    rope_theta=10_000.0,
    act="silu",
    n_experts=128,
    moe_top_k=2,
    moe_d_ff=4864,
    moe_dense_residual=True,  # dense FFN in parallel with the MoE branch
    capacity_factor=1.25,
    supports_long_context=False,
    notes="long_500k skipped: pure full attention.",
    source="hf:Snowflake/snowflake-arctic-base",
))


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=96,
        vocab_size=512, n_experts=8, moe_top_k=2, moe_d_ff=96, remat=False,
    )
