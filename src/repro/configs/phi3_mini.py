"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU.  [arXiv:2404.14219; unverified]
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,  # full MHA per the assignment line
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10_000.0,
    act="silu",
    supports_long_context=False,
    notes="long_500k skipped: pure full attention (assignment skip rule).",
    source="arXiv:2404.14219",
))


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=96, n_heads=8, n_kv_heads=8, d_ff=192,
        vocab_size=512, remat=False,
    )
