"""Architecture registry: importing this package registers every config.

``--arch <id>`` in the launchers resolves through ``repro.config.get_config``.
"""

from repro.configs import (  # noqa: F401
    gemma3_1b,
    phi3_mini,
    granite_20b,
    llama32_3b,
    deepseek_v3,
    arctic_480b,
    zamba2_2p7b,
    llava_next_mistral,
    rwkv6_1p6b,
    whisper_tiny,
    xtime_tabular,
)

ASSIGNED_ARCHS = [
    "gemma3-1b",
    "phi3-mini-3.8b",
    "granite-20b",
    "llama3.2-3b",
    "deepseek-v3-671b",
    "arctic-480b",
    "zamba2-2.7b",
    "llava-next-mistral-7b",
    "rwkv6-1.6b",
    "whisper-tiny",
]

ALL_ARCHS = ASSIGNED_ARCHS + ["xtime-tabular"]
