"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — Finch, data-dependent decay.  [arXiv:2404.05892; unverified]
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,  # wkv heads = d_model / rwkv_head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    act="relu",  # squared relu in channel mixing
    rwkv_head_dim=64,
    supports_long_context=True,  # O(1) recurrent state
    notes=(
        "Token-shift lerp uses static per-channel mu (RWKV-5 style); the "
        "signature data-dependent decay w_t keeps its full LoRA form "
        "(DESIGN.md). long_500k runs via the recurrent path."
    ),
    source="arXiv:2404.05892",
))


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, d_ff=224, vocab_size=512, rwkv_head_dim=16,
        n_heads=4, n_kv_heads=4, remat=False,
    )
