"""granite-20b [dense]: 52L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576
vocab=49152 — llama-arch code model.  [arXiv:2405.04324; hf]
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,  # MQA
    d_ff=24576,
    vocab_size=49152,
    rope_theta=10_000.0,
    act="silu",
    supports_long_context=False,
    notes="long_500k skipped: pure full attention. MQA (kv=1): decode cache "
          "is sequence-sharded on `model` (cannot shard 1 KV head).",
    source="arXiv:2405.04324",
))


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=96, n_heads=8, n_kv_heads=1, d_ff=192,
        vocab_size=512, remat=False,
    )
