"""whisper-tiny [audio]: 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865
— enc-dec, conv frontend (stub).  [arXiv:2212.04356; unverified]
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,  # decoder layers
    n_encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    is_encoder_decoder=True,
    embeddings_input=True,  # frame embeddings from the stubbed conv frontend
    supports_long_context=False,
    notes=(
        "Conv/mel frontend stubbed: encoder consumes precomputed (B, T, d) "
        "frame embeddings. Decoder tokens per cell = seq_len/8. long_500k "
        "skipped: full-attention decoder. vocab 51865 is not divisible by "
        "the 16-way model axis — embed stays replicated on `model` (the "
        "partitioner's divisibility fit)."
    ),
    source="arXiv:2212.04356",
))


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, remat=False,
    )
