"""xtime-tabular: the paper's own workload as the 11th selectable config.

A maximum-size ensemble per the paper's hardware constraint search
(§V-A 'X-TIME 8bit'): N_trees=4096, N_leaves,max=256, N_feat=130 (the
gas-concentration outlier width), 8-bit bins — CAM rows sharded on the
mesh `model` axis, query batch on `data`(×`pod`), NoC reduction = psum.
"""

from repro.config import XTimeConfig, register

CONFIG = register(XTimeConfig(
    name="xtime-tabular",
    n_trees=4096,
    max_leaves=256,
    n_features=130,
    n_bins=256,
    n_classes=8,
    task="multiclass",
))


def smoke() -> XTimeConfig:
    import dataclasses

    return dataclasses.replace(CONFIG, n_trees=64, max_leaves=32, n_features=16,
                               n_classes=3)
