"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000 — anyres tiling frontend (STUB: ``input_specs``
provides precomputed patch/text embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    act="silu",
    embeddings_input=True,  # anyres vision tower + projector stubbed
    supports_long_context=False,
    notes=(
        "Backbone = mistral-7b. Modality frontend is a stub per the "
        "assignment: inputs are precomputed (B, S, d) embeddings mixing "
        "image patches and text. long_500k skipped: full attention."
    ),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
))


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=96, n_heads=8, n_kv_heads=2, d_ff=192,
        vocab_size=512, remat=False,
    )
