"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3.  [hf:meta-llama/Llama-3.2-1B; unverified]
"""

from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    act="silu",
    supports_long_context=False,
    notes="long_500k skipped: pure full attention.",
    source="hf:meta-llama/Llama-3.2-1B",
))


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_ff=192,
        vocab_size=512, remat=False,
    )
