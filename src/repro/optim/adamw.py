"""AdamW in pure JAX pytrees: global-norm clipping, decoupled weight decay
(matrix params only), warmup+cosine schedule, configurable moment dtype
(bf16 moments halve optimizer HBM for the 671B dry-run cells).

ZeRO-1 note: moments inherit each parameter's sharding (params are already
FSDP-sharded over `data`), so optimizer state is fully sharded with no
extra machinery; the update is elementwise and stays local.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"  # 'bfloat16' halves optimizer memory


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def _decay_mask(params: Any) -> Any:
    """Weight decay on >=2-D weights only (norms/biases/scalars exempt)."""
    return jax.tree.map(lambda p: jnp.asarray(float(p.ndim >= 2), jnp.float32), params)


class AdamW:
    def __init__(self, cfg: AdamWConfig):
        self.cfg = cfg

    def init(self, params: Any) -> dict:
        mdt = jnp.bfloat16 if self.cfg.moment_dtype == "bfloat16" else jnp.float32
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(
        self, grads: Any, state: dict, params: Any
    ) -> tuple[Any, dict, dict]:
        cfg = self.cfg
        step = state["step"] + 1
        lr = lr_schedule(cfg, step)

        # global-norm clip in fp32
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
        )
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
        decay = _decay_mask(params)

        def upd(g, m, v, p, dmask):
            gf = g.astype(jnp.float32) * scale
            m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
            v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
            mhat = m2 / bc1
            vhat = v2 / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * dmask * p.astype(
                jnp.float32
            )
            p2 = p.astype(jnp.float32) - lr * delta
            return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

        # flatten (NamedTuple leaves make tuple-based unzipping unsafe)
        g_l, treedef = jax.tree.flatten(grads)
        m_l = jax.tree.leaves(state["m"])
        v_l = jax.tree.leaves(state["v"])
        p_l = jax.tree.leaves(params)
        d_l = jax.tree.leaves(decay)
        outs = [upd(*args) for args in zip(g_l, m_l, v_l, p_l, d_l)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_state = {
            "m": treedef.unflatten([o[1] for o in outs]),
            "v": treedef.unflatten([o[2] for o in outs]),
            "step": step,
        }
        return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
