"""Gradient compression with error feedback (1-bit-Adam-style int8 variant).

For cross-pod gradient reduction the wire format is int8 + one fp32 scale
per tensor; the quantization error is carried in a residual buffer and
added back next step (error feedback), which keeps convergence unbiased.
Used by the trainer's ``compress_grads='int8'`` mode: gradients are
quantized before the (slow) pod-axis reduction and dequantized after,
cutting pod-link bytes 4x at bf16 (§Perf collective-term lever).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, residual: Any | None = None):
    """Returns ((q_tree, scale_tree), new_residual)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    corrected = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual
    )
    # flatten: NamedTuple params make tuple-leaf unzipping unsafe
    c_l, treedef = jax.tree.flatten(corrected)
    qs = [quantize_int8(c) for c in c_l]
    q = treedef.unflatten([t[0] for t in qs])
    s = treedef.unflatten([t[1] for t in qs])
    new_residual = treedef.unflatten(
        [c - dequantize_int8(qq, ss) for c, (qq, ss) in zip(c_l, qs)]
    )
    return (q, s), new_residual


def decompress_tree(q: Any, s: Any, like: Any):
    return jax.tree.map(
        lambda qq, ss, g: dequantize_int8(qq, ss).astype(g.dtype), q, s, like
    )
