from repro.optim.adamw import AdamW, AdamWConfig, lr_schedule  # noqa: F401
from repro.optim.compress import (  # noqa: F401
    quantize_int8,
    dequantize_int8,
    compress_tree,
    decompress_tree,
)
