"""Programmable H-tree NoC (§III-D) and its mapping onto mesh collectives.

The physical chip connects 4096 cores through a radix-4 H-tree (1365
routers) to a co-processor.  Each router has one config bit:

    1 = accumulate   incoming leaf flits are summed before forwarding
                     (regression / binary classification, Fig. 7a)
    0 = forward      flits pass through untouched; the CP reduces
                     globally (multiclass, Fig. 7b)

Input batching (Fig. 7c) replicates the model across core groups and sets
the bits to accumulate *below* the replication boundary and forward above
it.

On the TPU mesh, the same three programs become collective plans:
  accumulate        -> psum over the `model` axis (ICI all-reduce is an
                       in-network reduction tree, like the H-tree)
  forward           -> per-class partial sums kept as channels; one psum
                       of the (B, n_classes) block (numerically identical,
                       but the traffic model differs — more flits/sample)
  batch             -> table replicated; batch sharded over `model` too;
                       no cross-core reduction (replica groups)
  hybrid            -> the 2-D batch × core program for large meshes: rows
                       shard over `model` AND the batch over every axis;
                       queries all-gather along `model` into each row
                       shard, partial margins psum_scatter back — an
                       all-reduce split into its gather/reduce-scatter
                       halves so no device ever holds a replicated output
                       block.  (A mesh-level extension of Fig. 7c, not a
                       router program the 1365-router chip can express —
                       shard_map only, see DESIGN.md §8.)

This module computes the router program + traffic statistics for the perf
model, and the collective plan used by ``XTimeEngine``.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.compile import CAMTable, ChipSpec, CorePlacement

# engine noc_config -> the explicit collective(s) the shard_map path
# issues over the row axis (introspection for benches/examples/docs)
ENGINE_COLLECTIVES = {
    "accumulate": "psum",
    "batch": "none (replica groups)",
    "hybrid": "all_gather + psum_scatter",
}


@dataclass
class NoCPlan:
    config: str  # 'accumulate' | 'forward' | 'batch'
    n_levels: int  # H-tree depth
    router_bits: list[int]  # per level, 1=accumulate 0=forward
    n_classes: int
    replication: int
    flits_per_sample_per_level: list[float]  # upward traffic at each level
    engine_noc_config: str  # XTimeEngine noc_config string
    reduction_axes: tuple[str, ...]  # mesh axes the reduction spans

    @property
    def flits_per_sample(self) -> float:
        return float(sum(self.flits_per_sample_per_level))

    @property
    def cp_ops_per_sample(self) -> int:
        """Reduction work left for the co-processor."""
        if self.config == "forward":
            # class-wise sums over the forwarded streams + argmax
            return self.n_classes + 1
        return 1  # threshold compare / identity


def plan_noc(
    table: CAMTable,
    placement: CorePlacement,
    *,
    spec: ChipSpec | None = None,
    batching: bool = True,
) -> NoCPlan:
    """Derive the router program for a compiled + placed model."""
    spec = spec or placement.spec
    n_levels = int(round(np.log(spec.n_cores) / np.log(spec.noc_radix)))
    n_used = placement.n_cores_used
    repl = placement.replication if batching else 1

    multiclass = table.task == "multiclass" or (
        table.kind == "rf" and table.n_outputs > 1
    )

    if multiclass:
        # Fig. 7(b): logits of *different* classes cannot be summed in a
        # router.  The compiler places same-class trees in contiguous core
        # subtrees, accumulates inside each class subtree (bits=1) and
        # forwards the n_classes streams above it (bits=0) — this yields
        # the paper's stated throughput bound of 1/N_classes samples per
        # clock at the root.
        config = "forward"
        cores_per_class = max(1, int(np.ceil(n_used / max(1, table.n_outputs))))
        boundary = int(np.ceil(np.log(cores_per_class) / np.log(spec.noc_radix)))
        boundary = min(boundary, n_levels)
        bits = [1] * boundary + [0] * (n_levels - boundary)
        # per-level upward flits per sample on the busiest link
        flits = [1.0] * boundary + [float(table.n_outputs)] * (n_levels - boundary)
        engine_cfg = "accumulate"  # numerics: per-class channels then psum
    elif repl > 1 and batching:
        # Fig. 7(c): accumulate below the replication boundary, forward above.
        config = "batch"
        boundary = max(1, int(np.ceil(np.log(max(1, n_used)) / np.log(spec.noc_radix))))
        bits = [1] * boundary + [0] * (n_levels - boundary)
        flits = [1.0] * boundary + [1.0] * (n_levels - boundary)
        engine_cfg = "batch"
    else:
        # Fig. 7(a): pure accumulate.
        config = "accumulate"
        bits = [1] * n_levels
        flits = [1.0] * n_levels  # one running-sum flit per router output
        engine_cfg = "accumulate"
    return NoCPlan(
        config=config,
        n_levels=n_levels,
        router_bits=bits,
        n_classes=table.n_outputs,
        replication=repl,
        flits_per_sample_per_level=flits,
        engine_noc_config=engine_cfg,
        reduction_axes=("model",) if engine_cfg != "batch" else (),
    )
