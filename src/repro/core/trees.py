"""Decision-tree ensembles trained on pre-binned features.

The container has no xgboost / lightgbm / scikit-learn, so the training
substrate the paper depends on (XGBoost-style gradient boosting and random
forests, §II-A) is implemented here from scratch:

  * ``train_gbdt`` — histogram-based second-order gradient boosting
    (XGBoost-style gain, leaf-wise best-first growth, lr shrinkage,
    row/column subsampling), for regression / binary / multiclass.
  * ``train_rf``   — bagged CART forests (multi-output variance reduction,
    equivalent to gini up to a constant for one-hot targets), leaves store
    the majority class or the mean.

Both trainers operate directly on **binned** features (uint8/uint16 bin
indices from ``quantize.FeatureQuantizer``) — exactly the paper's setting
where thresholds live on an 8-bit grid (§V-A, 'X-TIME 8bit').  Split
convention: ``bin < t`` goes left, so in float space ``x < edges[t-1]``
goes left; the quantizer uses the same convention, making binned inference
bit-identical to float inference.

Trees are stored as flat arrays (struct-of-arrays), the same tabular node
format the X-TIME compiler ingests (§II-D).
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Literal

import numpy as np

Task = Literal["regression", "binary", "multiclass"]


# ---------------------------------------------------------------------------
# Tree container
# ---------------------------------------------------------------------------


@dataclass
class Tree:
    """Array-based binary tree over binned features.

    Internal node j: if ``x_bins[feature[j]] < threshold[j]`` descend to
    ``left[j]`` else ``right[j]``.  Leaf j has ``feature[j] == -1`` and
    prediction ``value[j]`` (scalar logit / target).
    """

    feature: np.ndarray  # (n_nodes,) int32, -1 => leaf
    threshold: np.ndarray  # (n_nodes,) int32 bin split point, in [1, n_bins-1]
    left: np.ndarray  # (n_nodes,) int32
    right: np.ndarray  # (n_nodes,) int32
    value: np.ndarray  # (n_nodes,) float32

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_leaves(self) -> int:
        return int((self.feature < 0).sum())

    @property
    def max_depth(self) -> int:
        depth = np.zeros(self.n_nodes, dtype=np.int32)
        best = 0
        for j in range(self.n_nodes):  # parents precede children by construction
            if self.feature[j] >= 0:
                depth[self.left[j]] = depth[j] + 1
                depth[self.right[j]] = depth[j] + 1
            else:
                best = max(best, int(depth[j]))
        return best

    def leaf_ids(self, xb: np.ndarray) -> np.ndarray:
        """Vectorized traversal: node index of the leaf each row lands in."""
        node = np.zeros(xb.shape[0], dtype=np.int32)
        active = self.feature[node] >= 0
        while active.any():
            f = self.feature[node]
            t = self.threshold[node]
            go_left = xb[np.arange(xb.shape[0]), np.maximum(f, 0)] < t
            nxt = np.where(go_left, self.left[node], self.right[node])
            node = np.where(active, nxt, node)
            active = self.feature[node] >= 0
        return node

    def predict_bins(self, xb: np.ndarray) -> np.ndarray:
        """(n, F) binned features -> (n,) leaf values."""
        return self.value[self.leaf_ids(xb)]


@dataclass
class Ensemble:
    """A trained forest in the paper's tabular exchange format (§III-A).

    ``tree_class[i]`` is the class whose logit tree i contributes to
    (0 for regression/binary).  GBDT multiclass emits one tree per class per
    round; RF classification stores a vote of 1.0 and the per-leaf majority
    class (``leaf_class_mode == 'leaf'``), matching the paper's class-ID
    column in the CAM table.
    """

    trees: list[Tree]
    n_features: int
    n_bins: int
    task: Task
    kind: Literal["gbdt", "rf"]
    n_classes: int = 1  # logical classes (1 for regression; 2 for binary)
    tree_class: np.ndarray | None = None  # (n_trees,)
    base_score: float = 0.0
    # 'tree': all leaves of tree i belong to tree_class[i] (GBDT).
    # 'leaf': class id varies per leaf (RF classification majority vote).
    leaf_class_mode: Literal["tree", "leaf"] = "tree"
    leaf_class: list[np.ndarray] = field(default_factory=list)  # per tree (n_nodes,)
    # imported models (repro.ingest) may carry margin layouts the native
    # trainers never produce, e.g. a summing binary forest with one
    # probability lane per class; None keeps the native derivation
    n_outputs_override: int | None = None

    @property
    def n_trees(self) -> int:
        return len(self.trees)

    @property
    def n_outputs(self) -> int:
        """Width of the raw margin vector (number of accumulator channels)."""
        if self.n_outputs_override is not None:
            return self.n_outputs_override
        if self.task == "multiclass":
            return self.n_classes
        if self.kind == "rf" and self.task == "binary":
            return 2  # vote counts per class
        return 1

    @property
    def max_leaves(self) -> int:
        return max(t.n_leaves for t in self.trees)

    @property
    def total_leaves(self) -> int:
        return sum(t.n_leaves for t in self.trees)

    # -- reference prediction by explicit traversal (the "GPU-style" path) --

    def raw_margin(self, xb: np.ndarray) -> np.ndarray:
        """(n, n_outputs) summed leaf values before the final reduction op."""
        n = xb.shape[0]
        out = np.zeros((n, self.n_outputs), dtype=np.float64)
        for i, tree in enumerate(self.trees):
            if self.leaf_class_mode == "leaf":
                leaves = tree.leaf_ids(xb)
                vals = tree.value[leaves]
                cls = self.leaf_class[i][leaves]
                np.add.at(out, (np.arange(n), cls), vals)
            else:
                c = 0 if self.tree_class is None else int(self.tree_class[i])
                out[:, c] += tree.predict_bins(xb)
        out += self.base_score
        if self.kind == "rf":
            out /= max(1, self.n_trees)
        return out.astype(np.float32)

    def predict(self, xb: np.ndarray) -> np.ndarray:
        """Final model prediction (class id / regression value) — the CP op.

        Classification decides by margin layout: a single channel is a
        logit (sign test), several channels are per-class scores (argmax)
        — covering native GBDT/RF and every imported-ensemble layout.
        """
        margin = self.raw_margin(xb)
        if self.task == "regression":
            return margin[:, 0]
        if margin.shape[1] == 1:
            return (margin[:, 0] > 0.0).astype(np.int32)
        return np.argmax(margin, axis=1).astype(np.int32)


# ---------------------------------------------------------------------------
# Histogram machinery (shared by GBDT and RF)
# ---------------------------------------------------------------------------


def _hist(
    xb: np.ndarray, g: np.ndarray, h: np.ndarray, idx: np.ndarray, n_bins: int
) -> tuple[np.ndarray, np.ndarray]:
    """Per-(output, feature, bin) gradient and (feature, bin) hessian hists.

    g: (n, K) multi-output gradients, h: (n,) shared hessians.
    Returns (G, H) with shapes (K, F, n_bins) and (F, n_bins).  Built with
    bincounts over a flattened (row, feature) index — the numpy analog of
    the fused histogram kernels in LightGBM/XGBoost.
    """
    n, F = idx.shape[0], xb.shape[1]
    K = g.shape[1]
    flat = xb[idx].astype(np.int64) + np.arange(F, dtype=np.int64)[None, :] * n_bins
    flat = flat.ravel()
    G = np.empty((K, F, n_bins), dtype=np.float64)
    for k in range(K):
        gw = np.broadcast_to(g[idx, k][:, None], (n, F)).ravel()
        G[k] = np.bincount(flat, weights=gw, minlength=F * n_bins).reshape(F, n_bins)
    hw = np.broadcast_to(h[idx, None], (n, F)).ravel()
    H = np.bincount(flat, weights=hw, minlength=F * n_bins).reshape(F, n_bins)
    return G, H


def _best_split(
    G: np.ndarray,
    H: np.ndarray,
    reg_lambda: float,
    min_child_weight: float,
    feat_mask: np.ndarray | None = None,
) -> tuple[float, int, int]:
    """XGBoost gain (summed over outputs) over all (feature, bin) candidates.

    G: (K, F, n_bins), H: (F, n_bins).  Returns (gain, feature, t) where
    rows with bin < t go left.  gain <= 0 means no useful split.
    """
    Gtot = G.sum(axis=2, keepdims=True)  # (K, F, 1)
    Htot = H.sum(axis=1, keepdims=True)  # (F, 1)
    GL = np.cumsum(G, axis=2)[:, :, :-1]  # (K, F, n_bins-1)
    HL = np.cumsum(H, axis=1)[:, :-1]  # (F, n_bins-1)
    GR = Gtot - GL
    HR = Htot - HL
    parent = ((Gtot**2) / (Htot + reg_lambda)[None]).sum(axis=0)  # (F, 1)
    gain = (GL**2 / (HL + reg_lambda)[None] + GR**2 / (HR + reg_lambda)[None]).sum(
        axis=0
    ) - parent  # (F, n_bins-1)
    ok = (HL >= min_child_weight) & (HR >= min_child_weight)
    if feat_mask is not None:
        ok &= feat_mask[:, None]
    gain = np.where(ok, gain, -np.inf)
    j = int(np.argmax(gain))
    f, t = divmod(j, gain.shape[1])
    return float(gain[f, t]), int(f), int(t) + 1


@dataclass
class _Node:
    idx: np.ndarray  # row indices reaching this node
    G: np.ndarray  # (K, F, n_bins) grad hist
    H: np.ndarray  # (F, n_bins) hess hist
    tree_pos: int  # index in the output arrays


def _grow_tree(
    xb: np.ndarray,
    g: np.ndarray,
    h: np.ndarray,
    idx: np.ndarray,
    *,
    n_bins: int,
    max_leaves: int,
    max_depth: int,
    reg_lambda: float,
    min_child_weight: float,
    learning_rate: float,
    colsample: float,
    rng: np.random.Generator,
) -> Tree:
    """Leaf-wise (best-first) growth with histogram subtraction.

    For K == 1 the leaf value is the Newton step -G/(H+λ)·lr; for K > 1 the
    tree structure is grown on the summed gain and leaf payloads are
    recomputed by the caller.
    """
    F = xb.shape[1]
    if g.ndim == 1:
        g = g[:, None]
    feature = [np.int32(-1)]
    threshold = [np.int32(0)]
    left = [np.int32(-1)]
    right = [np.int32(-1)]
    value = [np.float32(0)]
    depth = {0: 0}

    def leaf_value(node: _Node) -> float:
        Gt = node.G[0].sum()
        Ht = node.H.sum()
        return float(-Gt / (Ht + reg_lambda) * learning_rate)

    feat_mask = None
    if colsample < 1.0:
        k = max(1, int(round(colsample * F)))
        chosen = rng.choice(F, size=k, replace=False)
        feat_mask = np.zeros(F, dtype=bool)
        feat_mask[chosen] = True

    G0, H0 = _hist(xb, g, h, idx, n_bins)
    root = _Node(idx=idx, G=G0, H=H0, tree_pos=0)
    value[0] = np.float32(leaf_value(root))

    heap: list = []  # (-gain, counter, node, f, t)
    counter = 0

    def push(node: _Node) -> None:
        nonlocal counter
        if depth[node.tree_pos] >= max_depth or node.idx.shape[0] < 2:
            return
        gain, f, t = _best_split(node.G, node.H, reg_lambda, min_child_weight, feat_mask)
        if np.isfinite(gain) and gain > 1e-12:
            heapq.heappush(heap, (-gain, counter, node, f, t))
            counter += 1

    push(root)
    n_leaves = 1
    while heap and n_leaves < max_leaves:
        _, _, node, f, t = heapq.heappop(heap)
        rows = node.idx
        go_left = xb[rows, f] < t
        li, ri = rows[go_left], rows[~go_left]
        if li.size == 0 or ri.size == 0:
            continue
        # histogram subtraction: build the smaller child, derive the other
        if li.size <= ri.size:
            GL_, HL_ = _hist(xb, g, h, li, n_bins)
            GR_, HR_ = node.G - GL_, node.H - HL_
        else:
            GR_, HR_ = _hist(xb, g, h, ri, n_bins)
            GL_, HL_ = node.G - GR_, node.H - HR_

        pos = node.tree_pos
        feature[pos] = np.int32(f)
        threshold[pos] = np.int32(t)
        left[pos] = np.int32(len(feature))
        right[pos] = np.int32(len(feature) + 1)
        for child_idx, Gc, Hc in ((li, GL_, HL_), (ri, GR_, HR_)):
            child = _Node(idx=child_idx, G=Gc, H=Hc, tree_pos=len(feature))
            feature.append(np.int32(-1))
            threshold.append(np.int32(0))
            left.append(np.int32(-1))
            right.append(np.int32(-1))
            value.append(np.float32(leaf_value(child)))
            depth[child.tree_pos] = depth[pos] + 1
            push(child)
        n_leaves += 1

    return Tree(
        feature=np.asarray(feature, dtype=np.int32),
        threshold=np.asarray(threshold, dtype=np.int32),
        left=np.asarray(left, dtype=np.int32),
        right=np.asarray(right, dtype=np.int32),
        value=np.asarray(value, dtype=np.float32),
    )


# ---------------------------------------------------------------------------
# Gradient boosting (XGBoost-style, §II-A "GB")
# ---------------------------------------------------------------------------


@dataclass
class GBDTParams:
    n_rounds: int = 50
    learning_rate: float = 0.1
    max_leaves: int = 256  # the paper's N_leaves,max constraint
    max_depth: int = 8
    reg_lambda: float = 1.0
    min_child_weight: float = 1.0
    subsample: float = 1.0
    colsample: float = 1.0
    seed: int = 0


def _sigmoid(z: np.ndarray) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-np.clip(z, -30, 30)))


def _softmax(z: np.ndarray) -> np.ndarray:
    z = z - z.max(axis=1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=1, keepdims=True)


def train_gbdt(
    xb: np.ndarray,
    y: np.ndarray,
    *,
    task: Task,
    n_bins: int,
    n_classes: int = 1,
    params: GBDTParams | None = None,
) -> Ensemble:
    """Second-order gradient boosting on binned features."""
    p = params or GBDTParams()
    rng = np.random.default_rng(p.seed)
    n = xb.shape[0]
    y = np.asarray(y)

    if task == "regression":
        base = float(np.mean(y))
        margin = np.zeros((n, 1))
    elif task == "binary":
        pos = float(np.clip(np.mean(y), 1e-6, 1 - 1e-6))
        base = float(np.log(pos / (1 - pos)))
        margin = np.zeros((n, 1))
    else:
        base = 0.0
        margin = np.zeros((n, n_classes))

    trees: list[Tree] = []
    tree_class: list[int] = []
    for _ in range(p.n_rounds):
        if task == "regression":
            pred = margin[:, 0] + base
            grads = [(0, (pred - y).astype(np.float64), np.ones(n))]
        elif task == "binary":
            prob = _sigmoid(margin[:, 0] + base)
            grads = [(0, (prob - y).astype(np.float64), np.maximum(prob * (1 - prob), 1e-16))]
        else:
            prob = _softmax(margin + base)
            grads = [
                (
                    c,
                    (prob[:, c] - (y == c)).astype(np.float64),
                    np.maximum(prob[:, c] * (1 - prob[:, c]), 1e-16),
                )
                for c in range(n_classes)
            ]

        for c, g, h in grads:
            if p.subsample < 1.0:
                m = max(1, int(round(p.subsample * n)))
                idx = rng.choice(n, size=m, replace=False)
            else:
                idx = np.arange(n)
            tree = _grow_tree(
                xb, g, h, idx,
                n_bins=n_bins,
                max_leaves=p.max_leaves,
                max_depth=p.max_depth,
                reg_lambda=p.reg_lambda,
                min_child_weight=p.min_child_weight,
                learning_rate=p.learning_rate,
                colsample=p.colsample,
                rng=rng,
            )
            trees.append(tree)
            tree_class.append(c)
            margin[:, c] += tree.predict_bins(xb)

    return Ensemble(
        trees=trees,
        n_features=xb.shape[1],
        n_bins=n_bins,
        task=task,
        kind="gbdt",
        n_classes=(n_classes if task == "multiclass" else (2 if task == "binary" else 1)),
        tree_class=np.asarray(tree_class, dtype=np.int32),
        base_score=base,
        leaf_class_mode="tree",
    )


# ---------------------------------------------------------------------------
# Random forests (§II-A "RF")
# ---------------------------------------------------------------------------


@dataclass
class RFParams:
    n_trees: int = 100
    max_leaves: int = 256
    max_depth: int = 12
    min_child_weight: float = 1.0
    colsample: float = 1.0  # per-tree feature subsample ("max_features")
    bootstrap: bool = True
    seed: int = 0


def train_rf(
    xb: np.ndarray,
    y: np.ndarray,
    *,
    task: Task,
    n_bins: int,
    n_classes: int = 1,
    params: RFParams | None = None,
) -> Ensemble:
    """Bagged CART forest.

    Classification trees are grown on multi-output squared loss over one-hot
    targets (variance-reduction gain, equal to gini gain up to a factor of 2
    for one-hot y); leaves are relabelled with the exact in-bag majority
    class.  Regression trees minimize variance; leaves store the in-bag
    mean.  The ensemble averages (regression) or votes (classification).
    """
    p = params or RFParams()
    rng = np.random.default_rng(p.seed)
    n = xb.shape[0]
    y = np.asarray(y)
    k_cls = max(2, n_classes)

    trees: list[Tree] = []
    leaf_class: list[np.ndarray] = []
    tree_class: list[int] = []

    for _ in range(p.n_trees):
        idx = rng.choice(n, size=n, replace=True) if p.bootstrap else np.arange(n)
        if task == "regression":
            g = (-y).astype(np.float64)[:, None]  # leaf value = mean(y) with lr=1
        else:
            g = -(y[:, None] == np.arange(k_cls)[None, :]).astype(np.float64)
        h = np.ones(n, dtype=np.float64)
        tree = _grow_tree(
            xb, g, h, idx,
            n_bins=n_bins,
            max_leaves=p.max_leaves,
            max_depth=p.max_depth,
            reg_lambda=1e-9,
            min_child_weight=p.min_child_weight,
            learning_rate=1.0,
            colsample=p.colsample,
            rng=rng,
        )
        if task == "regression":
            # leaf value = -mean(g) = mean(y) over in-bag rows: already set
            trees.append(tree)
            tree_class.append(0)
        else:
            # exact per-leaf majority vote over in-bag rows
            leaves = tree.leaf_ids(xb[idx])
            votes = np.zeros((tree.n_nodes, k_cls), dtype=np.int64)
            np.add.at(votes, (leaves, y[idx].astype(np.int64)), 1)
            maj = votes.argmax(axis=1).astype(np.int32)
            tree.value = np.ones(tree.n_nodes, dtype=np.float32)  # one vote
            trees.append(tree)
            tree_class.append(0)
            leaf_class.append(maj)

    return Ensemble(
        trees=trees,
        n_features=xb.shape[1],
        n_bins=n_bins,
        task=task,
        kind="rf",
        n_classes=(n_classes if task == "multiclass" else (2 if task == "binary" else 1)),
        tree_class=np.asarray(tree_class, dtype=np.int32),
        base_score=0.0,
        leaf_class_mode=("leaf" if task != "regression" else "tree"),
        leaf_class=leaf_class,
    )


# ---------------------------------------------------------------------------
# Synthetic deep ensembles (compression workloads)
# ---------------------------------------------------------------------------


def random_deep_ensemble(
    *,
    n_trees: int = 8,
    depth: int = 6,
    n_features: int = 16,
    n_bins: int = 256,
    task: Task = "regression",
    n_classes: int = 1,
    p_dup: float = 0.5,
    leaf_levels: int = 16,
    base_score: float = 0.5,
    seed: int = 0,
) -> Ensemble:
    """Random complete-depth ensemble shaped to exercise CAM compression.

    The trainers (`train_gbdt`/`train_rf`) never emit the structures the
    compression pass targets: their splits always partition live data, so
    no path carries a contradictory duplicate split, and their leaf
    values are distinct floats, so sibling leaves never compare equal.
    This generator produces both, deliberately:

      * with probability ``p_dup`` an internal node re-splits a feature
        already split on its path, with a threshold drawn over the FULL
        grid — thresholds outside the path's surviving ``[low, high)``
        interval make one child's CAM row structurally empty (prunable),
      * leaf values are drawn from the ``k/16`` grid (the paper-adjacent
        quantized leaf payload), so sibling leaves frequently hold
        bit-identical values and merge into their parent's interval.

    ``k/16`` payloads also make every margin exact in float32 (dyadic
    rationals, bounded magnitude), so any accumulation order yields the
    same bits — the property the differential tests and benchmarks rely
    on when comparing compressed against uncompressed tables at paper
    scale.  Trees are complete (``2**depth`` leaves each): depth 8 gives
    the paper's 256-leaf N_words bound exactly.
    """
    if depth < 1:
        raise ValueError("depth must be >= 1")
    if not 0.0 <= p_dup <= 1.0:
        raise ValueError("p_dup must be in [0, 1]")
    rng = np.random.default_rng(seed)
    k_cls = n_classes if task == "multiclass" else (2 if task == "binary" else 1)
    trees: list[Tree] = []
    tree_class: list[int] = []
    n_nodes = 2 ** (depth + 1) - 1
    for i in range(n_trees):
        feature = np.full(n_nodes, -1, dtype=np.int32)
        threshold = np.zeros(n_nodes, dtype=np.int32)
        left = np.full(n_nodes, -1, dtype=np.int32)
        right = np.full(n_nodes, -1, dtype=np.int32)
        value = np.zeros(n_nodes, dtype=np.float32)
        next_free = 1
        stack: list[tuple[int, int, tuple[int, ...]]] = [(0, 0, ())]
        while stack:
            j, d, path = stack.pop()
            if d == depth:
                value[j] = np.float32(
                    int(rng.integers(-leaf_levels, leaf_levels + 1)) / 16.0
                )
                continue
            if path and rng.random() < p_dup:
                f = int(path[int(rng.integers(0, len(path)))])
            else:
                f = int(rng.integers(0, n_features))
            threshold[j] = int(rng.integers(1, n_bins))
            feature[j] = f
            left[j] = next_free
            right[j] = next_free + 1
            stack.append((next_free, d + 1, path + (f,)))
            stack.append((next_free + 1, d + 1, path + (f,)))
            next_free += 2
        trees.append(
            Tree(feature=feature, threshold=threshold, left=left,
                 right=right, value=value)
        )
        tree_class.append(i % k_cls if task == "multiclass" else 0)
    return Ensemble(
        trees=trees,
        n_features=n_features,
        n_bins=n_bins,
        task=task,
        kind="gbdt",
        n_classes=k_cls,
        tree_class=np.asarray(tree_class, dtype=np.int32),
        base_score=float(base_score),
        leaf_class_mode="tree",
    )
