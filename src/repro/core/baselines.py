"""Baseline inference paths the paper compares against (§II-B, §V-B).

``TraversalBaseline`` is the GPU-style implementation: one logical thread
per (sample, tree) walking D dependent node fetches (gathers) — expressed
in JAX as a vmapped fori_loop over a padded struct-of-arrays forest.  It
is numerically identical to ``Ensemble.raw_margin`` and serves two roles:
  * the measured same-hardware baseline for the engine benchmarks
    (CAM single-shot match vs O(D) dependent gathers), and
  * the functional model of the Booster/FPGA LUT cores (§V-B), whose chip
    performance is modeled in perfmodel.booster_perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trees import Ensemble


class TraversalBaseline:
    """Padded array-of-trees traversal, jit/vmap friendly."""

    def __init__(self, ens: Ensemble) -> None:
        self.ens = ens
        T = ens.n_trees
        N = max(t.n_nodes for t in ens.trees)
        feat = np.full((T, N), -1, dtype=np.int32)
        thr = np.zeros((T, N), dtype=np.int32)
        left = np.zeros((T, N), dtype=np.int32)
        right = np.zeros((T, N), dtype=np.int32)
        val = np.zeros((T, N), dtype=np.float32)
        cls = np.zeros((T, N), dtype=np.int32)
        for i, t in enumerate(ens.trees):
            n = t.n_nodes
            feat[i, :n] = t.feature
            thr[i, :n] = t.threshold
            left[i, :n] = t.left
            right[i, :n] = t.right
            val[i, :n] = t.value
            if ens.leaf_class_mode == "leaf":
                cls[i, :n] = ens.leaf_class[i]
            else:
                cls[i, :n] = 0 if ens.tree_class is None else int(ens.tree_class[i])
        self.feature = jnp.asarray(feat)
        self.threshold = jnp.asarray(thr)
        self.left = jnp.asarray(left)
        self.right = jnp.asarray(right)
        self.value = jnp.asarray(val)
        self.leaf_cls = jnp.asarray(cls)
        self.depth = int(max(t.max_depth for t in ens.trees))
        self.n_outputs = ens.n_outputs

        ens_kind = ens.kind
        n_trees = ens.n_trees
        base = float(ens.base_score)
        n_out = self.n_outputs
        depth = self.depth

        def margin(q):  # q: (B, F) int32
            def one_tree(feat_t, thr_t, left_t, right_t, val_t, cls_t):
                def walk(qrow):
                    def body(_, node):
                        f = feat_t[node]
                        is_leaf = f < 0
                        go_left = qrow[jnp.maximum(f, 0)] < thr_t[node]
                        nxt = jnp.where(go_left, left_t[node], right_t[node])
                        return jnp.where(is_leaf, node, nxt)

                    node = jax.lax.fori_loop(0, depth, body, jnp.int32(0))
                    return val_t[node], cls_t[node]

                return jax.vmap(walk)(q)  # (B,), (B,)

            vals, clss = jax.vmap(one_tree)(
                self.feature, self.threshold, self.left, self.right, self.value, self.leaf_cls
            )  # (T, B)
            onehot = jax.nn.one_hot(clss, n_out, dtype=vals.dtype)  # (T, B, C)
            out = jnp.einsum("tb,tbc->bc", vals, onehot) + base
            if ens_kind == "rf":
                out = out / jnp.float32(max(1, n_trees))
            return out

        self._margin = jax.jit(margin)

    def raw_margin(self, q_bins: np.ndarray) -> jnp.ndarray:
        return self._margin(jnp.asarray(q_bins, dtype=jnp.int32))

    def predict(self, q_bins: np.ndarray) -> np.ndarray:
        m = np.asarray(self.raw_margin(q_bins))
        ens = self.ens
        if ens.task == "regression":
            return m[:, 0]
        if ens.n_outputs == 1:  # single-logit binary: sign test
            return (m[:, 0] > 0.0).astype(np.int32)
        return np.argmax(m, axis=1).astype(np.int32)
