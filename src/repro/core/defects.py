"""Analog-hardware defect injection (§V-A Fig. 9b).

The paper defines a defect as a 1-level random flip of a memristor
conductance or of a DAC output voltage, with half the affected devices
flipped up and half down.  On the 8-bit threshold grid a 1-level flip of a
4-bit sub-cell moves the stored bound by ±1 (LSB sub-cell) or ±16 (MSB
sub-cell); a DAC flip moves one query element the same way.

``relative_accuracy`` reproduces the Fig. 9(b) protocol: ideal accuracy /
defect-compromised accuracy averaged over repeated random draws.
"""

from __future__ import annotations

from dataclasses import replace as dc_replace

import numpy as np

from repro.core.compile import CAMTable


def _flip_levels(
    values: np.ndarray, frac: float, n_bins: int, rng: np.random.Generator
) -> np.ndarray:
    """Flip a fraction of entries by ±1 sub-cell level (±1 or ±16 codes)."""
    flat = values.reshape(-1).copy()
    n = flat.size
    k = int(round(frac * n))
    if k == 0:
        return values.copy()
    idx = rng.choice(n, size=k, replace=False)
    # half up, half down; 50/50 LSB (±1) vs MSB (±16) sub-cell
    magnitude = np.where(rng.random(k) < 0.5, 1, 16)
    sign = np.where(np.arange(k) % 2 == 0, 1, -1)
    rng.shuffle(sign)
    flat[idx] = np.clip(flat[idx] + sign * magnitude, 0, n_bins)
    return flat.reshape(values.shape)


def inject_table_defects(
    table: CAMTable, frac: float, rng: np.random.Generator
) -> CAMTable:
    """Memristor defects: each stored bound (2 devices per macro-cell per
    side) independently eligible for a 1-level flip."""
    low = _flip_levels(table.low, frac, table.n_bins, rng)
    high = _flip_levels(table.high, frac, table.n_bins, rng)
    # flipped levels can leave the packed encoding's range (e.g. low
    # pushed to n_bins, high below low): the perturbed table drops to the
    # universal int32 layout — the defect study measures accuracy, and
    # the engine resolves 'auto' dtype from this field
    return dc_replace(
        table,
        low=low.astype(np.int32),
        high=high.astype(np.int32),
        table_dtype="int32",
    )


def inject_query_defects(
    q_bins: np.ndarray, frac: float, n_bins: int, rng: np.random.Generator
) -> np.ndarray:
    """DAC defects: 1-level flips on the applied query voltages."""
    out = _flip_levels(q_bins.astype(np.int64), frac, n_bins - 1, rng)
    return out.astype(q_bins.dtype if q_bins.dtype != np.uint8 else np.int32)


def relative_accuracy(
    ideal_acc: float, defect_accs: list[float]
) -> tuple[float, float]:
    """Fig. 9(b) metric: mean and std of defect_acc / ideal_acc."""
    rel = np.asarray(defect_accs, dtype=np.float64) / max(ideal_acc, 1e-12)
    return float(rel.mean()), float(rel.std())
