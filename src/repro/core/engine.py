"""X-TIME inference engine: compiled CAM table -> batched predictions.

Single-device path: the Pallas kernel (TPU) or its jnp oracle (CPU),
under a plain ``jax.jit``.  Execution knobs arrive as a ``DeployConfig``
(``XTimeEngine.from_config`` / ``CompiledModel.engine``); the loose-kwarg
constructor form is deprecated.

Kernel v2 (DESIGN.md §10): at bind time the engine packs the canonical
int32 exclusive-high table into the narrowest dtype the grid permits
(``resolve_table_dtype`` — uint8 for ≤256 bins, inclusive upper bounds,
compared natively), precomputes the wildcard tile-activity mask the
kernel uses to skip all-wildcard compare tiles, and resolves
``interpret='auto'`` against the bound platform.  All of it is
semantics-free: every (backend, mode, table_dtype) combination computes
identical bits (tests/test_kernel_v2.py).

Scale-out path (``config.spmd``, DESIGN.md §8): on a mesh the CAM rows
(cores) shard over ``config.row_axis`` and the query batch over
``config.batch_axis`` (× ``pod``), and the §III-D H-tree router program
becomes collectives in one of two partitioning modes:

  * ``spmd='shard_map'`` (default with a mesh) — the kernel runs once
    per device shard and the NoC plan is issued as EXPLICIT collectives:
    ``psum`` over the row axis for ``noc_config='accumulate'``, no
    collective for the replicated-table ``'batch'`` program, and
    all-gather + ``psum_scatter`` for the 2-D ``'hybrid'`` program.
  * ``spmd='gspmd'`` — implicit ``NamedSharding`` placement; the XLA
    partitioner places the equivalent collectives.  Kept as the
    independent oracle the shard_map path is property-tested
    bit-equivalent against (tests/test_scaleout.py).

The engine reproduces ``Ensemble.raw_margin`` / ``Ensemble.predict``
bit-for-bit on binned inputs — that equivalence is the correctness
contract (tested in tests/test_engine.py), and it holds across every
(spmd, noc_config) combination.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 re-exports it at the top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - version-dependent import path
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core.compile import CAMTable
from repro.core.deploy import DeployConfig
from repro.core.precision import get_cell_mode
from repro.kernels import ops as kops
from repro.kernels.cam_match import default_interpret, pallas_available
from repro.kernels.ref import cam_match_ref

_UNSET = object()  # distinguishes "kwarg not passed" from an explicit default


def resolve_table_dtype(table: CAMTable, config: DeployConfig) -> str:
    """Effective kernel table dtype for this (table, config) binding.

    Modes with a pinned ``CellMode.table_dtype_policy`` always run that
    layout (int32 exclusive-high for the bit-faithful macro-cell modes,
    float32 soft-encoded bounds for 'soft' — ``DeployConfig`` rejects
    conflicting explicit dtypes); otherwise 'auto' takes the
    compile-time selection carried on the table, and an explicit packed
    dtype must actually hold the grid (inclusive bounds -> n_bins-1).
    """
    policy = get_cell_mode(config.mode).table_dtype_policy
    if policy is not None:
        return policy
    dt = table.table_dtype if config.table_dtype == "auto" else config.table_dtype
    if dt != "int32" and table.n_bins - 1 > np.iinfo(dt).max:
        raise ValueError(
            f"table_dtype {dt!r} cannot hold n_bins={table.n_bins} "
            "(inclusive bounds store values up to n_bins-1)"
        )
    return dt


def _wrap_shard_map(fn, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off (the Pallas kernel body
    is opaque to the rep-rule checker); the flag was renamed ``check_rep``
    -> ``check_vma`` across jax versions, so try both before giving it up
    entirely."""
    kw = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    for check_kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return _shard_map(fn, **kw, **check_kw)
        except TypeError:  # pragma: no cover - version-dependent signature
            continue
    raise TypeError("no compatible shard_map signature found")


@dataclass
class EngineArrays:
    low: jnp.ndarray  # (R_pad, F_pad) table dtype
    high: jnp.ndarray  # (inclusive upper bounds when packed)
    leaf: jnp.ndarray  # (R_pad, C_pad) float32
    tile_mask: jnp.ndarray  # (R_pad/r_blk, F_pad/f_blk) int32
    r_pad: int
    f_pad: int
    c_pad: int
    table_dtype: str = "int32"
    inclusive: bool = False  # high bounds stored inclusive?


class XTimeEngine:
    """Batched tree-ensemble inference on a compiled CAM table.

    Args:
      table: compiled ensemble.
      config: a ``DeployConfig`` holding every execution knob — the
        canonical construction path (``XTimeEngine.from_config`` /
        ``CompiledModel.engine``).  'auto' noc_config resolves to
        'accumulate' here; the artifact layer resolves it from the
        compiled NoC plan before binding.
      mesh: optional jax Mesh. When given, rows are sharded over
        ``config.row_axis`` and batch over ``config.batch_axis`` (+
        leading 'pod' axis if present), and ``config.noc_config`` picks
        the collective program realizing the paper's router bits
        ('accumulate' / 'batch' / 'hybrid').  ``config.spmd`` selects
        explicit shard_map collectives (default on a mesh) or implicit
        GSPMD partitioning — bit-equivalent paths, DESIGN.md §8.

    The loose keyword form (``backend=``, ``mode=``, ``b_blk=``, ...) is
    deprecated: those knobs now live in ``DeployConfig``.  It still works
    — the kwargs are folded into a config — but emits a
    ``DeprecationWarning``.
    """

    def __init__(
        self,
        table: CAMTable,
        *,
        config: DeployConfig | None = None,
        mesh: Mesh | None = None,
        backend=_UNSET,
        mode=_UNSET,
        row_axis=_UNSET,
        batch_axis=_UNSET,
        noc_config=_UNSET,
        b_blk=_UNSET,
        r_blk=_UNSET,
        c_mult=_UNSET,
        interpret=_UNSET,
    ) -> None:
        legacy = {
            k: v
            for k, v in (
                ("backend", backend), ("mode", mode), ("row_axis", row_axis),
                ("batch_axis", batch_axis), ("noc_config", noc_config),
                ("b_blk", b_blk), ("r_blk", r_blk), ("c_mult", c_mult),
                ("interpret", interpret),
            )
            if v is not _UNSET
        }
        if legacy:
            if config is not None:
                raise TypeError(
                    "pass execution knobs via config=DeployConfig(...) OR as "
                    f"loose kwargs, not both (got config and {sorted(legacy)})"
                )
            warnings.warn(
                "loose XTimeEngine execution kwargs are deprecated; pass "
                "config=DeployConfig(...) or use repro.api.build(...).engine()",
                DeprecationWarning,
                stacklevel=2,
            )
            config = DeployConfig(**legacy)
        config = config or DeployConfig()

        self.table = table
        self.config = config
        # compressed tables may have dropped all-wildcard feature columns
        # (repro.core.compress): queries arrive at the LOGICAL width and
        # are narrowed to the stored columns before any padding/matching
        self.feature_ids = (
            None
            if table.feature_ids is None
            else np.asarray(table.feature_ids, dtype=np.int64)
        )
        # column-clustered tables (order_columns_by_activity) additionally
        # permute their stored columns; queries follow AFTER the narrowing
        self.col_perm = (
            None
            if table.col_perm is None
            else np.asarray(table.col_perm, dtype=np.int64)
        )
        self.backend = config.backend
        if self.backend == "pallas" and not pallas_available():
            # jaxlib builds without the pallas TPU extension can't run the
            # v2 kernel even interpreted; the jnp oracle computes the same
            # bits, so degrade loudly instead of crashing at first predict
            warnings.warn(
                "pallas TPU support unavailable in this jaxlib; engine "
                "falls back to the jnp oracle (identical results)",
                RuntimeWarning,
                stacklevel=2,
            )
            self.backend = "jnp"
        self.mode = config.mode
        self.mesh = mesh
        self.row_axis = config.row_axis
        self.batch_axis = config.batch_axis
        noc_cfg = config.noc_config
        self.noc_config = "accumulate" if noc_cfg == "auto" else noc_cfg
        self.b_blk = config.b_blk
        self.r_blk = config.r_blk
        self.f_blk = config.f_blk
        # 'auto' interpret resolves against the bound platform: compiled
        # Pallas on TPU, the interpreter everywhere else — so callers never
        # hard-code the slow interpreter onto real hardware again
        self.interpret = (
            default_interpret() if config.interpret == "auto"
            else bool(config.interpret)
        )
        # kernel v2 compact layout: the narrowest dtype the grid permits
        # (DESIGN.md §10).  Packed tables store inclusive upper bounds and
        # compare with the 'inclusive' cell, bit-equal to 'direct' on the
        # exclusive layout; the faithful modes stay on int32.
        self.table_dtype = resolve_table_dtype(table, config)
        if get_cell_mode(config.mode).soft:
            self.kernel_mode = "soft"
        elif np.dtype(self.table_dtype).kind == "u":
            self.kernel_mode = "inclusive"
        else:
            self.kernel_mode = config.mode
        # soft-mode boundary temperature — static (selects the trace);
        # pinned to 0.0 for hard modes so they share one jit cache entry
        # regardless of the config's tau knob
        self.tau = float(config.tau) if self.kernel_mode == "soft" else 0.0
        # kernel v3 fused epilogue: the base-score add rides the kernel's
        # last feature tile.  Only the single-device pallas path is
        # eligible — under a row-sharded mesh the per-shard partials are
        # psum'd, which would count the base once per shard.
        eligible = self.backend == "pallas" and mesh is None
        if config.fuse_epilogue == "auto":
            self.fuse_epilogue = eligible
        else:
            self.fuse_epilogue = bool(config.fuse_epilogue)
            if self.fuse_epilogue and not eligible:
                raise ValueError(
                    "fuse_epilogue=True needs backend='pallas' and no mesh "
                    "(a row-sharded reduction would multiply the base "
                    "score); use 'auto' to fuse only when eligible"
                )
        # 'auto' partitioning resolves at bind time: explicit shard_map
        # collectives when there is a mesh to communicate over, plain jit
        # otherwise (without a mesh both modes are the same program).
        if mesh is None:
            self.spmd = "gspmd"
        elif config.spmd == "auto":
            self.spmd = "shard_map"
        else:
            self.spmd = config.spmd
        if mesh is not None:
            missing = [
                ax
                for ax in (self.row_axis, self.batch_axis)
                if ax not in mesh.axis_names
            ]
            if missing:
                raise ValueError(
                    f"mesh {mesh.axis_names} lacks configured axes {missing}"
                )
            if self.noc_config == "hybrid" and self.spmd != "shard_map":
                raise ValueError(
                    "noc_config='hybrid' (all-gather + psum_scatter) is only "
                    "expressible with spmd='shard_map'"
                )

        # row padding must also be divisible by the row-shard count
        row_mult = self.r_blk
        if mesh is not None and self.noc_config in ("accumulate", "hybrid"):
            row_mult = self.r_blk * mesh.shape[self.row_axis]
        low, high, leaf, inclusive = kops.pack_tables(
            table.low, table.high, table.leaf_matrix(),
            r_blk=row_mult, c_mult=config.c_mult, n_bins=table.n_bins,
            f_blk=self.f_blk, dtype=self.table_dtype,
            inclusive=(True if self.kernel_mode == "inclusive" else None),
        )
        tile_mask = kops.wildcard_tile_mask(
            low, high, r_blk=self.r_blk, f_blk=self.f_blk,
            n_bins=table.n_bins, inclusive=inclusive,
        )
        self.arrays = EngineArrays(
            low=jnp.asarray(low),
            high=jnp.asarray(high),
            leaf=jnp.asarray(leaf),
            tile_mask=jnp.asarray(tile_mask),
            r_pad=low.shape[0],
            f_pad=low.shape[1],
            c_pad=leaf.shape[1],
            table_dtype=self.table_dtype,
            inclusive=inclusive,
        )
        # fused-epilogue bias row: base score broadcast over C_pad (the
        # padding channels are sliced off by the epilogue, so the extra
        # adds are dead); None when the epilogue stays separate
        self._bias = (
            jnp.full((1, self.arrays.c_pad), jnp.float32(table.base_score))
            if self.fuse_epilogue
            else None
        )
        # soft mode's uncertainty channel (DESIGN.md §15): a SEPARATE
        # moments leaf matrix [leaf, leaf^2, mass] scattered per output
        # channel.  One extra kernel pass over it yields the raw weighted
        # sums (m1, m2, mass) the leaf-spread uncertainty derives from —
        # keeping the margin/predict path on the plain leaf matrix, whose
        # operand shapes (and therefore float reduction order, and the
        # tau->0 bit-equality with 'direct') stay identical to the hard
        # modes.  Bias is never fused into this pass.
        self._moments = None
        if self.kernel_mode == "soft":
            lm = np.asarray(table.leaf_matrix(), dtype=np.float32)  # (R, C)
            R, C = lm.shape
            onehot = np.zeros_like(lm)
            cls = np.asarray(table.class_id, dtype=np.int64) % max(1, C)
            onehot[np.arange(R), cls] = 1.0  # row mass per output channel
            m = np.concatenate([lm, lm * lm, onehot], axis=1)  # (R, 3C)
            c3_pad = -(-3 * C // config.c_mult) * config.c_mult
            m_pad = np.zeros((self.arrays.r_pad, c3_pad), dtype=np.float32)
            m_pad[:R, : 3 * C] = m
            self._moments = jnp.asarray(m_pad)
        if mesh is not None:
            self._place_on_mesh()
        self._fn_cache: dict = {}

    @classmethod
    def from_config(
        cls, table: CAMTable, config: DeployConfig, *, mesh: Mesh | None = None
    ) -> "XTimeEngine":
        """Canonical constructor: bind a compiled table + deploy config to a
        backend/mesh.  ``config.noc_config`` must already be resolved
        ('auto' is treated as 'accumulate'); ``CompiledModel.engine``
        resolves it from the NoC plan first."""
        return cls(table, config=config, mesh=mesh)

    # -- placement ---------------------------------------------------------

    def _batch_spec(self) -> P:
        axes = [self.batch_axis]
        if self.mesh is not None and "pod" in self.mesh.axis_names:
            axes = ["pod", self.batch_axis]
        if self.noc_config in ("batch", "hybrid"):
            axes.append(self.row_axis)  # batch over cores too
        return P(tuple(axes))

    def _row_spec(self) -> P:
        if self.noc_config == "batch":
            return P()  # table replicated in every core group
        return P(self.row_axis)

    def _place_on_mesh(self) -> None:
        assert self.mesh is not None
        rs = NamedSharding(self.mesh, self._row_spec())
        self.arrays.low = jax.device_put(self.arrays.low, rs)
        self.arrays.high = jax.device_put(self.arrays.high, rs)
        self.arrays.leaf = jax.device_put(self.arrays.leaf, rs)
        # the tile-activity mask shards with the rows it describes
        self.arrays.tile_mask = jax.device_put(self.arrays.tile_mask, rs)
        if self._moments is not None:  # soft moments shard like the leaves
            self._moments = jax.device_put(self._moments, rs)

    # -- compute -----------------------------------------------------------

    def _kernel_fn(self, bias=_UNSET) -> Callable:
        """(q, low, high, leaf, mask) -> (B, C_pad) raw accumulated leaf
        sums over the rows it is handed — no epilogue, no collectives.
        Under shard_map the operands (and B/R) are per-shard.  ``bias``
        defaults to the engine's fused-epilogue row; the moments path
        passes None (no base score belongs in the raw moment sums)."""
        backend, mode, tau = self.backend, self.kernel_mode, self.tau
        b_blk, r_blk, f_blk = self.b_blk, self.r_blk, self.f_blk
        interpret = self.interpret
        if bias is _UNSET:
            bias = self._bias

        def kernel(q, low, high, leaf, mask):
            if backend == "pallas":
                return kops.cam_match(
                    q, low, high, leaf, mask, bias,
                    out_b=q.shape[0], out_c=leaf.shape[1],
                    b_blk=b_blk, r_blk=r_blk, f_blk=f_blk,
                    mode=mode, interpret=interpret, tau=tau,
                )
            return cam_match_ref(q, low, high, leaf, mode=mode, tau=tau)

        return kernel

    def _epilogue_fn(self) -> Callable:
        """Channel slice + base score + RF averaging — applied exactly once,
        AFTER any cross-core reduction (adding the base score per shard
        would count it row-shard-count times).  When the engine fuses the
        epilogue into the kernel (kernel v3) the base score already landed
        on each output tile's last visit — in the same float order, so the
        bits match — and only the slice (+ RF divide) remains here."""
        table, fused = self.table, self.fuse_epilogue

        def epilogue(out):
            out = out[:, : table.n_outputs]
            if not fused:
                out = out + jnp.float32(table.base_score)
            if table.kind == "rf":
                out = out / jnp.float32(max(1, table.n_trees))
            return out

        return epilogue

    def _margin_fn(self) -> Callable:
        """Raw-margin function of (q, low, high, leaf) — jit-compatible.

        With ``spmd='shard_map'`` the kernel runs per device shard and the
        NoC router program is issued as explicit collectives (DESIGN.md
        §8): ``accumulate`` -> psum of the partial margins over the row
        axis (the H-tree in-network reduction); ``batch`` -> replicated
        tables, batch split over every axis, no collective; ``hybrid`` ->
        the queries arrive sharded over (batch × core), are all-gathered
        along the row axis, and the partial margins reduce-scatter back so
        the output stays 2-D-sharded (all-reduce cost without the
        replicated output of 'accumulate').
        """
        kernel, epilogue = self._kernel_fn(), self._epilogue_fn()
        reduced = self._reduced_fn(kernel)
        return lambda q, low, high, leaf, mask: epilogue(
            reduced(q, low, high, leaf, mask)
        )

    def _reduced_fn(self, kernel: Callable) -> Callable:
        """Wrap ``kernel`` with the cross-core reduction program: under
        ``spmd='shard_map'`` the NoC plan's explicit collectives, plain
        pass-through otherwise.  Shared by the margin and moments paths —
        both are row sums, so the same router program applies."""
        if self.mesh is not None and self.spmd == "shard_map":
            noc, row_axis = self.noc_config, self.row_axis

            def body(q, low, high, leaf, mask):
                if noc == "hybrid":
                    q = jax.lax.all_gather(q, row_axis, axis=0, tiled=True)
                out = kernel(q, low, high, leaf, mask)
                if noc == "accumulate":
                    out = jax.lax.psum(out, row_axis)
                elif noc == "hybrid":
                    out = jax.lax.psum_scatter(
                        out, row_axis, scatter_dimension=0, tiled=True
                    )
                return out

            qs, rs = self._batch_spec(), self._row_spec()
            return _wrap_shard_map(body, self.mesh, (qs, rs, rs, rs, rs), qs)
        return kernel

    def _jitted(self, key: str, donate: bool = False) -> Callable:
        cache_key = (key, donate)
        if cache_key in self._fn_cache:
            return self._fn_cache[cache_key]
        table = self.table
        if key == "moments":
            # soft uncertainty channel: the same reduced kernel run over
            # the (R_pad, 3C) moments matrix instead of the leaves, with
            # no bias (a base score has no place in raw moment sums) and
            # an epilogue that only strips the channel padding
            reduced = self._reduced_fn(self._kernel_fn(bias=None))
            n3 = 3 * table.n_outputs

            def fn(q, low, high, leaf, mask):
                return reduced(q, low, high, leaf, mask)[:, :n3]

        else:
            margin = self._margin_fn()
            want_pred = key == "predict"

            def fn(q, low, high, leaf, mask):
                m = margin(q, low, high, leaf, mask)
                if not want_pred:
                    return m
                if table.task == "regression":
                    return m[:, 0]
                if table.n_outputs == 1:  # single-logit binary: sign test
                    return (m[:, 0] > 0.0).astype(jnp.int32)
                return jnp.argmax(m, axis=1).astype(jnp.int32)

        # The serving path donates the query buffer: each coalesced batch is
        # a freshly padded array that is dead after the call, so XLA may
        # reuse its storage (free on backends without donation support).
        donate_kw = {"donate_argnums": (0,)} if donate else {}
        if self.mesh is not None:
            bs = NamedSharding(self.mesh, self._batch_spec())
            rs = NamedSharding(self.mesh, self._row_spec())
            out_s = NamedSharding(self.mesh, self._batch_spec())
            jfn = jax.jit(fn, in_shardings=(bs, rs, rs, rs, rs),
                          out_shardings=out_s, **donate_kw)
        else:
            jfn = jax.jit(fn, **donate_kw)
        self._fn_cache[cache_key] = jfn
        return jfn

    def select_features(self, q: np.ndarray | jnp.ndarray) -> jnp.ndarray:
        """Narrow ``(B, n_features)`` query bins to the stored table
        columns, then apply the compile-time column permutation
        (``CAMTable.col_perm``) — identity for plain tables.  Queries
        already at the (narrower) physical width pass through, so the
        serving batcher can narrow once per flush before bucket padding;
        a PURE permutation preserves the width, so that shortcut never
        applies to it and callers must pass logical-order queries (both
        serving paths — ``_prep_queries`` and the batcher flush — call
        this exactly once)."""
        q = jnp.asarray(q)
        fids, perm = self.feature_ids, self.col_perm
        if fids is None and perm is None:
            return q
        if (
            fids is not None
            and q.ndim == 2
            and q.shape[1] == fids.shape[0]
            and fids.shape[0] != self.table.n_features
        ):
            return q  # already narrowed (and permuted) by an earlier call
        if q.ndim != 2 or q.shape[1] != self.table.n_features:
            expect = f"expected (_, {self.table.n_features}) query bins"
            if fids is not None:
                expect += f" (or pre-selected (_, {fids.shape[0]}))"
            raise ValueError(f"{expect}, got {q.shape}")
        if fids is not None:
            q = q[:, fids]
        if perm is not None:
            q = q[:, perm]
        return q

    def _prep_queries(self, q_bins: np.ndarray | jnp.ndarray) -> jnp.ndarray:
        # pad to a batch both the kernel tiling and the mesh sharding accept
        mult = int(np.lcm(self.b_blk, self.batch_multiple))
        q = kops.pad_queries(
            self.select_features(q_bins), self.arrays.f_pad, b_blk=mult,
            dtype=self.table_dtype,
        )
        if self.mesh is not None:
            q = jax.device_put(q, NamedSharding(self.mesh, self._batch_spec()))
        return q

    def raw_margin(self, q_bins: np.ndarray | jnp.ndarray) -> jnp.ndarray:
        """(B, n_outputs) — matches ``Ensemble.raw_margin`` on binned input."""
        B = q_bins.shape[0]
        q = self._prep_queries(q_bins)
        a = self.arrays
        return self._jitted("margin")(q, a.low, a.high, a.leaf, a.tile_mask)[:B]

    def predict(self, q_bins: np.ndarray | jnp.ndarray) -> jnp.ndarray:
        """Final predictions — matches ``Ensemble.predict``."""
        B = q_bins.shape[0]
        q = self._prep_queries(q_bins)
        a = self.arrays
        return self._jitted("predict")(q, a.low, a.high, a.leaf, a.tile_mask)[:B]

    # -- soft-mode uncertainty channel (DESIGN.md §15) -----------------------

    def raw_moments(self, q_bins: np.ndarray | jnp.ndarray) -> jnp.ndarray:
        """(B, 3*n_outputs) raw soft moments ``[m1 | m2 | mass]``.

        Per output channel c: ``m1 = sum_r s_r * leaf[r, c]``,
        ``m2 = sum_r s_r * leaf[r, c]^2`` and ``mass = sum_r s_r`` over
        the rows routed to c, with s_r the row's soft match score — the
        weighted leaf-value moments the spread/uncertainty derives from.
        Soft engines only."""
        if self._moments is None:
            raise ValueError(
                "raw_moments/uncertainty require the soft cell mode "
                f"(this engine runs mode={self.mode!r}); rebind with "
                "DeployConfig(mode='soft')"
            )
        B = q_bins.shape[0]
        q = self._prep_queries(q_bins)
        a = self.arrays
        out = self._jitted("moments")(
            q, a.low, a.high, self._moments, a.tile_mask
        )
        return out[:B]

    def uncertainty(self, q_bins: np.ndarray | jnp.ndarray) -> jnp.ndarray:
        """(B, n_outputs) calibrated uncertainty: the score-weighted
        population spread (std) of the leaf values behind each output
        channel.  At tau=0 exactly one row per tree matches, every
        weight is 0/1 and the spread is the honest across-tree
        disagreement; finite tau additionally counts boundary ambiguity
        (several leaves of one tree sharing a query's weight)."""
        m = np.asarray(self.raw_moments(q_bins), dtype=np.float64)
        C = self.table.n_outputs
        m1, m2, mass = m[:, :C], m[:, C : 2 * C], m[:, 2 * C : 3 * C]
        mass = np.maximum(mass, 1e-12)  # empty channels -> 0 spread, not NaN
        mean = m1 / mass
        var = np.maximum(m2 / mass - mean * mean, 0.0)
        return jnp.asarray(np.sqrt(var, dtype=np.float64).astype(np.float32))

    # -- bucketed serving path ----------------------------------------------

    @property
    def batch_multiple(self) -> int:
        """Smallest batch granularity a serving bucket must respect.

        The Pallas kernel tiles the batch in ``b_blk`` blocks, so its
        buckets must be ``b_blk`` multiples; the jnp/XLA oracle accepts any
        batch, letting the serving layer use power-of-two buckets below
        ``b_blk``.  A mesh additionally requires the batch axis to divide
        evenly across its batch shards — and under ``spmd='shard_map'``
        each shard's LOCAL batch runs the Pallas kernel on its own, so
        the global batch must be a ``b_blk × shards`` multiple.
        """
        mult = self.b_blk if self.backend == "pallas" else 1
        if self.mesh is not None:
            shards = self.mesh.shape[self.batch_axis]
            if "pod" in self.mesh.axis_names:
                shards *= self.mesh.shape["pod"]
            if self.noc_config in ("batch", "hybrid"):
                shards *= self.mesh.shape[self.row_axis]
            if self.spmd == "shard_map" and self.backend == "pallas":
                mult = self.b_blk * shards
            else:
                mult = max(mult, shards)
        return mult

    def padded_fn(self, kind: str = "predict") -> Callable:
        """Bucket-aware jitted entry for the serving layer.

        Returns a callable of one pre-padded ``(bucket_b, f_pad)`` int32
        query block (see ``kops.pad_to_bucket``) that yields the FULL
        padded output — the caller owns un-padding.  ``jax.jit``
        specializes once per bucket shape, so a shape-bucketed request
        stream compiles ``O(log max_batch)`` variants instead of one per
        request size.  The query buffer is donated (dead after the call).
        """
        if kind not in ("predict", "margin"):
            raise ValueError(f"unknown kind {kind!r}")
        jfn = self._jitted(kind, donate=True)
        a = self.arrays

        def run(q_padded: jnp.ndarray) -> jnp.ndarray:
            if q_padded.ndim != 2 or q_padded.shape[1] != a.f_pad:
                raise ValueError(
                    f"expected (_, {a.f_pad}) padded queries, got {q_padded.shape}"
                )
            if q_padded.dtype != np.dtype(self.table_dtype):
                # packed engines compare queries in the table dtype; casting
                # here keeps pre-v2 callers (int32 buckets) on one jit entry
                # (wrap-checked: a narrowed out-of-range bin would match
                # rows it must not)
                kops.check_query_range(q_padded, self.table_dtype)
                q_padded = q_padded.astype(np.dtype(self.table_dtype))
            if q_padded.shape[0] % self.batch_multiple:
                raise ValueError(
                    f"bucket {q_padded.shape[0]} not a multiple of "
                    f"batch_multiple={self.batch_multiple}"
                )
            if self.mesh is not None:
                q_padded = jax.device_put(
                    q_padded, NamedSharding(self.mesh, self._batch_spec())
                )
            with warnings.catch_warnings():
                # integer queries can never alias the float32 outputs (and
                # CPU lacks donation entirely); donation still releases the
                # buffer early on TPU, so keep it but drop the noise.
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable"
                )
                return jfn(q_padded, a.low, a.high, a.leaf, a.tile_mask)

        return run

    def predict_padded(self, q_padded: jnp.ndarray) -> jnp.ndarray:
        """``predict`` on a pre-padded bucket; returns padded outputs."""
        return self.padded_fn("predict")(q_padded)

    def raw_margin_padded(self, q_padded: jnp.ndarray) -> jnp.ndarray:
        """``raw_margin`` on a pre-padded bucket; returns padded outputs."""
        return self.padded_fn("margin")(q_padded)

    # -- dry-run hooks -------------------------------------------------------

    def serve_step_for_dryrun(self):
        """(fn, in_shardings, out_shardings) for launch/dryrun.py."""
        assert self.mesh is not None, "dry-run requires a mesh"
        margin = self._margin_fn()
        bs = NamedSharding(self.mesh, self._batch_spec())
        rs = NamedSharding(self.mesh, self._row_spec())
        return margin, (bs, rs, rs, rs, rs), bs

    def input_specs(self, batch: int) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(
            (batch, self.arrays.f_pad), np.dtype(self.table_dtype)
        )
