"""X-TIME chip performance model (§III-C Eq. 4/5, §IV-B Fig. 8, §V Fig. 10/11).

The paper evaluates a simulated 16 nm chip with an SST cycle-detailed
simulator; this module is the analytical equivalent, built from the same
architectural constants (1 GHz clock, λ_CAM = 4 cycles, λ_C = 12 cycles,
4096 cores, radix-4 H-tree) and calibrated against every number the paper
reports:

  * core throughput 250 MS/s (≤4 trees/core, Eq. 4) / ~200 MS/s (5 trees,
    Eq. 5),
  * chip latency ~100 ns for typical models,
  * 19 W peak power, energy down to ~0.3 nJ/decision with batching,
  * Booster comparison: O(D) core occupancy, 1/(4D) samples/clock,
  * GPU comparison: latency 10 µs – 1 ms (V100, FIL kernels).

It consumes the compiler's ``CorePlacement`` and ``NoCPlan`` so every
number responds to the actual model mapping, exactly like the paper's
toolchain.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.compile import CAMTable, ChipSpec, CorePlacement
from repro.core.noc import NoCPlan


# ---------------------------------------------------------------------------
# Power / area constants (Fig. 8: "area and power mainly consumed by the
# analog CAM arrays, peripherals negligible"; totals calibrated to the
# paper's 19 W peak for 4096 active cores at 16 nm).
# ---------------------------------------------------------------------------


@dataclass
class PowerAreaSpec:
    acam_mw_per_core: float = 4.20  # aCAM arrays + DAC + SA + P-Ch (dominant)
    sram_logic_mw_per_core: float = 0.25  # buffer, MMR, SRAM, ACC
    router_mw: float = 0.50  # per router, TSMC 16nm-ish
    cp_w: float = 0.40  # co-processor + IO
    acam_mm2_per_core: float = 0.030  # 256x130 macro-cells + periph
    sram_logic_mm2_per_core: float = 0.006
    router_mm2: float = 0.002
    cp_mm2: float = 2.0

    def chip_power_w(self, spec: ChipSpec, active_cores: int | None = None) -> float:
        n = spec.n_cores if active_cores is None else active_cores
        return (
            n * (self.acam_mw_per_core + self.sram_logic_mw_per_core) / 1e3
            + spec.n_routers * self.router_mw / 1e3
            + self.cp_w
        )

    def chip_area_mm2(self, spec: ChipSpec) -> float:
        return (
            spec.n_cores * (self.acam_mm2_per_core + self.sram_logic_mm2_per_core)
            + spec.n_routers * self.router_mm2
            + self.cp_mm2
        )


@dataclass
class PerfReport:
    name: str
    latency_ns: float
    throughput_msps: float  # million samples / s
    energy_nj_per_dec: float
    power_w: float
    area_mm2: float
    bottleneck: str
    n_cores_used: int
    replication: int

    def as_row(self) -> dict:
        return {
            "name": self.name,
            "latency_ns": round(self.latency_ns, 2),
            "throughput_msps": round(self.throughput_msps, 2),
            "energy_nj_per_dec": round(self.energy_nj_per_dec, 4),
            "power_w": round(self.power_w, 2),
            "bottleneck": self.bottleneck,
            "cores": self.n_cores_used,
            "replication": self.replication,
        }


# ---------------------------------------------------------------------------
# X-TIME chip model
# ---------------------------------------------------------------------------


def core_throughput_msps(n_trees_core: int, spec: ChipSpec, n_samples: int = 10**6) -> float:
    """Eq. 4 / Eq. 5: pipelined core throughput.

    ≤4 trees/core: a new sample enters every λ_CAM cycles (Eq. 4, ~250 MS/s).
    >4 trees/core: the MMR needs N_B = N_trees,core iterations, inserting
    bubbles (Eq. 5, ~200 MS/s at 5 trees).
    """
    f_hz = spec.clock_ghz * 1e9
    if n_trees_core <= spec.lambda_cam:
        cycles = spec.lambda_core + spec.lambda_cam * (n_samples - 1)
    else:
        cycles = spec.lambda_core + n_trees_core * (n_samples - 1)
    return n_samples / (cycles / f_hz) / 1e6


def xtime_perf(
    table: CAMTable,
    placement: CorePlacement,
    noc: NoCPlan,
    *,
    spec: ChipSpec | None = None,
    power_area: PowerAreaSpec | None = None,
    batch: int = 1 << 20,
    io_overhead_cycles: int = 60,
) -> PerfReport:
    """Latency/throughput/energy for one model on one X-TIME chip.

    ``io_overhead_cycles`` covers chip ingress/egress + CP decision,
    calibrated so typical Table-II models land at the paper's ~100 ns
    latency (§V-A).
    """
    spec = spec or placement.spec
    pa = power_area or PowerAreaSpec()
    f_hz = spec.clock_ghz * 1e9

    # --- latency of a single sample (unbatched) ---
    # input broadcast: feature vector streams down the H-tree; queued arrays
    # receive ceil(F/65) sequential segments (§III-C input segmentation).
    # Physical columns only: compression-collapsed wildcard columns are
    # never broadcast, so the latency/throughput respond to the rewrite.
    seg = placement.n_feature_segments
    bcast_cycles = noc.n_levels + int(np.ceil(table.n_cols / spec.flit_bytes))
    core_cycles = spec.lambda_core + spec.lambda_cam * max(0, seg - spec.n_queued) // spec.n_queued
    mmr_extra = max(0, placement.max_trees_per_core - 1)  # sequential leaf reads
    noc_up_cycles = noc.n_levels + int(np.ceil(noc.flits_per_sample_per_level[-1])) - 1
    cp_cycles = noc.cp_ops_per_sample
    lat_cycles = (
        bcast_cycles + core_cycles + mmr_extra + noc_up_cycles + cp_cycles + io_overhead_cycles
    )
    latency_ns = lat_cycles / f_hz * 1e9

    # --- steady-state throughput ---
    tau_core = core_throughput_msps(placement.max_trees_per_core, spec, batch)
    # root link: 1 flit/cycle; multiclass forwards n_outputs flits/sample
    root_flits = noc.flits_per_sample_per_level[-1]
    tau_noc = f_hz / root_flits / 1e6
    # input broadcast: one feature segment (65 features) per cycle down the
    # tree; queued arrays consume n_queued segments in parallel per search.
    tau_in = f_hz / max(1.0, seg / spec.n_queued * spec.lambda_cam) / 1e6
    tau_chip = min(tau_core, tau_noc, tau_in)
    bottleneck = {tau_core: "core-pipeline", tau_noc: "noc-root", tau_in: "input-broadcast"}[
        tau_chip
    ]
    throughput = tau_chip * noc.replication  # input batching (§III-D)

    # --- power / energy ---
    active = placement.n_cores_used * noc.replication
    power = pa.chip_power_w(spec, active_cores=active)
    energy_nj = power / (throughput * 1e6) * 1e9
    area = pa.chip_area_mm2(spec)

    return PerfReport(
        name="x-time",
        latency_ns=latency_ns,
        throughput_msps=throughput,
        energy_nj_per_dec=energy_nj,
        power_w=power,
        area_mm2=area,
        bottleneck=bottleneck,
        n_cores_used=placement.n_cores_used,
        replication=noc.replication,
    )


# ---------------------------------------------------------------------------
# Kernel v2 memory-traffic model (DESIGN.md §10) — what compact dtypes and
# wildcard tile skipping buy on the TPU/CPU adaptation, as bytes.
# ---------------------------------------------------------------------------


def kernel_traffic_model(
    *,
    batch: int,
    rows: int,
    features: int,
    channels: int,
    table_dtype: str = "int32",
    tile_skip_fraction: float = 0.0,
    rows_saved: int = 0,
    cols_saved: int = 0,
) -> dict:
    """Bytes one cam_match call streams through VMEM, and its arithmetic
    intensity — the roofline inputs the autotuner's candidates move.

    ``rows``/``features`` are the COMPRESSED shapes actually streamed
    (pass ``CAMTable.n_rows``/``CAMTable.n_cols``); ``rows_saved`` /
    ``cols_saved`` carry what compression removed so the report can
    price the rewrite (``uncompressed_ratio``: table traffic the naive
    one-row-per-leaf layout would have streamed, relative to this one).
    ``table_dtype`` scales the threshold-table and query traffic (the low
    and high tables dominate: 2·R·F cells vs B·F queries).
    ``tile_skip_fraction`` discounts COMPARE OPS only: the v2 kernel's
    ``@pl.when`` guard skips the VPU work of an all-wildcard tile, but
    the BlockSpec pipeline still streams its blocks into VMEM — the
    bytes are spent either way (index-map-level skipping is future
    work).  Returns raw byte counts plus ``packed_ratio`` — table
    traffic relative to the v1 int32 layout (4.0 for uint8).
    """
    itemsize = np.dtype(table_dtype).itemsize
    live = 1.0 - tile_skip_fraction
    bytes_tables = 2 * rows * features * itemsize
    bytes_queries = batch * features * itemsize
    bytes_leaf = rows * channels * 4
    bytes_out = batch * channels * 4
    total = bytes_tables + bytes_queries + bytes_leaf + bytes_out
    compare_ops = 2.0 * batch * rows * features * live
    mac_ops = 2.0 * batch * rows * channels
    naive_tables = (
        2 * (rows + rows_saved) * (features + cols_saved) * itemsize
    )
    return {
        "bytes_tables": bytes_tables,
        "bytes_queries": bytes_queries,
        "bytes_leaf": bytes_leaf,
        "bytes_out": bytes_out,
        "bytes_total": total,
        "compare_ops": compare_ops,
        "mac_ops": mac_ops,
        "intensity_ops_per_byte": (compare_ops + mac_ops) / max(1.0, total),
        "packed_ratio": 4.0 / itemsize,
        "uncompressed_ratio": naive_tables / max(1, bytes_tables),
    }


# ---------------------------------------------------------------------------
# Booster (He et al., IPDPS'22) — digital LUT ASIC comparison (§V-B)
# ---------------------------------------------------------------------------


def booster_perf(
    table: CAMTable,
    placement: CorePlacement,
    noc: NoCPlan,
    *,
    depth: int,
    spec: ChipSpec | None = None,
    power_area: PowerAreaSpec | None = None,
    node_cycles: int = 4,
) -> PerfReport:
    """Same chip/NoC, LUT cores: O(D) node fetches per sample (4 cyc/node),
    new sample admitted every 4·D cycles (paper: throughput 1/4D)."""
    spec = spec or placement.spec
    pa = power_area or PowerAreaSpec()
    f_hz = spec.clock_ghz * 1e9

    traverse_cycles = node_cycles * depth
    bcast_cycles = noc.n_levels + int(np.ceil(table.n_cols / spec.flit_bytes))
    noc_up = noc.n_levels + int(np.ceil(noc.flits_per_sample_per_level[-1])) - 1
    lat_cycles = bcast_cycles + traverse_cycles + noc_up + noc.cp_ops_per_sample + 60
    tau_core = f_hz / traverse_cycles / 1e6  # 1/(4D) samples/clock
    tau_noc = f_hz / noc.flits_per_sample_per_level[-1] / 1e6
    tau = min(tau_core, tau_noc) * noc.replication
    power = pa.chip_power_w(spec, active_cores=placement.n_cores_used * noc.replication)
    return PerfReport(
        name="booster-model",
        latency_ns=lat_cycles / f_hz * 1e9,
        throughput_msps=tau,
        energy_nj_per_dec=power / (tau * 1e6) * 1e9,
        power_w=power,
        area_mm2=pa.chip_area_mm2(spec),
        bottleneck="lut-traversal" if tau_core < tau_noc else "noc-root",
        n_cores_used=placement.n_cores_used,
        replication=noc.replication,
    )


# ---------------------------------------------------------------------------
# GPU analytical model (V100 + RAPIDS FIL, §IV-C) — calibrated to the
# paper's measured range (latency 10 µs – 1 ms; Fig. 11 trends: linear in
# N_trees and D, flat in N_feat).
# ---------------------------------------------------------------------------


@dataclass
class GPUSpec:
    """V100 + FIL constants.

    ``node_visit_rate`` is the single calibrated parameter: effective
    (sample, tree, level) gathers per second under FIL's breadth-first
    interleaved layout.  8.22e10/s reproduces the paper's Churn-modelling
    measurement pair — ~0.98 ms batch latency and ~21 MS/s saturated
    throughput for 404 trees x depth 8 at a ~20 K saturation batch —
    which yields the 9740x / 119x headline comparison exactly.  The model
    keeps the paper's observed scaling: throughput prop. 1/(N_trees*D),
    flat in N_feat (Fig. 11), latency dominated by the saturated-batch
    sweep.
    """

    kernel_launch_us: float = 10.0  # fixed kernel + scheduling overhead
    node_visit_rate: float = 8.22e10  # gathers/s, memory-system bound
    saturation_batch: int = 20480  # batch at which throughput plateaus
    imbalance: float = 1.2  # tall-tree synchronization penalty (§II-B)


def gpu_perf_model(
    *,
    n_trees: int,
    depth: int,
    batch: int | None = None,
    gpu: GPUSpec | None = None,
) -> PerfReport:
    """Analytical V100 inference model for tree ensembles (§IV-C protocol:
    kernel time only, batch swept to saturation)."""
    g = gpu or GPUSpec()
    b = g.saturation_batch if batch is None else batch
    visits = float(b) * n_trees * max(1, depth) * g.imbalance
    sweep_us = visits / g.node_visit_rate * 1e6
    lat_us = g.kernel_launch_us + sweep_us
    throughput = b / (lat_us * 1e-6) / 1e6
    return PerfReport(
        name="gpu-model",
        latency_ns=lat_us * 1e3,
        throughput_msps=throughput,
        energy_nj_per_dec=250.0 / (throughput * 1e6) * 1e9,  # 250 W card
        power_w=250.0,
        area_mm2=815.0,
        bottleneck="memory-gather" if sweep_us > g.kernel_launch_us else "launch-overhead",
        n_cores_used=80,
        replication=1,
    )
