"""X-TIME compiler: tree ensembles -> CAM tables -> core placement (§II-D, §III-A).

Every root-to-leaf path of every tree becomes one CAM row storing per
feature an integer range ``[low, high)`` over the quantizer's bin grid
(don't-care = the full range ``[0, n_bins)``), plus the leaf value, tree id
and class id — the ``L x (2*N_feat + 3)`` table of §III-A.

``pack_cores`` then performs the paper's placement: trees are assigned to
cores (first-fit decreasing over the N_words = N_stacked * H row budget),
features are segmented over queued arrays, and models smaller than the chip
are replicated for input batching (§III-D).  The placement feeds the cycle
model in ``perfmodel.py`` and defines the row-shard boundaries of the
distributed engine.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.trees import Ensemble

# Kernel table dtypes, narrowest first.  The packed (unsigned) dtypes store
# INCLUSIVE upper bounds so the full bin range [0, n_bins) fits the dtype
# (n_bins=256 needs values up to 255, not 256) — see DESIGN.md §10.
TABLE_DTYPES = ("uint8", "uint16", "int32")


def select_table_dtype(n_bins: int) -> str:
    """Narrowest kernel dtype the grid cardinality permits (§III-B: the
    paper's native precision is 8-bit; uint8 covers its whole design
    space).  Packed dtypes hold inclusive bounds, so ``n_bins - 1`` is
    the largest stored value."""
    if n_bins <= 1 << 8:
        return "uint8"
    if n_bins <= 1 << 16:
        return "uint16"
    return "int32"


@dataclass
class CAMTable:
    """The compiled ensemble: one row per leaf (root-to-leaf path).

    ``low``/``high`` are always held here in canonical int32
    exclusive-high form — the semantic layer every compiler/analysis
    consumer reads.  ``table_dtype`` records the packed dtype the KERNEL
    path may stream instead (selected at compile time from ``n_bins``);
    the engine performs the actual packing (inclusive-high, narrow
    dtype) at bind time and the artifact stores the packed form at rest.

    ``feature_ids`` is set by the compression pass when it physically
    drops all-wildcard feature columns (``repro.core.compress``): it maps
    each stored column back to the original query feature index, so the
    engine selects ``q[:, feature_ids]`` before matching.  ``None`` means
    the identity layout (every query feature has a column).
    ``n_features`` always stays the LOGICAL query width; ``n_cols`` is
    the physical table width.

    ``col_perm`` records the compile-time column clustering
    (``order_columns_by_activity``): stored column ``j`` holds what the
    pre-clustering layout held at column ``col_perm[j]``, so the engine
    permutes selected queries with ``q[:, col_perm]`` before matching.
    It is a PURE permutation (width-preserving), which is exactly why it
    cannot ride ``feature_ids``: the engine's pass-through shortcut for
    pre-narrowed queries keys off the width change, and a permutation
    has none.  ``None`` means original column order.
    """

    low: np.ndarray  # (R, n_cols) int32, inclusive lower bin bound
    high: np.ndarray  # (R, n_cols) int32, exclusive upper bin bound
    leaf: np.ndarray  # (R,) float32 leaf value (logit / vote / mean)
    tree_id: np.ndarray  # (R,) int32
    class_id: np.ndarray  # (R,) int32, output channel of the leaf
    n_trees: int
    n_features: int
    n_bins: int
    n_outputs: int
    task: str
    kind: str
    base_score: float
    n_classes: int
    table_dtype: str = "int32"  # packed kernel dtype (schema v1-additive)
    feature_ids: np.ndarray | None = None  # (n_cols,) int32 (schema v3-additive)
    col_perm: np.ndarray | None = None  # (n_cols,) int32 (schema v3-additive)

    @property
    def n_rows(self) -> int:
        return int(self.low.shape[0])

    @property
    def n_cols(self) -> int:
        """Physical feature-column count of the stored table (equals
        ``n_features`` unless compression collapsed wildcard columns)."""
        return int(self.low.shape[1])

    def dont_care_fraction(self) -> float:
        """Fraction of cells programmed to the full range (wildcards)."""
        dc = (self.low == 0) & (self.high == self.n_bins)
        return float(dc.mean())

    def feature_occupancy(self) -> np.ndarray:
        """(n_cols,) fraction of rows with a real (non-wildcard) range per
        stored feature column — how hard each queued-array column works
        (``scripts/ingest.py`` prints the mean for ingested tables; the
        compression pass collapses columns where this is exactly 0)."""
        dc = (self.low == 0) & (self.high == self.n_bins)
        return 1.0 - dc.mean(axis=0)

    def row_tile_activity(self, f_blk: int) -> np.ndarray:
        """(R, ceil(F/f_blk)) bool — which feature tiles each row actually
        constrains (non-wildcard).  The shared primitive behind
        ``tile_activity`` and the wildcard row ordering;
        ``kops.wildcard_tile_mask`` is the padded/packed kernel-side twin.
        """
        act = ~((self.low == 0) & (self.high == self.n_bins))
        R, F = act.shape
        nf = max(1, -(-F // f_blk))
        padded = np.zeros((R, nf * f_blk), dtype=bool)
        padded[:, :F] = act
        return padded.reshape(R, nf, f_blk).any(axis=-1)

    def packed_row_activity(self, f_blk: int) -> np.ndarray:
        """(R, ceil(T/8)) uint8 — each row's feature-tile activity bitmask,
        bit-packed big-endian (tile 0 is the MSB of byte 0).  One byte per
        8 feature tiles instead of one bool per tile; byte-lexicographic
        order equals the numeric order of the unpacked bitmask, so this is
        the sort key behind the wildcard row clustering at any tile count.
        """
        return np.packbits(self.row_tile_activity(f_blk), axis=1)

    def tile_activity(self, r_blk: int, f_blk: int) -> np.ndarray:
        """(ceil(R/r_blk), ceil(F/f_blk)) bool — does any cell of the tile
        hold a real (non-wildcard) range?  An all-wildcard tile matches
        every query, so the v2 kernel skips its compare entirely."""
        rows = self.row_tile_activity(f_blk)
        R, nf = rows.shape
        nr = max(1, -(-R // r_blk))
        padded = np.zeros((nr * r_blk, nf), dtype=bool)
        padded[:R] = rows
        return padded.reshape(nr, r_blk, nf).any(axis=1)

    def tile_skip_fraction(self, r_blk: int, f_blk: int) -> float:
        """Fraction of (r_blk, f_blk) compare tiles the v2 kernel skips —
        what wildcard-aware row ordering maximizes."""
        act = self.tile_activity(r_blk, f_blk)
        return float(1.0 - act.mean()) if act.size else 0.0

    def permuted(self, perm: np.ndarray) -> "CAMTable":
        """The same table with rows reordered by ``perm`` — semantically
        identical (the match+accumulate is row-order invariant)."""
        return replace(
            self,
            low=self.low[perm],
            high=self.high[perm],
            leaf=self.leaf[perm],
            tree_id=self.tree_id[perm],
            class_id=self.class_id[perm],
        )

    def leaf_matrix(self) -> np.ndarray:
        """(R, n_outputs) leaf values scattered to their class channel.

        ``match @ leaf_matrix`` is the in-core accumulation + class routing:
        the MXU replacement for the paper's MMR + SRAM + ACC path.
        """
        m = np.zeros((self.n_rows, self.n_outputs), dtype=np.float32)
        m[np.arange(self.n_rows), self.class_id] = self.leaf
        return m


def validate_ensemble(ens: Ensemble) -> None:
    """Structural preconditions of the compiler, checked up front so a
    malformed model (hand-built or ingested) fails with a diagnosis
    instead of an index error mid-traversal."""
    F, B = ens.n_features, ens.n_bins
    for i, tree in enumerate(ens.trees):
        n = tree.n_nodes
        internal = tree.feature >= 0
        if np.any(tree.feature >= F):
            raise ValueError(f"tree {i}: split feature >= n_features={F}")
        t = tree.threshold[internal]
        if t.size and (t.min() < 1 or t.max() > B - 1):
            raise ValueError(
                f"tree {i}: bin threshold outside [1, {B - 1}] "
                f"(n_bins={B}) — was the model lowered onto this grid?"
            )
        kids = np.concatenate([tree.left[internal], tree.right[internal]])
        if kids.size and (kids.min() < 0 or kids.max() >= n):
            raise ValueError(f"tree {i}: child index outside [0, {n})")
    if ens.leaf_class_mode == "leaf" and len(ens.leaf_class) != ens.n_trees:
        raise ValueError("leaf_class_mode='leaf' needs leaf_class per tree")


def order_rows_by_wildcards(table: CAMTable, f_blk: int = 128) -> CAMTable:
    """Cluster rows by which feature tiles they actually constrain.

    Tree rows are overwhelmingly wildcards (MonoSparse-CAM,
    arXiv:2407.11071): a depth-d path constrains ≤ d of F features.
    Sorting rows by their per-feature-tile activity bitmask groups rows
    that are all-wildcard in the same ``f_blk``-wide tile into the same
    row blocks, turning those (r_blk, f_blk) tiles into skippable
    no-ops for the v2 kernel.  Stable sort: rows with identical
    activity keep their tree-traversal order.
    """
    # bit-packed per-row activity masks: byte-lexicographic order equals
    # the numeric order of the full bitmask (tile 0 = MSB), at any tile
    # count — no <63-tile integer-key special case.  lexsort's last key
    # is primary, so feed the bytes most-significant-last; it is stable,
    # so rows with identical activity keep their tree-traversal order.
    packed = table.packed_row_activity(f_blk)  # (R, ceil(T/8)) uint8
    perm = np.lexsort(packed.T[::-1])
    return table.permuted(perm)


def order_columns_by_activity(table: CAMTable, f_blk: int = 128) -> CAMTable:
    """Cluster feature COLUMNS so all-wildcard features cost zero matches.

    Compression may leave (and uncompressed tables always have) columns
    that no row constrains — every cell is the full range, so they match
    any query.  Scattered among active columns they poison their
    ``f_blk``-wide tiles; moved together at the tail they join the
    always-match column padding and their tiles drop out of the kernel's
    wildcard tile mask entirely.  Stable partition: active columns keep
    their original relative order, so partially-active tiles stay as
    clustered as the original layout had them.

    The permutation is recorded on ``CAMTable.col_perm`` (composed with
    any existing one) and the row clustering re-runs on the new layout —
    both are semantics-free given the engine permutes queries to match
    (``XTimeEngine.select_features``).  Identity permutations return the
    table unchanged (no ``col_perm``, artifact schema stays put).
    """
    active = table.feature_occupancy() > 0.0
    perm = np.argsort(~active, kind="stable").astype(np.int32)
    if np.array_equal(perm, np.arange(table.n_cols, dtype=np.int32)):
        return table  # nothing to move; don't stamp a trivial col_perm
    prev = table.col_perm
    combined = perm if prev is None else np.asarray(prev, np.int32)[perm]
    out = replace(
        table,
        low=table.low[:, perm],
        high=table.high[:, perm],
        col_perm=combined,
    )
    return order_rows_by_wildcards(out, f_blk)


def compile_ensemble(
    ens: Ensemble,
    *,
    table_dtype: str = "auto",
    order_rows: bool = True,
    cluster_columns: bool = False,
) -> CAMTable:
    """Traverse every tree, emit one CAM row per leaf.

    ``table_dtype='auto'`` selects the narrowest kernel dtype the bin
    grid permits (``select_table_dtype``); pass ``'int32'`` to pin the
    v1 wide layout.  ``order_rows`` applies the wildcard-aware row
    clustering (row order never affects results — see ``permuted``).
    ``cluster_columns`` additionally runs ``order_columns_by_activity``,
    recording the column permutation on the table (``col_perm``) so the
    engine permutes queries to match; off by default because it bumps
    the artifact schema to v3 and only pays off when all-wildcard
    feature columns exist.
    """
    if table_dtype == "auto":
        table_dtype = select_table_dtype(ens.n_bins)
    if table_dtype not in TABLE_DTYPES:
        raise ValueError(f"table_dtype {table_dtype!r} not in {TABLE_DTYPES}")
    if table_dtype != "int32" and ens.n_bins - 1 > np.iinfo(table_dtype).max:
        raise ValueError(
            f"table_dtype {table_dtype!r} cannot hold n_bins={ens.n_bins} "
            "(inclusive bounds store values up to n_bins-1)"
        )
    validate_ensemble(ens)
    F, B = ens.n_features, ens.n_bins
    lows: list[np.ndarray] = []
    highs: list[np.ndarray] = []
    leaves: list[float] = []
    tree_ids: list[int] = []
    class_ids: list[int] = []

    for i, tree in enumerate(ens.trees):
        # iterative DFS carrying the [low, high) box of the current path
        stack = [(0, np.zeros(F, dtype=np.int32), np.full(F, B, dtype=np.int32))]
        while stack:
            node, lo, hi = stack.pop()
            f = int(tree.feature[node])
            if f < 0:  # leaf
                lows.append(lo)
                highs.append(hi)
                leaves.append(float(tree.value[node]))
                tree_ids.append(i)
                if ens.leaf_class_mode == "leaf":
                    class_ids.append(int(ens.leaf_class[i][node]))
                else:
                    c = 0 if ens.tree_class is None else int(ens.tree_class[i])
                    class_ids.append(c)
                continue
            t = int(tree.threshold[node])
            llo, lhi = lo.copy(), hi.copy()
            lhi[f] = min(lhi[f], t)  # left: bin < t
            rlo, rhi = lo.copy(), hi.copy()
            rlo[f] = max(rlo[f], t)  # right: bin >= t
            stack.append((int(tree.right[node]), rlo, rhi))
            stack.append((int(tree.left[node]), llo, lhi))

    table = CAMTable(
        low=np.stack(lows).astype(np.int32),
        high=np.stack(highs).astype(np.int32),
        leaf=np.asarray(leaves, dtype=np.float32),
        tree_id=np.asarray(tree_ids, dtype=np.int32),
        class_id=np.asarray(class_ids, dtype=np.int32),
        n_trees=ens.n_trees,
        n_features=F,
        n_bins=B,
        n_outputs=ens.n_outputs,
        task=ens.task,
        kind=ens.kind,
        base_score=ens.base_score,
        n_classes=ens.n_classes,
        table_dtype=table_dtype,
    )
    if order_rows:
        table = order_rows_by_wildcards(table)
    if cluster_columns:
        table = order_columns_by_activity(table)
    return table


# ---------------------------------------------------------------------------
# Core placement (§III-A, §III-C)
# ---------------------------------------------------------------------------


@dataclass
class ChipSpec:
    """X-TIME single-chip architecture constants (§III-C, §IV-B)."""

    n_cores: int = 4096
    array_rows: int = 128  # H
    array_cols: int = 65
    n_stacked: int = 2  # row-wise extension: N_words = n_stacked * array_rows
    n_queued: int = 2  # column-wise extension: width = n_queued * array_cols
    clock_ghz: float = 1.0
    lambda_cam: int = 4  # cycles per aCAM search (precharge, MSB, LSB, latch)
    lambda_core: int = 12  # end-to-end core latency in cycles
    peak_power_w: float = 19.0
    n_routers: int = 1365  # H-tree over 4096 cores (4096/4 + ... + 1)
    flit_bytes: int = 8  # 64-bit leaf flits
    noc_radix: int = 4

    @property
    def n_words(self) -> int:
        return self.n_stacked * self.array_rows

    @property
    def core_width(self) -> int:
        return self.n_queued * self.array_cols


@dataclass
class CorePlacement:
    """Result of packing one model onto the chip."""

    spec: ChipSpec
    # per used core: list of tree indices mapped to it
    core_trees: list[list[int]] = field(default_factory=list)
    core_rows_used: list[int] = field(default_factory=list)
    n_feature_segments: int = 1  # queued-array groups of <=65 features
    replication: int = 1  # input-batching copies of the whole model (§III-D)

    @property
    def n_cores_used(self) -> int:
        return len(self.core_trees)

    @property
    def max_trees_per_core(self) -> int:
        return max((len(t) for t in self.core_trees), default=0)

    @property
    def word_utilization(self) -> float:
        cap = self.n_cores_used * self.spec.n_words
        return (sum(self.core_rows_used) / cap) if cap else 0.0


def pack_cores(table: CAMTable, spec: ChipSpec | None = None) -> CorePlacement:
    """First-fit-decreasing placement of trees onto cores.

    A tree's leaves must live in one core (the MMR iterates matches locally,
    §III-A); the paper's hyperparameter search bounds N_leaves,max = 256 =
    N_words so this always holds for compliant models.
    """
    spec = spec or ChipSpec()
    leaves_per_tree = np.bincount(table.tree_id, minlength=table.n_trees)
    if leaves_per_tree.max(initial=0) > spec.n_words:
        raise ValueError(
            f"tree with {int(leaves_per_tree.max())} leaves exceeds core capacity "
            f"N_words={spec.n_words}; retrain with max_leaves<={spec.n_words}"
        )

    order = np.argsort(-leaves_per_tree)  # decreasing
    core_trees: list[list[int]] = []
    core_free: list[int] = []
    for t in order:
        need = int(leaves_per_tree[t])
        placed = False
        for c in range(len(core_trees)):
            if core_free[c] >= need:
                core_trees[c].append(int(t))
                core_free[c] -= need
                placed = True
                break
        if not placed:
            core_trees.append([int(t)])
            core_free.append(spec.n_words - need)
    n_used = len(core_trees)
    if n_used > spec.n_cores:
        raise ValueError(
            f"model needs {n_used} cores > chip capacity {spec.n_cores}; "
            "shard across chips (PCIe card scenario, §III-D)"
        )

    # segmentation counts the PHYSICAL columns streamed into the queued
    # arrays — collapsed wildcard columns cost no segment
    n_seg = int(np.ceil(table.n_cols / spec.array_cols))
    replication = max(1, spec.n_cores // max(1, n_used))
    return CorePlacement(
        spec=spec,
        core_trees=core_trees,
        core_rows_used=[spec.n_words - f for f in core_free],
        n_feature_segments=n_seg,
        replication=replication,
    )


def padded_table(
    table: CAMTable, row_multiple: int = 256
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Pad rows to a multiple (tile/shard size). Padding rows can never match
    (low=1 > high=0 for every feature).  Returns (low, high, leaf_matrix, R_pad).
    """
    R = table.n_rows
    R_pad = int(np.ceil(R / row_multiple)) * row_multiple
    low = np.ones((R_pad, table.n_cols), dtype=np.int32)
    high = np.zeros((R_pad, table.n_cols), dtype=np.int32)
    low[:R] = table.low
    high[:R] = table.high
    leaf_m = np.zeros((R_pad, table.n_outputs), dtype=np.float32)
    leaf_m[:R] = table.leaf_matrix()
    return low, high, leaf_m, R_pad
