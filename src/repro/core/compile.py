"""X-TIME compiler: tree ensembles -> CAM tables -> core placement (§II-D, §III-A).

Every root-to-leaf path of every tree becomes one CAM row storing per
feature an integer range ``[low, high)`` over the quantizer's bin grid
(don't-care = the full range ``[0, n_bins)``), plus the leaf value, tree id
and class id — the ``L x (2*N_feat + 3)`` table of §III-A.

``pack_cores`` then performs the paper's placement: trees are assigned to
cores (first-fit decreasing over the N_words = N_stacked * H row budget),
features are segmented over queued arrays, and models smaller than the chip
are replicated for input batching (§III-D).  The placement feeds the cycle
model in ``perfmodel.py`` and defines the row-shard boundaries of the
distributed engine.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.trees import Ensemble


@dataclass
class CAMTable:
    """The compiled ensemble: one row per leaf (root-to-leaf path)."""

    low: np.ndarray  # (R, F) int32, inclusive lower bin bound
    high: np.ndarray  # (R, F) int32, exclusive upper bin bound
    leaf: np.ndarray  # (R,) float32 leaf value (logit / vote / mean)
    tree_id: np.ndarray  # (R,) int32
    class_id: np.ndarray  # (R,) int32, output channel of the leaf
    n_trees: int
    n_features: int
    n_bins: int
    n_outputs: int
    task: str
    kind: str
    base_score: float
    n_classes: int

    @property
    def n_rows(self) -> int:
        return int(self.low.shape[0])

    def dont_care_fraction(self) -> float:
        """Fraction of cells programmed to the full range (wildcards)."""
        dc = (self.low == 0) & (self.high == self.n_bins)
        return float(dc.mean())

    def feature_occupancy(self) -> np.ndarray:
        """(F,) fraction of rows with a real (non-wildcard) range per
        feature — how hard each queued-array column works
        (``scripts/ingest.py`` prints the mean for ingested tables)."""
        dc = (self.low == 0) & (self.high == self.n_bins)
        return 1.0 - dc.mean(axis=0)

    def leaf_matrix(self) -> np.ndarray:
        """(R, n_outputs) leaf values scattered to their class channel.

        ``match @ leaf_matrix`` is the in-core accumulation + class routing:
        the MXU replacement for the paper's MMR + SRAM + ACC path.
        """
        m = np.zeros((self.n_rows, self.n_outputs), dtype=np.float32)
        m[np.arange(self.n_rows), self.class_id] = self.leaf
        return m


def validate_ensemble(ens: Ensemble) -> None:
    """Structural preconditions of the compiler, checked up front so a
    malformed model (hand-built or ingested) fails with a diagnosis
    instead of an index error mid-traversal."""
    F, B = ens.n_features, ens.n_bins
    for i, tree in enumerate(ens.trees):
        n = tree.n_nodes
        internal = tree.feature >= 0
        if np.any(tree.feature >= F):
            raise ValueError(f"tree {i}: split feature >= n_features={F}")
        t = tree.threshold[internal]
        if t.size and (t.min() < 1 or t.max() > B - 1):
            raise ValueError(
                f"tree {i}: bin threshold outside [1, {B - 1}] "
                f"(n_bins={B}) — was the model lowered onto this grid?"
            )
        kids = np.concatenate([tree.left[internal], tree.right[internal]])
        if kids.size and (kids.min() < 0 or kids.max() >= n):
            raise ValueError(f"tree {i}: child index outside [0, {n})")
    if ens.leaf_class_mode == "leaf" and len(ens.leaf_class) != ens.n_trees:
        raise ValueError("leaf_class_mode='leaf' needs leaf_class per tree")


def compile_ensemble(ens: Ensemble) -> CAMTable:
    """Traverse every tree, emit one CAM row per leaf."""
    validate_ensemble(ens)
    F, B = ens.n_features, ens.n_bins
    lows: list[np.ndarray] = []
    highs: list[np.ndarray] = []
    leaves: list[float] = []
    tree_ids: list[int] = []
    class_ids: list[int] = []

    for i, tree in enumerate(ens.trees):
        # iterative DFS carrying the [low, high) box of the current path
        stack = [(0, np.zeros(F, dtype=np.int32), np.full(F, B, dtype=np.int32))]
        while stack:
            node, lo, hi = stack.pop()
            f = int(tree.feature[node])
            if f < 0:  # leaf
                lows.append(lo)
                highs.append(hi)
                leaves.append(float(tree.value[node]))
                tree_ids.append(i)
                if ens.leaf_class_mode == "leaf":
                    class_ids.append(int(ens.leaf_class[i][node]))
                else:
                    c = 0 if ens.tree_class is None else int(ens.tree_class[i])
                    class_ids.append(c)
                continue
            t = int(tree.threshold[node])
            llo, lhi = lo.copy(), hi.copy()
            lhi[f] = min(lhi[f], t)  # left: bin < t
            rlo, rhi = lo.copy(), hi.copy()
            rlo[f] = max(rlo[f], t)  # right: bin >= t
            stack.append((int(tree.right[node]), rlo, rhi))
            stack.append((int(tree.left[node]), llo, lhi))

    return CAMTable(
        low=np.stack(lows).astype(np.int32),
        high=np.stack(highs).astype(np.int32),
        leaf=np.asarray(leaves, dtype=np.float32),
        tree_id=np.asarray(tree_ids, dtype=np.int32),
        class_id=np.asarray(class_ids, dtype=np.int32),
        n_trees=ens.n_trees,
        n_features=F,
        n_bins=B,
        n_outputs=ens.n_outputs,
        task=ens.task,
        kind=ens.kind,
        base_score=ens.base_score,
        n_classes=ens.n_classes,
    )


# ---------------------------------------------------------------------------
# Core placement (§III-A, §III-C)
# ---------------------------------------------------------------------------


@dataclass
class ChipSpec:
    """X-TIME single-chip architecture constants (§III-C, §IV-B)."""

    n_cores: int = 4096
    array_rows: int = 128  # H
    array_cols: int = 65
    n_stacked: int = 2  # row-wise extension: N_words = n_stacked * array_rows
    n_queued: int = 2  # column-wise extension: width = n_queued * array_cols
    clock_ghz: float = 1.0
    lambda_cam: int = 4  # cycles per aCAM search (precharge, MSB, LSB, latch)
    lambda_core: int = 12  # end-to-end core latency in cycles
    peak_power_w: float = 19.0
    n_routers: int = 1365  # H-tree over 4096 cores (4096/4 + ... + 1)
    flit_bytes: int = 8  # 64-bit leaf flits
    noc_radix: int = 4

    @property
    def n_words(self) -> int:
        return self.n_stacked * self.array_rows

    @property
    def core_width(self) -> int:
        return self.n_queued * self.array_cols


@dataclass
class CorePlacement:
    """Result of packing one model onto the chip."""

    spec: ChipSpec
    # per used core: list of tree indices mapped to it
    core_trees: list[list[int]] = field(default_factory=list)
    core_rows_used: list[int] = field(default_factory=list)
    n_feature_segments: int = 1  # queued-array groups of <=65 features
    replication: int = 1  # input-batching copies of the whole model (§III-D)

    @property
    def n_cores_used(self) -> int:
        return len(self.core_trees)

    @property
    def max_trees_per_core(self) -> int:
        return max((len(t) for t in self.core_trees), default=0)

    @property
    def word_utilization(self) -> float:
        cap = self.n_cores_used * self.spec.n_words
        return (sum(self.core_rows_used) / cap) if cap else 0.0


def pack_cores(table: CAMTable, spec: ChipSpec | None = None) -> CorePlacement:
    """First-fit-decreasing placement of trees onto cores.

    A tree's leaves must live in one core (the MMR iterates matches locally,
    §III-A); the paper's hyperparameter search bounds N_leaves,max = 256 =
    N_words so this always holds for compliant models.
    """
    spec = spec or ChipSpec()
    leaves_per_tree = np.bincount(table.tree_id, minlength=table.n_trees)
    if leaves_per_tree.max(initial=0) > spec.n_words:
        raise ValueError(
            f"tree with {int(leaves_per_tree.max())} leaves exceeds core capacity "
            f"N_words={spec.n_words}; retrain with max_leaves<={spec.n_words}"
        )

    order = np.argsort(-leaves_per_tree)  # decreasing
    core_trees: list[list[int]] = []
    core_free: list[int] = []
    for t in order:
        need = int(leaves_per_tree[t])
        placed = False
        for c in range(len(core_trees)):
            if core_free[c] >= need:
                core_trees[c].append(int(t))
                core_free[c] -= need
                placed = True
                break
        if not placed:
            core_trees.append([int(t)])
            core_free.append(spec.n_words - need)
    n_used = len(core_trees)
    if n_used > spec.n_cores:
        raise ValueError(
            f"model needs {n_used} cores > chip capacity {spec.n_cores}; "
            "shard across chips (PCIe card scenario, §III-D)"
        )

    n_seg = int(np.ceil(table.n_features / spec.array_cols))
    replication = max(1, spec.n_cores // max(1, n_used))
    return CorePlacement(
        spec=spec,
        core_trees=core_trees,
        core_rows_used=[spec.n_words - f for f in core_free],
        n_feature_segments=n_seg,
        replication=replication,
    )


def padded_table(
    table: CAMTable, row_multiple: int = 256
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Pad rows to a multiple (tile/shard size). Padding rows can never match
    (low=1 > high=0 for every feature).  Returns (low, high, leaf_matrix, R_pad).
    """
    R = table.n_rows
    R_pad = int(np.ceil(R / row_multiple)) * row_multiple
    low = np.ones((R_pad, table.n_features), dtype=np.int32)
    high = np.zeros((R_pad, table.n_features), dtype=np.int32)
    low[:R] = table.low
    high[:R] = table.high
    leaf_m = np.zeros((R_pad, table.n_outputs), dtype=np.float32)
    leaf_m[:R] = table.leaf_matrix()
    return low, high, leaf_m, R_pad
