"""Hyperparameter search under X-TIME hardware constraints (§IV-A), plus
the kernel v2 execution autotuner (DESIGN.md §10).

The paper optimizes every model/dataset pair with Hyperopt (100 trials)
subject to the chip constraints (N_trees <= 4096, N_leaves,max <= 256,
8-bit thresholds) and picks the best configuration on held-out data.
This module reproduces that workflow with seeded random search over the
same space (no hyperopt offline; random search is a strong baseline for
these low-dimensional spaces).

``autotune_kernel`` is the execution-side twin: given a compiled table it
sweeps the kernel's ``(b_blk, r_blk, table_dtype, cell mode)`` space on
the device jax is actually bound to, times each candidate end to end
(padding included — what serving pays), and returns a ``TunePlan`` whose
winner folds into a ``DeployConfig``.  ``CompiledModel.with_tuning``
persists the plan in the artifact sidecar so a serve process cold-starts
straight into the tuned configuration with no re-search.
"""

from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field

import numpy as np

from repro.core.compile import CAMTable
from repro.core.deploy import DeployConfig
from repro.core.precision import get_cell_mode
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import Ensemble, GBDTParams, RFParams, train_gbdt, train_rf
from repro.data.tabular import TabularDataset, accuracy_metric

# v2: the plan carries a measured-cost DISPATCH table — one winning
# (kernel version, block sizes) entry per swept batch bucket — on top of
# the v1 top-level winner fields (which stay the primary-batch winner,
# so v1 readers keep working and v1 plans keep loading: ``from_dict``
# defaults an absent dispatch to empty and ``dispatch_for`` falls back
# to the top-level winner).
TUNE_SCHEMA_VERSION = 2


def kernel_version(table_dtype: str) -> str:
    """Kernel generation a resolved table dtype binds: the v1 int32
    exclusive-high layout, the v2 packed inclusive-high layout
    (uint8/uint16), or the float32 soft-encoded layout ('soft', running
    log-sum scratch).  The autotuner's dispatch table records this per
    batch bucket — the measured winner, not a size heuristic."""
    if table_dtype == "int32":
        return "v1"
    return "soft" if np.dtype(table_dtype).kind == "f" else "v2"


@dataclass
class HWConstraints:
    """§V-A 'X-TIME 8bit' envelope."""

    max_trees: int = 4096
    max_leaves: int = 256
    n_bins: int = 256


@dataclass
class Trial:
    params: dict
    valid_score: float
    n_trees: int
    max_leaves: int


@dataclass
class SearchResult:
    best: Trial
    trials: list[Trial] = field(default_factory=list)
    ensemble: Ensemble | None = None
    quantizer: FeatureQuantizer | None = None

    @property
    def test_ready(self) -> bool:
        return self.ensemble is not None


def _sample_gbdt(rng: np.random.Generator, hw: HWConstraints, n_classes: int) -> dict:
    leaves = int(rng.choice([16, 32, 64, 128, hw.max_leaves]))
    # rounds bounded so total trees respect the chip (multiclass: x classes)
    max_rounds = max(8, hw.max_trees // max(1, n_classes))
    return {
        "n_rounds": int(rng.integers(10, min(120, max_rounds))),
        "learning_rate": float(10 ** rng.uniform(-1.5, -0.4)),
        "max_leaves": leaves,
        "max_depth": int(rng.integers(4, 11)),
        "subsample": float(rng.uniform(0.6, 1.0)),
        "colsample": float(rng.uniform(0.5, 1.0)),
        "reg_lambda": float(10 ** rng.uniform(-1, 1)),
    }


def _sample_rf(rng: np.random.Generator, hw: HWConstraints) -> dict:
    return {
        "n_trees": int(rng.integers(20, min(200, hw.max_trees))),
        "max_leaves": int(rng.choice([32, 64, 128, hw.max_leaves])),
        "max_depth": int(rng.integers(6, 14)),
        "colsample": float(rng.uniform(0.3, 0.9)),
    }


def random_search(
    ds: TabularDataset,
    *,
    kind: str = "gbdt",
    n_trials: int = 20,
    hw: HWConstraints | None = None,
    seed: int = 0,
) -> SearchResult:
    """Seeded random search; scores on the VALIDATION split; refits the
    winner and returns it ready for CAM compilation."""
    hw = hw or HWConstraints()
    rng = np.random.default_rng(seed)
    quant = FeatureQuantizer.fit(ds.x_train, hw.n_bins)
    xb_tr = quant.transform(ds.x_train)
    xb_va = quant.transform(ds.x_valid)

    trials: list[Trial] = []
    best: Trial | None = None
    best_ens: Ensemble | None = None
    for t in range(n_trials):
        if kind == "gbdt":
            p = _sample_gbdt(rng, hw, ds.n_classes)
            ens = train_gbdt(
                xb_tr, ds.y_train, task=ds.task, n_bins=hw.n_bins,
                n_classes=ds.n_classes, params=GBDTParams(seed=seed + t, **p),
            )
        else:
            p = _sample_rf(rng, hw)
            ens = train_rf(
                xb_tr, ds.y_train, task=ds.task, n_bins=hw.n_bins,
                n_classes=ds.n_classes, params=RFParams(seed=seed + t, **p),
            )
        assert ens.n_trees <= hw.max_trees and ens.max_leaves <= hw.max_leaves
        score = accuracy_metric(ds.task, ds.y_valid, ens.predict(xb_va))
        trial = Trial(params=p, valid_score=score, n_trees=ens.n_trees,
                      max_leaves=ens.max_leaves)
        trials.append(trial)
        if best is None or score > best.valid_score:
            best, best_ens = trial, ens
    return SearchResult(best=best, trials=trials, ensemble=best_ens,
                        quantizer=quant)


# ---------------------------------------------------------------------------
# Kernel execution autotuner (kernel v2, DESIGN.md §10)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TunePlan:
    """The winning kernel configuration(s) of one ``autotune_kernel`` sweep.

    Serializes into the compiled-artifact sidecar (``CompiledModel.save``
    under the ``"tuning"`` key) so a reloaded artifact binds its engine
    with the tuned block sizes and dtype instead of re-searching.

    Schema v2 adds ``dispatch``: one measured-cost entry per swept batch
    bucket — ``{"batch", "b_blk", "r_blk", "table_dtype", "mode",
    "kernel", "us_per_call"}`` — because the v1/v2 kernel crossover is
    shape-dependent (the packed layout loses below a size threshold; see
    benchmarks/records).  ``dispatch_for(batch)`` resolves a serving
    batch to its bucket's winner, and ``apply(config, batch=...)`` folds
    it in; registry cold starts bind the winning kernel per bucket via
    ``CompiledModel.engine(batch_hint=...)``.  The top-level fields stay
    the PRIMARY-batch winner, so v1 plans load (empty dispatch) and v1
    readers of v2 plans see a valid single-bucket plan.
    """

    b_blk: int
    r_blk: int
    table_dtype: str  # resolved dtype ('uint8'/'uint16'/'int32'), not 'auto'
    mode: str
    backend: str
    us_per_call: float
    batch: int
    trials: list[dict] = field(default_factory=list)  # full sweep record
    env: dict = field(default_factory=dict)  # platform the sweep ran on
    dispatch: list[dict] = field(default_factory=list)  # per-batch winners (v2)
    schema_version: int = TUNE_SCHEMA_VERSION

    @property
    def kernel(self) -> str:
        """Kernel version the primary winner binds ('v1' | 'v2')."""
        return kernel_version(self.table_dtype)

    def dispatch_for(self, batch: int) -> dict:
        """The measured winner for a serving ``batch``: the SMALLEST swept
        bucket that covers it (a larger batch than every bucket takes the
        largest — its measurement is the closest regime).  Plans without
        a dispatch table (schema v1) fall back to the top-level winner as
        a synthesized single-bucket entry."""
        entries = sorted(self.dispatch, key=lambda e: int(e["batch"]))
        for e in entries:
            if batch <= int(e["batch"]):
                return e
        if entries:
            return entries[-1]
        return {
            "batch": self.batch, "b_blk": self.b_blk, "r_blk": self.r_blk,
            "table_dtype": self.table_dtype, "mode": self.mode,
            "kernel": self.kernel, "us_per_call": self.us_per_call,
        }

    def apply(self, config: DeployConfig, batch: int | None = None) -> DeployConfig:
        """Fold the winner into ``config`` (the tuned execution knobs).

        With ``batch`` the dispatch table picks the bucket winner; without
        it the primary top-level winner applies (v1 behavior)."""
        if batch is None:
            return config.replace(
                b_blk=self.b_blk,
                r_blk=self.r_blk,
                table_dtype=self.table_dtype,
                mode=self.mode,
                backend=self.backend,
            )
        e = self.dispatch_for(batch)
        return config.replace(
            b_blk=int(e["b_blk"]),
            r_blk=int(e["r_blk"]),
            table_dtype=str(e["table_dtype"]),
            mode=str(e["mode"]),
            backend=self.backend,
        )

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "TunePlan":
        known = {f.name for f in cls.__dataclass_fields__.values()}  # type: ignore[attr-defined]
        return cls(**{k: v for k, v in d.items() if k in known})


def _tune_env() -> dict:
    import jax

    return {
        "platform": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "jax": jax.__version__,
    }


def _time_margin(engine, q: np.ndarray, *, warmup: int, iters: int) -> float:
    """Median wall microseconds of one end-to-end ``raw_margin`` call."""
    for _ in range(warmup):
        np.asarray(engine.raw_margin(q))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        np.asarray(engine.raw_margin(q))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def autotune_kernel(
    model,
    *,
    deploy: DeployConfig | None = None,
    batch: int = 256,
    batches: tuple[int, ...] = (),
    b_blks: tuple[int, ...] = (64, 128, 256),
    r_blks: tuple[int, ...] = (128, 256, 512),
    table_dtypes: tuple[str, ...] | None = None,
    modes: tuple[str, ...] | None = None,
    warmup: int = 1,
    iters: int = 3,
    seed: int = 0,
) -> TunePlan:
    """Sweep the kernel execution space on the bound device; return the plan.

    ``model`` is a ``CAMTable`` or a ``repro.api.CompiledModel`` (whose
    own deploy config seeds the sweep unless ``deploy`` overrides it).
    Candidates are the cross product of ``b_blks`` × ``r_blks`` × the
    admissible (table_dtype, mode) pairs, deduplicated by their RESOLVED
    kernel layout — e.g. 'direct' and 'inclusive' collapse onto the same
    packed-inclusive kernel, and the faithful modes only ever run int32.
    The dtype axis is the kernel VERSION axis: the default sweep times
    both the v1 int32 layout and the v2 packed layout, because neither
    wins at every shape.  Every candidate computes the same bits (the
    engine equivalence contract), so the sweep is purely a performance
    search.

    ``batches`` adds batch buckets beyond the primary ``batch``: every
    candidate is timed at every bucket (padding included — what serving
    pays) and the per-bucket winners become the plan's DISPATCH table,
    so a registry cold start binds the measured-best kernel per serving
    bucket (``CompiledModel.engine(batch_hint=...)``).  The top-level
    winner stays the primary-``batch`` one.

    The winner is returned as a :class:`TunePlan`;
    ``CompiledModel.with_tuning(plan)`` persists it in the artifact.
    """
    from repro.core.engine import XTimeEngine, resolve_table_dtype

    if isinstance(model, CAMTable):
        table = model
    else:  # CompiledModel — avoid importing repro.api here (cycle)
        table = model.table
        if deploy is None:
            deploy = getattr(model, "deploy", None)
    deploy = deploy or DeployConfig()

    if modes is None:
        # dtype-pinned modes (the faithful macro-cell modes, 'soft') are a
        # deliberate semantic choice — keep them; the packable fast modes
        # sweep both int-compare flavours
        modes = ("direct", "inclusive") if get_cell_mode(deploy.mode).packable \
            else (deploy.mode,)
    if table_dtypes is None:
        table_dtypes = ("auto", "int32")

    seen: set[tuple] = set()
    candidates: list[DeployConfig] = []
    for mode in modes:
        policy = get_cell_mode(mode).table_dtype_policy
        for dt in table_dtypes:
            if policy is not None and dt not in ("auto", policy):
                continue
            cfg = deploy.replace(mode=mode, table_dtype=dt)
            resolved = resolve_table_dtype(table, cfg)
            kernel_mode = (
                "inclusive" if np.dtype(resolved).kind == "u" else mode
            )
            for b_blk in b_blks:
                for r_blk in r_blks:
                    key = (b_blk, r_blk, resolved, kernel_mode)
                    if key in seen:
                        continue
                    seen.add(key)
                    candidates.append(
                        cfg.replace(
                            b_blk=b_blk, r_blk=r_blk, table_dtype=resolved
                        )
                    )

    buckets = sorted({int(batch), *(int(b) for b in batches)})
    rng = np.random.default_rng(seed)
    # one query pool sized for the largest bucket; each bucket slices a
    # prefix so every candidate sees identical inputs per bucket
    q_pool = rng.integers(0, table.n_bins, size=(max(buckets), table.n_features))
    trials: list[dict] = []
    # per-bucket best, engines reused across buckets (jit caches per shape)
    best: dict[int, tuple[float, DeployConfig]] = {}
    for cfg in candidates:
        engine = XTimeEngine.from_config(table, cfg)
        for b in buckets:
            us = _time_margin(engine, q_pool[:b], warmup=warmup, iters=iters)
            trials.append({
                "batch": b, "b_blk": cfg.b_blk, "r_blk": cfg.r_blk,
                "table_dtype": cfg.table_dtype, "mode": cfg.mode,
                "kernel": kernel_version(cfg.table_dtype),
                "us_per_call": round(us, 2),
            })
            if b not in best or us < best[b][0]:
                best[b] = (us, cfg)

    assert best, "empty autotune candidate set"
    dispatch = [
        {
            "batch": b, "b_blk": c.b_blk, "r_blk": c.r_blk,
            "table_dtype": c.table_dtype, "mode": c.mode,
            "kernel": kernel_version(c.table_dtype),
            "us_per_call": round(u, 2),
        }
        for b, (u, c) in sorted(best.items())
    ]
    us, cfg = best[int(batch)]
    return TunePlan(
        b_blk=cfg.b_blk,
        r_blk=cfg.r_blk,
        table_dtype=cfg.table_dtype,
        mode=cfg.mode,
        backend=cfg.backend,
        us_per_call=round(us, 2),
        batch=batch,
        trials=trials,
        env=_tune_env(),
        dispatch=dispatch,
    )
