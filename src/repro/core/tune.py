"""Hyperparameter search under X-TIME hardware constraints (§IV-A).

The paper optimizes every model/dataset pair with Hyperopt (100 trials)
subject to the chip constraints (N_trees <= 4096, N_leaves,max <= 256,
8-bit thresholds) and picks the best configuration on held-out data.
This module reproduces that workflow with seeded random search over the
same space (no hyperopt offline; random search is a strong baseline for
these low-dimensional spaces).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.quantize import FeatureQuantizer
from repro.core.trees import Ensemble, GBDTParams, RFParams, train_gbdt, train_rf
from repro.data.tabular import TabularDataset, accuracy_metric


@dataclass
class HWConstraints:
    """§V-A 'X-TIME 8bit' envelope."""

    max_trees: int = 4096
    max_leaves: int = 256
    n_bins: int = 256


@dataclass
class Trial:
    params: dict
    valid_score: float
    n_trees: int
    max_leaves: int


@dataclass
class SearchResult:
    best: Trial
    trials: list[Trial] = field(default_factory=list)
    ensemble: Ensemble | None = None
    quantizer: FeatureQuantizer | None = None

    @property
    def test_ready(self) -> bool:
        return self.ensemble is not None


def _sample_gbdt(rng: np.random.Generator, hw: HWConstraints, n_classes: int) -> dict:
    leaves = int(rng.choice([16, 32, 64, 128, hw.max_leaves]))
    # rounds bounded so total trees respect the chip (multiclass: x classes)
    max_rounds = max(8, hw.max_trees // max(1, n_classes))
    return {
        "n_rounds": int(rng.integers(10, min(120, max_rounds))),
        "learning_rate": float(10 ** rng.uniform(-1.5, -0.4)),
        "max_leaves": leaves,
        "max_depth": int(rng.integers(4, 11)),
        "subsample": float(rng.uniform(0.6, 1.0)),
        "colsample": float(rng.uniform(0.5, 1.0)),
        "reg_lambda": float(10 ** rng.uniform(-1, 1)),
    }


def _sample_rf(rng: np.random.Generator, hw: HWConstraints) -> dict:
    return {
        "n_trees": int(rng.integers(20, min(200, hw.max_trees))),
        "max_leaves": int(rng.choice([32, 64, 128, hw.max_leaves])),
        "max_depth": int(rng.integers(6, 14)),
        "colsample": float(rng.uniform(0.3, 0.9)),
    }


def random_search(
    ds: TabularDataset,
    *,
    kind: str = "gbdt",
    n_trials: int = 20,
    hw: HWConstraints | None = None,
    seed: int = 0,
) -> SearchResult:
    """Seeded random search; scores on the VALIDATION split; refits the
    winner and returns it ready for CAM compilation."""
    hw = hw or HWConstraints()
    rng = np.random.default_rng(seed)
    quant = FeatureQuantizer.fit(ds.x_train, hw.n_bins)
    xb_tr = quant.transform(ds.x_train)
    xb_va = quant.transform(ds.x_valid)

    trials: list[Trial] = []
    best: Trial | None = None
    best_ens: Ensemble | None = None
    for t in range(n_trials):
        if kind == "gbdt":
            p = _sample_gbdt(rng, hw, ds.n_classes)
            ens = train_gbdt(
                xb_tr, ds.y_train, task=ds.task, n_bins=hw.n_bins,
                n_classes=ds.n_classes, params=GBDTParams(seed=seed + t, **p),
            )
        else:
            p = _sample_rf(rng, hw)
            ens = train_rf(
                xb_tr, ds.y_train, task=ds.task, n_bins=hw.n_bins,
                n_classes=ds.n_classes, params=RFParams(seed=seed + t, **p),
            )
        assert ens.n_trees <= hw.max_trees and ens.max_leaves <= hw.max_leaves
        score = accuracy_metric(ds.task, ds.y_valid, ens.predict(xb_va))
        trial = Trial(params=p, valid_score=score, n_trees=ens.n_trees,
                      max_leaves=ens.max_leaves)
        trials.append(trial)
        if best is None or score > best.valid_score:
            best, best_ens = trial, ens
    return SearchResult(best=best, trials=trials, ensemble=best_ens,
                        quantizer=quant)
