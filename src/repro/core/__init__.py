"""X-TIME core: the paper's contribution as a composable JAX module.

Pipeline:  train (trees.py)  ->  quantize (quantize.py)  ->  compile to CAM
table (compile.py)  ->  inference engine (engine.py, kernels/cam_match.py)
->  NoC reduction (noc.py)  ->  chip performance model (perfmodel.py).

``XTimeEngine`` / ``CompiledModel`` / ``build`` are exported lazily (PEP
562): engine.py pulls in repro.kernels (which imports repro.core.precision
back through this package), so resolving them on first attribute access —
instead of at package import — keeps ``repro.kernels.ref`` -> ``repro.core``
acyclic while still allowing ``from repro.core import XTimeEngine``.
"""

from repro.core.trees import (  # noqa: F401
    Tree,
    Ensemble,
    GBDTParams,
    RFParams,
    train_gbdt,
    train_rf,
)
from repro.core.quantize import FeatureQuantizer  # noqa: F401
from repro.core.compile import (  # noqa: F401
    CAMTable,
    ChipSpec,
    CorePlacement,
    compile_ensemble,
    pack_cores,
)
from repro.core.deploy import DeployConfig  # noqa: F401

_LAZY = {
    "XTimeEngine": "repro.core.engine",
    "EngineArrays": "repro.core.engine",
    "CompiledModel": "repro.api",
    "build": "repro.api",
    # kernel autotuner (tune.py imports the engine lazily itself, but its
    # module also pulls the training stack — keep it off the import path)
    "TunePlan": "repro.core.tune",
    "autotune_kernel": "repro.core.tune",
}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        value = getattr(importlib.import_module(_LAZY[name]), name)
        globals()[name] = value  # cache: next access skips this hook
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(_LAZY))
