"""X-TIME core: the paper's contribution as a composable JAX module.

Pipeline:  train (trees.py)  ->  quantize (quantize.py)  ->  compile to CAM
table (compile.py)  ->  inference engine (engine.py, kernels/cam_match.py)
->  NoC reduction (noc.py)  ->  chip performance model (perfmodel.py).
"""

from repro.core.trees import (  # noqa: F401
    Tree,
    Ensemble,
    GBDTParams,
    RFParams,
    train_gbdt,
    train_rf,
)
from repro.core.quantize import FeatureQuantizer  # noqa: F401
from repro.core.compile import CAMTable, compile_ensemble, pack_cores  # noqa: F401

# NOTE: XTimeEngine is intentionally NOT re-exported here — engine.py
# depends on repro.kernels which depends on repro.core.precision; importing
# it eagerly would make `repro.kernels.ref` -> `repro.core` circular.
# Use `from repro.core.engine import XTimeEngine`.
