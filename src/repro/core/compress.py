"""RETENTION-style CAM table compression (arXiv:2312.03088, PAPERS.md).

The naive one-row-per-leaf mapping explodes CAM rows on paper-scale
models (4096 trees x depth 8 -> 1M rows); RETENTION shows large
ensembles fit bounded CAM capacity with resource-efficient row mapping,
and MonoSparse-CAM's sparsity observations say many lowered rows/cells
are dead weight.  ``compress_table`` runs between ``compile_ensemble``
and packing (``repro.api.build(compress=...)``) and applies three
strictly bit-equivalence-preserving rewrites:

  prune  — drop rows that can never match: structurally empty intervals
           (``low >= high``, produced by contradictory duplicate splits
           on one path) and, when the artifact's own ``FeatureQuantizer``
           grid is attached, rows whose interval starts at or above the
           feature's realizable bin count.  Grid-vacuous upper bounds
           (``high >= effective_bins``) are widened to full wildcards —
           they exclude nothing a real query can present, and widening
           feeds both the column collapse and the kernel's wildcard tile
           skipping.
  merge  — RETENTION's common-prefix factoring: two rows of the SAME
           tree and class channel whose interval boxes are identical in
           every feature but one, adjacent in that one (``high_a ==
           low_b``), and whose leaf payloads are bit-identical, are one
           leaf split needlessly in two — they fuse into the union row.
           Iterated to fixpoint, a constant subtree collapses level by
           level into its root's single row.
  collapse — feature columns that are all-wildcard across every row
           (``CAMTable.feature_occupancy() == 0``) are physically
           dropped; ``CAMTable.feature_ids`` records the surviving
           original indices so the engine selects query columns before
           matching.  Dropped columns cost zero CAM cells, zero queued-
           array segments and zero kernel feature tiles.

Bit-equivalence contract (tests/test_compress.py): for every query the
engine can be handed — any bin vector when no grid is given, any
grid-realizable bin vector when one is — the per-query multiset of leaf
values accumulated into each output channel is IDENTICAL before and
after compression.  Pruned rows contribute only a +0.0 that float
addition absorbs; merged rows replace {v, v-matched-once} with the same
v matched once (a query inside the union interval matched exactly one of
the two adjacent source rows); collapsed columns never constrained any
match.  What can therefore NOT merge: rows with bit-different leaf
values (the sum would change), rows of different trees or class channels
(both could match one query — the multiset would lose a term), and
duplicate rows with IDENTICAL boxes (each contributes its value; fusing
them would halve the contribution) — see DESIGN.md §11.

Grid-aware stages (unreachable-row pruning, vacuous-bound widening) run
only when a grid is passed: they are exact for every query produced by
``FeatureQuantizer.transform`` but would change results for bin vectors
outside the grid's realizable range, which is why ``build`` passes the
artifact's own attached quantizer and nothing else.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace

import numpy as np

from repro.core.compile import CAMTable, order_rows_by_wildcards
from repro.core.quantize import FeatureQuantizer

# 'off' is the identity; 'prune' = dead rows + grid widening; 'merge' adds
# sibling-interval factoring; 'full' adds wildcard-column collapse.
# 'auto' is the serving alias for the strongest level.
COMPRESS_LEVELS = ("off", "prune", "merge", "full", "auto")


@dataclass
class CompressionReport:
    """Per-stage accounting of one ``compress_table`` run (artifact
    sidecar payload — ``CompiledModel.compression``)."""

    level: str
    rows_before: int
    rows_after: int
    cols_before: int
    cols_after: int
    pruned_empty: int = 0  # structurally empty [low, high) boxes
    pruned_unreachable: int = 0  # empty under the quantizer grid only
    merged_rows: int = 0  # rows removed by sibling-interval factoring
    widened_cells: int = 0  # grid-vacuous bounds widened to wildcard
    collapsed_columns: int = 0  # all-wildcard feature columns dropped
    sentinel_rows: int = 0  # wildcard zero-leaf rows kept (empty-table guard)

    @property
    def rows_saved(self) -> int:
        return self.rows_before - self.rows_after

    @property
    def row_savings_fraction(self) -> float:
        return self.rows_saved / self.rows_before if self.rows_before else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # derived numbers ride along: the sidecar is read by dashboards
        # and the bench gate, neither of which should re-derive them
        d["rows_saved"] = self.rows_saved
        d["row_savings_fraction"] = self.row_savings_fraction
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CompressionReport":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def resolve_level(level: str) -> str:
    """Validate and normalize a compression level ('auto' -> 'full')."""
    if level not in COMPRESS_LEVELS:
        raise ValueError(f"compress level {level!r} not in {COMPRESS_LEVELS}")
    return "full" if level == "auto" else level


def _effective_bins(table: CAMTable, grid: FeatureQuantizer | None) -> np.ndarray:
    """(n_cols,) realizable bin count per PHYSICAL column, capped at the
    table grid (no grid -> every bin below n_bins is presumed reachable)."""
    if grid is None:
        return np.full(table.n_cols, table.n_bins, dtype=np.int64)
    if grid.n_features != table.n_features:
        raise ValueError(
            f"grid covers {grid.n_features} features but the table queries "
            f"{table.n_features}; compress with the artifact's own quantizer"
        )
    eff = np.minimum(grid.effective_bins_array(), table.n_bins)
    if table.feature_ids is not None:
        eff = eff[np.asarray(table.feature_ids, dtype=np.int64)]
    return eff


def _merge_rows(
    low: np.ndarray,
    high: np.ndarray,
    leaf: np.ndarray,
    tree_id: np.ndarray,
    class_id: np.ndarray,
    n_bins: int,
) -> tuple[np.ndarray, int]:
    """Fixpoint sibling-interval factoring; mutates ``high`` in place.

    Returns ``(alive_mask, n_merged)``.  Rows group by (class channel,
    leaf BITS, box-minus-one-feature); within a group, intervals along
    the remaining feature are sorted and strictly-adjacent neighbours
    (``high_a == low_b``) fuse.  Bit-level leaf keys keep +0.0 and -0.0
    apart, and identical (duplicate) intervals are never adjacent, so
    duplicate leaves survive untouched — both deliberate (see module
    docstring).  Per-tree work is tiny (<= N_words rows), so the python
    group loop only ever sees a few hundred rows.
    """
    alive = np.ones(low.shape[0], dtype=bool)
    leaf_key = leaf.astype(np.float32).view(np.uint32).astype(np.int64)
    n_merged = 0
    for t in np.unique(tree_id):
        rows = np.flatnonzero(tree_id == t)
        changed = True
        while changed:
            changed = False
            live = rows[alive[rows]]
            if live.shape[0] < 2:
                break
            constrained = np.flatnonzero(
                ((low[live] > 0) | (high[live] < n_bins)).any(axis=0)
            )
            for f in constrained:
                live = rows[alive[rows]]
                if live.shape[0] < 2:
                    break
                # group key: everything but feature f's interval, as one
                # int64 row hashed through a void view (vectorized)
                box = np.column_stack(
                    [
                        class_id[live].astype(np.int64),
                        leaf_key[live],
                        np.delete(low[live], f, axis=1).astype(np.int64),
                        np.delete(high[live], f, axis=1).astype(np.int64),
                    ]
                )
                keys = np.ascontiguousarray(box).view(
                    [("", np.int64)] * box.shape[1]
                ).ravel()
                _, inv, counts = np.unique(
                    keys, return_inverse=True, return_counts=True
                )
                for g in np.flatnonzero(counts > 1):
                    members = live[inv == g]
                    members = members[np.argsort(low[members, f], kind="stable")]
                    cur = members[0]
                    for r in members[1:]:
                        if high[cur, f] == low[r, f]:
                            high[cur, f] = high[r, f]
                            alive[r] = False
                            n_merged += 1
                            changed = True
                        else:
                            cur = r
    return alive, n_merged


def compress_table(
    table: CAMTable,
    grid: FeatureQuantizer | None = None,
    *,
    level: str = "auto",
) -> tuple[CAMTable, CompressionReport]:
    """Compress a compiled CAM table; returns ``(table, report)``.

    ``grid`` enables the grid-aware stages and must be the quantizer the
    table's queries flow through (``build`` passes the artifact's own);
    without it only query-universal rewrites run.  The result is
    re-ordered by wildcard tile activity (a permutation — row order never
    affects results) so the savings also reach the v2 kernel's tile
    skipping.  ``level='off'`` is the identity.
    """
    level = resolve_level(level)
    n_rows, n_cols = table.n_rows, table.n_cols
    report = CompressionReport(
        level=level,
        rows_before=n_rows,
        rows_after=n_rows,
        cols_before=n_cols,
        cols_after=n_cols,
    )
    if level == "off":
        return table, report

    low = np.asarray(table.low, dtype=np.int32).copy()
    high = np.asarray(table.high, dtype=np.int32).copy()
    B = table.n_bins
    eff = _effective_bins(table, grid)

    # -- prune: never-matching rows, then grid-vacuous bound widening ------
    empty = (low >= high).any(axis=1)
    unreachable = (low >= eff[None, :]).any(axis=1) & ~empty
    keep = ~(empty | unreachable)
    report.pruned_empty = int(empty.sum())
    report.pruned_unreachable = int(unreachable.sum())
    low, high = low[keep], high[keep]
    leaf = np.asarray(table.leaf, dtype=np.float32)[keep]
    tree_id = np.asarray(table.tree_id, dtype=np.int32)[keep]
    class_id = np.asarray(table.class_id, dtype=np.int32)[keep]
    # realizable bins stop at eff-1, so high >= eff excludes nothing a
    # grid query can present: widen to the full range (more wildcards ->
    # more merges, collapses and skippable tiles)
    vacuous = (high >= eff[None, :]) & (high < B)
    report.widened_cells = int(vacuous.sum())
    high[vacuous] = B

    # -- merge: sibling-interval common-prefix factoring -------------------
    if level in ("merge", "full") and low.shape[0] > 1:
        alive, n_merged = _merge_rows(low, high, leaf, tree_id, class_id, B)
        report.merged_rows = n_merged
        low, high = low[alive], high[alive]
        leaf, tree_id, class_id = leaf[alive], tree_id[alive], class_id[alive]

    # an entirely-pruned table (every row dead) still has to pack, pad and
    # place: keep one all-wildcard zero-leaf sentinel row — it adds +0.0
    # to channel 0 of every query, exactly what the dead rows added
    if low.shape[0] == 0:
        low = np.zeros((1, n_cols), dtype=np.int32)
        high = np.full((1, n_cols), B, dtype=np.int32)
        leaf = np.zeros(1, dtype=np.float32)
        tree_id = np.zeros(1, dtype=np.int32)
        class_id = np.zeros(1, dtype=np.int32)
        report.sentinel_rows = 1

    # -- collapse: drop all-wildcard feature columns -----------------------
    feature_ids = table.feature_ids
    if level == "full":
        keep_cols = ~((low == 0) & (high == B)).all(axis=0)
        if not keep_cols.any():
            keep_cols[0] = True  # zero-width queries are degenerate
        dropped = n_cols - int(keep_cols.sum())
        if dropped:
            cols = (
                np.asarray(table.feature_ids, dtype=np.int32)
                if table.feature_ids is not None
                else np.arange(table.n_features, dtype=np.int32)
            )
            feature_ids = cols[keep_cols]
            low, high = low[:, keep_cols], high[:, keep_cols]
            report.collapsed_columns = dropped

    report.rows_after = int(low.shape[0])
    report.cols_after = int(low.shape[1])
    out = replace(
        table,
        low=low,
        high=high,
        leaf=leaf,
        tree_id=tree_id,
        class_id=class_id,
        feature_ids=feature_ids,
    )
    return order_rows_by_wildcards(out), report
