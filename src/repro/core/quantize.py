"""Per-feature quantile binning — the paper's N_bit feature grid (§III-B).

X-TIME represents thresholds and queries on an N_bit grid (256 bins for the
8-bit configuration that matches FP accuracy, 16 bins for the 4-bit
iso-area ablation).  ``FeatureQuantizer`` computes per-feature quantile cut
points on training data; trees are trained *directly on bins* so the CAM
table, the traversal baseline, and the float model agree bit-exactly.

Convention (shared with trees.py and compile.py):
    bin(x) = searchsorted(edges, x, side='right')  in [0, n_bins-1]
    split "bin < t" == "x < edges[t-1]"
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class FeatureQuantizer:
    edges: list[np.ndarray]  # per feature, ascending unique cut points (<= n_bins-1)
    n_bins: int

    @property
    def n_features(self) -> int:
        return len(self.edges)

    @staticmethod
    def from_thresholds(
        thresholds: list[np.ndarray],
        n_bins: int = 256,
        on_overflow: str = "merge",
    ) -> tuple["FeatureQuantizer", list[int]]:
        """Build the grid directly from a model's own split points (§III-B).

        Ingestion path: instead of fitting quantiles on training data, the
        per-feature edge set IS the sorted unique thresholds of the
        imported ensemble, so every split lands exactly on a grid edge and
        binned inference is bit-identical to float inference.

        A feature may carry at most ``n_bins - 1`` distinct thresholds.
        Beyond that, ``on_overflow='merge'`` keeps an evenly-spaced
        subsample (nearest-edge remapping then loses exactness — the
        ingest report records every merged threshold), while ``'raise'``
        rejects the model.  Returns ``(quantizer, merged_per_feature)``.
        """
        if not 2 <= n_bins <= 65536:
            raise ValueError(f"n_bins must be in [2, 65536], got {n_bins}")
        if on_overflow not in ("merge", "raise"):
            raise ValueError(f"on_overflow {on_overflow!r} not in (merge, raise)")
        edges: list[np.ndarray] = []
        merged: list[int] = []
        cap = n_bins - 1
        for f, th in enumerate(thresholds):
            e = np.unique(np.asarray(th, dtype=np.float64))
            if not np.all(np.isfinite(e)):
                raise ValueError(f"feature {f}: non-finite threshold")
            if e.shape[0] > cap:
                if on_overflow == "raise":
                    raise ValueError(
                        f"feature {f}: {e.shape[0]} distinct thresholds exceed "
                        f"the {cap}-edge grid (n_bins={n_bins}); raise n_bins "
                        "or allow on_overflow='merge'"
                    )
                keep = np.round(np.linspace(0, e.shape[0] - 1, cap)).astype(int)
                merged.append(e.shape[0] - cap)
                e = e[np.unique(keep)]
            else:
                merged.append(0)
            edges.append(e)
        return FeatureQuantizer(edges=edges, n_bins=n_bins), merged

    @staticmethod
    def fit(x: np.ndarray, n_bins: int = 256) -> "FeatureQuantizer":
        """Quantile cuts per feature; duplicate quantiles are collapsed."""
        if not 2 <= n_bins <= 65536:
            raise ValueError(f"n_bins must be in [2, 65536], got {n_bins}")
        edges = []
        qs = np.linspace(0, 1, n_bins + 1)[1:-1]
        for f in range(x.shape[1]):
            col = x[:, f]
            col = col[np.isfinite(col)]
            if col.size == 0:
                edges.append(np.zeros((0,), dtype=np.float64))
                continue
            e = np.unique(np.quantile(col, qs))
            # drop degenerate cuts at the extremes (everything on one side)
            e = e[(e > col.min()) & (e <= col.max())]
            edges.append(np.asarray(e, dtype=np.float64))
        return FeatureQuantizer(edges=edges, n_bins=n_bins)

    def transform(self, x: np.ndarray) -> np.ndarray:
        """Float features -> integer bins (n, F).

        dtype is uint8 when n_bins <= 256 (the paper's DAC input width),
        else int32.  NaN (missing) maps to bin 0 — the trainer can still
        route it; the CAM don't-care covers the missing-feature case.
        """
        out = np.zeros(x.shape, dtype=np.int64)
        for f in range(x.shape[1]):
            col = np.nan_to_num(x[:, f], nan=-np.inf)
            out[:, f] = np.searchsorted(self.edges[f], col, side="right")
        dtype = np.uint8 if self.n_bins <= 256 else np.int32
        return out.astype(dtype)

    def effective_bins(self, f: int) -> int:
        """Number of distinct bins actually realizable for feature f."""
        return int(self.edges[f].shape[0]) + 1

    def effective_bins_array(self) -> np.ndarray:
        """(n_features,) realizable bin counts — ``transform`` can only
        ever emit bins in ``[0, effective_bins(f) - 1]`` per feature, so
        anything a CAM row constrains at or above that count is dead
        weight the compression pass prunes/widens against this vector."""
        return np.asarray(
            [e.shape[0] + 1 for e in self.edges], dtype=np.int64
        )

    def threshold_value(self, f: int, t: int) -> float:
        """Float-space threshold for split 'bin < t' (x < edges[t-1])."""
        return float(self.edges[f][t - 1])

    def bin_of_threshold(self, f: int, v: float) -> tuple[int, bool]:
        """Bin split point ``t`` realizing float split ``x < v`` as
        ``bin < t``, plus whether the mapping is exact.

        Exact iff ``v`` is a grid edge (always true on an unmerged
        ``from_thresholds`` grid); otherwise the nearest edge is used —
        the ingest report counts these remapped splits.
        """
        e = self.edges[f]
        if e.shape[0] == 0:
            raise ValueError(f"feature {f} has no grid edges to split on")
        i = int(np.searchsorted(e, v, side="left"))
        if i < e.shape[0] and e[i] == v:
            return i + 1, True
        lo = max(i - 1, 0)
        hi = min(i, e.shape[0] - 1)
        j = lo if abs(e[lo] - v) <= abs(e[hi] - v) else hi
        return j + 1, False
