"""Unified deployment configuration: the ONE place execution knobs live.

Every consumer of the engine used to re-thread the same loose kwargs
(``backend``, ``mode``, ``b_blk``, ``r_blk``, ``noc_config``, mesh axes)
through ``XTimeEngine``, the registry's engine kwargs, benchmarks and
examples.  ``DeployConfig`` collects them into one frozen, serializable
dataclass that travels INSIDE the compiled artifact (``repro.api.build``
-> ``CompiledModel``), so a model saved on one host binds to an engine on
another with identical execution semantics.

``noc_config='auto'`` defers the collective choice to the compiled NoC
plan (``NoCPlan.engine_noc_config``) at engine-bind time — the paper's
router program decides, not the caller.  A bare engine with no plan
resolves 'auto' to 'accumulate' (Fig. 7a), the universal-correctness
config.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

from repro.core.precision import CELL_MODES, get_cell_mode, mode_names

BACKENDS = ("jnp", "pallas")
NOC_CONFIGS = ("auto", "accumulate", "batch", "hybrid")
SPMD_MODES = ("auto", "gspmd", "shard_map")
TABLE_DTYPES = ("auto", "uint8", "uint16", "int32", "float32")
# every user-facing mode list derives from the CellMode registry
# (repro.core.precision) — the tuples below are kept as the back-compat
# names downstream code imports, never hand-enumerated again
MODES = mode_names()
FAITHFUL_MODES = tuple(m.name for m in CELL_MODES.values() if m.faithful)
PACKABLE_MODES = tuple(m.name for m in CELL_MODES.values() if m.packable)
# table-compression levels (repro.core.compress): 'auto' == 'full'
COMPRESS_LEVELS = ("off", "prune", "merge", "full", "auto")


@dataclass(frozen=True)
class DeployConfig:
    """Execution knobs for a compiled model, independent of any device.

    Attributes:
      backend: 'jnp' (XLA-fused oracle, distributed default) or 'pallas'
        (TPU kernel; ``interpret=True`` on CPU).
      mode: aCAM cell comparison mode ('direct' | 'inclusive' |
        'msb_lsb' | 'two_cycle').
      noc_config: 'auto' resolves from the compiled ``NoCPlan``;
        'accumulate' / 'batch' / 'hybrid' force the engine collective
        ('hybrid' is the 2-D batch × core program for large meshes —
        shard_map only, DESIGN.md §8).
      spmd: how a mesh engine is partitioned.  'shard_map' runs the
        kernel per device shard and issues the NoC plan's collectives
        explicitly; 'gspmd' keeps the implicit ``NamedSharding`` +
        compiler-placed collectives; 'auto' resolves at engine-bind
        time (mesh present -> 'shard_map', no mesh -> 'gspmd').
      row_axis / batch_axis: mesh axis names for CAM-row sharding and
        batch sharding (plus a leading 'pod' axis when present).
      b_blk / r_blk: kernel batch/row tile sizes — also the padding
        granularity of queries and CAM rows.
      f_blk: feature tile width of the v2 kernel's third grid dimension
        (lane multiple; features pad to it, DESIGN.md §10).
      table_dtype: kernel table dtype.  'auto' takes the compile-time
        selection carried on the ``CAMTable`` (uint8 for ≤256 bins,
        uint16 to 65536, int32 beyond); an explicit packed dtype
        overrides it; modes with a pinned dtype policy
        (``CellMode.table_dtype_policy`` — the faithful modes pin the
        int32 exclusive-high layout, 'soft' pins float32 soft-encoded
        bounds) always run that layout.
      tau: boundary temperature of the 'soft' cell mode, in BIN units —
        the sigmoid width of each cell's match score.  ``0.0`` is the
        exact hard limit (bit-equal predictions to 'direct'); the
        default gives gentle sub-bin smoothing.  Ignored by hard modes.
      c_mult: leaf-channel padding multiple (kernel lane packing).
      interpret: run the Pallas kernel in interpret mode.  'auto'
        (default) resolves at engine-bind time: compiled on TPU,
        interpreted elsewhere — callers no longer hard-code it.
      fuse_epilogue: fuse the epilogue's base-score add into the Pallas
        kernel's last feature tile (kernel v3) — bit-identical, saves
        the separate epilogue pass's HBM round-trip.  'auto' (default)
        fuses exactly when eligible: backend='pallas' with no mesh (a
        row-sharded psum would count the base once per shard).  True
        demands fusion (engine bind fails if ineligible); False keeps
        the separate epilogue (the differential-test pivot).
      batching: chip-side input batching (§III-D Fig. 7c) — replicate a
        small model across core groups; feeds ``plan_noc`` at build time.
      compress: RETENTION-style table compression level applied between
        compile and packing ('off' | 'prune' | 'merge' | 'full', with
        'auto' = 'full' — see ``repro.core.compress``).  Like
        ``batching`` this is a BUILD-time knob: it rewrites the CAM
        table itself, so it cannot be overridden at engine-bind time and
        ``with_deploy`` pins it to what the artifact's table actually is.
    """

    backend: str = "jnp"
    mode: str = "direct"
    noc_config: str = "auto"
    spmd: str = "auto"
    row_axis: str = "model"
    batch_axis: str = "data"
    b_blk: int = 128
    r_blk: int = 256
    f_blk: int = 128
    table_dtype: str = "auto"
    tau: float = 0.1
    c_mult: int = 8
    interpret: bool | str = "auto"
    fuse_epilogue: bool | str = "auto"
    batching: bool = False
    compress: str = "off"

    def __post_init__(self) -> None:
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        cell = get_cell_mode(self.mode)  # unknown modes list the registry
        if self.noc_config not in NOC_CONFIGS:
            raise ValueError(
                f"noc_config {self.noc_config!r} not in {NOC_CONFIGS}"
            )
        if self.spmd not in SPMD_MODES:
            raise ValueError(f"spmd {self.spmd!r} not in {SPMD_MODES}")
        if self.table_dtype not in TABLE_DTYPES:
            raise ValueError(
                f"table_dtype {self.table_dtype!r} not in {TABLE_DTYPES}"
            )
        policy = cell.table_dtype_policy
        if policy is not None and self.table_dtype not in ("auto", policy):
            raise ValueError(
                f"mode {self.mode!r} pins the {policy!r} table layout; "
                f"table_dtype={self.table_dtype!r} is only available for "
                f"modes {PACKABLE_MODES}"
            )
        if self.table_dtype == "float32" and not cell.soft:
            raise ValueError(
                "table_dtype 'float32' is the soft-encoded layout; it "
                f"requires mode='soft' (got mode={self.mode!r})"
            )
        if not (
            isinstance(self.tau, (int, float))
            and math.isfinite(self.tau)
            and self.tau >= 0.0
        ):
            raise ValueError(
                f"tau must be a finite temperature >= 0, got {self.tau!r}"
            )
        if self.b_blk < 1 or self.r_blk < 1 or self.c_mult < 1:
            raise ValueError("b_blk, r_blk and c_mult must be >= 1")
        if self.f_blk < 1:
            raise ValueError("f_blk must be >= 1")
        if self.interpret not in (True, False, "auto"):
            raise ValueError("interpret must be True, False or 'auto'")
        if self.fuse_epilogue not in (True, False, "auto"):
            raise ValueError("fuse_epilogue must be True, False or 'auto'")
        if self.compress not in COMPRESS_LEVELS:
            raise ValueError(
                f"compress {self.compress!r} not in {COMPRESS_LEVELS}"
            )

    # -- derivation ----------------------------------------------------------

    def replace(self, **changes) -> "DeployConfig":
        """A copy with ``changes`` applied (frozen-dataclass update)."""
        return dataclasses.replace(self, **changes)

    # -- (de)serialization ---------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "DeployConfig":
        """Rebuild from a JSON dict; unknown keys are ignored so minor
        additive schema revisions stay loadable."""
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})
