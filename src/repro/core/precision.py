"""Increased-precision analog CAM arithmetic (§III-B, Eq. 1-3, Table I).

Memristor cells hold M=4 bits; the paper's macro-cell evaluates an
N=2M=8-bit range compare by splitting the threshold T = 16*T_MSB + T_LSB
and the query q = 16*q_MSB + q_LSB and computing (Eq. 3):

    T_L <= q < T_H  <=>
        [(q_M >= T_LM + 1) OR  (q_L >= T_LL)] AND (q_M >= T_LM)
    AND [(q_M <  T_HM)     OR  (q_L <  T_HL)] AND (q_M <  T_HM + 1)

This module reproduces that logic bit-exactly (``match_msb_lsb``), plus a
cycle-level simulation of the two-step search of Table I
(``match_two_cycle``): cycle 1 evaluates the OR brackets with the LSB and
shifted-MSB inputs; cycle 2 keeps the match line charged only if the
conjunctive MSB terms also hold ("always care" on the LSB sub-cell).  Both
are property-tested against the direct comparison ``(T_L <= q) & (q < T_H)``
over the full 8-bit space.

All functions are pure jnp and vectorize over arbitrary leading shapes, so
they drop into the engine / Pallas kernel as an alternate match mode.  jax
is imported lazily (inside the match functions, at trace time) so this
module — home of the :class:`CellMode` registry ``DeployConfig`` resolves
through — keeps artifact load/inspect paths jax-free.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Callable

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - typing only
    import jax.numpy as jnp

M_BITS = 4
M_LEVELS = 1 << M_BITS  # 16 analog levels per sub-cell


def split_msb_lsb(v: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """v in [0, 256) -> (v >> 4, v & 15), each an M-bit quantity."""
    import jax.numpy as jnp

    v = v.astype(jnp.int32)
    return v >> M_BITS, v & (M_LEVELS - 1)


def match_direct(q: jnp.ndarray, t_low: jnp.ndarray, t_high: jnp.ndarray) -> jnp.ndarray:
    """The ideal 8-bit comparison the macro-cell must reproduce."""
    import jax.numpy as jnp

    q = q.astype(jnp.int32)
    return (t_low.astype(jnp.int32) <= q) & (q < t_high.astype(jnp.int32))


def match_inclusive(q: jnp.ndarray, t_low: jnp.ndarray, t_high: jnp.ndarray) -> jnp.ndarray:
    """Compact uint8 table format (EXPERIMENTS.md §Perf X1): INCLUSIVE
    upper bound so all of [0, 255] fits in uint8 — low <= q <= high.
    Never-match rows encode low=1 > high=0; always-match cells low=0,
    high=255.  Compared in the native (unsigned) dtype: no upcast."""
    return (t_low <= q) & (q <= t_high)


def match_msb_lsb(q: jnp.ndarray, t_low: jnp.ndarray, t_high: jnp.ndarray) -> jnp.ndarray:
    """Eq. 3 evaluated with only M-bit comparisons (the macro-cell logic)."""
    qm, ql = split_msb_lsb(q)
    tlm, tll = split_msb_lsb(t_low)
    thm, thl = split_msb_lsb(t_high)
    lower = ((qm >= tlm + 1) | (ql >= tll)) & (qm >= tlm)  # Eq. 2
    upper = ((qm < thm) | (ql < thl)) & (qm < thm + 1)  # dual for q < T_H
    return lower & upper


def match_two_cycle(q: jnp.ndarray, t_low: jnp.ndarray, t_high: jnp.ndarray) -> jnp.ndarray:
    """Cycle-level simulation of the Table-I two-step search.

    The physical match line (MAL) is precharged once; each cycle can only
    *discharge* it (wired-AND across cycles).  Per Table I:

      cycle 1  inputs: q_LLSB=q_LSB, q_HLSB=q_LSB, q_LMSB=q_MSB-1, q_HMSB=q_MSB
               -> each macro-cell's OR of (MSB sub-cell, LSB sub-cell) must
               hold: [(q_M-1 >= T_LM) | (q_L >= T_LL)] for the lower bound
               and [(q_M < T_HM) | (q_L < T_HL)] for the upper bound.
      cycle 2  inputs: q_LLSB=VDD, q_HLSB=GND ("always care", i.e. the LSB
               sub-cells are driven to *always mismatch* given the lo/hi
               side circuit polarity), q_LMSB=q_MSB, q_HMSB=q_MSB-1
               -> the macro-cell OR degenerates to its MSB term, evaluating
               the conjunctive terms (q_M >= T_LM) and (q_M < T_HM + 1).

    Because the MAL can only be discharged, the state after cycle 2 is the
    AND of both cycles' evaluations, which equals Eq. 3.
    """
    import jax.numpy as jnp

    qm, ql = split_msb_lsb(q)
    tlm, tll = split_msb_lsb(t_low)
    thm, thl = split_msb_lsb(t_high)

    # cycle 1: OR brackets.  Lower-bound macro-cell: MSB sub-cell sees
    # q_MSB-1 against ">= T_LM" (i.e. q_MSB >= T_LM+1); LSB sub-cell sees
    # q_LSB against ">= T_LL".  Upper-bound: MSB sub-cell q_MSB < T_HM, LSB
    # q_LSB < T_HL.  The macro-cell keeps MAL charged if either sub-cell
    # matches (parallel pull-down paths in series with each other, Fig. 5a).
    cyc1_lower = ((qm - 1) >= tlm) | (ql >= tll)
    cyc1_upper = (qm < thm) | (ql < thl)
    mal_after_1 = cyc1_lower & cyc1_upper

    # cycle 2: LSB sub-cells driven to always-mismatch (VDD/GND per Table I),
    # so the macro-cell OR reduces to the MSB sub-cell's standalone term.
    lsb_forced_mismatch = jnp.zeros_like(ql, dtype=bool)
    cyc2_lower = (qm >= tlm) | lsb_forced_mismatch
    cyc2_upper = ((qm - 1) < thm) | lsb_forced_mismatch  # q_MSB < T_HM + 1
    mal_after_2 = mal_after_1 & cyc2_lower & cyc2_upper

    return mal_after_2


def macro_cell_count(n_features: int, n_bits: int = 8) -> int:
    """aCAM sub-cells per row for the given precision (area model input).

    Direct unary extension would need 2^(N-M) cells per threshold; the
    paper's scheme needs exactly 2 sub-cells per macro-cell (×2 thresholds
    folded into one macro-cell pair) — doubling area and search latency
    rather than exponentiating them (§III-B).
    """
    if n_bits <= M_BITS:
        return n_features  # single sub-cell per feature
    if n_bits <= 2 * M_BITS:
        return 2 * n_features  # the paper's macro-cell
    raise ValueError(">8-bit thresholds are out of the paper's design space")


# ---------------------------------------------------------------------------
# Soft-boundary cell mode (analog sigmoid match lines, DESIGN.md §15)
# ---------------------------------------------------------------------------
#
# The MoS₂ analog-CAM line of work shows the aCAM match line is not a step
# function: near a stored threshold the discharge is sigmoid-shaped.  The
# 'soft' cell mode models that physics — each cell scores
#
#     s(q) = sigmoid((q - low_f) / tau) * sigmoid((high_f - q) / tau)
#
# with the bounds pre-encoded at HALF-INTEGER offsets (low_f = low - 0.5,
# high_f = high - 0.5, see ``encode_soft_bounds``) so the tau -> 0 limit is
# EXACTLY the hard exclusive-high indicator ``low <= q < high`` on integer
# bins: the sigmoid arguments are never zero at the limit, so no boundary
# bin can round differently from the hard compare.  Rows aggregate by
# product of cells — accumulated as a SUM of log-scores (the running-AND's
# additive twin), which is what the Pallas kernel carries in its scratch.
#
# Wildcard cells encode (-inf, +inf): ``log_sigmoid(+inf) == 0.0`` exactly,
# so an all-wildcard tile contributes log-score 0 and the kernel's tile
# skipping stays valid.  Never-match cells (padding rows) encode
# (+inf, -inf) -> log-score -inf -> row score exactly 0.  Every log-score
# is <= 0, so the accumulated sum never produces NaN.


def encode_soft_bounds(
    low, high, n_bins: int
) -> tuple[np.ndarray, np.ndarray]:
    """Int bounds -> the float32 half-integer soft encoding (host-side ok).

    Maps the canonical exclusive-high int32 layout onto the soft cell's
    native float32 form: real cells at ``(low - 0.5, high - 0.5)``,
    wildcard cells (the full grid ``[0, n_bins)``) at ``(-inf, +inf)`` and
    never-match cells (``high <= low``, e.g. row padding's low=1/high=0)
    at ``(+inf, -inf)``.
    """
    low = np.asarray(low, dtype=np.int64)
    high = np.asarray(high, dtype=np.int64)
    lo_f = (low - 0.5).astype(np.float32)
    hi_f = (high - 0.5).astype(np.float32)
    wildcard = (low <= 0) & (high >= n_bins)
    never = high <= low
    lo_f[wildcard], hi_f[wildcard] = -np.inf, np.inf
    lo_f[never], hi_f[never] = np.inf, -np.inf
    return lo_f, hi_f


def soft_cell_logscore(
    q: jnp.ndarray, low_f: jnp.ndarray, high_f: jnp.ndarray, tau: float
) -> jnp.ndarray:
    """Per-cell log match score on soft-encoded float32 bounds.

    ``tau`` is the boundary temperature in BIN units (static — it selects
    the trace, not a runtime operand).  ``tau == 0`` is the exact hard
    limit: log 1 inside ``(low_f, high_f)``, -inf outside; the encoding's
    half-integer offsets guarantee an integer bin never lands ON a bound.
    """
    import jax
    import jax.numpy as jnp

    q = q.astype(jnp.float32)
    if tau == 0.0:
        inside = (q > low_f) & (q < high_f)
        return jnp.where(inside, jnp.float32(0.0), -jnp.inf)
    inv = jnp.float32(1.0 / tau)
    return jax.nn.log_sigmoid((q - low_f) * inv) + jax.nn.log_sigmoid(
        (high_f - q) * inv
    )


def soft_match_scores(
    q: jnp.ndarray,  # (B, F) float32 (or int bins; cast internally)
    low_f: jnp.ndarray,  # (R, F) soft-encoded float32 bounds
    high_f: jnp.ndarray,
    tau: float,
) -> jnp.ndarray:
    """(B, R) row match scores in [0, 1]: exp of the summed log-scores."""
    import jax.numpy as jnp

    logs = soft_cell_logscore(
        q[:, None, :], low_f[None, :, :], high_f[None, :, :], tau
    )
    return jnp.exp(jnp.sum(logs, axis=-1))


# ---------------------------------------------------------------------------
# CellMode registry: the one place a cell mode's contract lives
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CellMode:
    """Descriptor for one aCAM cell comparison mode.

    Attributes:
      name: the ``DeployConfig.mode`` string.
      match: the cell-level jnp comparison ``(q, low, high) -> bool`` the
        kernel and reference dispatch on — ``None`` for the soft mode,
        whose scoring is parametric in tau (``soft_cell_logscore``).
      table_dtype_policy: the dtype this mode PINS its kernel tables to
        (``'int32'`` for the bit-faithful macro-cell modes, ``'float32'``
        for soft), or ``None`` when the mode accepts the compile-selected
        / packed layouts.
      faithful: bit-faithful aCAM macro-cell arithmetic (Eq. 3 / Table I).
      packable: may run the packed unsigned inclusive-high table layout
        (the kernel-v2 compact encoding).
      soft: numeric sigmoid match scores instead of a boolean match line.
    """

    name: str
    match: Callable | None
    table_dtype_policy: str | None
    faithful: bool
    packable: bool
    soft: bool = False


CELL_MODES: dict[str, CellMode] = {
    m.name: m
    for m in (
        CellMode("direct", match_direct, None, faithful=False, packable=True),
        CellMode(
            "inclusive", match_inclusive, None, faithful=False, packable=True
        ),
        CellMode("msb_lsb", match_msb_lsb, "int32", faithful=True, packable=False),
        CellMode(
            "two_cycle", match_two_cycle, "int32", faithful=True, packable=False
        ),
        CellMode(
            "soft", None, "float32", faithful=False, packable=False, soft=True
        ),
    )
}


def mode_names() -> tuple[str, ...]:
    """Registered cell-mode names, registration order (user-facing lists)."""
    return tuple(CELL_MODES)


def get_cell_mode(name: str) -> CellMode:
    """Resolve a mode name; unknown names list what IS registered."""
    try:
        return CELL_MODES[name]
    except KeyError:
        raise ValueError(
            f"unknown cell mode {name!r}; registered modes: {mode_names()}"
        ) from None
