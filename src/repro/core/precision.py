"""Increased-precision analog CAM arithmetic (§III-B, Eq. 1-3, Table I).

Memristor cells hold M=4 bits; the paper's macro-cell evaluates an
N=2M=8-bit range compare by splitting the threshold T = 16*T_MSB + T_LSB
and the query q = 16*q_MSB + q_LSB and computing (Eq. 3):

    T_L <= q < T_H  <=>
        [(q_M >= T_LM + 1) OR  (q_L >= T_LL)] AND (q_M >= T_LM)
    AND [(q_M <  T_HM)     OR  (q_L <  T_HL)] AND (q_M <  T_HM + 1)

This module reproduces that logic bit-exactly (``match_msb_lsb``), plus a
cycle-level simulation of the two-step search of Table I
(``match_two_cycle``): cycle 1 evaluates the OR brackets with the LSB and
shifted-MSB inputs; cycle 2 keeps the match line charged only if the
conjunctive MSB terms also hold ("always care" on the LSB sub-cell).  Both
are property-tested against the direct comparison ``(T_L <= q) & (q < T_H)``
over the full 8-bit space.

All functions are pure jnp and vectorize over arbitrary leading shapes, so
they drop into the engine / Pallas kernel as an alternate match mode.
"""

from __future__ import annotations

import jax.numpy as jnp

M_BITS = 4
M_LEVELS = 1 << M_BITS  # 16 analog levels per sub-cell


def split_msb_lsb(v: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """v in [0, 256) -> (v >> 4, v & 15), each an M-bit quantity."""
    v = v.astype(jnp.int32)
    return v >> M_BITS, v & (M_LEVELS - 1)


def match_direct(q: jnp.ndarray, t_low: jnp.ndarray, t_high: jnp.ndarray) -> jnp.ndarray:
    """The ideal 8-bit comparison the macro-cell must reproduce."""
    q = q.astype(jnp.int32)
    return (t_low.astype(jnp.int32) <= q) & (q < t_high.astype(jnp.int32))


def match_inclusive(q: jnp.ndarray, t_low: jnp.ndarray, t_high: jnp.ndarray) -> jnp.ndarray:
    """Compact uint8 table format (EXPERIMENTS.md §Perf X1): INCLUSIVE
    upper bound so all of [0, 255] fits in uint8 — low <= q <= high.
    Never-match rows encode low=1 > high=0; always-match cells low=0,
    high=255.  Compared in the native (unsigned) dtype: no upcast."""
    return (t_low <= q) & (q <= t_high)


def match_msb_lsb(q: jnp.ndarray, t_low: jnp.ndarray, t_high: jnp.ndarray) -> jnp.ndarray:
    """Eq. 3 evaluated with only M-bit comparisons (the macro-cell logic)."""
    qm, ql = split_msb_lsb(q)
    tlm, tll = split_msb_lsb(t_low)
    thm, thl = split_msb_lsb(t_high)
    lower = ((qm >= tlm + 1) | (ql >= tll)) & (qm >= tlm)  # Eq. 2
    upper = ((qm < thm) | (ql < thl)) & (qm < thm + 1)  # dual for q < T_H
    return lower & upper


def match_two_cycle(q: jnp.ndarray, t_low: jnp.ndarray, t_high: jnp.ndarray) -> jnp.ndarray:
    """Cycle-level simulation of the Table-I two-step search.

    The physical match line (MAL) is precharged once; each cycle can only
    *discharge* it (wired-AND across cycles).  Per Table I:

      cycle 1  inputs: q_LLSB=q_LSB, q_HLSB=q_LSB, q_LMSB=q_MSB-1, q_HMSB=q_MSB
               -> each macro-cell's OR of (MSB sub-cell, LSB sub-cell) must
               hold: [(q_M-1 >= T_LM) | (q_L >= T_LL)] for the lower bound
               and [(q_M < T_HM) | (q_L < T_HL)] for the upper bound.
      cycle 2  inputs: q_LLSB=VDD, q_HLSB=GND ("always care", i.e. the LSB
               sub-cells are driven to *always mismatch* given the lo/hi
               side circuit polarity), q_LMSB=q_MSB, q_HMSB=q_MSB-1
               -> the macro-cell OR degenerates to its MSB term, evaluating
               the conjunctive terms (q_M >= T_LM) and (q_M < T_HM + 1).

    Because the MAL can only be discharged, the state after cycle 2 is the
    AND of both cycles' evaluations, which equals Eq. 3.
    """
    qm, ql = split_msb_lsb(q)
    tlm, tll = split_msb_lsb(t_low)
    thm, thl = split_msb_lsb(t_high)

    # cycle 1: OR brackets.  Lower-bound macro-cell: MSB sub-cell sees
    # q_MSB-1 against ">= T_LM" (i.e. q_MSB >= T_LM+1); LSB sub-cell sees
    # q_LSB against ">= T_LL".  Upper-bound: MSB sub-cell q_MSB < T_HM, LSB
    # q_LSB < T_HL.  The macro-cell keeps MAL charged if either sub-cell
    # matches (parallel pull-down paths in series with each other, Fig. 5a).
    cyc1_lower = ((qm - 1) >= tlm) | (ql >= tll)
    cyc1_upper = (qm < thm) | (ql < thl)
    mal_after_1 = cyc1_lower & cyc1_upper

    # cycle 2: LSB sub-cells driven to always-mismatch (VDD/GND per Table I),
    # so the macro-cell OR reduces to the MSB sub-cell's standalone term.
    lsb_forced_mismatch = jnp.zeros_like(ql, dtype=bool)
    cyc2_lower = (qm >= tlm) | lsb_forced_mismatch
    cyc2_upper = ((qm - 1) < thm) | lsb_forced_mismatch  # q_MSB < T_HM + 1
    mal_after_2 = mal_after_1 & cyc2_lower & cyc2_upper

    return mal_after_2


def macro_cell_count(n_features: int, n_bits: int = 8) -> int:
    """aCAM sub-cells per row for the given precision (area model input).

    Direct unary extension would need 2^(N-M) cells per threshold; the
    paper's scheme needs exactly 2 sub-cells per macro-cell (×2 thresholds
    folded into one macro-cell pair) — doubling area and search latency
    rather than exponentiating them (§III-B).
    """
    if n_bits <= M_BITS:
        return n_features  # single sub-cell per feature
    if n_bits <= 2 * M_BITS:
        return 2 * n_features  # the paper's macro-cell
    raise ValueError(">8-bit thresholds are out of the paper's design space")
