"""Training driver: data pipeline -> jitted train step -> fault-tolerant
loop with async checkpoints.  Runs real steps on host devices (CPU mesh
for tests/examples; the same code path lowers on the production mesh in
the dry-run).

CLI:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --steps 50 --global-batch 8 --seq 256 --scale 0.05 --run-dir /tmp/run
``--scale`` shrinks width/depth for CPU-sized runs (examples use it); the
config dims stay exact when --scale 1.
"""

from __future__ import annotations

import argparse
import json
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.data.tokens import EmbeddingPipeline, TokenPipeline
from repro.ft.runtime import FaultTolerantRunner
from repro.launch.mesh import make_host_mesh
from repro.models.registry import LMBundle, build_model
from repro.optim.adamw import AdamW, AdamWConfig
from repro.optim.compress import compress_tree, decompress_tree
from repro.sharding.partition import (
    MeshAxes,
    activation_sharder,
    batch_pspec,
    param_pspecs,
)
from jax.sharding import NamedSharding, PartitionSpec as P


def make_train_step(bundle: LMBundle, opt: AdamW, mesh=None, *,
                    microbatch: int = 0, compress: bool = False):
    """Returns jitted (params, opt_state, residual, batch) ->
    (params, opt_state, residual, metrics).

    ``microbatch`` > 0 splits the batch into that many accumulation steps
    (scan) — gradient accumulation for big global batches.
    ``compress`` int8-quantizes gradients with error feedback before the
    optimizer (simulating the compressed cross-pod reduction wire format).
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(bundle.loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def step(params, opt_state, residual, batch):
        if microbatch and microbatch > 1:
            def split(x):
                return x.reshape(microbatch, x.shape[0] // microbatch, *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_fn(carry, one):
                acc, loss_sum = carry
                loss, _m, g = grads_of(params, one)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_sum + loss), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = jax.lax.scan(acc_fn, (zero, jnp.float32(0.0)), mb)
            grads = jax.tree.map(lambda g: g / microbatch, gsum)
            loss = loss_sum / microbatch
            metrics = {"loss": loss}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if compress:
            (q, s), residual = compress_tree(grads, residual)
            grads = decompress_tree(q, s, grads)
        params, opt_state, om = opt.update(grads, opt_state, params)
        return params, opt_state, residual, {"loss": loss, **om}

    if mesh is None:
        return jax.jit(step)
    return jax.jit(step)  # shardings flow from the placed inputs


def place_params(mesh, cfg, params):
    axes = MeshAxes(mesh)
    specs = param_pspecs(params, cfg, axes)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs
    )


def place_batch(mesh, batch):
    axes = MeshAxes(mesh)
    bp = batch_pspec(axes)
    def put(x):
        spec = bp if x.ndim >= 1 else P()
        return jax.device_put(jnp.asarray(x), NamedSharding(mesh, spec))
    return {k: put(v) for k, v in batch.items()}


def train(
    cfg,
    *,
    steps: int,
    global_batch: int,
    seq_len: int,
    run_dir: str,
    mesh=None,
    ckpt_every: int = 20,
    microbatch: int = 0,
    compress: bool = False,
    failure_at: int | None = None,
    seed: int = 0,
    opt_cfg: AdamWConfig | None = None,
    log_every: int = 10,
) -> list[dict]:
    """Fault-tolerant training loop.  Returns per-step metric history."""
    bundle = build_model(cfg)
    if mesh is not None:
        bundle.model.shard_x = activation_sharder(mesh)
    opt = AdamW(opt_cfg or AdamWConfig(warmup_steps=max(5, steps // 20),
                                       decay_steps=steps))

    if cfg.embeddings_input or cfg.is_encoder_decoder:
        pipe: Any = EmbeddingPipeline(
            d_model=cfg.d_model, global_batch=global_batch, seq_len=seq_len,
            vocab_size=cfg.vocab_size, seed=seed,
        )
        get_batch = lambda step: pipe.batch(
            step, kind="audio" if cfg.is_encoder_decoder else "vlm"
        )
    else:
        pipe = TokenPipeline(cfg.vocab_size, global_batch, seq_len, seed=seed)
        get_batch = pipe.batch

    step_fn = make_train_step(bundle, opt, mesh, microbatch=microbatch,
                              compress=compress)

    def init_state():
        params = bundle.init_params(jax.random.key(seed))
        if mesh is not None:
            params = place_params(mesh, cfg, params)
        opt_state = opt.init(params)
        residual = (
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if compress else {"none": jnp.zeros(())}
        )
        return {"params": params, "opt": opt_state, "residual": residual}

    def one_step(state, step):
        batch = get_batch(step)
        batch = place_batch(mesh, batch) if mesh is not None else jax.tree.map(
            jnp.asarray, batch
        )
        params, opt_state, residual, metrics = step_fn(
            state["params"], state["opt"], state["residual"], batch
        )
        metrics = {k: float(v) for k, v in metrics.items()}
        return {"params": params, "opt": opt_state, "residual": residual}, metrics

    def placer(state):
        if mesh is None:
            return jax.tree.map(jnp.asarray, state)
        # elastic re-placement: params/opt re-sharded for the current mesh
        placed_params = place_params(mesh, cfg, state["params"])
        specs = param_pspecs(state["params"], cfg, MeshAxes(mesh))
        placed_opt = {
            "m": jax.tree.map(lambda x, s: jax.device_put(jnp.asarray(x), NamedSharding(mesh, s)),
                              state["opt"]["m"], specs),
            "v": jax.tree.map(lambda x, s: jax.device_put(jnp.asarray(x), NamedSharding(mesh, s)),
                              state["opt"]["v"], specs),
            "step": jnp.asarray(state["opt"]["step"]),
        }
        return {"params": placed_params, "opt": placed_opt,
                "residual": jax.tree.map(jnp.asarray, state["residual"])}

    runner = FaultTolerantRunner(
        run_dir, one_step, init_state, ckpt_every=ckpt_every
    )

    printed = []

    def on_metrics(step, m):
        if step % log_every == 0 or step == steps - 1:
            line = {k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in m.items() if k in ("step", "loss", "lr", "dt")}
            printed.append(line)
            print(json.dumps(line), flush=True)

    _state, history = runner.run(
        steps, failure_at=failure_at, placer=placer, on_metrics=on_metrics
    )
    return history


def _scaled(cfg, scale: float):
    if scale >= 1.0:
        return cfg
    d = max(64, int(cfg.d_model * scale) // 16 * 16)
    heads = max(2, int(cfg.n_heads * scale))
    while d % heads:
        heads -= 1
    kv = max(1, min(cfg.n_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return cfg.replace(
        n_layers=max(2, int(cfg.n_layers * scale)),
        d_model=d, n_heads=heads, n_kv_heads=kv, head_dim=0,
        d_ff=max(128, int(cfg.d_ff * scale) // 16 * 16),
        vocab_size=min(cfg.vocab_size, 8192),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--run-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--use-mesh", action="store_true")
    args = ap.parse_args()

    cfg = _scaled(get_config(args.arch), args.scale)
    mesh = make_host_mesh() if args.use_mesh else None
    t0 = time.time()
    hist = train(
        cfg, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq, run_dir=args.run_dir, mesh=mesh,
        ckpt_every=args.ckpt_every, microbatch=args.microbatch,
        compress=args.compress,
    )
    print(f"done: {len(hist)} steps in {time.time()-t0:.1f}s; "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
