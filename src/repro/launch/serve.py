"""Batched serving: prefill -> jitted decode loop with sampling.

Also hosts the §Perf shard_map flash-decode variant (partial-softmax KV
merge) used when KV heads cannot be sharded (MQA / gemma3 kv=1).

CLI:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
      --scale 0.05 --batch 4 --prompt-len 64 --max-new 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.models.registry import LMBundle, build_model


def _pad_cache_seq(cfg, cache, prefill_len: int, total_len: int):
    """Grow every per-position cache leaf from prefill_len to total_len."""
    extra = total_len - prefill_len

    def pad(leaf):
        if leaf.ndim >= 4 and leaf.shape[2] == prefill_len:
            padding = [(0, 0)] * leaf.ndim
            padding[2] = (0, extra)
            return jnp.pad(leaf, padding)
        return leaf

    if cfg.family == "ssm":
        return cache  # recurrent state only
    return jax.tree.map(pad, cache)


def generate(
    bundle: LMBundle,
    params,
    tokens: jnp.ndarray,  # (B, S) prompt
    *,
    max_new: int,
    temperature: float = 0.0,
    seed: int = 0,
) -> np.ndarray:
    """Greedy / temperature sampling.  Returns (B, max_new) new tokens."""
    cfg = bundle.cfg
    b, s = tokens.shape
    logits, cache = jax.jit(bundle.prefill)(params, {"tokens": tokens})
    cache = _pad_cache_seq(cfg, cache, s, s + max_new)

    decode = jax.jit(bundle.decode_step)
    key = jax.random.key(seed)

    def sample(logits, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(
            jnp.int32
        )

    out = []
    key, sub = jax.random.split(key)
    tok = sample(logits, sub)
    out.append(tok)
    for i in range(max_new - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(s + i))
        key, sub = jax.random.split(key)
        tok = sample(logits, sub)
        out.append(tok)
    return np.stack([np.asarray(t) for t in out], axis=1)


def main() -> None:
    from repro.launch.train import _scaled

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = _scaled(get_config(args.arch), args.scale)
    bundle = build_model(cfg)
    params = bundle.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )
    t0 = time.time()
    toks = generate(bundle, params, prompts, max_new=args.max_new,
                    temperature=args.temperature)
    dt = time.time() - t0
    total = args.batch * args.max_new
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s); sample row: {toks[0][:16].tolist()}")


if __name__ == "__main__":
    main()
