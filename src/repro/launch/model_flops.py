"""Analytic MODEL_FLOPS per (arch x shape) cell.

MODEL_FLOPS = 6*N*D for dense training (N = active non-embedding params,
D = tokens), 6*N_active*D for MoE, plus the attention quadratic term
(causal: S/2 average context; windowed: min(S, W)); forward-only cells
(prefill) use 2*N*D; decode cells use 2*N per token plus the KV-cache
attention term.  Used for the roofline "useful compute" ratio
MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste — note remat
intentionally recomputes, so trained cells with remat=True sit near ~0.75
by construction: fwd+fwd(recompute)+bwd = 8N vs 6N useful).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.config import ModelConfig, ShapeCell


def _param_counts(bundle) -> tuple[float, float]:
    """(total_params, embedding_params) from the shape pytree."""
    shape = bundle.params_shape()
    total = 0.0
    embed = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shape)[0]:
        n = float(np.prod(leaf.shape))
        total += n
        key = jax.tree_util.keystr(path)
        if "embed" in key or "lm_head" in key:
            embed += n
    return total, embed


def active_params(cfg: ModelConfig, bundle) -> float:
    """Non-embedding params active per token (MoE: top_k+shared of E)."""
    total, embed = _param_counts(bundle)
    body = total - embed
    if not cfg.is_moe:
        return body
    # split expert weights from the rest, scale by activation fraction
    shape = bundle.params_shape()
    expert = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shape)[0]:
        if len(leaf.shape) >= 3 and cfg.n_experts in leaf.shape:
            expert += float(np.prod(leaf.shape))
    frac = cfg.moe_top_k / cfg.n_experts
    return (body - expert) + expert * frac


def _attn_flops_per_seq(cfg: ModelConfig, s: int, fwd_mult: float) -> float:
    """QK^T + AV flops for one sequence across all layers."""
    if cfg.family == "ssm":
        # rwkv: state update per token: H * hd * hd * ~4 ops
        h = cfg.d_model // max(1, cfg.rwkv_head_dim)
        return fwd_mult * cfg.n_layers * s * h * cfg.rwkv_head_dim**2 * 4
    if cfg.family == "hybrid":
        # mamba layers: per token H*P*N*~6 state ops; shared attn every period
        from repro.models.mamba2 import dims as mdims

        di, heads, _ = mdims(cfg)
        ssm = fwd_mult * cfg.n_layers * s * heads * cfg.ssm_head_dim * cfg.ssm_state * 6
        n_attn = cfg.n_layers // max(1, cfg.shared_attn_period)
        attn = fwd_mult * n_attn * 2 * 2 * (s * s / 2) * cfg.n_heads * cfg.resolved_head_dim
        return ssm + attn
    hd = cfg.v_head_dim if cfg.use_mla else cfg.resolved_head_dim
    qk_hd = (cfg.qk_nope_dim + cfg.qk_rope_dim) if cfg.use_mla else cfg.resolved_head_dim
    per_layer_ctx = []
    for i in range(cfg.n_layers):
        if cfg.local_global_period > 0 and cfg.sliding_window > 0:
            w = 0 if (i + 1) % cfg.local_global_period == 0 else cfg.sliding_window
        else:
            w = cfg.sliding_window
        # average attended context per query under causal (+ window) mask
        if w and w > 0:
            ctx = min(w, s / 2)
        else:
            ctx = s / 2
        per_layer_ctx.append(ctx)
    total_ctx = sum(per_layer_ctx)
    # 2 matmuls (QK, AV) x 2 flops x S queries x ctx keys x H x hd
    return fwd_mult * 2 * 2 * s * total_ctx * cfg.n_heads * (qk_hd + hd) / 2


def model_flops(cfg: ModelConfig, cell: ShapeCell, bundle) -> float:
    """Useful model FLOPs for one step of this cell (whole cluster)."""
    n_act = active_params(cfg, bundle)
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        fwd_mult = 6.0  # fwd 2N + bwd 4N
        if cfg.is_encoder_decoder:
            s_dec = max(64, s // 8)
            tokens = b * (s + s_dec) / 2  # rough enc+dec split
        else:
            tokens = b * s
        return fwd_mult * n_act * tokens + b * _attn_flops_per_seq(cfg, s, 3.0)
    if cell.kind == "prefill":
        tokens = b * s
        return 2.0 * n_act * tokens + b * _attn_flops_per_seq(cfg, s, 1.0)
    # decode: one token, full cache attended
    hd = cfg.kv_lora_rank if cfg.use_mla else cfg.resolved_head_dim
    if cfg.family == "ssm":
        h = cfg.d_model // max(1, cfg.rwkv_head_dim)
        attn = cfg.n_layers * h * cfg.rwkv_head_dim**2 * 4
    elif cfg.family == "hybrid":
        from repro.models.mamba2 import dims as mdims

        di, heads, _ = mdims(cfg)
        attn = cfg.n_layers * heads * cfg.ssm_head_dim * cfg.ssm_state * 6
        attn += (cfg.n_layers // max(1, cfg.shared_attn_period)) * 2 * 2 * s * \
            cfg.n_heads * cfg.resolved_head_dim
    else:
        per_layer = []
        for i in range(cfg.n_layers):
            if cfg.local_global_period > 0 and cfg.sliding_window > 0:
                w = 0 if (i + 1) % cfg.local_global_period == 0 else cfg.sliding_window
            else:
                w = cfg.sliding_window
            ctx = min(w, s) if (w and w > 0) else s
            per_layer.append(ctx)
        attn = 2 * 2 * sum(per_layer) * cfg.n_heads * hd
    return b * (2.0 * n_act + attn)
