"""Production mesh construction.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; smoke tests and
benches see the default single device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods (512 chips).

    Axes: `data` (batch / FSDP), `model` (TP / EP / CAM rows); `pod`
    (multi-pod) acts as outer data parallelism + FSDP extension — gradient
    reduction over `pod` crosses the (slow) inter-pod links, which is
    where gradient compression applies (optim/compress.py).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(n_data: int | None = None, n_model: int | None = None):
    """Small mesh over whatever local devices exist (tests)."""
    n = len(jax.devices())
    if n_data is None or n_model is None:
        n_model = 1
        n_data = n
        for m in (4, 2):
            if n % m == 0:
                n_model = m
                n_data = n // m
                break
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
