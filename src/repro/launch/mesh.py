"""Production mesh construction.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; smoke tests and
benches see the default single device).
"""

from __future__ import annotations

import jax


def _make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    # jax >= 0.5 takes explicit axis_types (we want Auto everywhere);
    # 0.4.x has no AxisType and its make_mesh is Auto-only already.
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = (
        {"axis_types": (axis_type.Auto,) * len(axes)}
        if axis_type is not None
        else {}
    )
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods (512 chips).

    Axes: `data` (batch / FSDP), `model` (TP / EP / CAM rows); `pod`
    (multi-pod) acts as outer data parallelism + FSDP extension — gradient
    reduction over `pod` crosses the (slow) inter-pod links, which is
    where gradient compression applies (optim/compress.py).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(n_data: int | None = None, n_model: int | None = None):
    """Small mesh over whatever local devices exist (tests)."""
    n = len(jax.devices())
    if n_data is None or n_model is None:
        n_model = 1
        n_data = n
        for m in (4, 2):
            if n % m == 0:
                n_model = m
                n_data = n // m
                break
    return _make_mesh((n_data, n_model), ("data", "model"))
