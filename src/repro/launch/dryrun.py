import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (including repro.*):
# jax locks the device count at first initialization, and the multi-pod
# dry-run needs 512 placeholder host devices to build the production mesh.
# Do NOT move them or set this flag globally — smoke tests and benchmarks
# must see the real single device.

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.config import SHAPES, get_config  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.model_flops import model_flops  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.optim.adamw import AdamW, AdamWConfig  # noqa: E402
from repro.sharding.partition import (  # noqa: E402
    MeshAxes,
    activation_sharder,
    attach,
    batch_pspec,
    cache_pspecs,
    param_pspecs,
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (per the assignment):
  * compiled.memory_analysis()  — proves the program fits (bytes/device),
  * compiled.cost_analysis()    — raw XLA numbers (scan bodies counted
    once; kept for reference),
  * hlo_analysis.analyze()      — trip-count-aware dot FLOPs, fusion-
    boundary HBM bytes and collective bytes (the roofline inputs),
  * the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio.

Results land in results/dryrun/<arch>__<shape>__<mesh>.json and are
aggregated into EXPERIMENTS.md by benchmarks/aggregate.py.
"""


def _moe_moment_dtype(cfg) -> str:
    # 671B-class models need bf16 moments to fit (DESIGN.md §5)
    return "bfloat16" if getattr(cfg, "n_experts", 0) >= 128 else "float32"


def lower_cell(arch: str, shape: str, multi_pod: bool, flash_blk: int = 1024):
    """Returns (lowered, meta) for one dry-run cell."""
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = MeshAxes(mesh)

    if getattr(cfg, "family", "") == "xtime":
        return _lower_xtime(cfg, shape, mesh, axes)

    cell = SHAPES[shape]
    if cell.name == "long_500k" and not cfg.supports_long_context:
        raise SkipCell(f"{arch} is pure full-attention; long_500k skipped per "
                       "assignment rule (see DESIGN.md §Arch-applicability)")

    bundle = build_model(cfg, flash_blk=flash_blk)
    bundle.model.shard_x = activation_sharder(mesh, axes)
    _install_moe_hooks(cfg, mesh, axes)
    params_sds = bundle.params_shape()
    pspecs = param_pspecs(params_sds, cfg, axes)
    params_in = attach(mesh, params_sds, pspecs)
    bspec = batch_pspec(axes)

    def shard_batch(tree):
        def one(sds):
            if len(sds.shape) >= 1 and sds.shape[0] == cell.global_batch:
                spec = axes.fit(
                    tuple(bspec) + (None,) * (len(sds.shape) - 1), sds.shape
                )
            else:
                spec = P()
            return jax.ShapeDtypeStruct(
                sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
            )

        return jax.tree.map(one, tree)

    specs = bundle.input_specs(cell)

    if cell.kind == "train":
        opt = AdamW(AdamWConfig(moment_dtype=_moe_moment_dtype(cfg)))
        opt_sds = jax.eval_shape(opt.init, params_sds)
        opt_specs = {
            "m": pspecs, "v": pspecs,
            "step": P(),
        }
        opt_in = attach(mesh, opt_sds, opt_specs)
        batch_in = shard_batch(specs)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                bundle.loss_fn, has_aux=True
            )(params, batch)
            new_params, new_opt, om = opt.update(grads, opt_state, params)
            return new_params, new_opt, {"loss": loss, **om}

        lowered = jax.jit(train_step).lower(params_in, opt_in, batch_in)
        fn_kind = "train_step"
    elif cell.kind == "prefill":
        batch_in = shard_batch(specs)

        def prefill_step(params, batch):
            logits, cache = bundle.prefill(params, batch)
            return logits, cache

        lowered = jax.jit(prefill_step).lower(params_in, batch_in)
        fn_kind = "serve_prefill"
    else:  # decode
        cache_sds = specs["cache"]
        cspecs = cache_pspecs(cache_sds, cfg, axes)
        cache_in = attach(mesh, cache_sds, cspecs)
        token_in = jax.ShapeDtypeStruct(
            specs["token"].shape, specs["token"].dtype,
            sharding=NamedSharding(
                mesh, axes.fit(tuple(bspec), specs["token"].shape)
            ),
        )
        pos_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))

        def serve_step(params, cache, token, pos):
            return bundle.decode_step(params, cache, token, pos)

        lowered = jax.jit(serve_step).lower(params_in, cache_in, token_in, pos_in)
        fn_kind = "serve_step"

    mf = model_flops(cfg, cell, bundle)
    meta = {
        "arch": arch, "shape": shape, "kind": fn_kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(mesh.devices.shape)),
        "model_flops_total": mf,
    }
    return lowered, meta


class SkipCell(Exception):
    pass


def _install_moe_hooks(cfg, mesh, axes: MeshAxes) -> None:
    """Token-dim / expert-dim sharding constraints for the MoE dispatch.

    REPRO_MOE_IMPL=shardmap selects the explicit all-to-all shard_map
    implementation (§Perf D2) instead of the pjit path."""
    from repro.models import moe as moe_mod

    if not getattr(cfg, "n_experts", 0):
        moe_mod.set_shard_hooks(None, None)
        moe_mod.set_impl(None)
        return
    if os.environ.get("REPRO_MOE_IMPL", "") == "shardmap":
        from repro.models.moe_shardmap import make_shardmap_moe

        moe_mod.set_impl(make_shardmap_moe(mesh))
    else:
        moe_mod.set_impl(None)
    b = axes.batch_axes()
    bspec = b if len(b) > 1 else (b[0] if b else None)

    def shard_tokens(x):
        spec = axes.fit((bspec,) + (None,) * (x.ndim - 1), x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def shard_experts(x):  # (E, C, d): EP on experts, DP on capacity slots
        spec = axes.fit(("model", axes.fsdp) + (None,) * (x.ndim - 2), x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def shard_weights(w):  # (E, d, f): EP kept, fsdp axis gathered pre-use
        spec = axes.fit(("model", None, None), w.shape)
        return jax.lax.with_sharding_constraint(w, NamedSharding(mesh, spec))

    moe_mod.set_shard_hooks(shard_tokens, shard_experts, shard_weights)


# ---------------------------------------------------------------------------
# X-TIME tabular cell (the paper's own workload on the production mesh)
# ---------------------------------------------------------------------------


def _lower_xtime(cfg, shape: str, mesh, axes: MeshAxes, compact: bool = True):
    """CAM rows sharded on `model`, batch on `data`(x`pod`); the psum over
    `model` *is* the H-tree reduction (DESIGN.md §2).

    ``compact`` (§Perf X1, default after hillclimb): bounds stored as
    uint8 with INCLUSIVE upper bound (match = low <= q <= high; the
    paper's 8-bit grid fits exactly: never-match rows are low=1 > high=0,
    always-match cells low=0, high=255) and bf16 leaf values — a 4x cut
    of the dominant table-stream traffic vs the int32/f32 baseline.
    """
    from repro.kernels.ref import cam_match_ref

    batch = {"serve_32k": 32768, "serve_1m": 1_048_576}[shape]
    rows = cfg.n_trees * cfg.max_leaves  # 4096 x 256 = 1,048,576 CAM rows
    f_pad = int(np.ceil(cfg.n_features / 128)) * 128
    c_pad = 8
    bspec = batch_pspec(axes)
    rs = NamedSharding(mesh, P("model", None))
    bdt = jnp.uint8 if compact else jnp.int32
    q_in = jax.ShapeDtypeStruct((batch, f_pad), bdt,
                                sharding=NamedSharding(mesh, bspec))
    low_in = jax.ShapeDtypeStruct((rows, f_pad), bdt, sharding=rs)
    high_in = jax.ShapeDtypeStruct((rows, f_pad), bdt, sharding=rs)
    leaf_in = jax.ShapeDtypeStruct(
        (rows, c_pad), jnp.bfloat16 if compact else jnp.float32, sharding=rs
    )

    if compact:
        # row-chunked accumulation (§Perf X2): the kernel-style blocking.
        # A monolithic (B, R) match matrix materializes B*R bools many
        # times over (measured 2.8 s memory term / 1 TiB temps at R = 1M);
        # scanning row chunks and accumulating (B, C) logits keeps only a
        # (B, Rc) tile live per step — same numbers, ~30x less traffic.
        r_chunk = 65536

        chunk_rs = NamedSharding(mesh, P(None, "model", None))
        chunk_qs = NamedSharding(
            mesh, axes.fit((None,) + tuple(bspec) + (None,), (1, batch, 1))
        )
        b_chunk = min(batch, 131072)  # live (Bq, Rc) tile ≈ 8 GiB/dev

        def serve_step(q, low, high, leaf):
            nc = low.shape[0] // r_chunk
            nbq = q.shape[0] // b_chunk
            # keep row/batch dims sharded INSIDE each chunk — without the
            # constraints the reshapes replicate the operands and every
            # device scans all rows (measured: 16x compute).
            lows = jax.lax.with_sharding_constraint(
                low.reshape(nc, r_chunk, low.shape[1]), chunk_rs)
            highs = jax.lax.with_sharding_constraint(
                high.reshape(nc, r_chunk, high.shape[1]), chunk_rs)
            leafs = jax.lax.with_sharding_constraint(
                leaf.reshape(nc, r_chunk, leaf.shape[1]), chunk_rs)
            qs = jax.lax.with_sharding_constraint(
                q.reshape(nbq, b_chunk, q.shape[1]), chunk_qs)

            def q_step(_, qc):
                def step(acc, xs):
                    lo, hi, lf = xs
                    cell = (lo[None] <= qc[:, None, :]) & (qc[:, None, :] <= hi[None])
                    match = jnp.all(cell, axis=-1)  # (Bq, Rc)
                    return acc + jax.lax.dot(
                        match.astype(lf.dtype), lf,
                        preferred_element_type=jnp.float32,
                    ), None

                acc0 = jnp.zeros((qc.shape[0], leaf.shape[1]), jnp.float32)
                out, _ = jax.lax.scan(step, acc0, (lows, highs, leafs))
                return None, out

            _, outs = jax.lax.scan(q_step, None, qs)
            return outs.reshape(q.shape[0], leaf.shape[1])
    else:
        def serve_step(q, low, high, leaf):
            return cam_match_ref(q, low, high, leaf, mode="direct")

    lowered = jax.jit(serve_step).lower(q_in, low_in, high_in, leaf_in)
    # MODEL_FLOPS counts only MXU work (match @ leaf_matrix); the range
    # compares are VPU integer ops, reported separately so the useful-FLOP
    # ratio stays comparable with the LM rows.
    mf = 2.0 * float(batch) * rows * c_pad
    meta = {
        "arch": cfg.name, "shape": shape, "kind": "xtime_serve",
        "mesh": "2x16x16" if axes.pod else "16x16",
        "n_devices": int(np.prod(mesh.devices.shape)),
        "model_flops_total": mf,
        "compare_ops_total": 2.0 * float(batch) * rows * cfg.n_features,
    }
    return lowered, meta


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             flash_blk: int = 1024) -> dict:
    t0 = time.time()
    mesh_name = "multi" if multi_pod else "single"
    result: dict = {"arch": arch, "shape": shape, "mesh": mesh_name}
    try:
        lowered, meta = lower_cell(arch, shape, multi_pod, flash_blk)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, list):  # jax 0.4.x returns [dict], >=0.5 a dict
            ca = ca[0] if ca else {}
        cost = hlo_analysis.analyze(compiled.as_text())
        n_dev = meta["n_devices"]
        terms = hlo_analysis.roofline_from_cost(
            cost, model_flops_per_dev=meta["model_flops_total"] / n_dev
        )
        result.update(meta)
        result.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "code_bytes": int(mem.generated_code_size_in_bytes),
            },
            "cost_analysis_raw": {
                "flops": float(ca.get("flops", -1.0)),
                "bytes": float(ca.get("bytes accessed", -1.0)),
            },
            "hlo": {
                "dot_flops_per_dev": cost.dot_flops,
                "hbm_bytes_per_dev": cost.fusion_boundary_bytes,
                "collective_bytes_per_dev": cost.collective_bytes,
                "collective_breakdown": cost.collective_breakdown,
                "n_whiles": cost.n_whiles,
                "trip_counts": cost.trip_counts[:64],
            },
            "roofline": {
                "compute_s": terms.compute_s,
                "memory_s": terms.memory_s,
                "collective_s": terms.collective_s,
                "dominant": terms.dominant,
                "bound_s": terms.bound_s,
                "model_flops_ratio": terms.useful_flop_ratio,
            },
        })
        # per-device HBM check vs v5e (16 GiB)
        per_dev = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + mem.temp_size_in_bytes)
        result["memory"]["total_per_device_gib"] = round(per_dev / 2**30, 3)
        result["memory"]["fits_v5e_16gib"] = bool(per_dev < 16 * 2**30)
    except SkipCell as e:
        result.update({"status": "skip", "reason": str(e)})
    except Exception as e:  # noqa: BLE001
        result.update({
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        })
    result["wall_s"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}.json")
    with open(fn, "w") as f:
        json.dump(result, f, indent=1, default=float)
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description="X-TIME framework multi-pod dry-run")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--flash-blk", type=int, default=1024)
    args = ap.parse_args()
    res = run_cell(args.arch, args.shape, args.multi_pod, args.out_dir,
                   args.flash_blk)
    brief = {k: v for k, v in res.items()
             if k in ("arch", "shape", "mesh", "status", "compile_s", "wall_s",
                      "error", "reason")}
    print(json.dumps(brief))
    if res["status"] == "ok":
        print("memory_analysis:", json.dumps(res["memory"]))
        print("roofline:", json.dumps(res["roofline"]))


if __name__ == "__main__":
    main()
