"""Trip-count-aware HLO analysis for the roofline terms.

``compiled.cost_analysis()`` counts every while-loop (lax.scan) body
exactly ONCE, which silently undercounts a 61-layer scanned stack by 61x
(verified experimentally — see EXPERIMENTS.md §Dry-run notes).  This
module parses ``compiled.as_text()`` directly and:

  * extracts every while loop's trip count from its condition region
    (XLA canonicalizes scan conditions to ``compare(iv, constant(N)),
    LT``), and propagates multipliers through nested computations;
  * sums **dot FLOPs** per computation (recursing into fusion/call
    subcomputations) x trip multiplier — the compute roofline numerator;
  * sums **fusion-boundary bytes** (operands + results of top-level
    instructions, internal fusion values excluded) x multiplier — a
    principled HBM-traffic estimate: fusion boundaries are exactly the
    materialization points;
  * sums **collective bytes** (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute operand sizes) x multiplier — the
    collective roofline numerator.  Bytes are per-device (HLO shapes are
    already sharded under SPMD).

Validated against cost_analysis on scan-free programs (tests/test_roofline.py).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)=%?\{?([\w.\-, %]+)\}?")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of possibly-tuple HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> tuple[str, list[int]] | None:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


@dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    raw: str


@dataclass
class Computation:
    name: str
    instructions: list[Instruction] = field(default_factory=list)
    by_name: dict = field(default_factory=dict)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        s = line.rstrip()
        if not s:
            continue
        # computation header: `%name (params...) -> type {`  or `ENTRY %name ...{`
        if s.endswith("{") and ("(" in s) and ("=" not in s.split("(")[0]):
            m = re.search(r"%([\w.\-]+)\s*\(", s)
            if m:
                cur = Computation(name=m.group(1))
                comps[cur.name] = cur
            continue
        if s.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(s)
        if not mi:
            continue
        name, rest = mi.group(1), mi.group(2)
        # type is everything up to the opcode '(' — find `op(` after type
        mo = re.match(r"((?:\([^)]*\)|[\w\[\],{}\/ ]+?)*?)\s*([\w\-]+)\(", rest)
        if not mo:
            continue
        type_str, opcode = mo.group(1).strip(), mo.group(2)
        # operands: first parenthesized group after opcode
        paren = rest[mo.end() - 1:]
        depth = 0
        end = 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = re.findall(r"%([\w.\-]+)", paren[: end + 1])
        inst = Instruction(name=name, type_str=type_str, opcode=opcode,
                           operands=operands, raw=s)
        cur.instructions.append(inst)
        cur.by_name[name] = inst
    return comps


def _trip_count(cond: Computation) -> int:
    """Scan conditions: compare(iv, constant(N)), direction=LT."""
    consts = {}
    for inst in cond.instructions:
        if inst.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", inst.raw)
            if m:
                consts[inst.name] = int(m.group(1))
    # find the compare (possibly wrapped in a fusion) and take the constant
    for inst in cond.instructions:
        if "compare" in inst.raw or inst.opcode == "fusion":
            for op in inst.operands:
                if op in consts:
                    return max(1, consts[op])
    if consts:
        return max(1, max(consts.values()))
    return 1


@dataclass
class HLOCost:
    dot_flops: float = 0.0
    fusion_boundary_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: dict = field(default_factory=dict)
    n_whiles: int = 0
    trip_counts: list[int] = field(default_factory=list)


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    """2 x out_elems x contraction_size from the dot's dnums + lhs shape."""
    out = _shape_dims(inst.type_str)
    if out is None:
        return 0.0
    _, out_dims = out
    out_elems = float(np.prod(out_dims)) if out_dims else 1.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.raw)
    lhs = comp.by_name.get(inst.operands[0]) if inst.operands else None
    if m is None or lhs is None:
        return 2.0 * out_elems  # degenerate
    lshape = _shape_dims(lhs.type_str)
    if lshape is None:
        return 2.0 * out_elems
    _, ldims = lshape
    contract = 1.0
    for d in (int(x) for x in m.group(1).split(",") if x):
        if d < len(ldims):
            contract *= ldims[d]
    return 2.0 * out_elems * contract


def analyze(text: str) -> HLOCost:
    comps = parse_hlo(text)
    entry = None
    for name, c in comps.items():
        if name.startswith("main") or entry is None:
            if name.startswith("main"):
                entry = c
    if entry is None and comps:
        entry = next(iter(comps.values()))

    cost = HLOCost()
    visited_flops_cache: dict[str, tuple[float, float, dict]] = {}

    def comp_cost(cname: str, depth: int = 0) -> tuple[float, float, dict]:
        """(dot_flops, boundary_bytes, collective_bytes_by_kind) of one
        execution of computation `cname`, recursing into calls."""
        if cname in visited_flops_cache:
            return visited_flops_cache[cname]
        comp = comps.get(cname)
        if comp is None or depth > 50:
            return 0.0, 0.0, {}
        flops = 0.0
        bbytes = 0.0
        coll: dict[str, float] = {}
        for inst in comp.instructions:
            if inst.opcode == "while":
                body_m = re.search(r"body=%?([\w.\-]+)", inst.raw)
                cond_m = re.search(r"condition=%?([\w.\-]+)", inst.raw)
                trips = 1
                if cond_m and cond_m.group(1) in comps:
                    trips = _trip_count(comps[cond_m.group(1)])
                cost.n_whiles += 1
                cost.trip_counts.append(trips)
                if body_m and body_m.group(1) in comps:
                    f, b, c = comp_cost(body_m.group(1), depth + 1)
                    flops += f * trips
                    bbytes += b * trips
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0.0) + v * trips
                continue
            if inst.opcode in ("conditional", "call", "custom-call"):
                for called in re.findall(r"(?:calls|branch_computations)=\{?%?([\w.\-]+)", inst.raw):
                    if called in comps:
                        f, b, c = comp_cost(called, depth + 1)
                        flops += f
                        bbytes += b
                        for k, v in c.items():
                            coll[k] = coll.get(k, 0.0) + v
            if inst.opcode == "dot":
                flops += _dot_flops(inst, comp)
            elif inst.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", inst.raw)
                if m and m.group(1) in comps:
                    f, _b, _c = comp_cost(m.group(1), depth + 1)
                    flops += f  # dots inside fusions count; bytes don't
            for kind in _COLLECTIVES:
                if inst.opcode == kind:
                    nbytes = sum(
                        _shape_bytes(comp.by_name[op].type_str)
                        for op in inst.operands
                        if op in comp.by_name
                    )
                    if nbytes == 0:  # fall back to result size
                        nbytes = _shape_bytes(inst.type_str)
                    coll[kind] = coll.get(kind, 0.0) + nbytes
            # fusion-boundary bytes: top-level instruction operands+result
            if inst.opcode in ("fusion", "dot", "convolution", "copy",
                               "transpose", "reshape", "dynamic-slice",
                               "dynamic-update-slice", "gather", "scatter",
                               "reduce", "broadcast", "concatenate", "sort",
                               *_COLLECTIVES):
                nbytes = _shape_bytes(inst.type_str)
                for op in inst.operands:
                    if op in comp.by_name:
                        nbytes += _shape_bytes(comp.by_name[op].type_str)
                bbytes += nbytes
        out = (flops, bbytes, coll)
        visited_flops_cache[cname] = out
        return out

    if entry is not None:
        f, b, c = comp_cost(entry.name)
        cost.dot_flops = f
        cost.fusion_boundary_bytes = b
        cost.collective_breakdown = c
        cost.collective_bytes = sum(c.values())
    return cost


# ---------------------------------------------------------------------------
# Roofline terms (v5e constants from the assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    bytes_collective: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def as_row(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "hlo_flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.bytes_hbm,
            "coll_bytes_per_dev": self.bytes_collective,
            "model_flops_ratio": round(self.useful_flop_ratio, 4),
        }


def roofline_from_cost(
    cost: HLOCost, *, model_flops_per_dev: float = 0.0
) -> RooflineTerms:
    """Three terms in seconds, per the assignment formulas.

    All quantities are per-device (SPMD HLO shapes are sharded), so the
    'chips x' denominators are already applied.
    """
    return RooflineTerms(
        compute_s=cost.dot_flops / PEAK_FLOPS,
        memory_s=cost.fusion_boundary_bytes / HBM_BW,
        collective_s=cost.collective_bytes / ICI_BW,
        flops=cost.dot_flops,
        bytes_hbm=cost.fusion_boundary_bytes,
        bytes_collective=cost.collective_bytes,
        model_flops=model_flops_per_dev,
    )
