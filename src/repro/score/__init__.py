"""Streaming offline batch scoring: saved artifact × columnar file.

The throughput tier (DESIGN.md §14).  ``score_file`` is the entry
point; the reader/writer pieces are exported for callers that compose
their own pipelines::

    from repro.score import score_file

    res = score_file("model_artifact", "rows.npy", kind="predict",
                     chunk_rows=8192, out="preds.npy")
    print(f"{res.n_rows} rows at {res.rows_per_s:,.0f} rows/s")

Importing this package never touches jax — sources open, inputs are
inspected, and errors surface numpy-only; device work starts inside
``score_file`` once there are rows to score.
"""

from repro.score.pipeline import KINDS, ScoreResult, score_file
from repro.score.reader import (
    ArraySource,
    NpySource,
    ParquetSource,
    open_columnar,
)
from repro.score.writer import PredictionWriter

__all__ = [
    # pipeline
    "score_file",
    "ScoreResult",
    "KINDS",
    # columnar input sources
    "open_columnar",
    "ArraySource",
    "NpySource",
    "ParquetSource",
    # streaming output
    "PredictionWriter",
]
