"""Columnar input sources for the offline-scoring pipeline (DESIGN.md §14).

A *source* is anything that can hand the pipeline its rows in order, one
bounded chunk at a time, without materializing the whole file:

    ``ArraySource``    an in-memory (or already memory-mapped) 2-D array
    ``NpySource``      an ``.npy`` file opened with ``mmap_mode='r'`` —
                       the zero-dependency path: chunks are copied out of
                       the OS page cache, the full file is never resident
    ``ParquetSource``  a ``.parquet`` file streamed batch-by-batch via
                       pyarrow (optional dependency; a clean error names
                       the ``.npy`` fallback when it is absent)

``open_columnar`` picks the source from the input's type/suffix.  All
sources expose ``n_rows`` / ``n_features`` up front (the writer
preallocates its output from them) and ``iter_chunks(chunk_rows)``
yielding ``(start_row, chunk)`` with float or integer dtype preserved —
the pipeline decides whether the artifact's grid must bin them.

This module is deliberately numpy-only: opening and inspecting inputs
never touches jax device state (the same contract as artifact loading).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterator

import numpy as np

#: file suffixes ``open_columnar`` understands (lowercased)
NPY_SUFFIXES = (".npy",)
PARQUET_SUFFIXES = (".parquet", ".pq")


def _check_chunk_rows(chunk_rows: int) -> int:
    if int(chunk_rows) < 1:
        raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
    return int(chunk_rows)


@dataclass
class ArraySource:
    """Rows from a 2-D array already in (possibly mapped) memory.

    Chunks are *copies* of the slice (``np.ascontiguousarray``), so a
    memory-mapped backing array is only ever touched one chunk at a time
    and the pipeline may donate/overwrite what it is handed.
    """

    array: np.ndarray

    def __post_init__(self) -> None:
        if self.array.ndim != 2:
            raise ValueError(
                f"columnar input must be 2-D (rows, features), "
                f"got shape {self.array.shape}"
            )

    @property
    def n_rows(self) -> int:
        return int(self.array.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.array.shape[1])

    @property
    def dtype(self) -> np.dtype:
        return self.array.dtype

    def iter_chunks(
        self, chunk_rows: int
    ) -> Iterator[tuple[int, np.ndarray]]:
        chunk_rows = _check_chunk_rows(chunk_rows)
        for start in range(0, self.n_rows, chunk_rows):
            stop = min(start + chunk_rows, self.n_rows)
            yield start, np.ascontiguousarray(self.array[start:stop])

    def close(self) -> None:  # uniform interface; nothing to release
        pass


class NpySource(ArraySource):
    """A ``.npy`` file memory-mapped read-only — the zero-dependency
    billion-row path: the resident set is one chunk plus whatever the OS
    keeps cached, regardless of file size."""

    def __init__(self, path: str | Path) -> None:
        self.path = Path(path)
        super().__init__(np.load(self.path, mmap_mode="r"))

    def close(self) -> None:
        # drop the mmap reference; the OS unmaps when the array dies
        mm = getattr(self.array, "_mmap", None)
        self.array = np.zeros((0, self.n_features or 0))
        if mm is not None:  # pragma: no cover - platform-dependent attr
            mm.close()


@dataclass
class ParquetSource:
    """A ``.parquet`` file streamed via pyarrow's batch iterator.

    Optional-dependency path: importing this class is free, constructing
    it without pyarrow raises a clean error pointing at the ``.npy``
    route.  ``columns`` selects/orders feature columns; by default every
    column is used in schema order.
    """

    path: str | Path
    columns: list[str] | None = None
    _pf: object = field(init=False, repr=False, default=None)

    def __post_init__(self) -> None:
        try:
            import pyarrow.parquet as pq
        except ImportError as e:  # pragma: no cover - env-dependent
            raise ImportError(
                "reading .parquet needs the optional 'pyarrow' dependency "
                "(pip install pyarrow); .npy inputs stream with no extra "
                "dependencies"
            ) from e
        self.path = Path(self.path)
        self._pf = pq.ParquetFile(self.path)
        names = [f.name for f in self._pf.schema_arrow]
        if self.columns is None:
            self.columns = names
        else:
            missing = [c for c in self.columns if c not in names]
            if missing:
                raise ValueError(
                    f"{self.path}: columns {missing} not in parquet schema "
                    f"{names}"
                )

    @property
    def n_rows(self) -> int:
        return int(self._pf.metadata.num_rows)

    @property
    def n_features(self) -> int:
        return len(self.columns)

    @property
    def dtype(self) -> np.dtype:
        # the widest selected column type decides whether the pipeline
        # treats rows as pre-binned (all-integer) or grid-binned (float)
        schema = self._pf.schema_arrow
        kinds = [
            np.dtype(schema.field(c).type.to_pandas_dtype())
            for c in self.columns
        ]
        return np.result_type(*kinds) if kinds else np.dtype(np.float64)

    def iter_chunks(
        self, chunk_rows: int
    ) -> Iterator[tuple[int, np.ndarray]]:
        chunk_rows = _check_chunk_rows(chunk_rows)
        start = 0
        for batch in self._pf.iter_batches(
            batch_size=chunk_rows, columns=self.columns
        ):
            chunk = np.stack(
                [batch.column(i).to_numpy(zero_copy_only=False)
                 for i in range(batch.num_columns)],
                axis=1,
            )
            yield start, chunk
            start += chunk.shape[0]

    def close(self) -> None:
        self._pf.close()


def open_columnar(
    source,
    *,
    columns: list[str] | None = None,
) -> ArraySource | ParquetSource:
    """Open ``source`` as a chunk-iterable columnar input.

    ``source`` may be a 2-D ``np.ndarray`` (used as-is, zero copy until
    chunked), a ``.npy`` path (memory-mapped), or a ``.parquet`` path
    (streamed via optional pyarrow).  Already-open sources pass through.
    ``columns`` selects parquet feature columns; it is rejected for
    array inputs, whose column order is positional.
    """
    if hasattr(source, "iter_chunks"):  # already a source
        return source
    if isinstance(source, np.ndarray):
        if columns is not None:
            raise ValueError(
                "columns= applies to parquet inputs; slice array inputs "
                "before passing them"
            )
        return ArraySource(source)
    if isinstance(source, (str, Path)):
        path = Path(source)
        if not path.exists():
            raise FileNotFoundError(f"no such input file: {path}")
        suffix = path.suffix.lower()
        if suffix in NPY_SUFFIXES:
            if columns is not None:
                raise ValueError(
                    "columns= applies to parquet inputs; .npy columns are "
                    "positional"
                )
            return NpySource(path)
        if suffix in PARQUET_SUFFIXES:
            return ParquetSource(path, columns=columns)
        raise ValueError(
            f"unsupported columnar input {path.name!r}: expected one of "
            f"{NPY_SUFFIXES + PARQUET_SUFFIXES}"
        )
    raise TypeError(
        "open_columnar takes a 2-D ndarray, a .npy/.parquet path, or an "
        f"existing source, got {type(source).__name__}"
    )
