"""Streaming batch scoring: saved artifact × columnar file → predictions.

The throughput counterpart to the latency-focused serve tiers (DESIGN.md
§6/§12): bulk offline scoring of columnar rows — the `tact`-style BDT
analysis workload — at maximum rows/s.  The pipeline (DESIGN.md §14):

    read chunk i+1 ──┐ host: mmap slice → grid binning → bucket pad
                     │
    score chunk i  ──┤ device: donated transfer → batch-hinted kernel
                     │
    drain chunk i-1 ─┘ host: fetch outputs → streaming .npy writer

Three structural wins over naive whole-file one-shot scoring:

  * **bounded working set** — the kernel's ``(B, R)`` match intermediate
    stays chunk-sized and cache/VMEM-resident instead of growing with
    the file (a one-shot over 10⁵+ rows spills multi-GB intermediates
    through DRAM; over 10⁹ rows it simply does not fit);
  * **donated double-buffering** — chunk ``i``'s query buffer is donated
    to the device (``padded_fn``) while the host bins chunk ``i+1`` and
    drains chunk ``i-1``, so host→device transfer overlaps compute and
    at most two chunks are in flight;
  * **one compiled shape** — every chunk (tail included) pads to one
    bucket, so the whole file runs through a single jit entry, bound via
    ``CompiledModel.engine(batch_hint=...)`` so a tuned artifact's
    dispatch table picks the measured-best kernel for that bucket.

Bit-equivalence contract: every CAM row match and leaf accumulation is
per-query-row independent, so the concatenated streamed outputs are
BIT-IDENTICAL to a single ``predict``/``raw_margin`` call over the whole
file with the same engine configuration — across chunk sizes, tails,
double-buffering on/off, and the mesh ``batch`` NoC program
(tests/test_score.py).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.score.reader import open_columnar
from repro.score.writer import PredictionWriter

#: what ``kind`` selects — engine margins (the BDT analysis score) or
#: final predictions (argmax/sign/regression value)
KINDS = ("margin", "predict")


@dataclass(frozen=True)
class ScoreResult:
    """One streaming scoring run: the outputs plus its throughput record."""

    values: np.ndarray  # (n_rows, n_outputs) margins or (n_rows,) predictions
    path: Path | None  # where values were streamed (None: in-memory)
    kind: str
    n_rows: int
    n_features: int
    n_chunks: int
    chunk_rows: int
    bucket: int  # padded per-chunk batch (one jit entry for the whole file)
    binned: bool  # True when the artifact's grid binned float input
    double_buffered: bool
    elapsed_s: float
    engine: dict = field(default_factory=dict)  # bound-engine provenance

    @property
    def rows_per_s(self) -> float:
        if self.elapsed_s <= 0:
            return 0.0
        return self.n_rows / self.elapsed_s


def _load_model(model):
    from repro.api import CompiledModel  # numpy-only import

    if isinstance(model, (str, Path)):
        return CompiledModel.load(model)
    if not isinstance(model, CompiledModel):
        raise TypeError(
            "score_file takes a CompiledModel or a saved-artifact path, "
            f"got {type(model).__name__}"
        )
    return model


def _empty_tail(model, kind: str) -> tuple[tuple, np.dtype]:
    """Output (trailing shape, dtype) for a zero-row input, mirroring the
    engine's own output contract without binding an engine."""
    if kind == "margin":
        return (int(model.table.n_outputs),), np.dtype(np.float32)
    if model.table.task == "regression":
        return (), np.dtype(np.float32)
    return (), np.dtype(np.int32)


def score_file(
    model,
    source,
    *,
    kind: str = "margin",
    chunk_rows: int = 8192,
    out: str | Path | None = None,
    mesh=None,
    columns: list[str] | None = None,
    double_buffer: bool = True,
    **overrides,
) -> ScoreResult:
    """Stream ``source`` through ``model``'s engine chunk by chunk.

    Args:
      model: a ``CompiledModel`` or a saved-artifact base path.
      source: 2-D ndarray, ``.npy`` path (memory-mapped), ``.parquet``
        path (optional pyarrow), or an open reader source.  Float rows
        are binned chunk-by-chunk with the artifact's attached grid
        (``CompiledModel.quantizer``); integer rows are treated as
        already-binned queries and pass the grid by.
      kind: 'margin' (raw per-channel scores) or 'predict' (final
        predictions) — same outputs as ``XTimeEngine.raw_margin`` /
        ``predict`` over the whole file, bit for bit.
      chunk_rows: rows per chunk; the actual device batch is the
        ``bucket`` this pads to (engine tiling × mesh divisibility).
      out: optional ``.npy`` path to stream predictions into
        (preallocated memmap — bounded memory at any file size).
      mesh: optional jax Mesh; chunks then fan out under the ``batch``
        NoC program (replicated tables, zero cross-device collectives)
        unless ``overrides`` names another ``noc_config``.
      double_buffer: keep one chunk in flight while the host prepares
        the next (the donated-overlap pipeline).  ``False`` drains every
        chunk synchronously — same bits, no overlap (debug/measure).
      overrides: ``DeployConfig`` field updates for the engine binding.

    Returns a :class:`ScoreResult`; ``.values`` is the full output array
    (memmap-backed when ``out`` was given).
    """
    if kind not in KINDS:
        raise ValueError(f"kind {kind!r} not in {KINDS}")
    if chunk_rows < 1:
        raise ValueError(f"chunk_rows must be >= 1, got {chunk_rows}")
    model = _load_model(model)
    src = open_columnar(source, columns=columns)
    try:
        n_rows, n_feat = src.n_rows, src.n_features
        expect = int(model.table.n_features)
        if n_feat != expect:
            raise ValueError(
                f"input has {n_feat} feature columns, the artifact expects "
                f"{expect}"
            )
        needs_grid = np.dtype(src.dtype).kind not in "iu"
        if needs_grid and model.quantizer is None:
            raise ValueError(
                "float columnar input needs the artifact's feature grid to "
                "bin queries, but this artifact has none attached; build "
                "with quantizer=... (or from an ingested dump), or provide "
                "already-binned integer rows"
            )
        writer = PredictionWriter(n_rows, path=out)
        if n_rows == 0:
            # a valid (empty) scoring run; never touches jax
            values = writer.finalize(empty_like=_empty_tail(model, kind))
            return ScoreResult(
                values=values, path=writer.path, kind=kind, n_rows=0,
                n_features=n_feat, n_chunks=0, chunk_rows=chunk_rows,
                bucket=0, binned=needs_grid, double_buffered=double_buffer,
                elapsed_s=0.0, engine={},
            )

        from repro.kernels import ops as kops  # lazy: touches jax
        from repro.core.tune import kernel_version

        engine = model.engine(mesh=mesh, batch_hint=chunk_rows, **(
            {"noc_config": "batch", **overrides}
            if mesh is not None and "noc_config" not in overrides
            else overrides
        ))
        # one bucket for every chunk (tail included): a single jit entry,
        # sized to what both the kernel tiling and the mesh accept
        mult = int(np.lcm(engine.b_blk, engine.batch_multiple))
        bucket = int(np.ceil(min(chunk_rows, n_rows) / mult)) * mult
        run = engine.padded_fn(kind)
        quantizer = model.quantizer

        t0 = time.perf_counter()
        pending: tuple[int, int, object] | None = None
        n_chunks = 0
        for start, chunk in src.iter_chunks(chunk_rows):
            bins = quantizer.transform(chunk) if needs_grid else chunk
            q = kops.pad_to_bucket(
                engine.select_features(np.asarray(bins)),
                bucket, engine.arrays.f_pad, dtype=engine.table_dtype,
            )
            # dispatch is async: the device starts on this chunk (its
            # query buffer donated) while the host drains the previous
            # one and reads/bins the next — at most two chunks in flight
            dev = run(q)
            n_chunks += 1
            if pending is not None:
                p_start, p_len, p_dev = pending
                writer.write(p_start, np.asarray(p_dev)[:p_len])
                pending = None
            if double_buffer:
                pending = (start, chunk.shape[0], dev)
            else:
                writer.write(start, np.asarray(dev)[: chunk.shape[0]])
        if pending is not None:
            p_start, p_len, p_dev = pending
            writer.write(p_start, np.asarray(p_dev)[:p_len])
        values = writer.finalize()
        elapsed = time.perf_counter() - t0

        return ScoreResult(
            values=values, path=writer.path, kind=kind, n_rows=n_rows,
            n_features=n_feat, n_chunks=n_chunks, chunk_rows=chunk_rows,
            bucket=bucket, binned=needs_grid, double_buffered=double_buffer,
            elapsed_s=elapsed,
            engine={
                "backend": engine.backend,
                "table_dtype": engine.table_dtype,
                "kernel": kernel_version(engine.table_dtype),
                "spmd": engine.spmd,
                "noc_config": engine.noc_config,
                "devices": 1 if mesh is None else int(mesh.size),
            },
        )
    finally:
        src.close()
