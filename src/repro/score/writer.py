"""Streaming prediction writer for the offline-scoring pipeline.

The pipeline drains device outputs one chunk at a time; this writer puts
them where they belong without ever holding more than one chunk of
freshly produced output:

  * with a ``path`` — a preallocated ``.npy`` memmap
    (``np.lib.format.open_memmap``), so a billion-row scoring run
    streams straight to disk with a bounded resident set;
  * without — a preallocated in-memory array (the convenience path for
    callers that want the result as an ndarray).

Allocation is deferred to the first chunk: output dtype and trailing
shape fall out of what the engine actually produced (``(B, n_outputs)``
float32 margins vs ``(B,)`` integer predictions), so the writer never
second-guesses the engine's contract.  Numpy-only, like the reader.
"""

from __future__ import annotations

from pathlib import Path

import numpy as np


class PredictionWriter:
    """Collects per-chunk outputs into one ``(n_rows, ...)`` array/file."""

    def __init__(self, n_rows: int, path: str | Path | None = None) -> None:
        self.n_rows = int(n_rows)
        self.path = None if path is None else Path(path)
        if self.path is not None and self.path.suffix != ".npy":
            # writing raw npy bytes under a surprising suffix would make
            # the output unreadable by the obvious np.load call
            self.path = self.path.with_suffix(self.path.suffix + ".npy")
        self._out: np.ndarray | None = None
        self._written = 0

    def _allocate(self, first_chunk: np.ndarray) -> None:
        shape = (self.n_rows,) + first_chunk.shape[1:]
        if self.path is None:
            self._out = np.empty(shape, dtype=first_chunk.dtype)
        else:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._out = np.lib.format.open_memmap(
                self.path, mode="w+", dtype=first_chunk.dtype, shape=shape
            )

    def write(self, start: int, chunk: np.ndarray) -> None:
        """Place ``chunk`` at row ``start``; chunks must arrive in order
        (the pipeline drains its double buffer sequentially)."""
        if self._out is None:
            self._allocate(chunk)
        if start != self._written:
            raise ValueError(
                f"out-of-order chunk: expected row {self._written}, "
                f"got {start}"
            )
        stop = start + chunk.shape[0]
        if stop > self.n_rows:
            raise ValueError(
                f"chunk [{start}:{stop}) overruns the {self.n_rows}-row "
                "output"
            )
        self._out[start:stop] = chunk
        self._written = stop

    def finalize(self, empty_like: tuple | None = None) -> np.ndarray:
        """Flush and return the full output array.

        ``empty_like = (shape_tail, dtype)`` shapes a zero-row output
        when no chunk was ever written (an empty input file is a valid
        scoring run, not an error).
        """
        if self._out is None:
            tail, dtype = empty_like if empty_like is not None else ((), np.float32)
            self._allocate(np.empty((0,) + tuple(tail), dtype=dtype))
        if self._written != self.n_rows:
            raise ValueError(
                f"finalize after {self._written}/{self.n_rows} rows written"
            )
        if isinstance(self._out, np.memmap):
            self._out.flush()
        return self._out
