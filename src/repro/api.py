"""Compiled-artifact API: ``build()`` -> portable ``CompiledModel``.

The paper's deployment story is "compile the ensemble once, program the
CAM chip, then serve" (§II-D, Fig. 7d).  ``build`` is that compile step
as one call:

    cm = repro.api.build(ensemble)          # or a pre-compiled CAMTable
    cm.save("artifacts/churn")              # churn.npz + churn.json
    ...
    cm = CompiledModel.load("artifacts/churn")   # any host, no trainer
    engine = cm.engine(mesh=mesh)           # bind to devices on demand

``CompiledModel`` is the immutable unit of deployment — the CAM table,
its core placement, the NoC router program, the analytic chip report and
the ``DeployConfig`` execution knobs, together.  It serializes as an
``.npz`` (integer range tables + float leaf values) plus a JSON sidecar
(config / metadata / schema version), so a serve process cold-starts
from disk without training deps or recompilation — the registry path
(``repro.serve.TableRegistry.register(name, artifact)``).

The engine import happens lazily inside ``CompiledModel.engine`` so that
loading/inspecting artifacts never touches jax device state.
"""

from __future__ import annotations

import dataclasses
import json
import warnings
from dataclasses import dataclass
from pathlib import Path
from typing import TYPE_CHECKING

import numpy as np

from repro.core.compile import (
    CAMTable,
    ChipSpec,
    CorePlacement,
    compile_ensemble,
    order_columns_by_activity,
    pack_cores,
)
from repro.core.compress import compress_table, resolve_level
from repro.core.deploy import DeployConfig
from repro.core.noc import NoCPlan, plan_noc
from repro.core.perfmodel import PerfReport, xtime_perf
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import Ensemble

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.engine import XTimeEngine

# v2: packed-at-rest low/high arrays (narrow dtype, INCLUSIVE upper
# bounds) + the table_dtype key — a v1 reader would misread packed arrays
# as canonical int32 exclusive-high, so packed artifacts must fail its
# version gate cleanly.  v1 artifacts (int32, no table_dtype) still load.
# v3: column-collapsed tables carry a feature_ids array mapping stored
# columns back to query features, and column-clustered tables carry a
# col_perm array (order_columns_by_activity) — a v2 reader would match
# misaligned columns either way, so only artifacts whose columns were
# actually collapsed or permuted are stamped v3 (everything else stays
# v2, and v1/v2 artifacts still load; the 'compression' sidecar report
# alone is additive and needs no bump).
SCHEMA_VERSION = 3
_SUPPORTED_SCHEMAS = (1, 2, 3)
_FORMAT = "xtime-compiled-model"

# the CAMTable arrays stored in the .npz payload
_TABLE_ARRAYS = ("low", "high", "leaf", "tree_id", "class_id")
_TABLE_META = (
    "n_trees", "n_features", "n_bins", "n_outputs",
    "task", "kind", "base_score", "n_classes", "table_dtype",
)


@dataclass(frozen=True, eq=False)
class CompiledModel:
    """Immutable compiled artifact: everything between training and serving.

    Attributes:
      table: the compiled CAM rows (one per root-to-leaf path).
      placement: tree -> core packing on the chip (``pack_cores``).
      noc: H-tree router program + collective plan (``plan_noc``).
      perf: analytic chip numbers for this exact mapping (``xtime_perf``).
      deploy: execution knobs; ``engine()`` binds them to a backend/mesh.
    """

    table: CAMTable
    placement: CorePlacement
    noc: NoCPlan
    perf: PerfReport
    deploy: DeployConfig
    # ingestion extras (None for natively trained models): the grid the
    # model was lowered onto — needed to bin float queries at serve time
    # — and the lowering's validation report (sidecar provenance)
    quantizer: "FeatureQuantizer | None" = None
    ingest: dict | None = None
    # kernel-autotune provenance: the serialized ``repro.core.tune.TunePlan``
    # whose winner is already folded into ``deploy`` (see ``with_tuning``);
    # persisted in the sidecar so cold starts skip the re-search
    tuning: dict | None = None
    # table-compression provenance: the ``CompressionReport`` dict of the
    # pass that produced ``table`` (None when built with compress='off')
    compression: dict | None = None

    def __post_init__(self) -> None:
        # per-instance engine cache (frozen dataclass => set via object)
        object.__setattr__(self, "_engines", {})

    @property
    def chip(self) -> ChipSpec:
        return self.placement.spec

    # -- execution binding ---------------------------------------------------

    def resolved_deploy(
        self, mesh=None, batch_hint=None, **overrides
    ) -> DeployConfig:
        """The effective config an engine binds: the tuned dispatch entry
        for ``batch_hint`` folded in first (tuned artifacts only — the
        ``TunePlan.dispatch`` table picks the measured-best kernel version
        and block sizes for that serving bucket), then ``overrides``
        (explicit knobs outrank the dispatch), then 'auto' noc_config
        resolved from the compiled NoC plan ('batch' degrades to
        'accumulate' without a mesh to replicate over) and 'auto' spmd
        resolved from the mesh (explicit shard_map collectives on a mesh,
        plain jit otherwise — DESIGN.md §8)."""
        if "batching" in overrides:
            # a build-time knob: it changes the router program, not the
            # engine binding — silently ignoring it here would serve the
            # stale NoC plan
            raise ValueError(
                "'batching' is fixed at build time; use "
                "with_deploy(deploy.replace(batching=...)) to replan the NoC"
            )
        if "compress" in overrides:
            # also build-time: the level describes how the TABLE was
            # rewritten; binding cannot (de)compress an existing artifact
            raise ValueError(
                "'compress' is fixed at build time; re-run repro.api.build "
                "with compress=... to change the table compression level"
            )
        cfg = self.deploy
        if batch_hint is not None and self.tuning is not None:
            cfg = self.tune_plan().apply(cfg, batch=int(batch_hint))
        if overrides:
            cfg = cfg.replace(**overrides)
        if cfg.noc_config == "auto":
            noc_cfg = self.noc.engine_noc_config
            if noc_cfg == "batch" and mesh is None:
                noc_cfg = "accumulate"
            cfg = cfg.replace(noc_config=noc_cfg)
        if cfg.spmd == "auto":
            cfg = cfg.replace(spmd="gspmd" if mesh is None else "shard_map")
        return cfg

    def engine(self, mesh=None, batch_hint=None, **overrides) -> "XTimeEngine":
        """Lazily bind this artifact to an ``XTimeEngine``.

        Repeated calls with the same mesh/overrides return the same engine
        (and therefore hit its jit cache); a different mesh or override set
        binds a fresh one.  ``overrides`` are ``DeployConfig`` field
        updates (e.g. ``backend='pallas'``, ``b_blk=256``).

        ``batch_hint`` engages a tuned artifact's DISPATCH table: the
        engine binds the measured-best kernel version/blocks for that
        serving batch's bucket (``TunePlan.dispatch_for``).  Hints
        resolving to the same bucket share one engine; untuned artifacts
        ignore the hint.
        """
        bucket = None
        if batch_hint is not None and self.tuning is not None:
            bucket = int(self.tune_plan().dispatch_for(int(batch_hint))["batch"])
        key = (None if mesh is None else id(mesh), bucket,
               tuple(sorted(overrides.items())))
        cached = self._engines.get(key)
        if cached is not None:
            return cached
        from repro.core.engine import XTimeEngine  # lazy: touches jax

        eng = XTimeEngine.from_config(
            self.table,
            self.resolved_deploy(mesh, batch_hint=batch_hint, **overrides),
            mesh=mesh,
        )
        self._engines[key] = eng
        return eng

    def with_deploy(self, deploy: DeployConfig) -> "CompiledModel":
        """Same compiled tables, different execution config.

        Only the cheap chip-side plans are recomputed, and only when
        ``batching`` changed (it alters the router program) — the CAM
        table and core placement are reused as-is, never recompiled.
        ``deploy.compress`` is pinned to this artifact's actual level:
        the table is already (un)compressed, so carrying a different
        level over (registry hot swaps, config reuse) would only make
        the provenance lie.
        """
        if deploy.compress != self.deploy.compress:
            deploy = deploy.replace(compress=self.deploy.compress)
        if deploy == self.deploy:
            return self
        if deploy.batching == self.deploy.batching:
            return dataclasses.replace(self, deploy=deploy)
        noc = plan_noc(self.table, self.placement, batching=deploy.batching)
        perf = xtime_perf(self.table, self.placement, noc)
        return dataclasses.replace(self, noc=noc, perf=perf, deploy=deploy)

    def with_tuning(self, plan) -> "CompiledModel":
        """Fold an ``autotune_kernel`` winner into the artifact.

        The plan's knobs (b_blk/r_blk/table_dtype/mode/backend) replace
        the deploy config's, and the full plan rides the sidecar so
        reloaded artifacts — and ``TableRegistry`` cold starts — bind
        engines in the tuned configuration without re-searching.
        """
        tuned = self.with_deploy(plan.apply(self.deploy))
        return dataclasses.replace(tuned, tuning=plan.to_dict())

    def tune_plan(self):
        """The persisted ``TunePlan`` (None when never autotuned)."""
        if self.tuning is None:
            return None
        from repro.core.tune import TunePlan  # lazy: keeps load light

        return TunePlan.from_dict(self.tuning)

    # -- persistence ---------------------------------------------------------

    def save(self, path: str | Path) -> Path:
        """Write ``<base>.npz`` (tables) + ``<base>.json`` (sidecar).

        ``path`` may be the bare base path or end in ``.npz``/``.json``.
        Returns the sidecar path.
        """
        base = _base_path(path)
        base.parent.mkdir(parents=True, exist_ok=True)
        t = self.table
        arrays = {name: getattr(t, name) for name in _TABLE_ARRAYS}
        if t.table_dtype != "int32":
            # at-rest compaction mirrors the kernel layout: packed dtype,
            # INCLUSIVE upper bound (real rows always have high >= low+1,
            # so high-1 is representable; anything else — e.g. a table
            # whose arrays were mutated without resetting table_dtype —
            # must fail here, not wrap into a silently corrupt artifact)
            dt = np.dtype(t.table_dtype)
            top = np.iinfo(dt).max
            if t.high.size and (
                int(t.high.min()) < 1 or int(t.high.max()) - 1 > top
                or int(t.low.min()) < 0 or int(t.low.max()) > top
            ):
                raise ValueError(
                    f"table bounds do not fit table_dtype {t.table_dtype!r} "
                    "as inclusive ranges; rebuild with table_dtype='int32'"
                )
            arrays["low"] = t.low.astype(dt)
            arrays["high"] = (t.high - 1).astype(dt)
        if t.feature_ids is not None:
            arrays["feature_ids"] = np.asarray(t.feature_ids, dtype=np.int32)
        if t.col_perm is not None:
            arrays["col_perm"] = np.asarray(t.col_perm, dtype=np.int32)
        if self.quantizer is not None:
            # ragged per-feature edges stored flat + offsets
            edges = self.quantizer.edges
            arrays["q_edges"] = (np.concatenate(edges) if edges
                                 else np.zeros(0, dtype=np.float64))
            arrays["q_offsets"] = np.cumsum(
                [0] + [e.shape[0] for e in edges]
            ).astype(np.int64)
        np.savez_compressed(_sibling(base, ".npz"), **arrays)
        sidecar = {
            "format": _FORMAT,
            # only column-collapsed or column-permuted tables NEED the v3
            # reader; everything else stays v2 so older readers keep
            # loading it
            "schema_version": (
                SCHEMA_VERSION
                if (t.feature_ids is not None or t.col_perm is not None)
                else 2
            ),
            "table": {k: getattr(t, k) for k in _TABLE_META},
            "chip": dataclasses.asdict(self.chip),
            "placement": {
                "core_trees": self.placement.core_trees,
                "core_rows_used": self.placement.core_rows_used,
                "n_feature_segments": self.placement.n_feature_segments,
                "replication": self.placement.replication,
            },
            "noc": dataclasses.asdict(self.noc),
            "perf": dataclasses.asdict(self.perf),
            "deploy": self.deploy.to_dict(),
        }
        if self.quantizer is not None:
            sidecar["quantizer"] = {"n_bins": self.quantizer.n_bins}
        if self.ingest is not None:
            sidecar["ingest"] = self.ingest
        if self.tuning is not None:
            sidecar["tuning"] = self.tuning
        if self.compression is not None:
            sidecar["compression"] = self.compression
        out = _sibling(base, ".json")
        out.write_text(json.dumps(sidecar, indent=1))
        return out

    @classmethod
    def load(cls, path: str | Path) -> "CompiledModel":
        """Reconstruct an artifact saved by :meth:`save` — pure I/O plus
        dataclass assembly, no compiler or training imports."""
        base = _base_path(path)
        sidecar = json.loads(_sibling(base, ".json").read_text())
        if sidecar.get("format") != _FORMAT:
            raise ValueError(
                f"{base}: not a {_FORMAT} artifact "
                f"(format={sidecar.get('format')!r})"
            )
        version = sidecar.get("schema_version")
        if version not in _SUPPORTED_SCHEMAS:
            raise ValueError(
                f"{base}: artifact schema_version={version!r} is not in "
                f"the supported versions {_SUPPORTED_SCHEMAS}; re-run "
                "repro.api.build"
            )
        with np.load(_sibling(base, ".npz")) as npz:
            arrays = {name: npz[name] for name in _TABLE_ARRAYS}
            if sidecar["table"].get("table_dtype", "int32") != "int32":
                # packed-at-rest arrays: inclusive high in a narrow dtype;
                # restore the canonical int32 exclusive-high form
                arrays["low"] = arrays["low"].astype(np.int32)
                arrays["high"] = arrays["high"].astype(np.int32) + 1
            if "feature_ids" in npz:  # v3: column-collapsed table
                arrays["feature_ids"] = npz["feature_ids"].astype(np.int32)
            if "col_perm" in npz:  # v3: column-clustered table
                arrays["col_perm"] = npz["col_perm"].astype(np.int32)
            quantizer = None
            if "quantizer" in sidecar and "q_offsets" in npz:
                flat, off = npz["q_edges"], npz["q_offsets"]
                quantizer = FeatureQuantizer(
                    edges=[flat[off[i]:off[i + 1]].astype(np.float64)
                           for i in range(off.shape[0] - 1)],
                    n_bins=int(sidecar["quantizer"]["n_bins"]),
                )
        table = CAMTable(**arrays, **sidecar["table"])
        chip = ChipSpec(**sidecar["chip"])
        placement = CorePlacement(spec=chip, **sidecar["placement"])
        noc_d = dict(sidecar["noc"])
        noc_d["reduction_axes"] = tuple(noc_d["reduction_axes"])
        noc = NoCPlan(**noc_d)
        perf = PerfReport(**sidecar["perf"])
        deploy = DeployConfig.from_dict(sidecar["deploy"])
        return cls(
            table=table, placement=placement, noc=noc, perf=perf,
            deploy=deploy, quantizer=quantizer,
            ingest=sidecar.get("ingest"),
            tuning=sidecar.get("tuning"),
            compression=sidecar.get("compression"),
        )

    # -- float-in serving ----------------------------------------------------

    def _binned(self, x: np.ndarray, caller: str) -> np.ndarray:
        """Float queries -> the integer bins this artifact's tables index;
        already-binned integer queries pass through untouched."""
        x = np.asarray(x)
        if x.dtype.kind in "iu":
            return x
        if self.quantizer is None:
            raise ValueError(
                f"{caller} got float queries but this artifact has no "
                "feature grid attached; build with quantizer=... (or from "
                "an ingested dump), or pass already-binned integer queries"
            )
        return self.quantizer.transform(x)

    def predict(
        self,
        x: np.ndarray,
        *,
        mesh=None,
        return_uncertainty: bool = False,
        **overrides,
    ) -> np.ndarray:
        """Final predictions for a batch of float (or pre-binned) rows.

        The one-call entry point: bins ``x`` with the artifact's attached
        grid, binds the batch-hinted engine (a tuned artifact's dispatch
        table picks the measured-best kernel for this batch size), and
        runs it — replacing the old ``model.bin(x)`` →
        ``model.engine().predict(...)`` two-step.  Integer input skips
        the grid (already binned).  Engine bindings are memoized, so
        repeated same-shaped calls reuse the compiled entry.

        Returns ``(B,)`` int32 class ids, or float32 values for
        regression.  With ``return_uncertainty=True`` (soft cell mode
        only — DESIGN.md §15) returns ``(pred, unc)`` where ``unc`` is
        the ``(B,)`` calibrated leaf-spread uncertainty at each row's
        predicted channel.  For raw per-channel scores use
        :meth:`raw_margin`; for class probabilities
        :meth:`predict_proba`; for bulk file scoring
        ``repro.score.score_file``.
        """
        q = self._binned(x, "predict")
        eng = self.engine(mesh=mesh, batch_hint=q.shape[0], **overrides)
        if return_uncertainty and eng.kernel_mode != "soft":
            raise ValueError(
                "predict(return_uncertainty=True) requires cell_mode="
                f"'soft' (this binding runs mode={eng.mode!r}); build or "
                "bind with DeployConfig(mode='soft')"
            )
        pred = np.asarray(eng.predict(q))
        if not return_uncertainty:
            return pred
        u = np.asarray(eng.uncertainty(q))
        if self.table.task == "regression" or self.table.n_outputs == 1:
            unc = u[:, 0]
        else:  # the spread behind the channel that won the argmax
            unc = u[np.arange(pred.shape[0]), pred.astype(np.int64)]
        return pred, unc

    def predict_proba(
        self, x: np.ndarray, *, mesh=None, **overrides
    ) -> np.ndarray:
        """Class probabilities for a batch of float (or pre-binned) rows.

        Soft cell mode only: the sigmoid-match margins are a smooth
        probabilistic surface, so squashing them is meaningful — binary
        single-logit models return ``(B, 2)`` ``[1-p, p]`` via the
        sigmoid, multiclass models ``(B, n_classes)`` via the softmax.
        Hard modes (and regression tasks) raise.
        """
        q = self._binned(x, "predict_proba")
        eng = self.engine(mesh=mesh, batch_hint=q.shape[0], **overrides)
        if eng.kernel_mode != "soft":
            raise ValueError(
                "predict_proba requires cell_mode='soft' (this binding "
                f"runs mode={eng.mode!r}); build or bind with "
                "DeployConfig(mode='soft')"
            )
        if self.table.task == "regression":
            raise ValueError(
                "predict_proba is undefined for regression models; use "
                "predict(x, return_uncertainty=True) for a value with an "
                "uncertainty channel"
            )
        m = np.asarray(eng.raw_margin(q), dtype=np.float64)
        if self.table.n_outputs == 1:  # single-logit binary
            p = 1.0 / (1.0 + np.exp(-m[:, 0]))
            return np.stack([1.0 - p, p], axis=1).astype(np.float32)
        z = m - m.max(axis=1, keepdims=True)
        e = np.exp(z)
        return (e / e.sum(axis=1, keepdims=True)).astype(np.float32)

    def raw_margin(self, x: np.ndarray, *, mesh=None, **overrides) -> np.ndarray:
        """Raw ``(B, n_outputs)`` margins for float (or pre-binned) rows —
        the margin-kind counterpart of :meth:`predict`."""
        q = self._binned(x, "raw_margin")
        eng = self.engine(mesh=mesh, batch_hint=q.shape[0], **overrides)
        return np.asarray(eng.raw_margin(q))

    def bin(self, x: np.ndarray) -> np.ndarray:
        """Deprecated: float queries -> integer bins, the old first half of
        the ``bin()`` → ``engine().predict()`` two-step.

        Call :meth:`predict` / :meth:`raw_margin` directly (they bin
        internally), or ``model.quantizer.transform(x)`` when only the
        bins are wanted.
        """
        warnings.warn(
            "CompiledModel.bin() is deprecated: call model.predict(x) / "
            "model.raw_margin(x) directly (they bin float queries "
            "internally), or model.quantizer.transform(x) for bare bins",
            DeprecationWarning,
            stacklevel=2,
        )
        if self.quantizer is None:
            raise ValueError(
                "this artifact has no feature grid attached; bin queries "
                "with the FeatureQuantizer the model was trained on"
            )
        return self.quantizer.transform(np.asarray(x))

    # -- introspection -------------------------------------------------------

    def summary(self) -> dict:
        """Human-facing one-stop description (examples / logs)."""
        return {
            "rows": self.table.n_rows,
            "features": self.table.n_features,
            "columns": self.table.n_cols,
            "compress": self.deploy.compress,
            "rows_saved": (
                0 if self.compression is None
                else int(self.compression.get("rows_saved", 0))
            ),
            "trees": self.table.n_trees,
            "outputs": self.table.n_outputs,
            "task": self.table.task,
            "cores_used": self.placement.n_cores_used,
            "replication": self.placement.replication,
            "noc": self.noc.config,
            "latency_ns": round(self.perf.latency_ns, 1),
            "throughput_msps": round(self.perf.throughput_msps, 2),
            "backend": self.deploy.backend,
            "mode": self.deploy.mode,
            "table_dtype": self.table.table_dtype,
            "tuned": self.tuning is not None,
        }


def _base_path(path: str | Path) -> Path:
    p = Path(path)
    if p.suffix in (".npz", ".json"):
        return p.parent / p.name[: -len(p.suffix)]
    return p


def _sibling(base: Path, suffix: str) -> Path:
    # not ``with_suffix``: a dotted base like 'churn.8bit' must keep its dot
    return base.parent / (base.name + suffix)


def build(
    model,
    *,
    deploy: DeployConfig | None = None,
    chip: ChipSpec | None = None,
    n_bins: int = 256,
    on_overflow: str = "merge",
    quantizer: FeatureQuantizer | None = None,
    compress: str | None = None,
    cluster_columns: bool = False,
) -> CompiledModel:
    """Compile ``model`` into a portable, serializable ``CompiledModel``.

    The one-call replacement for the hand-wired ``compile_ensemble ->
    compress_table -> pack_cores -> plan_noc -> xtime_perf ->
    XTimeEngine`` pipeline.  ``model`` may be a native ``Ensemble``, a
    pre-compiled ``CAMTable``, an ``repro.ingest.ImportedEnsemble``, or
    a path to a serialized dump (XGBoost JSON / LightGBM text /
    sklearn-forest dict) — the last two run the ingestion frontend: the
    model is lowered onto an ``n_bins`` threshold grid built from its
    own split points (``on_overflow`` governs grids that don't fit) and
    the artifact carries the grid (``CompiledModel.bin``) plus the
    lowering report in its sidecar.

    ``deploy.batching`` selects the §III-D input-batching router program;
    ``chip`` overrides the architecture constants (defaults to the
    paper's 4096-core chip); ``quantizer`` attaches a float->bin grid to
    a natively trained model's artifact.

    ``compress`` (or ``deploy.compress``; the explicit argument wins)
    runs the RETENTION-style compression pass between compile and
    packing — 'prune'/'merge'/'full' or the 'auto' alias for 'full'
    (``repro.core.compress``).  The grid-aware stages key off the
    artifact's own quantizer (attached or ingested); placement, the NoC
    plan and the perf report are all computed on the compressed shapes,
    and the ``CompressionReport`` rides the sidecar.

    ``cluster_columns`` runs the kernel-v3 column clustering AFTER
    compression (``order_columns_by_activity``): all-wildcard feature
    columns move into trailing tiles so the kernel's wildcard tile mask
    skips them, with the permutation recorded on ``CAMTable.col_perm``
    (schema v3) and queries permuted to match at engine bind.
    """
    deploy = deploy or DeployConfig()
    level = resolve_level(deploy.compress if compress is None else compress)
    deploy = deploy.replace(compress=level)
    ingest_report = None
    if not isinstance(model, (Ensemble, CAMTable)):
        # ingestion frontend, imported lazily: artifact load/serve paths
        # never pay for the parsers
        from repro.ingest import ImportedEnsemble, load_model, lower_to_ensemble

        if isinstance(model, (str, Path)):
            model = load_model(model)
        if not isinstance(model, ImportedEnsemble):
            raise TypeError(
                "build() takes an Ensemble, CAMTable, ImportedEnsemble or "
                f"dump path, got {type(model).__name__}"
            )
        model, quantizer, report = lower_to_ensemble(
            model, n_bins=n_bins, on_overflow=on_overflow
        )
        ingest_report = report.to_dict()
    if isinstance(model, CAMTable):
        table = model
    else:
        table = compile_ensemble(model)
    compression = None
    if level != "off":
        table, creport = compress_table(table, quantizer, level=level)
        compression = creport.to_dict()
    if cluster_columns:
        table = order_columns_by_activity(table, f_blk=deploy.f_blk)
    placement = pack_cores(table, chip)
    noc = plan_noc(table, placement, batching=deploy.batching)
    perf = xtime_perf(table, placement, noc)
    return CompiledModel(
        table=table, placement=placement, noc=noc, perf=perf, deploy=deploy,
        quantizer=quantizer, ingest=ingest_report, compression=compression,
    )
