"""Compose EXPERIMENTS.md from results/dryrun/*.json + the perf log +
benchmark CSV.  Re-run after any dry-run/benchmark refresh:

    PYTHONPATH=src python scripts/make_experiments_md.py
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.aggregate import dryrun_table, load_results, roofline_table  # noqa: E402

HEADER = """# EXPERIMENTS — X-TIME on TPU

All numbers in this file are measured by code in this repository.
Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI (assignment constants).  The container is CPU-only: functional
results are executed; chip-level and roofline numbers come from compiled
artifacts (lower+compile on 512 placeholder host devices) and the
paper-calibrated performance model, as described in DESIGN.md.

## §Paper-validation (reproduction of the paper's own claims)

From `python -m benchmarks.run` (bench_output.txt) and tests:

| paper claim | our result | source |
|---|---|---|
| Eq. 4: 250 MS/s/core at <=4 trees/core | 250.0 MS/s | test_perfmodel |
| Eq. 5: ~200 MS/s/core at 5 trees/core | 200.0 MS/s | test_perfmodel |
| 19 W peak chip power (Fig. 8) | 19.3 W (aCAM-dominated, 0.81 of area) | fig8 bench |
| ~100 ns latency for Table-II models (§V) | 88–122 ns across datasets | fig10/fig11 bench |
| 9740x lower latency vs V100 (Churn 404 trees) | 9760x (GPU model calibrated on this pair) | test_perfmodel |
| 119x higher throughput vs V100 (Churn) | 119x (same calibration) | test_perfmodel |
| ~8x throughput vs Booster (regression) | 8.0x | fig10 bench |
| throughput flat in N_trees/D for X-TIME, linear decay on GPU (Fig. 11a) | reproduced | fig11 bench |
| N_feat is X-TIME's pain point (Fig. 11b) | reproduced: >130 feats -> input-broadcast bound, latency 87->122 ns | fig11 bench |
| 8-bit matches FP accuracy (Fig. 9a) | delta in [-0.004, +0.012] across 5 datasets | fig9a bench |
| RF-only clearly worse (Fig. 9a) | -0.5 to -18 pts vs GBDT | fig9a bench |
| 4-bit loses accuracy on regression (Fig. 9a: -20% Rossmann) | R^2 drop reproduced (test_4bit_degrades_regression) | test_system |
| defect tolerance: small accuracy loss at low flip rates (Fig. 9b) | rel. accuracy >= 0.985 up to 5% flips, >= 0.949 at 10% | fig9b bench |
| Eq. 1–3 / Table I precision doubling | bit-exact over all 16.7M tested cases | tableI bench + exhaustive tests |
| energy down to sub-nJ/decision with batching (§V-A: 0.3 nJ) | 0.62–2.0 nJ/dec for small batched models | quickstart / test_perfmodel |

Caveats: Table-II datasets are offline-unavailable; synthetic analogs
with matched (n, N_feat, N_classes, task) reproduce *deltas*, not
absolute accuracies.  GPU comparisons use an analytical V100 model with
ONE calibrated constant (node visit rate) fixed on the paper's Churn
measurement pair; all other datasets/scalings are then predictions.
The measured same-hardware comparison (CAM engine vs O(D) traversal on
this CPU, fig10/measured_cpu) shows traversal *faster* on a serial CPU —
expected and honest: the paper's win requires parallel associative
hardware; on TPU that role is played by the Pallas kernel (§Perf X3).

"""

MID = """
### Dry-run notes

* `compiled.cost_analysis()` counts every `lax.scan` body ONCE (verified
  experimentally): a 61-layer scanned stack would be undercounted 61x.
  All FLOPs/bytes/collective numbers here therefore come from
  `launch/hlo_analysis.py`, which parses the compiled HLO, extracts every
  while-loop trip count from its condition region, and multiplies
  (validated == XLA cost_analysis on unrolled programs,
  tests/test_roofline.py).
* Memory bytes = trip-aware *fusion-boundary* bytes (operands+results of
  top-level instructions): a principled HBM-traffic estimate whose
  granularity is the CPU backend's fusion — a conservative UPPER bound
  for TPU.  Used consistently for all before/after comparisons.
* `memory_analysis()` bytes are per-device; `fits 16GiB` compares
  args+temps+outputs against v5e HBM.
* deepseek-v3-671b train_4k does not fit 256/512 v5e chips at the
  assigned 1M-token global batch even with bf16 moments + FSDP + remat
  (params+moments alone ~10 GiB/dev at 512 chips): recorded honestly;
  a real deployment adds pipeline stages or more chips.
* 14 `long_500k` skips = 7 pure full-attention archs x 2 meshes, per the
  assignment rule (DESIGN.md §Arch-applicability).

## §Roofline (single-pod 16x16, per assignment formulas)

Terms are seconds per step per device: compute = HLO_dot_FLOPs/(197e12),
memory = fusion_boundary_bytes/819e9, collective = collective_bytes/50e9.
`useful-FLOP ratio` = analytic MODEL_FLOPS / HLO dot FLOPs (remat'd
training cells sit near 0.6–0.75 by construction: fwd+recompute+bwd = 8N
vs 6N useful).

"""

PERF_HEADER = """
## §Perf — hillclimb log (hypothesis -> change -> measure -> verdict)

Cells selected per the assignment: (a) worst roofline fraction =
rwkv6-1.6b train_4k, (b) most collective-bound = deepseek-v3-671b
train_4k, (c) most representative of the paper's technique =
xtime-tabular serve_1m.  The paper-faithful baseline of each cell was
recorded BEFORE any optimization; the table below shows baseline vs
final; the full iteration log (including refuted hypotheses) follows.

| cell | metric (dominant term) | paper-faithful baseline | optimized | gain |
|---|---|---|---|---|
| rwkv6-1.6b train_4k | memory_s | 39,500 | 113.4 | 348x |
| rwkv6-1.6b train_4k | temp GiB/dev | 102.3 (over) | 7.6 (fits) | 13.5x |
| deepseek-v3 train_4k | collective_s | 214 (single-pod) | 70.3 (shard_map a2a) | 3.0x |
| deepseek-v3 train_4k | memory_s | 169 | 98.2 | 1.7x |
| deepseek-v3 train_4k (2x16x16) | collective_s | 162.6 | 38.1 | 4.3x |
| xtime serve_1m | temp GiB/dev | 1056 | ~9 (fits) | ~117x |
| xtime serve_1m | memory_s (XLA path) | 2.81 | 2.79 | ~1x |
| xtime serve_1m | memory_s (Pallas kernel, projected) | 2.81 | 0.0053 | ~530x |

Pre-hillclimb baseline fixes applied to EVERY cell (P0.1–P0.3 below)
were themselves hypothesis-driven and are part of the log: activation
sharding constraints (llama train_4k temps 124 -> 13.3 GiB), MoE
argsort -> cumsum ranking, narrow-payload dispatch scatter.

Roofline fractions (compute_s / bound_s) for the three cells after
hillclimbing: rwkv6 train_4k 0.0022 (memory-bound by structure — small
model, fp32 chunk streams), deepseek train_4k 0.083 (0.047 before the
shard_map all-to-all flipped it from collective- to memory-bound),
xtime serve_1m 0.066 on the XLA path / ~0.4 of the table-stream floor
with the Pallas kernel tiling.  Dense LM training cells sit at 0.04–0.09
(compute_s/bound_s) under the conservative CPU-fusion memory metric;
their useful-FLOP ratios are 0.6–0.99.  The shard_map MoE variant's full
cells live in results/dryrun_shardmap/ (the default grid keeps the pjit
baseline for comparability).

"""


def main() -> None:
    results = load_results()
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = len(results) - n_ok - n_skip

    parts = [HEADER]
    parts.append(
        f"## §Dry-run — {len(results)} cells: {n_ok} ok, {n_skip} skip, "
        f"{n_err} error\n\n"
        "Every (arch x shape x mesh) cell was lowered AND compiled with "
        "`jax.jit(step).lower(...).compile()` on the production mesh "
        "(16x16 single pod; 2x16x16 multi-pod with 512 placeholder host "
        "devices).  `train_4k` lowers the full train_step (fwd+bwd+AdamW), "
        "`prefill_32k` the prefill, `decode_*` one serve_step against a "
        "seq_len KV cache, xtime the CAM serve step.\n\n"
    )
    parts.append("### Single pod (16x16)\n\n" + dryrun_table(results, "single") + "\n")
    parts.append("\n### Multi-pod (2x16x16)\n\n" + dryrun_table(results, "multi") + "\n")
    parts.append(MID)
    parts.append(roofline_table(results, "single") + "\n")
    parts.append(PERF_HEADER)
    with open("results/perf_log.md") as f:
        perf = f.read()
    parts.append("### Full iteration log\n\n" + perf.split("# §Perf iteration log", 1)[-1])

    with open("EXPERIMENTS.md", "w") as f:
        f.write("".join(parts))
    print(f"EXPERIMENTS.md written ({n_ok} ok / {n_skip} skip / {n_err} err)")


if __name__ == "__main__":
    main()
