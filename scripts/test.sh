#!/usr/bin/env bash
# Tier-1 test runner. Pins the environment every contributor and CI box
# needs so mesh tests behave identically everywhere:
#   * 8 fake host devices (sharding/serving tests build small meshes;
#     subprocess-based tests set their own flags and are unaffected),
#   * CPU platform (deterministic; the Pallas kernel runs interpret=True),
#   * src/ on PYTHONPATH (the repo is not installed in dev images).
# Usage: bash scripts/test.sh [pytest args...], e.g.
#   bash scripts/test.sh tests/test_serving.py -k bucket
set -euo pipefail
cd "$(dirname "$0")/.."

export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

exec python -m pytest -x -q "$@"
