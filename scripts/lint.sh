#!/usr/bin/env bash
# Static checks (no autofix): ruff over every Python tree in the repo.
# CI installs ruff itself; locally it must already be on PATH.
# Usage: bash scripts/lint.sh [extra ruff args...]
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v ruff >/dev/null 2>&1; then
    echo "error: ruff is not installed (pip install ruff)" >&2
    exit 1
fi

exec ruff check src tests benchmarks examples scripts "$@"
