"""Shared plumbing for the artifact CLIs (`ingest.py`, `score.py`).

One copy of the pieces both commands need — src/ bootstrap, artifact
loading with a friendly error, and the ``--expected`` golden-record
verification — so the two frontends cannot drift apart on how a record
is judged.

The golden record is a JSON file ``{x, raw_margin, predict}``: float
queries plus the frozen reference outputs.  Verification contract
(DESIGN.md §9): predictions must be BIT-IDENTICAL to the record
(regression excepted — its predictions ARE margins); raw margins must
sit within the engine's float32 accumulation tolerance (~1 ULP vs the
reference traversal).
"""

from __future__ import annotations

import json
import sys
from pathlib import Path


def bootstrap_src() -> None:
    """Make ``import repro`` work when running from a checkout."""
    src = str(Path(__file__).resolve().parent.parent / "src")
    if src not in sys.path:
        sys.path.insert(0, src)


bootstrap_src()

import numpy as np  # noqa: E402


def load_artifact(base: str | Path):
    """``CompiledModel.load`` with a CLI-grade error message."""
    from repro.api import CompiledModel  # lazy: --help stays instant

    base = Path(base)
    try:
        return CompiledModel.load(base)
    except FileNotFoundError:
        raise SystemExit(
            f"[load]    ERROR: no artifact at {base!s} "
            f"(expected {base}.npz + {base}.json — the pair "
            "scripts/ingest.py --out writes)"
        )


def load_expected(path: str | Path) -> dict:
    """Parse a golden record into arrays: x, raw_margin, predict."""
    exp = json.loads(Path(path).read_text())
    return {
        "x": np.asarray(exp["x"], dtype=np.float64),
        "raw_margin": np.asarray(exp["raw_margin"], dtype=np.float32),
        "predict": np.asarray(exp["predict"]),
    }


def check_against_record(
    got_margin: np.ndarray,
    got_pred: np.ndarray,
    exp: dict,
    task: str,
    source: str,
) -> int:
    """Judge served outputs against a loaded golden record.

    Returns a process exit code (0 ok / 1 fail) and prints the
    ``[verify]`` verdict lines both CLIs (and CI's golden jobs) grep.
    """
    want_margin, want_pred = exp["raw_margin"], exp["predict"]
    ok = True
    got_margin = np.asarray(got_margin, dtype=np.float32)
    if not np.allclose(got_margin, want_margin, rtol=1e-5, atol=1e-6):
        bad = int((~np.isclose(got_margin, want_margin,
                               rtol=1e-5, atol=1e-6)).sum())
        print(f"[verify]  FAIL raw_margin: {bad}/{want_margin.size} cells "
              "outside engine tolerance", file=sys.stderr)
        ok = False
    if task == "regression":
        # regression "predictions" ARE the margins: engine tolerance
        pred_ok = np.allclose(got_pred, want_pred, rtol=1e-5, atol=1e-6)
    else:
        pred_ok = np.array_equal(
            np.asarray(got_pred, dtype=want_pred.dtype), want_pred
        )
    if not pred_ok:
        print("[verify]  FAIL predict: outputs differ from the record",
              file=sys.stderr)
        ok = False
    if ok:
        print(f"[verify]  OK — {exp['x'].shape[0]} queries: predictions "
              f"bit-identical, margins within engine tolerance ({source})")
    return 0 if ok else 1


def verify_expected(artifact, expected_path: str | Path) -> int:
    """Serve a golden record's float queries through the artifact's
    engine (the one-call ``raw_margin``/``predict`` API) and judge."""
    exp = load_expected(expected_path)
    return check_against_record(
        artifact.raw_margin(exp["x"]),
        artifact.predict(exp["x"]),
        exp,
        artifact.table.task,
        Path(expected_path).name,
    )
