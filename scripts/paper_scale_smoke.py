"""Paper-scale compression smoke: 512 trees x depth 8 on an 8-device mesh.

The paper's scaling argument (Fig. 11) assumes large ensembles fit the
chip's bounded CAM row capacity; RETENTION-style compression
(repro.core.compress) is what makes that true for deep models whose
naive one-row-per-leaf lowering would not.  This smoke proves the whole
claim end to end on CI hardware:

  1. a 512-tree depth-8 duplicate-split ensemble (131072 naive rows) is
     built with ``compress='auto'`` and must shed >= 30% of its rows,
  2. bound to the 8-fake-device host mesh, the compressed per-shard row
     count must fit a budget (half the naive per-shard load) that the
     UNCOMPRESSED table provably exceeds — compression is the difference
     between fitting and not fitting,
  3. one served batch must return margins bit-equal to the float
     reference (k/16 leaves: exact float32 sums, no tolerance).

Run locally:  python scripts/paper_scale_smoke.py
(sets the 8-fake-device XLA flag itself if none is present).
"""

from __future__ import annotations

import os
import sys
from pathlib import Path

# must happen before any jax import (CI sets these already; local runs
# get the same environment for free)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np  # noqa: E402

N_TREES, DEPTH, N_FEATURES, N_BINS = 512, 8, 32, 256
MIN_SAVINGS = 0.30


def main() -> int:
    import jax

    from repro.api import build
    from repro.core.trees import random_deep_ensemble
    from repro.launch.mesh import make_host_mesh

    n_dev = len(jax.devices())
    if n_dev < 8:
        print(f"[smoke]   ERROR: need 8 fake devices, got {n_dev} "
              "(XLA_FLAGS was set too late?)", file=sys.stderr)
        return 1

    ens = random_deep_ensemble(
        n_trees=N_TREES, depth=DEPTH, n_features=N_FEATURES,
        n_bins=N_BINS, p_dup=0.5, seed=20260808,
    )
    cm = build(ens, compress="auto")
    rep = cm.compression
    naive_rows = rep["rows_before"]
    print(f"[build]   {N_TREES} trees x depth {DEPTH}: {naive_rows} naive "
          f"rows -> {rep['rows_after']} "
          f"({rep['row_savings_fraction']:.0%} saved, "
          f"{rep['cols_before'] - rep['cols_after']} columns collapsed)")
    assert rep["row_savings_fraction"] >= MIN_SAVINGS, (
        f"savings {rep['row_savings_fraction']:.3f} below the "
        f"{MIN_SAVINGS:.0%} acceptance floor"
    )

    mesh = make_host_mesh()
    eng = cm.engine(mesh=mesh)
    assert eng.spmd == "shard_map", eng.spmd
    n_row_shards = mesh.shape[eng.row_axis]
    shard_rows = eng.arrays.r_pad // n_row_shards
    naive_shard_rows = -(-naive_rows // n_row_shards)  # ceil
    budget = naive_shard_rows // 2
    print(f"[place]   mesh {dict(mesh.shape)}: {shard_rows} rows/shard "
          f"across {n_row_shards} '{eng.row_axis}' shards "
          f"(budget {budget}, naive would need {naive_shard_rows})")
    assert naive_shard_rows > budget, (
        "smoke is vacuous: the naive table fits the per-shard budget"
    )
    assert shard_rows <= budget, (
        f"compressed table does not fit: {shard_rows} rows/shard "
        f"> budget {budget}"
    )

    rng = np.random.default_rng(0)
    q = rng.integers(0, N_BINS, size=(64, N_FEATURES)).astype(np.int32)
    got = np.asarray(eng.raw_margin(q))
    ref = ens.raw_margin(q)
    if not np.array_equal(got, ref):
        print(f"[serve]   FAIL: served margins diverge from the float "
              f"reference (max err {np.abs(got - ref).max():.3e})",
              file=sys.stderr)
        return 1
    print(f"[serve]   OK — {q.shape[0]} queries served under shard_map, "
          "margins bit-equal to the float reference")
    return 0


if __name__ == "__main__":
    sys.exit(main())
