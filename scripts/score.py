"""Bulk-score a columnar file through a saved X-TIME artifact.

    python scripts/score.py artifacts/churn rows.npy --out preds.npy
    python scripts/score.py artifacts/churn rows.parquet --kind margin
    python scripts/score.py artifacts/churn rows.npy --expected golden.json

The offline-throughput counterpart of `scripts/ingest.py` (DESIGN.md
§14): loads the ``<artifact>.npz + .json`` pair, streams the input file
chunk by chunk through ``repro.score.score_file`` — binning float rows
with the artifact's own grid, double-buffering device dispatch — and
writes predictions to ``--out`` (a ``.npy`` memmap, bounded memory at
any file size) while reporting rows/s.

``--expected`` verifies the streamed outputs against a golden record
``{x, raw_margin, predict}`` (the same files CI's ingest-golden job
uses): the record's queries are written to a temp ``.npy``, streamed
through the scoring pipeline in BOTH kinds, and judged with the shared
``_cli.check_against_record`` contract — predictions bit-identical,
margins within engine tolerance (exit 1 otherwise).  CI's
``score-golden`` job runs this with pyarrow absent, proving the
zero-dependency npy path.
"""

from __future__ import annotations

import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from _cli import check_against_record, load_artifact, load_expected  # noqa: E402


def _report(res) -> None:
    eng = res.engine
    print(f"[score]   {res.n_rows} rows x {res.n_features} features -> "
          f"{res.kind}: {res.n_chunks} chunks of {res.chunk_rows} "
          f"(bucket {res.bucket}), "
          f"{'grid-binned' if res.binned else 'pre-binned'}, "
          f"{'double-buffered' if res.double_buffered else 'synchronous'}")
    if eng:
        print(f"[engine]  {eng['backend']}/{eng['table_dtype']} "
              f"kernel {eng['kernel']}, noc '{eng['noc_config']}', "
              f"{eng['devices']} device(s)")
    if res.elapsed_s > 0:
        print(f"[perf]    {res.elapsed_s:.3f} s, "
              f"{res.rows_per_s:,.0f} rows/s")
    if res.path is not None:
        print(f"[out]     {res.path}")


def _verify(artifact, expected_path: str, chunk_rows: int) -> int:
    """Stream the golden record's queries through the scoring pipeline
    (not the in-memory engine — the point is to certify the file path)
    and judge both kinds against the record."""
    from repro.score import score_file

    exp = load_expected(expected_path)
    with tempfile.TemporaryDirectory() as td:
        qpath = Path(td) / "golden_x.npy"
        import numpy as np

        np.save(qpath, exp["x"])
        got_m = score_file(artifact, qpath, kind="margin",
                           chunk_rows=chunk_rows)
        got_p = score_file(artifact, qpath, kind="predict",
                           chunk_rows=chunk_rows)
    return check_against_record(
        got_m.values, got_p.values, exp, artifact.table.task,
        f"{Path(expected_path).name}, streamed",
    )


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifact", help="saved artifact base path "
                                     "(the BASE of BASE.npz + BASE.json)")
    ap.add_argument("input", help="columnar rows: .npy (memory-mapped, "
                                  "zero-dependency) or .parquet (pyarrow)")
    ap.add_argument("--kind", default="predict",
                    choices=("predict", "margin"),
                    help="final predictions or raw per-channel margins "
                         "(default: %(default)s)")
    ap.add_argument("--out", metavar="NPY",
                    help="stream outputs to this .npy (memmap; omit to "
                         "score without writing)")
    ap.add_argument("--chunk-rows", type=int, default=8192, metavar="N",
                    help="rows per streamed chunk (default: %(default)s)")
    ap.add_argument("--columns", metavar="A,B,...",
                    help="parquet feature columns, in artifact feature "
                         "order (default: schema order)")
    ap.add_argument("--no-double-buffer", action="store_true",
                    help="drain each chunk synchronously (debug/measure; "
                         "same bits, no overlap)")
    ap.add_argument("--expected", metavar="JSON",
                    help="golden record {x, raw_margin, predict}: stream "
                         "its queries and verify both kinds bit-exactly")
    args = ap.parse_args(argv)

    artifact = load_artifact(args.artifact)
    if args.expected:
        return _verify(artifact, args.expected, args.chunk_rows)

    from repro.score import score_file  # lazy: --help stays instant

    try:
        res = score_file(
            artifact,
            args.input,
            kind=args.kind,
            chunk_rows=args.chunk_rows,
            out=args.out,
            columns=args.columns.split(",") if args.columns else None,
            double_buffer=not args.no_double_buffer,
        )
    except (ValueError, FileNotFoundError, ImportError) as e:
        print(f"[score]   ERROR: {e}", file=sys.stderr)
        return 1
    _report(res)
    return 0


if __name__ == "__main__":
    sys.exit(main())
