"""Convert a serialized model dump into a saved X-TIME CompiledModel.

    python scripts/ingest.py model.json --out artifacts/churn
    python scripts/ingest.py model.txt  --out artifacts/lgbm --n-bins 256
    python scripts/ingest.py model.json --out a/m --expected golden.json

Ingests an XGBoost-JSON / LightGBM-text / sklearn-forest dump with the
zero-dependency parsers in ``repro.ingest`` (the source libraries are
never imported), lowers it onto the threshold grid, compiles + places it
(``repro.api.build``), prints the lowering report, and writes the
``<out>.npz`` + ``<out>.json`` artifact a serve process cold-starts from
(``TableRegistry.register(name, CompiledModel.load(out))``).

``--expected`` verifies the saved artifact end-to-end: the recorded
float queries are binned with the artifact's grid and served through the
engine; raw margins and predictions must match the recorded reference
bit-exactly (exit 1 otherwise) — the CI ``ingest-golden`` job runs this
over every checked-in fixture.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

from _cli import verify_expected  # noqa: E402,F401  (bootstraps src/)

from repro.ingest import FORMATS, IngestError, load_model  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("dump", help="model dump (XGBoost .json / LightGBM .txt / "
                                 "sklearn-forest .json)")
    ap.add_argument("--out", required=True, metavar="BASE",
                    help="artifact base path (writes BASE.npz + BASE.json)")
    ap.add_argument("--format", default="auto",
                    choices=("auto",) + FORMATS)
    ap.add_argument("--n-bins", type=int, default=256,
                    help="threshold grid size (default: %(default)s — the "
                         "paper's 8-bit grid)")
    ap.add_argument("--strict", action="store_true",
                    help="reject models whose thresholds do not fit the grid "
                         "instead of merging (merging loses bit-exactness)")
    ap.add_argument("--batching", action="store_true",
                    help="build the §III-D input-batching router program")
    ap.add_argument("--compress", default="off", metavar="LEVEL",
                    help="CAM table compression level (off/prune/merge/full/"
                         "auto, default: %(default)s) — bit-equivalent row "
                         "merging + pruning, see repro.core.compress")
    ap.add_argument("--expected", metavar="JSON",
                    help="golden reference {x, raw_margin, predict}; verify "
                         "the saved artifact serves it bit-exactly")
    args = ap.parse_args(argv)

    from repro.api import CompiledModel, build  # lazy: --help stays instant
    from repro.core.deploy import DeployConfig

    try:
        imported = load_model(args.dump, format=args.format)
        artifact = build(
            imported,
            deploy=DeployConfig(batching=args.batching),
            n_bins=args.n_bins,
            on_overflow="raise" if args.strict else "merge",
            compress=args.compress,
        )
    except (IngestError, ValueError) as e:
        print(f"[ingest]  ERROR: {e}", file=sys.stderr)
        return 1

    rep = artifact.ingest or {}
    print(f"[ingest]  {imported.source} ({imported.source_kind}, "
          f"{imported.task}): {rep.get('n_source_trees')} trees -> "
          f"{rep.get('n_trees')} lowered, {artifact.table.n_rows} CAM rows")
    grid = [g for g in rep.get("grid", ()) if g["thresholds"]]
    peak = max((g["thresholds"] for g in grid), default=0)
    print(f"[grid]    {len(grid)}/{rep.get('n_features')} features split, "
          f"peak {peak}/{args.n_bins - 1} edges, "
          f"exact={rep.get('exact')} "
          f"(merged={rep.get('merged_thresholds')}, "
          f"remapped={rep.get('remapped_splits')})")
    for note in rep.get("notes", ()):
        print(f"[note]    {note}")
    if artifact.compression is not None:
        c = artifact.compression
        print(f"[compress] level '{c['level']}': {c['rows_before']} -> "
              f"{c['rows_after']} rows ({c['row_savings_fraction']:.0%} saved; "
              f"pruned {c['pruned_empty'] + c['pruned_unreachable']}, "
              f"merged {c['merged_rows']}, "
              f"{c['cols_before'] - c['cols_after']} columns collapsed)")
    print(f"[place]   {artifact.placement.n_cores_used} cores, "
          f"replication x{artifact.placement.replication}, "
          f"NoC '{artifact.noc.config}', "
          f"{artifact.table.feature_occupancy().mean():.0%} of CAM cells "
          "non-wildcard")

    sidecar = artifact.save(args.out)
    print(f"[save]    {sidecar} (+ .npz)")

    if args.expected:
        reloaded = CompiledModel.load(args.out)  # verify the DISK artifact
        return verify_expected(reloaded, Path(args.expected))
    return 0


if __name__ == "__main__":
    sys.exit(main())
