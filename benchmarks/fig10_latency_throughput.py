"""Fig. 10: latency/throughput — X-TIME chip model vs GPU model vs Booster
model, plus a *measured* same-hardware comparison (CPU): CAM engine vs
O(D) traversal baseline on identical trained models."""

from __future__ import annotations

import numpy as np

from benchmarks.common import budget, time_call, trained_model
from repro.api import build
from repro.core.baselines import TraversalBaseline
from repro.core.deploy import DeployConfig
from repro.core.perfmodel import booster_perf, gpu_perf_model

DATASETS = ["churn", "eye", "telco", "rossmann"]


def run() -> list[dict]:
    rows = []
    for name in DATASETS:
        ens, q, ds, xb_te = trained_model(name, "8bit", "gbdt")
        # batching=True: the paper's Fig. 10 protocol replicates small
        # models across core groups (§III-D), multiplying throughput
        cm = build(ens, deploy=DeployConfig(batching=True))
        depth = int(max(t.max_depth for t in ens.trees))

        xt = cm.perf
        gp = gpu_perf_model(n_trees=ens.n_trees, depth=depth)
        bo = booster_perf(cm.table, cm.placement, cm.noc, depth=depth)
        rows.append({
            "name": f"fig10/{name}/model",
            "us_per_call": xt.latency_ns / 1e3,
            "derived": (
                f"xtime_lat_ns={xt.latency_ns:.0f};xtime_tput_msps={xt.throughput_msps:.0f};"
                f"gpu_lat_ns={gp.latency_ns:.0f};gpu_tput_msps={gp.throughput_msps:.1f};"
                f"booster_lat_ns={bo.latency_ns:.0f};booster_tput_msps={bo.throughput_msps:.0f};"
                f"lat_speedup_vs_gpu={gp.latency_ns/xt.latency_ns:.0f}x;"
                f"tput_speedup_vs_gpu={xt.throughput_msps/gp.throughput_msps:.0f}x;"
                f"tput_vs_booster={xt.throughput_msps/bo.throughput_msps:.1f}x"
            ),
        })

        # measured on THIS machine: one CAM match op vs O(D) gathers
        b = budget(4096, 1024)
        xb = np.tile(xb_te, (int(np.ceil(b / len(xb_te))), 1))[:b]
        eng = cm.engine()
        trav = TraversalBaseline(ens)
        t_eng = time_call(lambda a: eng.raw_margin(a).block_until_ready(), xb)
        t_trav = time_call(lambda a: trav.raw_margin(a).block_until_ready(), xb)
        rows.append({
            "name": f"fig10/{name}/measured_cpu",
            "us_per_call": t_eng,
            "derived": (
                f"engine_us={t_eng:.0f};traversal_us={t_trav:.0f};"
                f"batch={b};engine_msps={b/t_eng:.3f};traversal_msps={b/t_trav:.3f}"
            ),
        })
    return rows
