"""Table I / Eq. 3: precision-doubling scheme — equivalence count over the
full 8-bit space and relative cost of the three kernel modes."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core import precision
from repro.kernels import ops as kops


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    q = jnp.arange(256)[:, None]
    tl = jnp.asarray(rng.integers(0, 256, size=65536))[None, :]
    th = jnp.asarray(rng.integers(0, 257, size=65536))[None, :]
    d = precision.match_direct(q, tl, th)
    m = precision.match_msb_lsb(q, tl, th)
    c = precision.match_two_cycle(q, tl, th)
    rows.append({
        "name": "tableI/equivalence",
        "us_per_call": 0.0,
        "derived": f"cases={256*65536};msb_lsb_equal={bool(jnp.all(d==m))};"
                   f"two_cycle_equal={bool(jnp.all(d==c))}",
    })

    # kernel-mode relative cost (interpret mode, CPU)
    b, r, f, cch = 128, 1024, 130, 8
    low = rng.integers(0, 256, size=(r, f)).astype(np.int32)
    high = np.minimum(low + rng.integers(0, 256, size=(r, f)), 256).astype(np.int32)
    leaf = rng.normal(size=(r, cch)).astype(np.float32)
    lo_p, hi_p, leaf_p = kops.pad_tables(low, high, leaf, n_bins=256)
    q_p = kops.pad_queries(jnp.asarray(rng.integers(0, 256, (b, f))), lo_p.shape[1])
    for mode in ("direct", "msb_lsb", "two_cycle"):
        us = time_call(
            lambda: kops.cam_match(
                q_p, jnp.asarray(lo_p), jnp.asarray(hi_p), jnp.asarray(leaf_p),
                out_b=b, out_c=cch, mode=mode, interpret=True,
            ).block_until_ready()
        )
        rows.append({
            "name": f"tableI/kernel_{mode}",
            "us_per_call": us,
            "derived": f"B={b};R={r};F={f}",
        })
    return rows
