"""Fig. 8: chip area and peak-power breakdown (16 nm constants)."""

from __future__ import annotations

from repro.core.compile import ChipSpec
from repro.core.perfmodel import PowerAreaSpec


def run() -> list[dict]:
    spec = ChipSpec()
    pa = PowerAreaSpec()
    acam_w = spec.n_cores * pa.acam_mw_per_core / 1e3
    sram_w = spec.n_cores * pa.sram_logic_mw_per_core / 1e3
    router_w = spec.n_routers * pa.router_mw / 1e3
    total_w = pa.chip_power_w(spec)
    acam_mm = spec.n_cores * pa.acam_mm2_per_core
    sram_mm = spec.n_cores * pa.sram_logic_mm2_per_core
    router_mm = spec.n_routers * pa.router_mm2
    total_mm = pa.chip_area_mm2(spec)
    return [
        {
            "name": "fig8/power_w",
            "us_per_call": 0.0,
            "derived": f"acam={acam_w:.2f};sram_logic={sram_w:.2f};"
                       f"routers={router_w:.2f};cp={pa.cp_w:.2f};total={total_w:.2f};"
                       f"paper_total=19.0",
        },
        {
            "name": "fig8/area_mm2",
            "us_per_call": 0.0,
            "derived": f"acam={acam_mm:.1f};sram_logic={sram_mm:.1f};"
                       f"routers={router_mm:.1f};cp={pa.cp_mm2:.1f};total={total_mm:.1f};"
                       f"acam_fraction={acam_mm/total_mm:.2f}",
        },
    ]
