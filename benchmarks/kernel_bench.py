"""cam_match kernel micro-benchmarks: XLA-fused oracle throughput on CPU
(the engine's distributed path) across CAM table sizes, + arithmetic
intensity accounting for the roofline."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import budget, time_call
from repro.kernels.ref import cam_match_ref


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(1)
    for (b, r, f, c) in [
        (256, 4096, 32, 8),
        (256, 16384, 130, 8),
        (budget(1024, 256), budget(65536, 16384), 130, 8),
    ]:
        low = rng.integers(0, 256, size=(r, f)).astype(np.int32)
        high = np.minimum(low + rng.integers(0, 256, size=(r, f)), 256).astype(np.int32)
        leaf = rng.normal(size=(r, c)).astype(np.float32)
        q = rng.integers(0, 256, size=(b, f)).astype(np.int32)
        fn = jax.jit(lambda qq, lo, hi, lf: cam_match_ref(qq, lo, hi, lf))
        args = tuple(map(jnp.asarray, (q, low, high, leaf)))
        us = time_call(lambda: fn(*args).block_until_ready())
        compare_ops = 2 * b * r * f  # two int compares per cell
        mac_ops = 2 * b * r * c
        rows.append({
            "name": f"kernel/cam_match_b{b}_r{r}_f{f}",
            "us_per_call": us,
            "derived": (
                f"samples_per_s={b/(us*1e-6):.0f};"
                f"gcompare_per_s={compare_ops/(us*1e-6)/1e9:.2f};"
                f"bytes={(b*f*4 + 2*r*f*4 + r*c*4):.0f}"
            ),
        })
    return rows
