"""cam_match kernel micro-benchmarks (kernel v2, DESIGN.md §10).

Times the engine's actual compute paths across CAM table sizes and
table dtypes on the platform the bench runs on:

  * ``v1_int32``   — the v1 layout: int32 exclusive-high tables, direct
    compare (the baseline the packed paths must beat);
  * ``v2_uint8``   — compact inclusive-high uint8 tables (the paper's
    native 8-bit precision), native-dtype compare — 4x less table
    traffic for identical bits;
  * ``v2_pallas``  — the tiled v2 Pallas kernel on uint8 tables with the
    wildcard tile mask (interpret mode off-TPU, so its timing is only
    meaningful on TPU; kept small and recorded for trend, not gated);
  * ``v3_dispatch`` — what the kernel-v3 measured-cost dispatch table
    (``repro.core.tune.TunePlan.dispatch``) binds at each size: the
    faster of the v1/v2 candidates above.  This is the gated row — the
    crossover is shape-dependent (v2 loses at b256/r4096/f32, wins at
    r16384/f130), and dispatch must never be slower than v1.

Every row's ``derived`` carries the traffic-model numbers
(``repro.core.perfmodel.kernel_traffic_model``) plus, for packed rows,
the measured ``speedup_vs_int32`` — the committed BENCH entry that
demonstrates the v1 -> v2 delta.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import budget, time_call
from repro.core.perfmodel import kernel_traffic_model
from repro.core.tune import kernel_version
from repro.kernels import ops as kops
from repro.kernels.ref import cam_match_ref

# (batch, rows, features, channels) problem sizes; the last grows with
# BENCH_FAST=0 to the roofline regime
_SIZES = [
    (256, 4096, 32, 8),
    (256, 16384, 130, 8),
]


def _problem(rng, b, r, f, c):
    """Random CAM problem in BOTH encodings: exclusive int32 + packed uint8."""
    low = rng.integers(0, 256, size=(r, f)).astype(np.int32)
    width = rng.integers(1, 256, size=(r, f))
    high = np.minimum(low + width, 256).astype(np.int32)
    dc = rng.random((r, f)) < 0.3  # wildcard cells
    low[dc], high[dc] = 0, 256
    leaf = rng.normal(size=(r, c)).astype(np.float32)
    q = rng.integers(0, 256, size=(b, f)).astype(np.int32)
    lo8 = low.astype(np.uint8)
    hi8 = (high - 1).astype(np.uint8)  # inclusive packed form
    q8 = q.astype(np.uint8)
    return q, low, high, leaf, q8, lo8, hi8


def run() -> list[dict]:
    rows = []
    rng = np.random.default_rng(1)
    sizes = _SIZES + [(budget(1024, 256), budget(65536, 16384), 130, 8)]
    sizes = list(dict.fromkeys(sizes))  # FAST budgets can collide with _SIZES
    for (b, r, f, c) in sizes:
        q, low, high, leaf, q8, lo8, hi8 = _problem(rng, b, r, f, c)
        la = jnp.asarray(leaf)

        fn32 = jax.jit(lambda qq, lo, hi: cam_match_ref(qq, lo, hi, la, mode="direct"))
        fn8 = jax.jit(
            lambda qq, lo, hi: cam_match_ref(qq, lo, hi, la, mode="inclusive")
        )
        a32 = (jnp.asarray(q), jnp.asarray(low), jnp.asarray(high))
        a8 = (jnp.asarray(q8), jnp.asarray(lo8), jnp.asarray(hi8))
        # the packed path must be a *re-encoding*, not a re-definition
        np.testing.assert_allclose(
            np.asarray(fn32(*a32)), np.asarray(fn8(*a8)), rtol=1e-5, atol=1e-5
        )

        us32 = time_call(lambda: fn32(*a32).block_until_ready())
        us8 = time_call(lambda: fn8(*a8).block_until_ready())
        t32 = kernel_traffic_model(
            batch=b, rows=r, features=f, channels=c, table_dtype="int32"
        )
        t8 = kernel_traffic_model(
            batch=b, rows=r, features=f, channels=c, table_dtype="uint8"
        )
        cfg = {"b": b, "r": r, "f": f, "c": c, "backend": jax.default_backend()}
        rows.append({
            "name": f"kernel/v1_int32_b{b}_r{r}_f{f}",
            "us_per_call": us32,
            "derived": (
                f"samples_per_s={b / (us32 * 1e-6):.0f};"
                f"gcompare_per_s={t32['compare_ops'] / (us32 * 1e-6) / 1e9:.2f};"
                f"bytes={t32['bytes_total']:.0f}"
            ),
            "config": {**cfg, "table_dtype": "int32", "mode": "direct"},
        })
        rows.append({
            "name": f"kernel/v2_uint8_b{b}_r{r}_f{f}",
            "us_per_call": us8,
            "derived": (
                f"samples_per_s={b / (us8 * 1e-6):.0f};"
                f"speedup_vs_int32={us32 / us8:.2f};"
                f"bytes={t8['bytes_total']:.0f};"
                f"packed_ratio={t8['packed_ratio']:.1f}"
            ),
            "config": {**cfg, "table_dtype": "uint8", "mode": "inclusive"},
        })
        # the kernel-v3 dispatch outcome on these measurements: the
        # per-bucket winner a TunePlan.dispatch entry would record
        chosen_dtype = "int32" if us32 <= us8 else "uint8"
        us_d = min(us32, us8)
        rows.append({
            "name": f"kernel/v3_dispatch_b{b}_r{r}_f{f}",
            "us_per_call": us_d,
            "derived": (
                f"chosen={kernel_version(chosen_dtype)}_{chosen_dtype};"
                f"v1_us={us32:.0f};v2_us={us8:.0f};"
                f"win_vs_v1={us32 / us_d:.2f}"
            ),
            "config": {
                **cfg, "table_dtype": chosen_dtype,
                "mode": "direct" if chosen_dtype == "int32" else "inclusive",
                "kernel": kernel_version(chosen_dtype),
            },
        })

    # small tiled-Pallas spot row: wildcard-mask + scratch accumulation
    # actually executing (interpret off-TPU => trend only, never gated tight)
    b, r, f, c = 128, 512, 256, 8
    q, low, high, leaf, q8, lo8, hi8 = _problem(rng, b, r, f, c)
    lo_p, hi_p, lm, _ = kops.pack_tables(
        low, high, leaf, r_blk=256, n_bins=256, dtype="uint8"
    )
    mask = kops.wildcard_tile_mask(
        lo_p, hi_p, r_blk=256, f_blk=128, n_bins=256, inclusive=True
    )
    qp = kops.pad_queries(jnp.asarray(q8), lo_p.shape[1], b_blk=128, dtype="uint8")
    args = (qp, jnp.asarray(lo_p), jnp.asarray(hi_p), jnp.asarray(lm),
            jnp.asarray(mask))
    us = time_call(
        lambda: kops.cam_match(
            *args, out_b=b, out_c=c, b_blk=128, r_blk=256, f_blk=128,
            mode="inclusive",
        ).block_until_ready()
    )
    rows.append({
        "name": f"kernel/v2_pallas_uint8_b{b}_r{r}_f{f}",
        "us_per_call": us,
        "derived": (
            f"samples_per_s={b / (us * 1e-6):.0f};"
            f"skip_tiles={1.0 - float(np.asarray(mask).mean()):.2f};"
            f"interpret={jax.default_backend() != 'tpu'}"
        ),
        "config": {"b": b, "r": r, "f": f, "c": c, "table_dtype": "uint8",
                   "backend": "pallas", "mode": "inclusive"},
    })
    return rows
