"""Fig. 11: throughput scaling vs N_trees, D (GPU degrades linearly;
X-TIME flat until the chip fills) and vs N_feat (X-TIME's pain point).

Plus a MEASURED scale-out section (``fig11c/``): the shard_map engine
across mesh sizes and NoC programs (accumulate / batch / hybrid).  On
fake host devices the wall-clock mixes host-thread parallelism with
dispatch+collective overhead and does not model real ICI scaling — the
value of the record is the per-revision trajectory that CI archives
(benchmarks/README.md)."""

from __future__ import annotations

import numpy as np

from repro.core.compile import CAMTable, pack_cores
from repro.core.noc import ENGINE_COLLECTIVES, plan_noc
from repro.core.perfmodel import gpu_perf_model, xtime_perf


def _synthetic_table(n_trees: int, depth: int, n_feat: int) -> CAMTable:
    """Random balanced ensemble (placement/perf only, no semantics)."""
    leaves = 2 ** depth
    r = n_trees * leaves
    rng = np.random.default_rng(0)
    low = np.zeros((r, n_feat), np.int32)
    high = np.full((r, n_feat), 256, np.int32)
    return CAMTable(
        low=low, high=high,
        leaf=rng.normal(size=(r,)).astype(np.float32),
        tree_id=np.repeat(np.arange(n_trees), leaves).astype(np.int32),
        class_id=np.zeros((r,), np.int32),
        n_trees=n_trees, n_features=n_feat, n_bins=256, n_outputs=1,
        task="binary", kind="gbdt", base_score=0.0, n_classes=2,
    )


def _measured_scaleout() -> list[dict]:
    """shard_map engine throughput over 1..N fake/real devices."""
    import jax
    from jax.sharding import Mesh

    from benchmarks.common import budget, time_call
    from repro.core.deploy import DeployConfig
    from repro.core.engine import XTimeEngine

    devices = jax.devices()
    n_feat, depth, n_trees = 32, 6, 64
    table = _synthetic_table(n_trees, depth, n_feat)
    b = budget(1024, 256)
    rng = np.random.default_rng(1)
    q = rng.integers(0, 256, size=(b, n_feat), dtype=np.int32)

    rows = []
    sizes = sorted({n for n in (1, 2, len(devices)) if n <= len(devices)})
    for n_dev in sizes:
        mesh = Mesh(np.asarray(devices[:n_dev]).reshape(1, n_dev),
                    ("data", "model"))
        for noc in ("accumulate", "batch", "hybrid"):
            cfg = DeployConfig(noc_config=noc, spmd="shard_map")
            eng = XTimeEngine.from_config(table, cfg, mesh=mesh)
            us = time_call(lambda: np.asarray(eng.raw_margin(q)))
            rows.append({
                "name": f"fig11c/scaleout_{noc}_d{n_dev}",
                "us_per_call": us,
                "derived": (
                    f"samples_per_s={b / (us * 1e-6):.0f};"
                    f"n_devices={n_dev};batch={b};"
                    f"collective={ENGINE_COLLECTIVES[noc]}"
                ),
                "config": {
                    "spmd": "shard_map", "noc_config": noc, "backend": "jnp",
                    "n_devices": n_dev, "batch": b,
                    "rows": int(table.low.shape[0]), "n_features": n_feat,
                },
            })
    return rows


def run() -> list[dict]:
    rows = []
    for n_trees in (64, 256, 1024, 4096):
        t = _synthetic_table(n_trees, 8, 32)
        plc = pack_cores(t)
        xt = xtime_perf(t, plc, plan_noc(t, plc))
        gp = gpu_perf_model(n_trees=n_trees, depth=8)
        rows.append({
            "name": f"fig11a/trees_{n_trees}",
            "us_per_call": 0.0,
            "derived": f"xtime_tput_msps={xt.throughput_msps:.0f};"
                       f"gpu_tput_msps={gp.throughput_msps:.1f};"
                       f"replication={plc.replication}",
        })
    for depth in (4, 6, 8):
        t = _synthetic_table(256, depth, 32)
        plc = pack_cores(t)
        xt = xtime_perf(t, plc, plan_noc(t, plc))
        gp = gpu_perf_model(n_trees=256, depth=depth)
        rows.append({
            "name": f"fig11a/depth_{depth}",
            "us_per_call": 0.0,
            "derived": f"xtime_tput_msps={xt.throughput_msps:.0f};"
                       f"gpu_tput_msps={gp.throughput_msps:.1f}",
        })
    for n_feat in (16, 65, 130, 260):
        t = _synthetic_table(256, 8, n_feat)
        plc = pack_cores(t)
        xt = xtime_perf(t, plc, plan_noc(t, plc))
        gp = gpu_perf_model(n_trees=256, depth=8)
        rows.append({
            "name": f"fig11b/feat_{n_feat}",
            "us_per_call": 0.0,
            "derived": f"xtime_tput_msps={xt.throughput_msps:.0f};"
                       f"xtime_lat_ns={xt.latency_ns:.0f};"
                       f"gpu_tput_msps={gp.throughput_msps:.1f};"
                       f"segments={plc.n_feature_segments};bottleneck={xt.bottleneck}",
        })
    rows.extend(_measured_scaleout())
    return rows
