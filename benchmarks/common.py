"""Shared benchmark helpers: model training cache + timing + provenance."""

from __future__ import annotations

import os
import subprocess
import time
from functools import lru_cache

import numpy as np

from repro.core.compile import compile_ensemble
from repro.core.quantize import FeatureQuantizer
from repro.core.trees import GBDTParams, RFParams, train_gbdt, train_rf
from repro.data.tabular import make_dataset

FAST = os.environ.get("BENCH_FAST", "1") != "0"


def budget(full: int, fast: int) -> int:
    return fast if FAST else full


@lru_cache(maxsize=1)
def git_rev() -> str:
    """Short git revision of the working tree ('unknown' outside a repo) —
    stamped into every BENCH_*.json record so the perf trajectory lines
    up with history."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else "unknown"
    except (OSError, subprocess.TimeoutExpired):  # pragma: no cover
        return "unknown"


@lru_cache(maxsize=None)
def trained_model(name: str, bits: str = "8bit", kind: str = "gbdt",
                  rounds: int | None = None, leaves: int | None = None):
    """(ensemble, quantizer, dataset, xb_test) for a Table-II dataset."""
    ds = make_dataset(name)
    n_bins = {"float": 4096, "8bit": 256, "4bit": 16}[bits]
    q = FeatureQuantizer.fit(ds.x_train, n_bins)
    xb_tr = q.transform(ds.x_train)
    # the paper's iso-area rule: 4-bit gets 2x leaves (§V-A)
    default_leaves = 128 if bits == "4bit" else 64
    leaves = leaves or default_leaves
    rounds = rounds or budget(60, 25)
    if kind == "gbdt":
        ens = train_gbdt(
            xb_tr, ds.y_train, task=ds.task, n_bins=n_bins,
            n_classes=ds.n_classes,
            params=GBDTParams(n_rounds=rounds, max_leaves=leaves,
                              learning_rate=0.15),
        )
    else:
        ens = train_rf(
            xb_tr, ds.y_train, task=ds.task, n_bins=n_bins,
            n_classes=ds.n_classes,
            params=RFParams(n_trees=rounds * 2, max_leaves=leaves, colsample=0.7),
        )
    return ens, q, ds, q.transform(ds.x_test)


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)
