"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md §Dry-run and
§Roofline tables (markdown), and BENCH_*.json perf records (committed
baselines + fresh runs) into the perf-trajectory table:

    python benchmarks/aggregate.py --bench benchmarks/baselines bench-out
"""

from __future__ import annotations

import glob
import json
import os


def load_results(dry_dir: str = "results/dryrun") -> list[dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(fn) as f:
            out.append(json.load(f))
    return out


def _fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def dryrun_table(results: list[dict], mesh: str) -> str:
    lines = [
        "| arch | shape | status | compile s | GiB/dev | fits 16GiB | "
        "collectives (top) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r.get("mesh") not in (mesh, {"single": "16x16", "multi": "2x16x16"}[mesh]):
            continue
        if r["status"] == "ok":
            mem = r["memory"]
            coll = r["hlo"]["collective_breakdown"]
            top = sorted(coll.items(), key=lambda kv: -kv[1])[:2]
            tops = ", ".join(f"{k} {v/2**30:.1f}G" for k, v in top) or "none"
            lines.append(
                f"| {r['arch']} | {r['shape']} | ok | {r.get('compile_s','-')} | "
                f"{mem['total_per_device_gib']} | "
                f"{'Y' if mem['fits_v5e_16gib'] else 'N'} | {tops} |"
            )
        elif r["status"] == "skip":
            lines.append(
                f"| {r['arch']} | {r['shape']} | skip | - | - | - | "
                f"{r['reason'][:60]} |"
            )
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | ERROR | - | - | - | "
                f"{r.get('error','')[:60]} |"
            )
    return "\n".join(lines)


def roofline_table(results: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "bound s | useful-FLOP ratio | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r.get("mesh") != {"single": "16x16", "multi": "2x16x16"}[mesh]:
            continue
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        hint = _bottleneck_hint(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | {t['dominant']} | "
            f"{t['bound_s']:.3g} | {t['model_flops_ratio']:.3f} | {hint} |"
        )
    return "\n".join(lines)


def _bottleneck_hint(r: dict) -> str:
    dom = r["roofline"]["dominant"]
    kind = r.get("kind", "")
    if dom == "collective":
        coll = r["hlo"]["collective_breakdown"]
        top = max(coll, key=coll.get) if coll else "?"
        return f"cut {top} volume (resharding/layout or fused collectives)"
    if dom == "memory":
        if "serve" in kind:
            return "shrink cache reads (windowed KV, quantized cache)"
        return "fuse elementwise chains / smaller remat residuals"
    return "increase arithmetic intensity (larger tiles, fewer reshards)"


def load_bench_records(dirs: list[str]) -> list[dict]:
    """Every BENCH_*.json payload under ``dirs``, oldest-committed first
    (baselines sort before fresh runs because callers list them first)."""
    out = []
    for d in dirs:
        for fn in sorted(glob.glob(os.path.join(d, "BENCH_*.json"))):
            with open(fn) as f:
                payload = json.load(f)
            payload["_path"] = fn
            out.append(payload)
    return out


def bench_table(payloads: list[dict]) -> str:
    """Perf trajectory: one row per bench entry, one column per record.

    The first payload (the committed baseline, by convention) anchors the
    delta column, making regressions/improvements plottable straight from
    the markdown."""
    if not payloads:
        return "(no BENCH_*.json records found)"
    revs = [p["git_rev"] for p in payloads]
    names = []
    for p in payloads:
        for r in p["records"]:
            key = (r["module"], r["name"])
            if key not in names:
                names.append(key)
    by_rev = [
        {(r["module"], r["name"]): float(r["us_per_call"])
         for r in p["records"]}
        for p in payloads
    ]
    header = "| module/name | " + " | ".join(f"{r} us" for r in revs) \
        + " | vs first |"
    lines = [header, "|---|" + "---|" * (len(revs) + 1)]
    for key in names:
        cells = [(f"{m[key]:.1f}" if key in m else "-") for m in by_rev]
        first = by_rev[0].get(key)
        last = by_rev[-1].get(key)
        delta = (f"{(last - first) / first:+.0%}"
                 if first and last is not None else "-")
        lines.append(f"| {key[0]}/{key[1]} | " + " | ".join(cells)
                     + f" | {delta} |")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--bench", nargs="+", metavar="DIR",
        help="print the BENCH_*.json perf-trajectory table for these "
             "directories (list the committed baselines dir first) "
             "instead of the dry-run tables",
    )
    args = ap.parse_args(argv)
    if args.bench:
        payloads = load_bench_records(args.bench)
        print(f"# Bench trajectory: {len(payloads)} records from "
              f"{', '.join(args.bench)}\n")
        print(bench_table(payloads))
        return
    results = load_results()
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = len(results) - n_ok - n_skip
    print(f"# Dry-run aggregate: {n_ok} ok / {n_skip} skip / {n_err} error\n")
    for mesh in ("single", "multi"):
        print(f"## Mesh {mesh}\n")
        print(dryrun_table(results, mesh))
        print()
    print("## Roofline (single-pod)\n")
    print(roofline_table(results, "single"))


if __name__ == "__main__":
    main()
