"""Serving-layer benchmark: requests/sec of the micro-batching ServeLoop
vs per-request ``XTimeEngine.predict`` at request batch size 1, swept over
the coalescing depth (rows per flush).

The per-request baseline is what the repo could do before ``repro.serve``
existed: every single-row request pays one dispatch of a ``b_blk``-padded
batch.  Coalescing N requests into one bucket amortizes both the dispatch
and the CAM sweep, which is precisely the input-batching argument of
§III-D — the acceptance bar for this PR is >= 5x at coalesce depth 256.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import budget, trained_model
from repro.api import build
from repro.core.engine import XTimeEngine
from repro.serve import ServeLoop, TableRegistry

COALESCE_DEPTHS = (16, 64, 256)


def _request_stream(xb_te: np.ndarray, n: int) -> np.ndarray:
    reps = int(np.ceil(n / len(xb_te)))
    return np.tile(xb_te, (reps, 1))[:n].astype(np.int32)


def _per_request_baseline(eng: XTimeEngine, stream: np.ndarray) -> float:
    """Requests/sec of one synchronous predict() per single-row request."""
    np.asarray(eng.predict(stream[:1]))  # compile
    t0 = time.perf_counter()
    for row in stream:
        np.asarray(eng.predict(row[None, :]))
    return len(stream) / (time.perf_counter() - t0)


def _served(reg: TableRegistry, stream: np.ndarray, depth: int) -> tuple[float, "object"]:
    loop = ServeLoop(reg, window_s=10.0, flush_rows=depth, max_batch=1024)
    # warm the bucket cache (full bucket + the drain remainder bucket)
    for row in stream[:depth]:
        loop.submit("bench", row)
    loop.drain()
    loop = ServeLoop(reg, window_s=10.0, flush_rows=depth, max_batch=1024)
    t0 = time.perf_counter()
    for row in stream:
        loop.submit("bench", row)
    loop.drain()
    rps = len(stream) / (time.perf_counter() - t0)
    return rps, loop.stats("bench")


def run() -> list[dict]:
    ens, q, ds, xb_te = trained_model("churn", "8bit", "gbdt")
    artifact = build(ens)  # compile once; the registry installs it as-is
    n_req = budget(2048, 512)
    stream = _request_stream(xb_te, n_req)

    reg = TableRegistry()
    reg.register("bench", artifact)
    base_rps = _per_request_baseline(reg.engine("bench"), stream)
    deploy = reg.get("bench").engine.config.to_dict()

    rows = [{
        "name": "serve/per_request_baseline",
        "us_per_call": 1e6 / base_rps,
        "derived": f"requests_per_s={base_rps:.0f};coalesce=1",
        "config": {**deploy, "coalesce": 1, "n_requests": n_req},
    }]
    for depth in COALESCE_DEPTHS:
        rps, stats = _served(reg, stream, depth)
        rows.append({
            "name": f"serve/microbatch_c{depth}",
            "us_per_call": 1e6 / rps,
            "derived": (
                f"requests_per_s={rps:.0f};coalesce={depth};"
                f"speedup_vs_per_request={rps / base_rps:.1f}x;"
                f"p50_ms={stats.p50_ms:.2f};p99_ms={stats.p99_ms:.2f};"
                f"flushes={stats.n_flushes}"
            ),
            "config": {**deploy, "coalesce": depth, "n_requests": n_req},
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
