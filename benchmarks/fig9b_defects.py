"""Fig. 9(b): relative accuracy vs memristor/DAC defect rate.

Extended with the soft-vs-hard degradation study (DESIGN.md §15): both
engines see IDENTICAL defect draws per (rate, repeat), each is scored
against its OWN clean-table accuracy (the soft surface carries a small
constant smoothing offset that is not a defect effect), and the
``smoothness`` rows record each curve's worst consecutive relative-
accuracy drop (starting from the clean point 1.0).  The in-module
assertion — soft's worst drop never exceeds hard's — is the graceful-
degradation claim the bench gate keeps pinned.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import budget, trained_model
from repro.core.compile import compile_ensemble
from repro.core.defects import (
    inject_query_defects,
    inject_table_defects,
    relative_accuracy,
)
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.data.tabular import accuracy_metric

FRACS = [0.002, 0.01, 0.05, 0.1]
# boundary temperature of the soft study, in bin units: wide enough to
# absorb +-1-level (LSB sub-cell) bound flips, narrow enough to keep the
# clean-table accuracy at the hard engine's level
TAU = 0.5


def _smoothness(means: list[float]) -> float:
    """Worst consecutive drop of a relative-accuracy curve, measured
    from the clean point (rel acc 1.0 at defect rate 0)."""
    seq = [1.0] + list(means)
    return max(a - b for a, b in zip(seq, seq[1:]))


def run() -> list[dict]:
    rows = []
    repeats = budget(20, 6)
    for name in ["churn", "eye"]:
        ens, q, ds, xb_te = trained_model(name, "8bit", "gbdt")
        xb = xb_te[:512]
        y = ds.y_test[:512]
        table = compile_ensemble(ens)
        soft_cfg = DeployConfig(mode="soft", tau=TAU)
        ideal_h = accuracy_metric(
            ds.task, y, np.asarray(XTimeEngine(table).predict(xb))
        )
        ideal_s = accuracy_metric(
            ds.task, y,
            np.asarray(XTimeEngine(table, config=soft_cfg).predict(xb)),
        )
        hard_means: list[float] = []
        soft_means: list[float] = []
        for frac in FRACS:
            h_accs, s_accs = [], []
            for r in range(repeats):
                rng = np.random.default_rng(1000 * r + 7)
                # ONE defect draw per repeat, shared by both engines —
                # the comparison isolates the cell response, not the noise
                t2 = inject_table_defects(table, frac, rng)
                q2 = inject_query_defects(xb.astype(np.int32), frac, 256, rng)
                h_accs.append(accuracy_metric(
                    ds.task, y, np.asarray(XTimeEngine(t2).predict(q2))
                ))
                s_accs.append(accuracy_metric(
                    ds.task, y,
                    np.asarray(
                        XTimeEngine(t2, config=soft_cfg).predict(q2)
                    ),
                ))
            mean, std = relative_accuracy(ideal_h, h_accs)
            rows.append({
                "name": f"fig9b/{name}/defect_{frac}",
                "us_per_call": 0.0,
                "derived": f"rel_acc={mean:.4f};std={std:.4f};ideal={ideal_h:.4f}",
            })
            s_mean, s_std = relative_accuracy(ideal_s, s_accs)
            rows.append({
                "name": f"fig9b/{name}/soft_defect_{frac}",
                "us_per_call": 0.0,
                "derived": (
                    f"rel_acc={s_mean:.4f};std={s_std:.4f};"
                    f"ideal={ideal_s:.4f};tau={TAU}"
                ),
            })
            hard_means.append(mean)
            soft_means.append(s_mean)
        hs, ss = _smoothness(hard_means), _smoothness(soft_means)
        # Accuracy on len(y) rows is quantised in steps of 1/len(y); the
        # worst-consecutive-drop statistic picks the extreme segment of a
        # 4-point mean curve, so allow two sample flips' worth of relative
        # accuracy as the noise floor before declaring the claim broken.
        noise = 2.0 / len(y) / ideal_h
        assert ss <= hs + noise, (
            f"{name}: soft (tau={TAU}) degraded LESS smoothly than hard "
            f"direct (worst drop {ss:.4f} vs {hs:.4f} + noise floor "
            f"{noise:.4f}) — the graceful-degradation claim of "
            "DESIGN.md §15 no longer holds"
        )
        rows.append({
            "name": f"fig9b/{name}/smoothness",
            "us_per_call": 0.0,
            "derived": f"hard={hs:.4f};soft={ss:.4f};tau={TAU}",
        })
    return rows
