"""Fig. 9(b): relative accuracy vs memristor/DAC defect rate."""

from __future__ import annotations

import numpy as np

from benchmarks.common import FAST, budget, trained_model
from repro.core.compile import compile_ensemble
from repro.core.defects import (
    inject_query_defects,
    inject_table_defects,
    relative_accuracy,
)
from repro.core.engine import XTimeEngine
from repro.data.tabular import accuracy_metric

FRACS = [0.002, 0.01, 0.05, 0.1]


def run() -> list[dict]:
    rows = []
    repeats = budget(20, 6)
    for name in ["churn", "eye"]:
        ens, q, ds, xb_te = trained_model(name, "8bit", "gbdt")
        xb = xb_te[:512]
        y = ds.y_test[:512]
        table = compile_ensemble(ens)
        ideal = accuracy_metric(
            ds.task, y, np.asarray(XTimeEngine(table).predict(xb))
        )
        for frac in FRACS:
            accs = []
            for r in range(repeats):
                rng = np.random.default_rng(1000 * r + 7)
                t2 = inject_table_defects(table, frac, rng)
                q2 = inject_query_defects(xb.astype(np.int32), frac, 256, rng)
                pred = np.asarray(XTimeEngine(t2).predict(q2))
                accs.append(accuracy_metric(ds.task, y, pred))
            mean, std = relative_accuracy(ideal, accs)
            rows.append({
                "name": f"fig9b/{name}/defect_{frac}",
                "us_per_call": 0.0,
                "derived": f"rel_acc={mean:.4f};std={std:.4f};ideal={ideal:.4f}",
            })
    return rows
