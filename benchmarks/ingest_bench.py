"""Ingestion-frontend benchmark: dump -> CompiledModel -> served latency.

Measures the cold-start cost a model owner pays to bring an external
model onto the engine (parse + threshold-grid lowering + compile +
placement) and the steady-state serve latency of the ingested artifact —
the end of the §II-D deployment pipeline when the model was never
trained in-process.  The dump is a real XGBoost-JSON document generated
from a natively trained ensemble, so sizes are representative and the
margins are verified bit-equal before timing.
"""

from __future__ import annotations

import json
import tempfile
from pathlib import Path

import numpy as np

from benchmarks.common import budget, time_call, trained_model
from repro.api import build
from repro.ingest import to_xgboost_json


def run() -> list[dict]:
    ens, q, ds, xb_te = trained_model("churn", "8bit", "gbdt")
    doc = to_xgboost_json(ens, q)
    rows: list[dict] = []
    with tempfile.TemporaryDirectory() as td:
        dump = Path(td) / "model.json"
        dump.write_text(json.dumps(doc))
        dump_kb = dump.stat().st_size / 1024

        # cold start: parse + lower + compile + place, from the file
        us = time_call(lambda: build(str(dump)), warmup=1,
                       iters=budget(5, 3))
        artifact = build(str(dump))
        rows.append({
            "name": "ingest/xgb_json_to_artifact",
            "us_per_call": us,
            "derived": (
                f"dump_kb={dump_kb:.0f};rows={artifact.table.n_rows};"
                f"trees={artifact.table.n_trees};"
                f"exact={artifact.ingest['exact']}"
            ),
            "config": {"n_bins": artifact.table.n_bins,
                       "source": artifact.ingest["source"]},
        })

        # correctness before timing: ingested margins == native margins
        x_float = ds.x_test[: min(256, len(ds.x_test))]
        ref = ens.raw_margin(q.transform(x_float))
        eng = artifact.engine()
        xb = artifact.quantizer.transform(x_float)
        if not np.allclose(np.asarray(eng.raw_margin(xb)), ref,
                           rtol=1e-5, atol=1e-6):
            raise AssertionError("ingested margins diverge from native model")

        batch = xb[: budget(256, 128)]
        np.asarray(eng.predict(batch))  # compile
        us = time_call(lambda: np.asarray(eng.predict(batch)),
                       warmup=1, iters=budget(10, 5))
        rows.append({
            "name": "ingest/serve_predict_batch",
            "us_per_call": us,
            "derived": (
                f"batch={batch.shape[0]};"
                f"us_per_row={us / batch.shape[0]:.2f}"
            ),
            "config": {**artifact.deploy.to_dict(),
                       "batch": int(batch.shape[0])},
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
