"""Benchmark driver: one module per paper table/figure.

Prints the legacy ``name,us_per_call,derived`` CSV on stdout AND writes a
structured ``BENCH_<gitrev>.json`` file (see ``SCHEMA`` below) so every
run leaves a machine-readable perf record — CI uploads it as an artifact
and ``--check`` re-validates it (benchmarks/README.md documents the
schema).  ``BENCH_FAST=0`` switches to full budgets.

Usage:
    python benchmarks/run.py                      # every module
    python benchmarks/run.py --only serve_bench   # subset
    python benchmarks/run.py --out bench-out      # record directory
    python benchmarks/run.py --check bench-out/BENCH_abc1234.json
    python benchmarks/run.py --check bench-out    # glob BENCH_*.json in a dir
    python benchmarks/run.py --check bench-out \
        --baseline benchmarks/baselines/BENCH_baseline.json --tolerance 50

Regression gate: ``--baseline`` compares each record's ``us_per_call``
against the committed baseline (matched on ``module/name``); any entry
slower than ``baseline * (1 + tolerance/100)`` fails the run (exit 3)
with a per-entry diff.  A baseline record may pin its own
``tolerance_pct``, overriding the global ``--tolerance`` for that entry
(tight kernel microbenches vs noisy end-to-end rows).  The gate runs
after a live benchmark run or — the CI ``bench-smoke`` path — against
an existing record via ``--check``.

Exit status is nonzero when any module fails (failures are also recorded
in the JSON payload, so CI keeps the partial record as an artifact).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

SCHEMA_VERSION = 1
_FORMAT = "xtime-bench"

# every record must carry these; "config" may be None for analytic rows
RECORD_FIELDS = ("module", "name", "us_per_call", "derived", "config", "git_rev")

MODULE_NAMES = [
    "fig8_area_power",
    "tableI_precision",
    "fig11_scaling",
    "kernel_bench",
    "fig9_accuracy",
    "fig9b_defects",
    "fig10_latency_throughput",
    "serve_bench",
    "serve_async_bench",
    "ingest_bench",
    "compress_bench",
    "score_bench",
]


def validate_payload(payload: dict) -> None:
    """Raise ValueError unless ``payload`` is a well-formed bench record
    file — the same check CI runs on the uploaded artifact."""
    if payload.get("format") != _FORMAT:
        raise ValueError(f"format {payload.get('format')!r} != {_FORMAT!r}")
    if payload.get("schema_version") != SCHEMA_VERSION:
        raise ValueError(
            f"schema_version {payload.get('schema_version')!r} != {SCHEMA_VERSION}"
        )
    for key in ("git_rev", "fast", "records", "failures", "env"):
        if key not in payload:
            raise ValueError(f"missing top-level key {key!r}")
    if not isinstance(payload["records"], list):
        raise ValueError("records must be a list")
    for i, rec in enumerate(payload["records"]):
        missing = [k for k in RECORD_FIELDS if k not in rec]
        if missing:
            raise ValueError(f"record {i} missing fields {missing}: {rec}")
        if not isinstance(rec["name"], str) or not isinstance(rec["derived"], str):
            raise ValueError(f"record {i}: name/derived must be strings")
        if not isinstance(rec["us_per_call"], (int, float)):
            raise ValueError(f"record {i}: us_per_call must be a number")
        if rec["config"] is not None and not isinstance(rec["config"], dict):
            raise ValueError(f"record {i}: config must be a dict or null")
        tol = rec.get("tolerance_pct")  # baseline-only per-entry override
        if tol is not None and (
            not isinstance(tol, (int, float)) or tol <= 0
        ):
            raise ValueError(
                f"record {i}: tolerance_pct must be a positive number"
            )
    for i, f in enumerate(payload["failures"]):
        if "module" not in f or "error" not in f:
            raise ValueError(f"failure {i} missing module/error: {f}")


def check_file(path: str | Path) -> dict:
    payload = json.loads(Path(path).read_text())
    validate_payload(payload)
    return payload


def check_path(path: str | Path) -> list[tuple[Path, dict]]:
    """Validate one record file, or every ``BENCH_*.json`` in a directory."""
    p = Path(path)
    if p.is_dir():
        files = sorted(p.glob("BENCH_*.json"))
        if not files:
            raise FileNotFoundError(f"{p}: no BENCH_*.json records")
    else:
        files = [p]
    return [(f, check_file(f)) for f in files]


def compare_to_baseline(
    records: list[dict], baseline: dict, tolerance_pct: float
) -> tuple[list[dict], list[str]]:
    """Per-entry us_per_call comparison against a baseline payload.

    Entries are matched on ``(module, name)``.  A baseline record may
    carry its own ``tolerance_pct`` — a hand-annotated per-entry
    override of the global flag, so tight low-variance microbenches
    (kernel rows) gate harder than noisy end-to-end ones.  Returns
    ``(regressions, lines)`` where each regression dict carries the
    entry, both timings and the ratio, and ``lines`` is the human diff
    (regressions, wins, and coverage changes) ready to print.
    """
    base = {
        (r["module"], r["name"]): (
            float(r["us_per_call"]), r.get("tolerance_pct")
        )
        for r in baseline["records"]
    }
    cur = {(r["module"], r["name"]): float(r["us_per_call"]) for r in records}
    regressions: list[dict] = []
    lines: list[str] = []
    for key in sorted(base.keys() & cur.keys()):
        (b, tol), c = base[key], cur[key]
        tol = tolerance_pct if tol is None else float(tol)
        allowed = 1.0 + tol / 100.0
        # analytic rows record 0.0us: equal-zero is fine, becoming
        # nonzero is a regression by definition
        ratio = (c / b) if b > 0 else (float("inf") if c > 0 else 1.0)
        tag = "ok"
        if ratio > allowed:
            tag = "REGRESSION"
            regressions.append({
                "module": key[0], "name": key[1],
                "baseline_us": b, "current_us": c, "ratio": ratio,
                "tolerance_pct": tol,
            })
        elif ratio < 1 / allowed:
            tag = "faster"
        lines.append(
            f"  {tag:>10}  {key[0]}/{key[1]}: {c:.1f}us vs baseline "
            f"{b:.1f}us ({ratio:.2f}x, tol +{tol:.0f}%)"
        )
    for key in sorted(cur.keys() - base.keys()):
        lines.append(f"  {'new':>10}  {key[0]}/{key[1]}: {cur[key]:.1f}us "
                     "(no baseline entry)")
    missing = sorted(base.keys() - cur.keys())
    for key in missing:
        lines.append(f"  {'missing':>10}  {key[0]}/{key[1]}: in baseline "
                     "but not in this run")
    return regressions, lines


def run_gate(records: list[dict], baseline_path: str | Path,
             tolerance_pct: float) -> bool:
    """Print the baseline diff; True iff no regression beyond tolerance."""
    baseline = check_file(baseline_path)
    regressions, lines = compare_to_baseline(records, baseline, tolerance_pct)
    print(f"# baseline {baseline_path} (git {baseline['git_rev']}), "
          f"default tolerance {tolerance_pct:.0f}% "
          "(per-entry tolerance_pct overrides apply)", file=sys.stderr)
    for ln in lines:
        print(ln, file=sys.stderr)
    if regressions:
        print(f"# PERF REGRESSION: {len(regressions)} entries beyond "
              "their tolerance", file=sys.stderr)
        return False
    print("# baseline gate: OK", file=sys.stderr)
    return True


def _bench_env() -> dict:
    import jax

    return {
        "platform": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "jax": jax.__version__,
        "python": sys.version.split()[0],
    }


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--only", nargs="+", choices=MODULE_NAMES, metavar="MODULE",
        help="run only these modules (default: all)",
    )
    ap.add_argument(
        "--out", default="benchmarks/out", metavar="DIR",
        help="directory for the BENCH_<gitrev>.json record (default: %(default)s)",
    )
    ap.add_argument(
        "--check", metavar="PATH",
        help="validate an existing BENCH_*.json (or every record in a "
             "directory) and print a summary, then exit",
    )
    ap.add_argument(
        "--baseline", metavar="FILE",
        help="baseline BENCH_*.json to gate against (see benchmarks/README.md)",
    )
    ap.add_argument(
        "--tolerance", type=float, default=25.0, metavar="PCT",
        help="allowed per-entry us_per_call slowdown over the baseline "
             "in percent; baseline entries with their own tolerance_pct "
             "override it (default: %(default)s)",
    )
    args = ap.parse_args(argv)

    if args.check:
        checked = check_path(args.check)
        failures = 0
        for path, payload in checked:
            print(
                f"{path}: valid {_FORMAT} v{payload['schema_version']} — "
                f"{len(payload['records'])} records, "
                f"{len(payload['failures'])} failures, "
                f"git {payload['git_rev']}, fast={payload['fast']}"
            )
            failures += len(payload["failures"])
        if args.baseline:
            # gate each record file on its own — merging would let a
            # stale fast record shadow a regressed one on duplicate keys
            gate_ok = True
            for path, payload in checked:
                print(f"# gating {path}", file=sys.stderr)
                gate_ok &= run_gate(
                    payload["records"], args.baseline, args.tolerance
                )
            if not gate_ok:
                sys.exit(3)
        sys.exit(1 if failures else 0)

    import importlib

    from benchmarks.common import FAST, git_rev

    selected = args.only or MODULE_NAMES

    rev = git_rev()
    records: list[dict] = []
    failures: list[dict] = []
    elapsed: dict[str, float] = {}
    print("name,us_per_call,derived")
    for name in selected:
        t0 = time.time()
        # import inside the guard: an import-time failure must land in
        # failures[] like any other, so the record file is still written
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for row in mod.run():
                print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
                records.append({
                    "module": name,
                    "name": row["name"],
                    "us_per_call": float(row["us_per_call"]),
                    "derived": row["derived"],
                    "config": row.get("config"),
                    "git_rev": rev,
                })
        except Exception:  # noqa: BLE001
            print(f"{name},-1,ERROR", file=sys.stderr)
            traceback.print_exc()
            failures.append({
                "module": name,
                "error": traceback.format_exc(limit=20)[-2000:],
            })
        elapsed[name] = round(time.time() - t0, 1)
        print(f"# {name} done in {elapsed[name]}s", file=sys.stderr)

    payload = {
        "format": _FORMAT,
        "schema_version": SCHEMA_VERSION,
        "git_rev": rev,
        "fast": FAST,
        "modules": selected,
        "env": _bench_env(),
        "elapsed_s": elapsed,
        "records": records,
        "failures": failures,
    }
    validate_payload(payload)  # never write a record CI would reject
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"BENCH_{rev}.json"
    out_path.write_text(json.dumps(payload, indent=1))
    print(f"# wrote {out_path} ({len(records)} records)", file=sys.stderr)
    if failures:
        sys.exit(1)
    if args.baseline and not run_gate(records, args.baseline, args.tolerance):
        sys.exit(3)


if __name__ == "__main__":
    main()
