"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  BENCH_FAST=0 for full budgets.
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        fig8_area_power,
        fig9_accuracy,
        fig9b_defects,
        fig10_latency_throughput,
        fig11_scaling,
        kernel_bench,
        serve_bench,
        tableI_precision,
    )

    modules = [
        ("fig8_area_power", fig8_area_power),
        ("tableI_precision", tableI_precision),
        ("fig11_scaling", fig11_scaling),
        ("kernel_bench", kernel_bench),
        ("fig9_accuracy", fig9_accuracy),
        ("fig9b_defects", fig9b_defects),
        ("fig10_latency_throughput", fig10_latency_throughput),
        ("serve_bench", serve_bench),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            for row in mod.run():
                print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},-1,ERROR", file=sys.stderr)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
