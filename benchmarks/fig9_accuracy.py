"""Fig. 9(a): accuracy under hardware constraints.

Configurations per dataset: Unconstrained (4096-bin 'float'), X-TIME 8bit
(256 bins), X-TIME 4bit (16 bins, 2x leaves — iso-area), Only-RF.
Synthetic Table-II analogs (offline container), so the *deltas* are the
reproduction target, not absolute accuracies.
"""

from __future__ import annotations

from benchmarks.common import FAST, trained_model
from repro.data.tabular import accuracy_metric

DATASETS = ["churn", "eye", "gesture", "telco", "rossmann"] + (
    [] if FAST else ["forest", "gas"]
)


def run() -> list[dict]:
    rows = []
    for name in DATASETS:
        accs = {}
        for label, bits, kind in (
            ("unconstrained", "float", "gbdt"),
            ("xtime_8bit", "8bit", "gbdt"),
            ("xtime_4bit", "4bit", "gbdt"),
            ("only_rf", "8bit", "rf"),
        ):
            ens, q, ds, xb_te = trained_model(name, bits, kind)
            accs[label] = accuracy_metric(ds.task, ds.y_test, ens.predict(xb_te))
        rows.append({
            "name": f"fig9a/{name}",
            "us_per_call": 0.0,
            "derived": ";".join(f"{k}={v:.4f}" for k, v in accs.items())
            + f";delta_8bit={accs['xtime_8bit']-accs['unconstrained']:+.4f}",
        })
    return rows
