"""Offline-scoring benchmark: streamed chunked pipeline vs naive one-shot.

Scores a large pre-binned query block through ``repro.score.score_file``
two ways on identical inputs:

  * **oneshot** — the whole file as a single synchronous chunk
    (``chunk_rows = n_rows``, no double buffer): the naive baseline a
    user gets from ``engine().raw_margin(whole_file)``; its ``(B, R)``
    float32 match intermediate grows with the file (4 GB at the gate
    size) and spills through DRAM;
  * **chunked** — the production pipeline: bounded chunks, one compiled
    bucket, donated double-buffered dispatch; the intermediate stays
    chunk-sized (64 MB) and cache-resident.

Before any timing, the streamed outputs are verified BIT-EQUAL to the
one-shot result — a pipeline that went fast by answering differently
must fail, not record.

The ``speedup`` entry is the ACCEPTANCE GATE (DESIGN.md §14): chunked
must deliver >= ``MIN_SPEEDUP`` x the one-shot rows/s on the gate
config (asserted here), and its ``us_per_call`` carries the inverse
ratio ``1000 / speedup`` — lower is better, like a timing — so the
committed baseline's ``tolerance_pct`` turns a shrinking advantage into
a CI failure the same way a slow kernel is.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import budget
from repro.api import build
from repro.core.deploy import DeployConfig
from repro.core.trees import random_deep_ensemble
from repro.score import score_file

# the gate config is sized so the one-shot match intermediate (B x R
# float32) is ~4 GB — decisively past cache, the regime the chunked
# pipeline exists for; FULL adds a second shape (wider features,
# smaller table) for the trajectory
GATE = {"n_trees": 128, "depth": 6, "n_features": 16,
        "batch": 131072, "chunk": 2048}
FULL_EXTRA = [
    {"n_trees": 64, "depth": 6, "n_features": 32,
     "batch": 131072, "chunk": 2048},
]
MIN_SPEEDUP = 1.5
# single-core wall clocks drift ~30% run to run (page-cache and
# allocator state); the gate takes the best-of-N min per path and stops
# early once the floor is cleared with margin
GATE_MAX_PAIRS = 3
N_BINS = 256


def _bench_config(cfg: dict) -> list[dict]:
    ens = random_deep_ensemble(
        n_trees=cfg["n_trees"], depth=cfg["depth"],
        n_features=cfg["n_features"], n_bins=N_BINS, seed=20260808,
    )
    # f_blk pinned to the true width: the jnp path must not pad
    # F -> 128 (8x dead compute would swamp what's being measured)
    cm = build(ens, deploy=DeployConfig(backend="jnp",
                                        f_blk=cfg["n_features"]))
    rng = np.random.default_rng(0)
    q = rng.integers(0, N_BINS, size=(cfg["batch"], cfg["n_features"]))
    q = q.astype(np.int32)
    tag = f"b{cfg['batch']}_r{cm.table.n_rows}_f{cfg['n_features']}"

    def oneshot():
        return score_file(cm, q, kind="margin", chunk_rows=cfg["batch"],
                          double_buffer=False)

    def chunked():
        return score_file(cm, q, kind="margin", chunk_rows=cfg["chunk"])

    # first runs compile each bucket's jit entry AND pin bit-equality
    ref, stream = oneshot(), chunked()
    if not np.array_equal(stream.values, ref.values):
        raise AssertionError(f"streamed != one-shot at {tag}")
    # timed runs (engine bindings warm): min elapsed per path across up
    # to GATE_MAX_PAIRS interleaved pairs, stopping once the gate
    # clears the floor with 10% margin — the min is the stable estimate
    # under single-core wall-clock drift
    one, chk = oneshot(), chunked()
    one_s, chk_s = one.elapsed_s, chk.elapsed_s
    for _ in range(GATE_MAX_PAIRS - 1):
        if cfg != GATE or one_s / chk_s >= MIN_SPEEDUP * 1.1:
            break
        o2, c2 = oneshot(), chunked()
        one_s = min(one_s, o2.elapsed_s)
        chk_s = min(chk_s, c2.elapsed_s)
    one_rows = one.n_rows / one_s
    chk_rows = chk.n_rows / chk_s
    speedup = one_s / chk_s
    rows = [
        {
            "name": f"score/oneshot_{tag}",
            "us_per_call": one_s * 1e6,
            "derived": (
                f"rows_per_s={one_rows:,.0f};chunks={one.n_chunks};"
                f"kernel={one.engine['kernel']};bits_equal=True"
            ),
            "config": {**cfg, "kind": "margin", "double_buffer": False},
        },
        {
            "name": f"score/chunked_{tag}",
            "us_per_call": chk_s * 1e6,
            "derived": (
                f"rows_per_s={chk_rows:,.0f};chunks={chk.n_chunks};"
                f"bucket={chk.bucket};speedup_vs_oneshot={speedup:.2f}"
            ),
            "config": {**cfg, "kind": "margin", "double_buffer": True},
        },
    ]
    if cfg == GATE:
        if speedup < MIN_SPEEDUP:
            raise AssertionError(
                f"chunked pipeline speedup {speedup:.2f}x below the "
                f"{MIN_SPEEDUP}x acceptance floor at {tag} "
                f"(oneshot {one_rows:,.0f} rows/s, "
                f"chunked {chk_rows:,.0f} rows/s)"
            )
        rows.append({
            # gate row: us_per_call is 1000/speedup (lower = better),
            # so the baseline tolerance_pct gates advantage loss
            "name": f"speedup_{tag}",
            "us_per_call": 1000.0 / speedup,
            "derived": (
                f"gate=chunked_speedup;speedup={speedup:.2f};"
                f"floor={MIN_SPEEDUP};"
                f"chunked_rows_per_s={chk_rows:,.0f}"
            ),
            "config": {**cfg, "kind": "margin"},
        })
    return rows


def run() -> list[dict]:
    rows: list[dict] = []
    for cfg in ([GATE] if budget(0, 1) else [GATE] + FULL_EXTRA):
        rows.extend(_bench_config(cfg))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
