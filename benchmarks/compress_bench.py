"""Compression benchmark: RETENTION-style row savings at paper scale.

Times ``compress_table`` on deep duplicate-split synthetic ensembles
(the population the pass exists for — trained boosters rarely emit
contradictory duplicate splits, ``random_deep_ensemble`` always does)
and records the achieved row savings.  Before any timing, the compressed
table is verified BIT-EQUAL to the uncompressed int32 oracle — a bench
that went fast by answering differently must fail, not record.

The ``rows_after_t512_d8`` entry is a REGRESSION GATE, not a timing: its
``us_per_call`` field carries the compressed row count of the 512-tree
depth-8 model, with a tight baseline ``tolerance_pct``, so a change that
quietly stops merging/pruning rows fails CI the same way a slow kernel
does.  The acceptance floor (>= 30% rows saved at that size) is asserted
here as well — the committed baseline documents the actual number.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import budget, time_call
from repro.core.compile import compile_ensemble
from repro.core.compress import compress_table
from repro.core.deploy import DeployConfig
from repro.core.engine import XTimeEngine
from repro.core.perfmodel import kernel_traffic_model
from repro.core.trees import random_deep_ensemble

# (n_trees, depth); the 512 x depth-8 point is the acceptance target
SIZES_FAST = [(64, 8), (512, 8)]
SIZES_FULL = [(64, 8), (512, 8), (1024, 8)]
GATE_SIZE = (512, 8)
MIN_SAVINGS = 0.30
N_FEATURES = 32
N_BINS = 256


def _bits_equal(table, compressed, n_queries: int = 64) -> bool:
    rng = np.random.default_rng(0)
    q = rng.integers(0, N_BINS, size=(n_queries, N_FEATURES)).astype(np.int32)
    oracle = DeployConfig(table_dtype="int32")  # empty rows break packing
    ref = np.asarray(XTimeEngine.from_config(table, oracle).raw_margin(q))
    got = np.asarray(
        XTimeEngine.from_config(compressed, DeployConfig()).raw_margin(q)
    )
    return bool(np.array_equal(got, ref))


def run() -> list[dict]:
    rows: list[dict] = []
    for n_trees, depth in (SIZES_FAST if budget(0, 1) else SIZES_FULL):
        ens = random_deep_ensemble(
            n_trees=n_trees, depth=depth, n_features=N_FEATURES,
            n_bins=N_BINS, p_dup=0.5, seed=20260808,
        )
        table = compile_ensemble(ens)
        compressed, rep = compress_table(table, level="full")
        if not _bits_equal(table, compressed):
            raise AssertionError(
                f"compressed table diverges from oracle at t{n_trees}_d{depth}"
            )
        us = time_call(
            lambda t=table: compress_table(t, level="full"),
            warmup=0, iters=budget(3, 1),
        )
        traffic = kernel_traffic_model(
            batch=128, rows=compressed.n_rows, features=compressed.n_cols,
            channels=compressed.n_outputs, table_dtype="uint8",
            rows_saved=rep.rows_saved,
            cols_saved=rep.cols_before - rep.cols_after,
        )
        rows.append({
            "name": f"compress/t{n_trees}_d{depth}",
            "us_per_call": us,
            "derived": (
                f"rows={rep.rows_before}->{rep.rows_after};"
                f"savings={rep.row_savings_fraction:.3f};"
                f"cols={rep.cols_before}->{rep.cols_after};"
                f"merged={rep.merged_rows};bits_equal=True;"
                f"uncompressed_ratio={traffic['uncompressed_ratio']:.2f}"
            ),
            "config": {"n_trees": n_trees, "depth": depth,
                       "n_features": N_FEATURES, "level": "full"},
        })
        if (n_trees, depth) == GATE_SIZE:
            if rep.row_savings_fraction < MIN_SAVINGS:
                raise AssertionError(
                    f"row savings {rep.row_savings_fraction:.3f} below the "
                    f"{MIN_SAVINGS:.0%} acceptance floor at t{n_trees}_d{depth}"
                )
            rows.append({
                # gate row: us_per_call IS the compressed row count —
                # the baseline's tolerance_pct turns savings loss into
                # a CI failure (see module docstring)
                "name": f"rows_after_t{n_trees}_d{depth}",
                "us_per_call": float(rep.rows_after),
                "derived": (
                    f"gate=rows_after;savings={rep.row_savings_fraction:.3f};"
                    f"floor={MIN_SAVINGS}"
                ),
                "config": {"n_trees": n_trees, "depth": depth,
                           "level": "full"},
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
