"""Async cluster serving benchmark: throughput + tail-latency SLOs under
deterministic traffic replay (DESIGN.md §12).

Three rows off one 2-replica ``ClusterServer`` over two registered models
(same compiled artifact; the point is per-model queues, not the model):

  * ``burst_throughput`` — the seeded heavy-tailed trace replayed
    as-fast-as-possible (``speed=0``): aggregate requests/s when the
    dispatcher coalesces freely up to ``max_batch``.
  * ``paced_p99`` — the SLO row.  A paced replay (5ms mean, below
    the cluster's flush capacity, so the tail reflects coalescing +
    service time rather than saturation backlog) measures
    enqueue→result latency per request; ``us_per_call`` is the p99 in
    microseconds, gated in CI against the committed baseline with a
    per-entry ``tolerance_pct`` (tail latency on shared runners is
    noisy — the gate catches order-of-magnitude regressions like a lost
    flush deadline, not scheduler jitter).
  * ``failover_burst`` — the same burst with replica 0 killed at the
    half-way mark: throughput under failover (one survivor does all the
    work after reclaim) with every accepted request still completing.

Each timed pass runs after a warmup replay of the SAME trace, then
``reset_stats()`` — bucket compiles never pollute the gated numbers.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import budget, trained_model
from repro.api import build
from repro.serve import ClusterServer, make_trace, replay_trace

MODELS = ("hot", "cold")
N_REPLICAS = 2
FLUSH_ROWS = 128
MAX_BATCH = 128


def _timed_replay(srv: ClusterServer, trace, streams, *, speed, callbacks=None):
    """(wall_s, LatencyStats) of one replay+drain with clean accounting."""
    srv.reset_stats()
    t0 = time.perf_counter()
    res = replay_trace(
        srv.submit, trace, streams, speed=speed, callbacks=callbacks
    )
    srv.drain(timeout=600)
    wall = time.perf_counter() - t0
    assert res.shed == 0 and res.submitted == len(trace.requests)
    return wall, srv.stats()


def run() -> list[dict]:
    ens, q, ds, xb_te = trained_model("churn", "8bit", "gbdt")
    artifact = build(ens)
    stream = np.ascontiguousarray(xb_te.astype(np.int32)[:512])
    streams = {m: stream for m in MODELS}
    n_burst = budget(2400, 480)
    n_paced = budget(1200, 300)

    base_cfg = {
        "n_replicas": N_REPLICAS, "flush_rows": FLUSH_ROWS,
        "max_batch": MAX_BATCH, "models": len(MODELS), "kind": "predict",
    }
    rows = []
    # straggler exclusion is effectively off (threshold 50x): on a shared
    # CPU runner the only "stragglers" are jit-compile blips, and an
    # exclusion mid-bench would silently turn the 2-replica rows into
    # 1-replica rows.  The failover row kills a replica EXPLICITLY.
    with ClusterServer(
        n_replicas=N_REPLICAS, flush_rows=FLUSH_ROWS, max_batch=MAX_BATCH,
        heartbeat_timeout_s=10.0, straggler_threshold=50.0,
    ) as srv:
        for m in MODELS:
            srv.register(m, artifact)

        burst = make_trace(MODELS, n_burst, seed=42, mean_interval_s=3e-4)
        replay_trace(srv.submit, burst, streams, speed=0)  # warm buckets
        srv.drain(timeout=600)
        wall, s = _timed_replay(srv, burst, streams, speed=0)
        rps = n_burst / wall
        rows.append({
            "name": "serve_async/burst_throughput",
            "us_per_call": 1e6 / rps,
            "derived": (
                f"requests_per_s={rps:.0f};rows_per_s={s.n_rows / wall:.0f};"
                f"p50_ms={s.p50_ms:.2f};p99_ms={s.p99_ms:.2f};"
                f"flushes={s.n_flushes}"
            ),
            "config": {**base_cfg, "n_requests": n_burst, "seed": 42},
        })

        paced = make_trace(MODELS, n_paced, seed=43, mean_interval_s=5e-3)
        replay_trace(srv.submit, paced, streams, speed=1.0)  # warm paced buckets
        srv.drain(timeout=600)
        wall, s = _timed_replay(srv, paced, streams, speed=1.0)
        rows.append({
            "name": "serve_async/paced_p99",
            "us_per_call": s.p99_ms * 1e3,
            "derived": (
                f"p99_ms={s.p99_ms:.2f};p50_ms={s.p50_ms:.2f};"
                f"mean_ms={s.mean_ms:.2f};requests_per_s={s.requests_per_s:.0f};"
                f"wall_s={wall:.2f};flushes={s.n_flushes}"
            ),
            "config": {
                **base_cfg, "n_requests": n_paced, "seed": 43,
                "mean_interval_s": 5e-3,
            },
        })

        kill = make_trace(
            MODELS, n_burst, seed=44, mean_interval_s=3e-4,
            marks=[(0.5, "kill")],
        )
        wall, s = _timed_replay(
            srv, kill, streams, speed=0,
            callbacks={"kill": lambda: srv.kill_replica(0)},
        )
        rps = n_burst / wall
        rep = srv.report()
        assert rep["failovers"] >= 1 and s.n_requests == n_burst
        rows.append({
            "name": "serve_async/failover_burst",
            "us_per_call": 1e6 / rps,
            "derived": (
                f"requests_per_s={rps:.0f};failovers={rep['failovers']};"
                f"completed={s.n_requests};p99_ms={s.p99_ms:.2f};"
                f"survivor_flushes={rep['replicas'][1]['flushes']}"
            ),
            "config": {**base_cfg, "n_requests": n_burst, "seed": 44,
                       "kill_at": 0.5},
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
